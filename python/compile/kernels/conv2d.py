"""2-d convolution as im2col + the tiled Pallas GEMM.

The paper's TVM backend lowers conv2d through loop nests scheduled per
target; the TPU-idiomatic rethink is to turn the convolution into one big
MXU matmul: extract the (N*OH*OW, KH*KW*C) patch matrix with an XLA
gather-style op (cheap, fuses into the surrounding HLO) and feed it to the
VMEM-tiled GEMM kernel from :mod:`.matmul`.  The GEMM is where essentially
all FLOPs live, so the hot-spot stays inside the Pallas kernel.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul


def conv2d(x, w, *, stride: int = 1, padding: int = 0):
    """NCHW conv: x (N, C, H, W), w (O, C, KH, KW) -> (N, O, OH, OW)."""
    n, c, h, wd = x.shape
    o, c2, kh, kw = w.shape
    assert c == c2, f"conv2d channels: {c} vs {c2}"
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
    )  # (N, C*KH*KW, OH, OW)
    _, ck, oh, ow = patches.shape
    # (N*OH*OW, C*KH*KW) @ (C*KH*KW, O)
    lhs = patches.transpose(0, 2, 3, 1).reshape(n * oh * ow, ck)
    rhs = w.reshape(o, ck).T
    out = matmul(lhs, rhs)
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
