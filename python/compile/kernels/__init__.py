"""Layer-1 Pallas kernels for the Relay reproduction.

Every kernel here is the compute hot-spot of a Relay "primitive function"
(the output of operator fusion).  They are authored TPU-style — tiled for
VMEM via BlockSpec, MXU-shaped accumulation — but always executed with
``interpret=True`` so that the surrounding L2 JAX graph lowers to plain HLO
the CPU PJRT client can run (real-TPU lowering emits Mosaic custom-calls the
CPU plugin cannot execute; see DESIGN.md §Hardware-Adaptation).

Correctness oracle: :mod:`compile.kernels.ref` (pure jnp), enforced by
``python/tests/``.
"""

from .matmul import matmul, dense_bias_act
from .conv2d import conv2d
from .quant import quant_matmul

__all__ = ["matmul", "dense_bias_act", "conv2d", "quant_matmul"]
