"""Tiled matrix-multiply Pallas kernels (the GEMM hot-spot).

The schedule mirrors what the paper's TVM backend does with loop tiling /
cache blocking, re-thought for the TPU memory hierarchy:

* the (M, N, K) iteration space is gridded into (bm, bn, bk) blocks;
* each (i, j) output tile owns a VMEM scratch accumulator that lives across
  the K grid dimension (double-buffered HBM->VMEM streaming of the x / y
  tiles is implied by the BlockSpec pipeline);
* the inner ``jnp.dot`` maps onto the 128x128 MXU systolic array with an
  f32 accumulator (``preferred_element_type``).

Block defaults are MXU-aligned for the paper-scale layers; tests sweep
non-default shapes via the padding wrapper.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default VMEM tile. 3 live f32 tiles (x, y, acc) at 128x128 = 192 KiB of
# ~16 MiB VMEM, leaving room for the pipeline's double buffers.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(a, rows: int, cols: int):
    """Zero-pad a 2-d array up to (rows, cols)."""
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_padded(x, y, bm: int, bn: int, bk: int):
    m, k = x.shape
    _, n = y.shape
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, y)


@functools.partial(jax.custom_vjp, nondiff_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
           bk: int = DEFAULT_BK):
    """``x @ y`` for 2-d f32/bf16 operands via the tiled Pallas kernel.

    Operands are zero-padded up to block multiples (zero rows/cols do not
    change the product) and the result is sliced back.

    Differentiation: pallas_call's automatic JVP cannot handle the scratch
    accumulator, so the gradient is a registered rule (mirroring how Relay
    registers per-operator gradients, Sec. 4.2) whose backward GEMMs reuse
    this same kernel.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dims: {k} vs {k2}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    out = _matmul_padded(_pad2(x, mp, kp), _pad2(y, kp, np_), bm, bn, bk)
    return out[:m, :n]


def _matmul_fwd(x, y, bm, bn, bk):
    return matmul(x, y, bm=bm, bn=bn, bk=bk), (x, y)


def _matmul_bwd(bm, bn, bk, res, g):
    x, y = res
    return matmul(g, y.T, bm=bm, bn=bn, bk=bk), \
        matmul(x.T, g, bm=bm, bn=bn, bk=bk)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int, act: str):
    """Fused dense + bias + activation: the archetypal Relay fusion group.

    Epilogue (bias add + nonlinearity) runs on the final K step while the
    accumulator tile is still resident in VMEM — exactly the benefit the
    paper's operator fusion buys by not materializing the intermediate.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        r = acc_ref[...] + b_ref[...]
        if act == "relu":
            r = jnp.maximum(r, 0.0)
        elif act == "tanh":
            r = jnp.tanh(r)
        elif act == "sigmoid":
            r = jax.nn.sigmoid(r)
        o_ref[...] = r.astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnames=("act", "bm", "bn", "bk"))
def dense_bias_act(x, w, b, act: str = "relu", bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """Fused ``act(x @ w + b)``.  ``act`` in {"none", "relu", "tanh", "sigmoid"}."""
    assert act in ("none", "relu", "tanh", "sigmoid"), act
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    nk = kp // bk
    xpad = _pad2(x, mp, kp)
    wpad = _pad2(w, kp, np_)
    bpad = jnp.pad(b, (0, np_ - n)).reshape(1, np_)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, nk=nk, act=act),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xpad, wpad, bpad)
    return out[:m, :n]


def _dense_fwd(x, w, b, act, bm, bn, bk):
    out = dense_bias_act(x, w, b, act=act, bm=bm, bn=bn, bk=bk)
    return out, (x, w, out)


def _dense_bwd(act, bm, bn, bk, res, g):
    x, w, out = res
    # d(act)/dz expressed in terms of the saved activation output.
    if act == "relu":
        dz = g * (out > 0.0).astype(g.dtype)
    elif act == "tanh":
        dz = g * (1.0 - out * out)
    elif act == "sigmoid":
        dz = g * out * (1.0 - out)
    else:
        dz = g
    dx = matmul(dz, w.T, bm=bm, bn=bn, bk=bk)
    dw = matmul(x.T, dz, bm=bm, bn=bn, bk=bk)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense_bias_act.defvjp(_dense_fwd, _dense_bwd)
