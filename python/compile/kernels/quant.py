"""Quantized (int8 x int8 -> int32) tiled matmul Pallas kernel.

This is the realized form of the paper's generic quantization flow
(Sec. 4.5): after annotate/calibrate/realize, conv/dense operators become
narrow-integer GEMMs with a wide accumulator.  On TPU the MXU natively
multiplies 8-bit operands into a 32-bit accumulator; we express that with
``preferred_element_type=int32`` over int8 tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .matmul import _ceil_to, _pad2


def _qmm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int, acc_bits: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    prod = jnp.dot(
        x_ref[...].astype(jnp.int32),
        y_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    if acc_bits == 16:
        # Simulate a 16-bit accumulator (paper's "8/16" scheme): saturate
        # the running sum to the int16 range on every step.
        acc_ref[...] = jnp.clip(acc_ref[...] + prod, -(2**15), 2**15 - 1)
    else:
        acc_ref[...] += prod

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def quant_matmul(x, y, *, acc_bits: int = 32, bm: int = 128, bn: int = 128,
                 bk: int = 128):
    """int8 ``x @ y`` with int32 (or saturating int16-simulated) accumulate."""
    assert x.dtype == jnp.int8 and y.dtype == jnp.int8
    assert acc_bits in (16, 32), acc_bits
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk, acc_bits=acc_bits),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=True,
    )(_pad2(x, mp, kp), _pad2(y, kp, np_))
    return out[:m, :n]
