"""Pure-jnp oracles for every Pallas kernel (the build-time ground truth)."""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def dense_bias_act_ref(x, w, b, *, act: str = "relu"):
    r = x @ w + b
    if act == "relu":
        r = jnp.maximum(r, 0.0)
    elif act == "tanh":
        r = jnp.tanh(r)
    elif act == "sigmoid":
        r = jax.nn.sigmoid(r)
    return r


def conv2d_ref(x, w, *, stride: int = 1, padding: int = 0):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def quant_matmul_ref(x, y, *, acc_bits: int = 32):
    xi = x.astype(jnp.int32)
    yi = y.astype(jnp.int32)
    if acc_bits == 32:
        return xi @ yi
    # Saturating 16-bit accumulation over K blocks of 128 (matches the
    # kernel's per-K-step clipping with the default block size).
    m, k = x.shape
    n = y.shape[1]
    acc = jnp.zeros((m, n), jnp.int32)
    bk = 128
    for s in range(0, k, bk):
        acc = jnp.clip(acc + xi[:, s:s + bk] @ yi[s:s + bk, :],
                       -(2**15), 2**15 - 1)
    return acc
