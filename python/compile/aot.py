"""AOT pipeline: lower the L2 JAX models to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
results via ``HloModuleProto::from_text_file`` -> PJRT compile -> execute.

HLO text — NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Every artifact gets a manifest entry (shapes/dtypes of inputs and outputs)
so the Rust runtime and its tests can construct matching literals without
re-parsing HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x):
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


def _manifest_entry(args_flat, out_flat):
    def desc(s):
        return {"shape": list(s.shape), "dtype": str(s.dtype)}
    return {
        "inputs": [desc(_spec(a)) for a in args_flat],
        "outputs": [desc(_spec(o)) for o in out_flat],
    }


def artifacts():
    """name -> (fn, example_args).  All fns return tuples (return_tuple=True)."""
    key = jax.random.PRNGKey(0)
    B = 32

    mlp_params = model.mlp_init(key)
    x = jnp.zeros((B, model.MLP_IN), jnp.float32)
    labels = jnp.zeros((B,), jnp.int32)
    lr = jnp.float32(0.1)

    cnn_params = model.cnn_init(key)
    img = jnp.zeros((8, 3, model.CNN_IMG, model.CNN_IMG), jnp.float32)

    rnn_params = model.rnn_init(key)
    xs = jnp.zeros((16, 8, model.RNN_IN), jnp.float32)
    h0 = jnp.zeros((8, model.RNN_HIDDEN), jnp.float32)

    def mlp_forward(*args):
        return (model.mlp_forward(args[:-1], args[-1]),)

    def mlp_jnp(*args):
        return (model.mlp_forward_jnp(args[:-1], args[-1]),)

    def mlp_train_step(*args):
        params, (xb, yb, lrv) = args[:-3], args[-3:]
        return model.mlp_train_step(params, xb, yb, lrv)

    def cnn_forward(*args):
        return (model.cnn_forward(args[:-1], args[-1]),)

    def rnn_forward(*args):
        return (model.rnn_forward(args[:-2], args[-2], args[-1]),)

    return {
        "mlp_forward": (mlp_forward, (*mlp_params, x)),
        "mlp_jnp": (mlp_jnp, (*mlp_params, x)),
        "mlp_train_step": (mlp_train_step, (*mlp_params, x, labels, lr)),
        "cnn_forward": (cnn_forward, (*cnn_params, img)),
        "rnn_forward": (rnn_forward, (*rnn_params, xs, h0)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the primary artifact; siblings are "
                         "written next to it")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    manifest = {}
    for name, (fn, ex_args) in artifacts().items():
        lowered = jax.jit(fn).lower(*map(_spec, ex_args))
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out = jax.eval_shape(fn, *map(_spec, ex_args))
        manifest[name] = _manifest_entry(ex_args, out)
        print(f"wrote {path} ({len(text)} chars)")

    # The Makefile's primary target: point it at the MLP forward module.
    with open(os.path.join(outdir, "mlp_forward.hlo.txt")) as f:
        primary = f.read()
    with open(args.out, "w") as f:
        f.write(primary)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
