"""Layer-2 JAX model definitions (build-time only).

These are the JAX twins of the Relay model zoo in ``rust/src/zoo/``: the
same topologies, expressed as jit-able JAX functions whose dense/conv
hot-spots call the Layer-1 Pallas kernels.  ``aot.py`` lowers each entry
point to HLO text; the Rust runtime (L3) loads and executes the artifacts
via PJRT with Python long gone.

Model scale note: paper topologies at reduced width so that CI-scale
machines regenerate every figure in minutes (DESIGN.md §5 substitutions).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import conv2d, dense_bias_act, matmul

# ---------------------------------------------------------------------------
# MLP — the end-to-end training workload (EXPERIMENTS.md §E2E).
# ---------------------------------------------------------------------------

MLP_IN = 64
MLP_HIDDEN = (128, 64)
MLP_OUT = 10


def mlp_init(key):
    """He-initialised parameters as a flat tuple (w1, b1, w2, b2, w3, b3)."""
    dims = (MLP_IN,) + MLP_HIDDEN + (MLP_OUT,)
    params = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout), jnp.float32)
        w = w * jnp.sqrt(2.0 / din)
        params += [w, jnp.zeros((dout,), jnp.float32)]
    return tuple(params)


def mlp_forward(params, x):
    """3-layer MLP; every layer is the fused dense_bias_act Pallas kernel."""
    w1, b1, w2, b2, w3, b3 = params
    h = dense_bias_act(x, w1, b1, act="relu")
    h = dense_bias_act(h, w2, b2, act="relu")
    return dense_bias_act(h, w3, b3, act="none")


def mlp_forward_jnp(params, x):
    """Pure-jnp twin of mlp_forward (no Pallas): lowers to plain dot/add/max
    HLO that the Rust HLO *importer* can translate into Relay IR — the
    framework-import demo (paper §4.1)."""
    w1, b1, w2, b2, w3, b3 = params
    h = jnp.maximum(x @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    return h @ w3 + b3


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy with integer labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def mlp_loss(params, x, labels):
    return softmax_xent(mlp_forward(params, x), labels)


def mlp_train_step(params, x, labels, lr):
    """One SGD step; returns (loss, *new_params).

    L2's fwd/bwd: ``jax.value_and_grad`` differentiates through the Pallas
    kernels (interpret mode is transparent to AD), so the backward pass of
    the fused dense layers is part of the same lowered HLO module.
    """
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, labels)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (loss,) + new_params


# ---------------------------------------------------------------------------
# CNN — vision-model stand-in used by runtime integration tests.
# ---------------------------------------------------------------------------

CNN_IMG = 16      # input is (N, 3, 16, 16)
CNN_C1, CNN_C2 = 8, 16


def cnn_init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w1 = jax.random.normal(k1, (CNN_C1, 3, 3, 3), jnp.float32) * 0.2
    w2 = jax.random.normal(k2, (CNN_C2, CNN_C1, 3, 3), jnp.float32) * 0.1
    flat = CNN_C2 * (CNN_IMG // 4) * (CNN_IMG // 4)
    w3 = jax.random.normal(k3, (flat, MLP_OUT), jnp.float32) * 0.05
    b3 = jnp.zeros((MLP_OUT,), jnp.float32)
    del k4
    return (w1, w2, w3, b3)


def _maxpool2(x):
    n, c, h, w = x.shape
    return x.reshape(n, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def cnn_forward(params, x):
    """conv-relu-pool ×2 then dense; convs run the Pallas im2col GEMM."""
    w1, w2, w3, b3 = params
    h = jnp.maximum(conv2d(x, w1, stride=1, padding=1), 0.0)
    h = _maxpool2(h)
    h = jnp.maximum(conv2d(h, w2, stride=1, padding=1), 0.0)
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    return dense_bias_act(h, w3, b3, act="none")


# ---------------------------------------------------------------------------
# RNN — NLP stand-in: a tanh RNN rolled with lax.scan.
# ---------------------------------------------------------------------------

RNN_IN = 32
RNN_HIDDEN = 64


def rnn_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    wx = jax.random.normal(k1, (RNN_IN, RNN_HIDDEN), jnp.float32) * 0.1
    wh = jax.random.normal(k2, (RNN_HIDDEN, RNN_HIDDEN), jnp.float32) * 0.1
    b = jnp.zeros((RNN_HIDDEN,), jnp.float32)
    del k3
    return (wx, wh, b)


def rnn_forward(params, xs, h0):
    """xs: (T, B, RNN_IN), h0: (B, RNN_HIDDEN) -> final hidden state.

    The recurrent matmuls go through the Pallas GEMM; scan keeps the HLO
    module size independent of sequence length (cf. paper §3.2.3: loops as
    first-class constructs rather than unrolled graphs).
    """
    wx, wh, b = params

    def step(h, x):
        h = jnp.tanh(matmul(x, wx) + matmul(h, wh) + b)
        return h, ()

    hT, _ = jax.lax.scan(step, h0, xs)
    return hT
