"""L2 model shape/semantics tests + AOT lowering smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import artifacts, to_hlo_text

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


def test_mlp_forward_shape():
    params = model.mlp_init(KEY)
    x = jax.random.normal(KEY, (4, model.MLP_IN))
    out = model.mlp_forward(params, x)
    assert out.shape == (4, model.MLP_OUT)
    assert jnp.all(jnp.isfinite(out))


def test_mlp_matches_pure_jnp():
    params = model.mlp_init(KEY)
    x = jax.random.normal(KEY, (8, model.MLP_IN))
    w1, b1, w2, b2, w3, b3 = params
    h = jnp.maximum(x @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    expect = h @ w3 + b3
    np.testing.assert_allclose(model.mlp_forward(params, x), expect,
                               rtol=1e-4, atol=1e-4)


def test_mlp_train_step_reduces_loss():
    params = model.mlp_init(KEY)
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (32, model.MLP_IN))
    labels = jax.random.randint(k2, (32,), 0, model.MLP_OUT)
    loss0 = model.mlp_loss(params, x, labels)
    for _ in range(5):
        out = model.mlp_train_step(params, x, labels, jnp.float32(0.5))
        params = out[1:]
    loss5 = model.mlp_loss(params, x, labels)
    assert loss5 < loss0


def test_cnn_forward_shape():
    params = model.cnn_init(KEY)
    img = jax.random.normal(KEY, (2, 3, model.CNN_IMG, model.CNN_IMG))
    out = model.cnn_forward(params, img)
    assert out.shape == (2, model.MLP_OUT)
    assert jnp.all(jnp.isfinite(out))


def test_rnn_forward_matches_pure_jnp():
    params = model.rnn_init(KEY)
    xs = jax.random.normal(KEY, (5, 3, model.RNN_IN))
    h0 = jnp.zeros((3, model.RNN_HIDDEN))
    out = model.rnn_forward(params, xs, h0)
    wx, wh, b = params
    h = h0
    for t in range(5):
        h = jnp.tanh(xs[t] @ wx + h @ wh + b)
    np.testing.assert_allclose(out, h, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["mlp_forward", "mlp_train_step",
                                  "cnn_forward", "rnn_forward"])
def test_artifact_lowers_to_hlo_text(name):
    fn, ex_args = artifacts()[name]
    specs = [jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a))
             for a in ex_args]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    # No TPU custom-calls may survive: the CPU PJRT client must run this.
    assert "tpu_custom_call" not in text
