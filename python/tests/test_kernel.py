"""Kernel-vs-ref correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes of every Pallas kernel and asserts
allclose against the pure-jnp oracle in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, dense_bias_act, matmul, quant_matmul
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=70)
small_dims = st.integers(min_value=1, max_value=20)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 64),
                                   (1, 1, 1), (8, 1024, 8), (37, 53, 29)])
def test_matmul_shapes(m, k, n):
    x = _rand(0, (m, k))
    y = _rand(1, (k, n))
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y),
                               rtol=1e-4, atol=1e-4)


def test_matmul_nondefault_blocks():
    x = _rand(2, (96, 160))
    y = _rand(3, (160, 48))
    out = matmul(x, y, bm=32, bn=16, bk=64)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_inside_jit():
    x = _rand(4, (64, 64))
    y = _rand(5, (64, 64))
    out = jax.jit(matmul)(x, y)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_grad_flows():
    # interpret-mode pallas is differentiable: the L2 training step relies
    # on this.
    x = _rand(6, (16, 24))
    y = _rand(7, (24, 8))
    g = jax.grad(lambda a: jnp.sum(matmul(a, y) ** 2))(x)
    g_ref = jax.grad(lambda a: jnp.sum((a @ y) ** 2))(x)
    np.testing.assert_allclose(g, g_ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------- dense fused

@settings(max_examples=20, deadline=None)
@given(m=dims, k=dims, n=dims,
       act=st.sampled_from(["none", "relu", "tanh", "sigmoid"]),
       seed=st.integers(0, 2**16))
def test_dense_bias_act_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    out = dense_bias_act(x, w, b, act=act)
    np.testing.assert_allclose(out, ref.dense_bias_act_ref(x, w, b, act=act),
                               rtol=1e-4, atol=1e-4)


def test_dense_bias_act_relu_clamps():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    assert jnp.all(dense_bias_act(x, w, b, act="relu") == 0.0)


# -------------------------------------------------------------- conv2d

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 3), c=st.integers(1, 4), o=st.integers(1, 4),
       hw=st.integers(4, 12), kh=st.integers(1, 3),
       stride=st.integers(1, 2), padding=st.integers(0, 1),
       seed=st.integers(0, 2**16))
def test_conv2d_matches_ref(n, c, o, hw, kh, stride, padding, seed):
    x = _rand(seed, (n, c, hw, hw))
    w = _rand(seed + 1, (o, c, kh, kh))
    out = conv2d(x, w, stride=stride, padding=padding)
    expect = ref.conv2d_ref(x, w, stride=stride, padding=padding)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("stride,padding", [(1, 0), (1, 1), (2, 1)])
def test_conv2d_resnet_shapes(stride, padding):
    x = _rand(0, (2, 8, 16, 16))
    w = _rand(1, (16, 8, 3, 3))
    out = conv2d(x, w, stride=stride, padding=padding)
    expect = ref.conv2d_ref(x, w, stride=stride, padding=padding)
    assert out.shape == expect.shape
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


# -------------------------------------------------------- quant matmul

@settings(max_examples=15, deadline=None)
@given(m=small_dims, k=small_dims, n=small_dims, seed=st.integers(0, 2**16))
def test_quant_matmul_i32(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.randint(k1, (m, k), -128, 128, jnp.int32).astype(jnp.int8)
    y = jax.random.randint(k2, (k, n), -128, 128, jnp.int32).astype(jnp.int8)
    np.testing.assert_array_equal(quant_matmul(x, y, acc_bits=32),
                                  ref.quant_matmul_ref(x, y, acc_bits=32))


def test_quant_matmul_i16_saturates():
    # Large positive products must clip to int16 range, not wrap.
    x = jnp.full((4, 512), 127, jnp.int8)
    y = jnp.full((512, 4), 127, jnp.int8)
    out = quant_matmul(x, y, acc_bits=16)
    assert jnp.all(out == 2**15 - 1)
    np.testing.assert_array_equal(out, ref.quant_matmul_ref(x, y, acc_bits=16))


def test_quant_matmul_i16_matches_ref_random():
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    x = jax.random.randint(k1, (16, 256), -128, 128, jnp.int32).astype(jnp.int8)
    y = jax.random.randint(k2, (256, 16), -128, 128, jnp.int32).astype(jnp.int8)
    np.testing.assert_array_equal(quant_matmul(x, y, acc_bits=16),
                                  ref.quant_matmul_ref(x, y, acc_bits=16))
