//! The evaluation model zoo (§5): the paper's vision and NLP workloads at
//! reduced width so every figure regenerates on a laptop-class CPU in
//! minutes (DESIGN.md §5 substitution). Topologies follow the originals:
//! DQN's three convs + two dense; MobileNet's depthwise-separable blocks;
//! ResNet-18's residual stages; VGG's conv-conv-pool stacks; RNN/GRU/LSTM
//! cells rolled with Relay's recursive-function loop encoding; CharRNN
//! generation; TreeLSTM recursion over the `Tree` ADT.
//!
//! Weights are seeded constants so runs are reproducible (the paper also
//! evaluates inference with random inputs, §5.1).

pub mod nlp;
pub mod vision;

pub use nlp::*;
pub use vision::*;

use crate::ir::{self, Dim, Module, Type, E};
use crate::tensor::{Rng, Tensor};

/// Rewrite the leading (batch) dimension of every tensor-typed `@main`
/// parameter annotation — including tensors nested inside ADT and tuple
/// annotations, e.g. the RNNs' `List[Tensor[(1, 16)]]` step inputs.
/// Weights are embedded constants, so this one edit re-types the whole
/// program: `Dim::Any` makes it batch-polymorphic (one compiled artifact
/// for every batch size, §3.3.1), a concrete `Dim::Known(n)`
/// re-monomorphizes it at batch `n`.
pub fn with_batch_dim(m: &Module, batch: Dim) -> Module {
    let mut out = m.clone();
    if let Some(f) = m.def("main") {
        let mut nf = f.clone();
        for (_, ann) in nf.params.iter_mut() {
            if let Some(t) = ann {
                *t = rebatch_type(t, batch);
            }
        }
        out.add_def("main", nf);
    }
    out
}

fn rebatch_type(t: &Type, batch: Dim) -> Type {
    match t {
        Type::Tensor { shape, dtype } if !shape.is_empty() => {
            let mut shape = shape.clone();
            shape[0] = batch;
            Type::Tensor { shape, dtype: *dtype }
        }
        Type::Adt { name, args } => Type::Adt {
            name: name.clone(),
            args: args.iter().map(|a| rebatch_type(a, batch)).collect(),
        },
        Type::Tuple(ts) => {
            Type::Tuple(ts.iter().map(|x| rebatch_type(x, batch)).collect())
        }
        _ => t.clone(),
    }
}

/// Weight factory with a deterministic seed per model.
pub struct Weights {
    rng: Rng,
}

impl Weights {
    pub fn new(seed: u64) -> Weights {
        Weights { rng: Rng::new(seed) }
    }

    pub fn tensor(&mut self, shape: &[usize], scale: f32) -> Tensor {
        self.rng.normal_tensor(shape, scale)
    }

    /// He-style scale for a conv/dense weight.
    pub fn he(&mut self, shape: &[usize]) -> E {
        let fan_in: usize = shape[1..].iter().product::<usize>().max(1);
        let scale = (2.0 / fan_in as f32).sqrt();
        ir::constant(self.rng.normal_tensor(shape, scale))
    }

    pub fn zeros(&mut self, shape: &[usize]) -> E {
        ir::constant(Tensor::zeros(shape, crate::tensor::DType::F32))
    }
}

/// Every benchmarked model, by paper name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Model {
    NatureDqn,
    MobileNet,
    ResNet18,
    Vgg16,
    Rnn,
    Gru,
    Lstm,
    CharRnn,
    TreeLstm,
}

impl Model {
    pub fn vision() -> [Model; 4] {
        [Model::NatureDqn, Model::MobileNet, Model::ResNet18, Model::Vgg16]
    }

    pub fn nlp() -> [Model; 5] {
        [Model::Rnn, Model::Gru, Model::Lstm, Model::CharRnn, Model::TreeLstm]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Model::NatureDqn => "nature-dqn",
            Model::MobileNet => "mobilenet",
            Model::ResNet18 => "resnet-18",
            Model::Vgg16 => "vgg-16",
            Model::Rnn => "rnn",
            Model::Gru => "gru",
            Model::Lstm => "lstm",
            Model::CharRnn => "char-rnn",
            Model::TreeLstm => "treelstm",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_main, Value};
    use crate::ty::check_module;

    #[test]
    fn all_vision_models_typecheck_and_run() {
        for model in Model::vision() {
            let (m, input) = vision::build(model, 42);
            check_module(&m).unwrap_or_else(|e| panic!("{}: {e}", model.name()));
            let out = eval_main(&m, vec![Value::Tensor(input)]).unwrap();
            let t = out.tensor();
            assert_eq!(t.shape()[0], 1, "{}", model.name());
            assert!(t.as_f32().iter().all(|v| v.is_finite()), "{}", model.name());
        }
    }

    #[test]
    fn vision_models_have_distinct_depths() {
        let n_ops = |model| {
            let (m, _) = vision::build(model, 0);
            let mut v = Vec::new();
            crate::ir::collect(
                &m.def("main").unwrap().body,
                &|e| matches!(&**e, crate::ir::Expr::Call { f, .. } if matches!(&**f, crate::ir::Expr::Op(_))),
                &mut v,
            );
            v.len()
        };
        assert!(n_ops(Model::Vgg16) > n_ops(Model::NatureDqn));
        assert!(n_ops(Model::ResNet18) > n_ops(Model::NatureDqn));
    }
}
