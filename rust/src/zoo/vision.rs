//! Vision models (Fig. 10/11/13/14 workloads), batch 1, NCHW.

use super::{Model, Weights};
use crate::ir::{self, AttrValue, Module, Type, Var, E};
use crate::tensor::{DType, Tensor};

fn conv(
    w: &mut Weights,
    x: E,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
) -> E {
    let weight = w.he(&[cout, cin / groups, k, k]);
    let mut attrs = ir::attrs(&[
        ("strides", AttrValue::IntVec(vec![stride as i64, stride as i64])),
        ("padding", AttrValue::Int(pad as i64)),
    ]);
    if groups != 1 {
        attrs.insert("groups".into(), AttrValue::Int(groups as i64));
    }
    ir::op_call_attrs("nn.conv2d", vec![x, weight], attrs)
}

fn conv_bn_relu(
    w: &mut Weights,
    x: E,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
) -> E {
    let c = conv(w, x, cin, cout, k, stride, pad, groups);
    // Inference-mode BN folds to a channel scale+shift: emit it as a
    // multiply by a constant scale (exercising FoldScaleAxis at -O3) plus
    // a bias add.
    let scale = ir::constant(w.tensor(&[cout, 1, 1], 0.05).clone());
    let scaled = ir::op_call("multiply", vec![c, map_abs(scale)]);
    let bias = ir::constant(Tensor::zeros(&[cout], DType::F32));
    let biased = ir::op_call_attrs(
        "nn.bias_add",
        vec![scaled, bias],
        ir::attrs(&[("axis", AttrValue::Int(1))]),
    );
    ir::op_call("nn.relu", vec![biased])
}

/// abs() at build time so scales stay positive (BN gammas).
fn map_abs(e: E) -> E {
    match &*e {
        ir::Expr::Const(t) => {
            let v: Vec<f32> = t.as_f32().iter().map(|x| x.abs() + 0.5).collect();
            ir::constant(Tensor::from_f32(t.shape().to_vec(), v))
        }
        _ => e,
    }
}

fn maxpool(x: E, k: usize) -> E {
    ir::op_call_attrs(
        "nn.max_pool2d",
        vec![x],
        ir::attrs(&[("pool_size", AttrValue::Int(k as i64))]),
    )
}

fn dense_bias_relu(w: &mut Weights, x: E, cin: usize, cout: usize, relu: bool) -> E {
    let weight = w.he(&[cout, cin]);
    let bias = w.zeros(&[cout]);
    let d = ir::op_call("nn.dense", vec![x, weight]);
    let b = ir::op_call_attrs(
        "nn.bias_add",
        vec![d, bias],
        ir::attrs(&[("axis", AttrValue::Int(1))]),
    );
    if relu {
        ir::op_call("nn.relu", vec![b])
    } else {
        b
    }
}

/// Build `(module, example_input)` for a vision model.
pub fn build(model: Model, seed: u64) -> (Module, Tensor) {
    let mut w = Weights::new(seed);
    let mut rng = crate::tensor::Rng::new(seed ^ 0xDEAD);
    match model {
        Model::NatureDqn => {
            // Paper topology: conv8x8/4, conv4x4/2, conv3x3/1, fc512, fc_out.
            // Reduced: 16x16 input, channels 8/16/16, fc 64.
            let input_shape = vec![1usize, 4, 16, 16];
            let x = Var::fresh("x");
            let mut h: E = ir::var(&x);
            h = ir::op_call("nn.relu", vec![conv(&mut w, h, 4, 8, 4, 2, 1, 1)]);
            h = ir::op_call("nn.relu", vec![conv(&mut w, h, 8, 16, 3, 2, 1, 1)]);
            h = ir::op_call("nn.relu", vec![conv(&mut w, h, 16, 16, 3, 1, 1, 1)]);
            h = ir::op_call("nn.batch_flatten", vec![h]);
            h = dense_bias_relu(&mut w, h, 16 * 4 * 4, 64, true);
            h = dense_bias_relu(&mut w, h, 64, 6, false);
            (finish(x, input_shape.clone(), h), rng.normal_tensor(&input_shape, 1.0))
        }
        Model::MobileNet => {
            // Depthwise-separable blocks.
            let input_shape = vec![1usize, 3, 32, 32];
            let x = Var::fresh("x");
            let mut h: E = ir::var(&x);
            h = conv_bn_relu(&mut w, h, 3, 8, 3, 2, 1, 1); // 16x16
            let mut c = 8;
            for (cout, stride) in [(16, 1), (32, 2), (32, 1)] {
                // depthwise
                h = conv_bn_relu(&mut w, h, c, c, 3, stride, 1, c);
                // pointwise
                h = conv_bn_relu(&mut w, h, c, cout, 1, 1, 0, 1);
                c = cout;
            }
            h = ir::op_call("nn.global_avg_pool2d", vec![h]);
            h = ir::op_call("nn.batch_flatten", vec![h]);
            h = dense_bias_relu(&mut w, h, c, 10, false);
            (finish(x, input_shape.clone(), h), rng.normal_tensor(&input_shape, 1.0))
        }
        Model::ResNet18 => {
            // Stem + 4 stages x 2 basic blocks (reduced widths).
            let input_shape = vec![1usize, 3, 32, 32];
            let x = Var::fresh("x");
            let mut h: E = ir::var(&x);
            h = conv_bn_relu(&mut w, h, 3, 8, 3, 1, 1, 1);
            let widths = [8usize, 16, 24, 32];
            let mut c = 8;
            for (stage, &cout) in widths.iter().enumerate() {
                let stride = if stage == 0 { 1 } else { 2 };
                // block 1 (may downsample)
                let shortcut = if stride != 1 || c != cout {
                    conv(&mut w, h.clone(), c, cout, 1, stride, 0, 1)
                } else {
                    h.clone()
                };
                let mut b = conv_bn_relu(&mut w, h, c, cout, 3, stride, 1, 1);
                b = conv(&mut w, b, cout, cout, 3, 1, 1, 1);
                h = ir::op_call("nn.relu", vec![ir::op_call("add", vec![b, shortcut])]);
                // block 2 (identity)
                let shortcut = h.clone();
                let mut b = conv_bn_relu(&mut w, h, cout, cout, 3, 1, 1, 1);
                b = conv(&mut w, b, cout, cout, 3, 1, 1, 1);
                h = ir::op_call("nn.relu", vec![ir::op_call("add", vec![b, shortcut])]);
                c = cout;
            }
            h = ir::op_call("nn.global_avg_pool2d", vec![h]);
            h = ir::op_call("nn.batch_flatten", vec![h]);
            h = dense_bias_relu(&mut w, h, c, 10, false);
            (finish(x, input_shape.clone(), h), rng.normal_tensor(&input_shape, 1.0))
        }
        Model::Vgg16 => {
            // conv-conv-pool stacks + two dense layers (reduced).
            let input_shape = vec![1usize, 3, 32, 32];
            let x = Var::fresh("x");
            let mut h: E = ir::var(&x);
            let mut c = 3;
            for cout in [8usize, 16, 32] {
                h = ir::op_call("nn.relu", vec![conv(&mut w, h, c, cout, 3, 1, 1, 1)]);
                h = ir::op_call("nn.relu", vec![conv(&mut w, h, cout, cout, 3, 1, 1, 1)]);
                h = maxpool(h, 2);
                c = cout;
            }
            h = ir::op_call("nn.batch_flatten", vec![h]);
            h = dense_bias_relu(&mut w, h, c * 4 * 4, 64, true);
            h = dense_bias_relu(&mut w, h, 64, 10, false);
            (finish(x, input_shape.clone(), h), rng.normal_tensor(&input_shape, 1.0))
        }
        other => panic!("{} is not a vision model", other.name()),
    }
}

/// DCGAN-style generator (Fig. 14 workload): dense projection + stacked
/// transposed convolutions. VTA cannot offload transposed convs, so this
/// model gains the least from the accelerator — the paper's spread.
pub fn build_dcgan(seed: u64) -> (Module, Tensor) {
    let mut w = Weights::new(seed);
    let mut rng = crate::tensor::Rng::new(seed ^ 0xDC6A);
    let z_shape = vec![1usize, 16];
    let x = Var::fresh("z");
    let mut h: E = ir::var(&x);
    h = dense_bias_relu(&mut w, h, 16, 32 * 4 * 4, true);
    h = ir::op_call_attrs(
        "reshape",
        vec![h],
        ir::attrs(&[("newshape", AttrValue::IntVec(vec![1, 32, 4, 4]))]),
    );
    let mut c = 32;
    for cout in [16usize, 8, 3] {
        let weight = w.he(&[c, cout, 4, 4]);
        h = ir::op_call_attrs(
            "nn.conv2d_transpose",
            vec![h, weight],
            ir::attrs(&[
                ("strides", AttrValue::IntVec(vec![2, 2])),
                ("padding", AttrValue::Int(1)),
            ]),
        );
        if cout != 3 {
            h = ir::op_call("nn.relu", vec![h]);
        } else {
            h = ir::op_call("tanh", vec![h]);
        }
        c = cout;
    }
    (finish(x, z_shape.clone(), h), rng.normal_tensor(&z_shape, 1.0))
}

/// Deeper ResNet variant for Fig. 14 (three blocks per stage ~ ResNet-34's
/// extra depth, reduced widths).
pub fn build_resnet34ish(seed: u64) -> (Module, Tensor) {
    let mut w = Weights::new(seed);
    let mut rng = crate::tensor::Rng::new(seed ^ 0x34);
    let input_shape = vec![1usize, 3, 32, 32];
    let x = Var::fresh("x");
    let mut h: E = ir::var(&x);
    h = conv_bn_relu(&mut w, h, 3, 8, 3, 1, 1, 1);
    let widths = [8usize, 16, 24, 32];
    let mut c = 8;
    for (stage, &cout) in widths.iter().enumerate() {
        let stride = if stage == 0 { 1 } else { 2 };
        for block in 0..3 {
            let s = if block == 0 { stride } else { 1 };
            let shortcut = if s != 1 || c != cout {
                conv(&mut w, h.clone(), c, cout, 1, s, 0, 1)
            } else {
                h.clone()
            };
            let mut b = conv_bn_relu(&mut w, h, c, cout, 3, s, 1, 1);
            b = conv(&mut w, b, cout, cout, 3, 1, 1, 1);
            h = ir::op_call("nn.relu", vec![ir::op_call("add", vec![b, shortcut])]);
            c = cout;
        }
    }
    h = ir::op_call("nn.global_avg_pool2d", vec![h]);
    h = ir::op_call("nn.batch_flatten", vec![h]);
    h = dense_bias_relu(&mut w, h, c, 10, false);
    (finish(x, input_shape.clone(), h), rng.normal_tensor(&input_shape, 1.0))
}

fn finish(x: Var, input_shape: Vec<usize>, body: E) -> Module {
    let mut m = Module::with_prelude();
    m.add_def(
        "main",
        ir::Function::new(
            vec![(x, Some(Type::tensor(input_shape, DType::F32)))],
            body,
        ),
    );
    m
}
