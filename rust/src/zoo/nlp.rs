//! NLP models (Fig. 12): recurrent cells rolled with Relay's
//! tail-recursive loop encoding over `List` ADTs — the exact expressivity
//! the paper's §3.2.3-3.2.5 features exist to provide. CharRNN generates
//! characters autoregressively; TreeLSTM recurses over the `Tree` ADT.

use super::{Model, Weights};
use crate::eval::value::Value;
use crate::ir::{self, AttrValue, Module, Pattern, Type, Var, E};
use crate::tensor::{DType, Rng, Tensor};

pub const HIDDEN: usize = 32;
pub const EMBED: usize = 16;
pub const VOCAB: usize = 26;
pub const SEQ_LEN: usize = 8;

fn dense(w: &mut Weights, x: E, cin: usize, cout: usize) -> E {
    let weight = w.he(&[cout, cin]);
    ir::op_call("nn.dense", vec![x, weight])
}

/// One step of the chosen cell: (x_t, h) -> h'.
fn cell(model: Model, w: &mut Weights, x: E, h: E, input: usize) -> E {
    match model {
        Model::Rnn | Model::CharRnn => {
            // h' = tanh(Wx x + Wh h)
            let a = dense(w, x, input, HIDDEN);
            let b = dense(w, h, HIDDEN, HIDDEN);
            ir::op_call("tanh", vec![ir::op_call("add", vec![a, b])])
        }
        Model::Gru => {
            // z = sig(Wz x + Uz h); r = sig(Wr x + Ur h);
            // n = tanh(Wn x + Un (r*h)); h' = (1-z)*n + z*h
            let z = ir::op_call(
                "sigmoid",
                vec![ir::op_call(
                    "add",
                    vec![dense(w, x.clone(), input, HIDDEN), dense(w, h.clone(), HIDDEN, HIDDEN)],
                )],
            );
            let r = ir::op_call(
                "sigmoid",
                vec![ir::op_call(
                    "add",
                    vec![dense(w, x.clone(), input, HIDDEN), dense(w, h.clone(), HIDDEN, HIDDEN)],
                )],
            );
            let rh = ir::op_call("multiply", vec![r, h.clone()]);
            let n = ir::op_call(
                "tanh",
                vec![ir::op_call(
                    "add",
                    vec![dense(w, x, input, HIDDEN), dense(w, rh, HIDDEN, HIDDEN)],
                )],
            );
            let one_minus_z =
                ir::op_call("subtract", vec![ir::scalar(1.0), z.clone()]);
            ir::op_call(
                "add",
                vec![
                    ir::op_call("multiply", vec![one_minus_z, n]),
                    ir::op_call("multiply", vec![z, h]),
                ],
            )
        }
        Model::Lstm | Model::TreeLstm => {
            // State is a tuple (h, c); returns a tuple.
            unreachable!("LSTM uses cell_lstm")
        }
        other => panic!("{} has no recurrent cell", other.name()),
    }
}

/// LSTM step over state tuple (h, c).
fn cell_lstm(w: &mut Weights, x: E, h: E, c: E, input: usize) -> (E, E) {
    let gate = |w: &mut Weights, x: &E, h: &E, act: &str| -> E {
        ir::op_call(
            act,
            vec![ir::op_call(
                "add",
                vec![dense(w, x.clone(), input, HIDDEN), dense(w, h.clone(), HIDDEN, HIDDEN)],
            )],
        )
    };
    let i = gate(w, &x, &h, "sigmoid");
    let f = gate(w, &x, &h, "sigmoid");
    let o = gate(w, &x, &h, "sigmoid");
    let g = gate(w, &x, &h, "tanh");
    let c2 = ir::op_call(
        "add",
        vec![
            ir::op_call("multiply", vec![f, c]),
            ir::op_call("multiply", vec![i, g]),
        ],
    );
    let h2 = ir::op_call("multiply", vec![o, ir::op_call("tanh", vec![c2.clone()])]);
    (h2, c2)
}

/// Build `(module, args)` where `@main` consumes a `List` of step inputs
/// and an initial hidden state, returning the final state. The loop is a
/// recursive Relay function over the list — runs on the interpreter.
pub fn build_recurrent(model: Model, seed: u64) -> (Module, Vec<Value>) {
    let mut w = Weights::new(seed);
    let mut m = Module::with_prelude();
    let xs = Var::fresh("xs");
    let h0 = Var::fresh("h0");

    let body = match model {
        Model::Lstm => {
            let loop_v = Var::fresh("loop");
            let l = Var::fresh("l");
            let hc = Var::fresh("hc");
            let head = Var::fresh("x");
            let tail = Var::fresh("rest");
            let (h2, c2) = cell_lstm(
                &mut w,
                ir::var(&head),
                ir::proj(ir::var(&hc), 0),
                ir::proj(ir::var(&hc), 1),
                EMBED,
            );
            let step = ir::call(ir::var(&loop_v), vec![ir::var(&tail), ir::tuple(vec![h2, c2])]);
            let fn_body = ir::match_(
                ir::var(&l),
                vec![
                    (
                        Pattern::Ctor("Cons".into(), vec![Pattern::Var(head), Pattern::Var(tail)]),
                        step,
                    ),
                    (Pattern::Ctor("Nil".into(), vec![]), ir::var(&hc)),
                ],
            );
            let func = ir::func(
                vec![(l.clone(), None), (hc.clone(), None)],
                fn_body,
            );
            ir::let_(
                loop_v.clone(),
                func,
                ir::call(
                    ir::var(&loop_v),
                    vec![
                        ir::var(&xs),
                        ir::tuple(vec![ir::var(&h0), ir::var(&h0)]),
                    ],
                ),
            )
        }
        _ => {
            let loop_v = Var::fresh("loop");
            let l = Var::fresh("l");
            let h = Var::fresh("h");
            let head = Var::fresh("x");
            let tail = Var::fresh("rest");
            let h2 = cell(model, &mut w, ir::var(&head), ir::var(&h), EMBED);
            let step = ir::call(ir::var(&loop_v), vec![ir::var(&tail), h2]);
            let fn_body = ir::match_(
                ir::var(&l),
                vec![
                    (
                        Pattern::Ctor("Cons".into(), vec![Pattern::Var(head), Pattern::Var(tail)]),
                        step,
                    ),
                    (Pattern::Ctor("Nil".into(), vec![]), ir::var(&h)),
                ],
            );
            let func = ir::func(vec![(l.clone(), None), (h.clone(), None)], fn_body);
            ir::let_(
                loop_v.clone(),
                func,
                ir::call(ir::var(&loop_v), vec![ir::var(&xs), ir::var(&h0)]),
            )
        }
    };
    let list_ty = Type::Adt {
        name: "List".into(),
        args: vec![Type::tensor(vec![1, EMBED], DType::F32)],
    };
    let h_ty = Type::tensor(vec![1, HIDDEN], DType::F32);
    m.add_def(
        "main",
        ir::Function::new(vec![(xs, Some(list_ty)), (h0, Some(h_ty))], body),
    );

    // Inputs: a SEQ_LEN list of (1, EMBED) tensors + zero hidden state.
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let items: Vec<Value> = (0..SEQ_LEN)
        .map(|_| Value::Tensor(rng.normal_tensor(&[1, EMBED], 1.0)))
        .collect();
    let args = vec![
        Value::list(items),
        Value::Tensor(Tensor::zeros(&[1, HIDDEN], DType::F32)),
    ];
    (m, args)
}

/// CharRNN generation: embed -> RNN cell -> logits -> argmax, looped for a
/// fixed number of steps; returns the final hidden state and last logits.
pub fn build_char_rnn(seed: u64) -> (Module, Vec<Value>) {
    let mut w = Weights::new(seed);
    let mut m = Module::with_prelude();
    let embed_table = w.he(&[VOCAB, EMBED]);
    let steps = Var::fresh("steps");
    let tok0 = Var::fresh("tok");
    let h0 = Var::fresh("h0");

    let loop_v = Var::fresh("gen");
    let n = Var::fresh("n");
    let tok = Var::fresh("t");
    let h = Var::fresh("h");
    // x = take(table, tok) reshaped to (1, EMBED)
    let x = ir::op_call_attrs(
        "reshape",
        vec![ir::op_call("take", vec![embed_table, ir::var(&tok)])],
        ir::attrs(&[("newshape", AttrValue::IntVec(vec![1, EMBED as i64]))]),
    );
    let h2 = cell(Model::CharRnn, &mut w, x, ir::var(&h), EMBED);
    let logits = dense(&mut w, h2.clone(), HIDDEN, VOCAB);
    let next_tok = ir::op_call_attrs(
        "argmax",
        vec![logits.clone()],
        ir::attrs(&[("axis", AttrValue::Int(1))]),
    );
    let recur = ir::call(
        ir::var(&loop_v),
        vec![
            ir::op_call("subtract", vec![ir::var(&n), ir::constant(Tensor::scalar_f32(1.0))]),
            next_tok,
            h2.clone(),
        ],
    );
    let fn_body = ir::if_(
        ir::op_call("greater", vec![ir::var(&n), ir::constant(Tensor::scalar_f32(0.0))]),
        recur,
        ir::tuple(vec![ir::var(&h), logits]),
    );
    let func = ir::func(
        vec![(n.clone(), None), (tok.clone(), None), (h.clone(), None)],
        fn_body,
    );
    let body = ir::let_(
        loop_v.clone(),
        func,
        ir::call(
            ir::var(&loop_v),
            vec![ir::var(&steps), ir::var(&tok0), ir::var(&h0)],
        ),
    );
    m.add_def(
        "main",
        ir::Function::new(vec![(steps, None), (tok0, None), (h0, None)], body),
    );
    let args = vec![
        Value::Tensor(Tensor::scalar_f32(SEQ_LEN as f32)),
        Value::Tensor(Tensor::from_i64(vec![1], vec![0])),
        Value::Tensor(Tensor::zeros(&[1, HIDDEN], DType::F32)),
    ];
    (m, args)
}

/// TreeLSTM (childsum-lite): recurse over a `Tree`, combining children
/// states by summation before the cell.
pub fn build_treelstm(seed: u64) -> (Module, Vec<Value>) {
    let mut w = Weights::new(seed);
    let mut m = Module::with_prelude();
    let tree = Var::fresh("tree");

    // sum_children: List[Tensor h] fold with add.
    let sum_v = Var::fresh("sum_h");
    let l = Var::fresh("l");
    let head = Var::fresh("hd");
    let tail = Var::fresh("tl");
    let sum_body = ir::match_(
        ir::var(&l),
        vec![
            (
                Pattern::Ctor("Cons".into(), vec![Pattern::Var(head.clone()), Pattern::Var(tail.clone())]),
                ir::op_call(
                    "add",
                    vec![ir::var(&head), ir::call(ir::var(&sum_v), vec![ir::var(&tail)])],
                ),
            ),
            (
                Pattern::Ctor("Nil".into(), vec![]),
                ir::constant(Tensor::zeros(&[1, HIDDEN], DType::F32)),
            ),
        ],
    );
    let sum_fn = ir::func(vec![(l.clone(), None)], sum_body);

    // encode: Tree[Tensor] -> h. Children encoded recursively via a
    // map-style inner recursion.
    let enc_v = Var::fresh("encode");
    let t = Var::fresh("t");
    let payload = Var::fresh("x");
    let kids = Var::fresh("kids");
    // map encode over children list
    let map_v = Var::fresh("map_enc");
    let ml = Var::fresh("ml");
    let mh = Var::fresh("mh");
    let mt = Var::fresh("mt");
    let map_body = ir::match_(
        ir::var(&ml),
        vec![
            (
                Pattern::Ctor("Cons".into(), vec![Pattern::Var(mh.clone()), Pattern::Var(mt.clone())]),
                ir::call(
                    ir::ctor("Cons"),
                    vec![
                        ir::call(ir::var(&enc_v), vec![ir::var(&mh)]),
                        ir::call(ir::var(&map_v), vec![ir::var(&mt)]),
                    ],
                ),
            ),
            (Pattern::Ctor("Nil".into(), vec![]), ir::ctor("Nil")),
        ],
    );
    let hsum = Var::fresh("hsum");
    let (h2, _c2) = {
        let x = ir::var(&payload);
        let h = ir::var(&hsum);
        let c = ir::constant(Tensor::zeros(&[1, HIDDEN], DType::F32));
        cell_lstm(&mut w, x, h, c, EMBED)
    };
    let enc_body = ir::match_(
        ir::var(&t),
        vec![(
            Pattern::Ctor("Rose".into(), vec![Pattern::Var(payload.clone()), Pattern::Var(kids.clone())]),
            ir::let_(
                map_v.clone(),
                ir::func(vec![(ml.clone(), None)], map_body),
                ir::let_(
                    hsum.clone(),
                    ir::call(
                        ir::global("sum_h"),
                        vec![ir::call(ir::var(&map_v), vec![ir::var(&kids)])],
                    ),
                    h2,
                ),
            ),
        )],
    );
    // Register sum_h as a global so both recursions can see it.
    if let crate::ir::Expr::Func(f) = &*sum_fn {
        let mut f = f.clone();
        // make it self-recursive through the global name
        f.body = replace_var_with_global(&f.body, &sum_v, "sum_h");
        m.add_def("sum_h", f);
    }
    let enc_fn = {
        let body = replace_var_with_global(&enc_body, &enc_v, "encode");
        ir::Function::new(vec![(t.clone(), None)], body)
    };
    m.add_def("encode", enc_fn);
    m.add_def(
        "main",
        ir::Function::new(
            vec![(tree.clone(), None)],
            ir::call(ir::global("encode"), vec![ir::var(&tree)]),
        ),
    );

    // Random tree input.
    let mut rng = Rng::new(seed ^ 0xF00D);
    let tree_v = random_tree(&mut rng, 3, 2);
    (m, vec![tree_v])
}

fn replace_var_with_global(e: &E, v: &Var, name: &str) -> E {
    crate::ir::rewrite_postorder(e, &mut |n| match &**n {
        crate::ir::Expr::Var(x) if x == v => Some(ir::global(name)),
        _ => None,
    })
}

/// Random Rose tree of tensors with the given depth/branching.
pub fn random_tree(rng: &mut Rng, depth: usize, branch: usize) -> Value {
    let payload = Value::Tensor(rng.normal_tensor(&[1, EMBED], 1.0));
    let children = if depth == 0 {
        Value::list(vec![])
    } else {
        Value::list(
            (0..branch)
                .map(|_| random_tree(rng, depth - 1, branch))
                .collect(),
        )
    };
    Value::Adt { ctor: "Rose".into(), fields: vec![payload, children] }
}

/// Dispatch: build any NLP model.
pub fn build_nlp(model: Model, seed: u64) -> (Module, Vec<Value>) {
    match model {
        Model::Rnn | Model::Gru | Model::Lstm => build_recurrent(model, seed),
        Model::CharRnn => build_char_rnn(seed),
        Model::TreeLstm => build_treelstm(seed),
        other => panic!("{} is not an NLP model", other.name()),
    }
}

/// The "hand-optimized C cell" baseline of Fig. 12: the same recurrence
/// computed directly against the tensor substrate, no IR interpretation.
pub fn hand_rnn_baseline(seed: u64, steps: usize) -> Tensor {
    let mut w = Weights::new(seed);
    let wx = w.tensor(&[HIDDEN, EMBED], 0.25);
    let wh = w.tensor(&[HIDDEN, HIDDEN], 0.25);
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let mut h = Tensor::zeros(&[1, HIDDEN], DType::F32);
    for _ in 0..steps {
        let x = rng.normal_tensor(&[1, EMBED], 1.0);
        let a = crate::tensor::dense(&x, &wx);
        let b = crate::tensor::dense(&h, &wh);
        h = crate::tensor::unary(
            crate::tensor::UnaryOp::Tanh,
            &crate::tensor::binary(crate::tensor::BinOp::Add, &a, &b),
        );
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_main;

    #[test]
    fn rnn_gru_run_and_produce_hidden() {
        for model in [Model::Rnn, Model::Gru] {
            let (m, args) = build_nlp(model, 7);
            let out = eval_main(&m, args).unwrap();
            assert_eq!(out.tensor().shape(), &[1, HIDDEN], "{}", model.name());
            assert!(out.tensor().as_f32().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn lstm_returns_state_tuple() {
        let (m, args) = build_nlp(Model::Lstm, 7);
        let out = eval_main(&m, args).unwrap();
        assert_eq!(out.tuple().len(), 2);
        assert_eq!(out.tuple()[0].tensor().shape(), &[1, HIDDEN]);
    }

    #[test]
    fn char_rnn_generates() {
        let (m, args) = build_nlp(Model::CharRnn, 7);
        let out = eval_main(&m, args).unwrap();
        let logits = &out.tuple()[1];
        assert_eq!(logits.tensor().shape(), &[1, VOCAB]);
    }

    #[test]
    fn treelstm_encodes_tree() {
        let (m, args) = build_nlp(Model::TreeLstm, 7);
        let out = eval_main(&m, args).unwrap();
        assert_eq!(out.tensor().shape(), &[1, HIDDEN]);
        assert!(out.tensor().as_f32().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn vm_executes_every_nlp_model_and_matches_the_interpreter() {
        // The executor-selection layer routes these to the VM (control
        // flow + ADTs reject the graph runtime), and results bit-match
        // the reference interpreter. The bit-comparison runs at -O0: the
        // reference is the *unoptimized* interpreter, and -O2+'s
        // TailAccum legitimately reassociates TreeLSTM's child-sum fold
        // (cross-level coverage lives in the pipeline proptests).
        use crate::eval::{CompileOptions, Executor};
        use crate::pass::OptLevel;
        for model in Model::nlp() {
            let (m, args) = build_nlp(model, 7);
            let reference = eval_main(&m, args.clone()).unwrap();
            let out = crate::eval::run_with(
                &m,
                CompileOptions::at(Executor::Vm, OptLevel::O0),
                args.clone(),
            )
            .unwrap_or_else(|e| panic!("{}: {e}", model.name()));
            assert!(
                reference.bits_eq(&out.value),
                "{}: VM diverged from interpreter: {reference:?} vs {:?}",
                model.name(),
                out.value
            );
            // The default (optimizing) auto path still lands on the VM.
            let auto = crate::eval::run_auto(&m, args).unwrap();
            assert_eq!(auto.executor, "vm", "{}", model.name());
        }
    }

    #[test]
    fn nlp_models_typecheck() {
        // Type inference over recursion + ADTs (TreeLSTM exercises both).
        for model in [Model::Rnn, Model::Gru] {
            let (m, _) = build_nlp(model, 7);
            crate::ty::check_module(&m).unwrap_or_else(|e| panic!("{}: {e}", model.name()));
        }
    }
}
