//! # relay — a reproduction of "Relay: A High-Level IR for Deep Learning"
//!
//! Roesch et al., 2019. A functional, statically-typed compiler IR for deep
//! learning, rebuilt as a Rust compiler stack over an XLA/PJRT execution
//! backend, with build-time JAX + Pallas kernels supplying the AOT artifact
//! path (see DESIGN.md for the full mapping).
//!
//! Layer map:
//! * [`ir`], [`ty`], [`pass`], [`eval`], [`quant`], [`graphrt`], [`vm`] —
//!   the Relay compiler itself (the paper's contribution). Three execution
//!   tiers share one value domain and launch metric:
//!   - `eval::Interp` — reference tree-walk interpreter (ground truth);
//!   - `graphrt::GraphRt` — flat node-list runtime for first-order,
//!     control-flow-free programs;
//!   - `vm::Vm` — register-based bytecode VM for control-flow-heavy
//!     programs (closures, ADTs, recursion);
//!   selected via `eval::Executor` / `eval::run_auto` (§3.1.3's
//!   executor-selection story; see rust/src/vm/README.md). Every tier
//!   compiles through ONE optimizing driver: `eval::CompileOptions`
//!   routes the §3.1.2 pass pipeline (`pass::optimize_traced`, default
//!   -O3, optional fixpoint cleanup loop) in front of executor lowering,
//!   the program cache keys on (module hash, OptLevel, executor,
//!   fixpoint), and `relay dump-passes` prints the instrumented per-pass
//!   trace. The compiled tiers are *memory-planned* (§3.1.3 static
//!   memory planning; see rust/src/graphrt/README.md): last-use liveness
//!   kill masks move dying values instead of cloning, hot elementwise
//!   kernels write into uniquely-owned input buffers in place
//!   (`op::inplace`, counted by `tensor::AllocStats`), and per-worker
//!   workspaces / frame pools make steady-state serving allocation-free
//!   outside the kernels. Compilation is *shape-polymorphic* (§3.3.1):
//!   tensor types admit a symbolic batch dimension (`ir::Dim::Any`), the
//!   op shape relations propagate it, and the compiled tiers resolve
//!   concrete shapes from the arriving inputs — one cached artifact per
//!   (rank, dtype, layout), not per batch size.
//! * [`tensor`], [`vta`] — substrates: tensor kernels and the simulated
//!   accelerator. The hot GEMM/conv family is cache-blocked and
//!   register-tiled with packed panels, fans outer tiles across a
//!   lazily-spawned std-only worker pool (`tensor::parallel`;
//!   `--kernel-threads` / `RELAY_KERNEL_THREADS`, `N=1` bypasses it),
//!   and is tuned per (op, shape) at compile time (`tensor::tune` +
//!   the `TuneKernels` pass; decisions ride the program-cache entry and
//!   surface in `dump-passes` / `--profile`). Tiled and parallel paths
//!   are bit-identical to the retained naive reference loops; see
//!   rust/src/tensor/README.md.
//! * [`backend`], [`runtime`], [`frontend`] — codegen to XLA, PJRT
//!   execution, and model importers (PJRT/XLA behind the `xla` feature).
//! * [`zoo`] — the evaluation model suite (vision + NLP).
//! * [`coordinator`] — CLI + batched inference server behind a resilient
//!   front door: bounded admission, per-request deadlines, load shedding,
//!   worker supervision (thin L3 driver). Dispatch is shape-polymorphic
//!   by default (`--poly`): one symbolic-batch compile serves every
//!   batch size at its exact size, zero padding; `--poly=off` keeps the
//!   bucketed fixed-shape path as a differential baseline.
//! * [`telemetry`] — cross-cutting observability (std-only, below every
//!   other layer): the process-wide metrics registry (counters, gauges,
//!   p50/p95/p99 latency histograms, Prometheus-style `/metrics` text),
//!   the opt-in per-op profiler behind `relay run --profile`, and the
//!   serving fleet's request spans (`relay serve --trace-json`). See
//!   rust/src/telemetry/README.md.

pub mod bench;
pub mod sync;
pub mod telemetry;
pub mod tensor;

pub mod ir;
pub mod op;
pub mod ty;

pub mod eval;
pub mod pass;

pub mod graphrt;
pub mod quant;
pub mod vm;

pub mod backend;
pub mod frontend;
pub mod runtime;

pub mod vta;
pub mod zoo;

pub mod coordinator;
