//! Generic quantization flow (§4.5): **annotate -> calibrate -> realize**.
//!
//! * *Annotate* rewrites the graph, inserting `qnn.simulated_quantize`
//!   (simQ) around the inputs of conv-like operators according to each
//!   operator's (overridable) annotate rule — Fig. 9's customization point.
//! * *Calibrate* runs the simulated graph on a calibration set, observing
//!   per-simQ activation ranges, and chooses power-of-two scales.
//! * *Realize* replaces the simulated ops with real narrow-integer ops
//!   (`qnn.quantize`, `qnn.conv2d`/`qnn.dense` with i16/i32 accumulation,
//!   `qnn.requantize`, `qnn.dequantize`).
//!
//! The scheme is parameterized by [`QConfig`] (input bits / accumulator
//! bits / rounding), reproducing Table 2's 8/16, 8/32, 16/32 design points.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::eval::value::Value;
use crate::eval::Interp;
use crate::ir::{
    self, op_call_attrs, rewrite_postorder, AttrValue, Attrs, Expr, Module, E,
};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QConfig {
    /// Bit width of quantized operands (8 or 16).
    pub input_bits: i64,
    /// Accumulator width (16 or 32).
    pub acc_bits: i64,
    /// Rounding mode for weight quantization ("round" | "stochastic_round").
    pub rounding: Rounding,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    Nearest,
    Stochastic,
}

impl QConfig {
    /// The paper's Table 2 design points.
    pub fn i8_i16() -> QConfig {
        QConfig { input_bits: 8, acc_bits: 16, rounding: Rounding::Nearest }
    }

    pub fn i8_i32() -> QConfig {
        QConfig { input_bits: 8, acc_bits: 32, rounding: Rounding::Nearest }
    }

    pub fn i16_i32() -> QConfig {
        QConfig { input_bits: 16, acc_bits: 32, rounding: Rounding::Nearest }
    }

    pub fn name(&self) -> String {
        format!("{}/{}", self.input_bits, self.acc_bits)
    }
}

/// Annotate rule: given the two inputs of a conv-like call, wrap them in
/// simQ ops. Overridable per operator (Fig. 9); the default treats both
/// operands as signed with nearest rounding.
pub type AnnotateFn = fn(&QConfig, E, E, &Attrs) -> (E, E);

fn default_annotate(cfg: &QConfig, lhs: E, rhs: E, _attrs: &Attrs) -> (E, E) {
    (sim_q(cfg, lhs, "round"), sim_q(cfg, rhs, "round"))
}

fn sim_q(cfg: &QConfig, e: E, rounding: &str) -> E {
    op_call_attrs(
        "qnn.simulated_quantize",
        vec![e],
        ir::attrs(&[
            ("bits", AttrValue::Int(cfg.input_bits)),
            // Scale is a placeholder until calibration assigns one.
            ("scale", AttrValue::Float(1.0 / 16.0)),
            ("rounding", AttrValue::Str(rounding.into())),
        ]),
    )
}

/// Registry of per-op annotate rules; `with_rule` overrides (Fig. 9's
/// `register_annotate_function(..., override=True)`).
pub struct Annotator {
    pub cfg: QConfig,
    rules: BTreeMap<&'static str, AnnotateFn>,
}

impl Annotator {
    pub fn new(cfg: QConfig) -> Annotator {
        let mut rules: BTreeMap<&'static str, AnnotateFn> = BTreeMap::new();
        rules.insert("nn.conv2d", default_annotate);
        rules.insert("nn.dense", default_annotate);
        Annotator { cfg, rules }
    }

    pub fn with_rule(mut self, op: &'static str, f: AnnotateFn) -> Annotator {
        self.rules.insert(op, f);
        self
    }

    /// Step 1: insert simQ ops.
    pub fn annotate(&self, e: &E) -> E {
        rewrite_postorder(&e.clone(), &mut |n| match &**n {
            Expr::Call { f, args, attrs } => {
                let name = match &**f {
                    Expr::Op(name) => name.as_str(),
                    _ => return None,
                };
                let rule = self.rules.get(name)?;
                if args.len() != 2 {
                    return None;
                }
                // Don't re-annotate.
                if is_simq(&args[0]) || is_simq(&args[1]) {
                    return None;
                }
                let (l, r) = rule(&self.cfg, args[0].clone(), args[1].clone(), attrs);
                Some(Arc::new(Expr::Call {
                    f: f.clone(),
                    args: vec![l, r],
                    attrs: attrs.clone(),
                }))
            }
            _ => None,
        })
    }
}

fn is_simq(e: &E) -> bool {
    matches!(&**e, Expr::Call { f, .. }
        if matches!(&**f, Expr::Op(n) if n == "qnn.simulated_quantize"))
}

/// Step 2: calibration. Runs the annotated expression on calibration
/// inputs with an instrumented interpreter that records the max-abs value
/// flowing into every simQ, then assigns each simQ the smallest
/// power-of-two scale covering the observed range.
pub fn calibrate(
    module: &Module,
    annotated: &E,
    calib_inputs: &[Vec<Value>],
) -> Result<E, String> {
    // Identify simQ sites by a stable numbering (post-order).
    let mut sites = Vec::new();
    number_simq(annotated, &mut sites);

    // Observe: evaluate with each calibration input; simQ is float->float,
    // so running the annotated graph directly works. We instrument by
    // rewriting each simQ site input through an observer op is avoided —
    // instead we simply evaluate the *argument* of each simQ site.
    // Practical approach: evaluate subexpressions via the interpreter per
    // site (costly but calibration is offline).
    let interp = Interp::new(module);
    let mut max_abs: Vec<f64> = vec![1e-9; sites.len()];
    for input in calib_inputs {
        // Bind function parameters if the annotated expr is a function.
        let env = match &**annotated {
            Expr::Func(f) => {
                let mut env = crate::eval::value::env_empty();
                for ((p, _), v) in f.params.iter().zip(input) {
                    env = crate::eval::value::env_bind(&env, p.clone(), v.clone());
                }
                env
            }
            _ => crate::eval::value::env_empty(),
        };
        for (i, site) in sites.iter().enumerate() {
            if let Expr::Call { args, .. } = &**site {
                let v = interp.eval(&args[0], &env)?;
                if let Value::Tensor(t) = v {
                    for j in 0..t.numel() {
                        max_abs[i] = max_abs[i].max(t.get_f64(j).abs());
                    }
                }
            }
        }
    }

    // Assign power-of-two scales: scale = 2^ceil(log2(max / qmax)).
    let mut idx = 0usize;
    let out = rewrite_simq(annotated, &mut |attrs| {
        let bits = attrs.get("bits").map(|v| v.as_int()).unwrap_or(8);
        let qmax = ((1i64 << (bits - 1)) - 1) as f64;
        let scale = (max_abs[idx] / qmax).log2().ceil().exp2();
        idx += 1;
        let mut a = attrs.clone();
        a.insert("scale".into(), AttrValue::Float(scale));
        a
    });
    Ok(out)
}

fn number_simq(e: &E, out: &mut Vec<E>) {
    // Post-order with a seen-set so shared subtrees number once, matching
    // rewrite_postorder's memoized visit order.
    fn go(e: &E, out: &mut Vec<E>, seen: &mut std::collections::BTreeSet<usize>) {
        let key = Arc::as_ptr(e) as usize;
        if !seen.insert(key) {
            return;
        }
        crate::ir::visit_children(e, |c| go(c, out, seen));
        if is_simq(e) {
            out.push(e.clone());
        }
    }
    go(e, out, &mut std::collections::BTreeSet::new());
}

fn rewrite_simq(e: &E, f: &mut dyn FnMut(&Attrs) -> Attrs) -> E {
    rewrite_postorder(&e.clone(), &mut |n| match &**n {
        Expr::Call { f: cf, args, attrs }
            if matches!(&**cf, Expr::Op(name) if name == "qnn.simulated_quantize") =>
        {
            Some(Arc::new(Expr::Call {
                f: cf.clone(),
                args: args.clone(),
                attrs: f(attrs),
            }))
        }
        _ => None,
    })
}

/// Step 3: realization — turn the simulated graph into a real
/// narrow-integer graph. Each annotated conv-like call becomes:
/// `dequantize(requantize-free accumulate(quantize(lhs), quantize(rhs)))`
/// with the combined scale folded into the final dequantize.
pub fn realize(e: &E, cfg: &QConfig) -> E {
    rewrite_postorder(&e.clone(), &mut |n| {
        let (f, args, attrs) = match &**n {
            Expr::Call { f, args, attrs } => (f, args, attrs),
            _ => return None,
        };
        let name = match &**f {
            Expr::Op(name) => name.as_str(),
            _ => return None,
        };
        if !matches!(name, "nn.conv2d" | "nn.dense") || args.len() != 2 {
            return None;
        }
        let (l_scale, lhs) = strip_simq(&args[0])?;
        let (r_scale, rhs) = strip_simq(&args[1])?;
        let ql = op_call_attrs(
            "qnn.quantize",
            vec![lhs],
            ir::attrs(&[
                ("scale", AttrValue::Float(l_scale)),
                ("bits", AttrValue::Int(cfg.input_bits)),
            ]),
        );
        let qr = op_call_attrs(
            "qnn.quantize",
            vec![rhs],
            ir::attrs(&[
                ("scale", AttrValue::Float(r_scale)),
                ("bits", AttrValue::Int(cfg.input_bits)),
            ]),
        );
        let qop = if name == "nn.conv2d" { "qnn.conv2d" } else { "qnn.dense" };
        let mut qattrs = attrs.clone();
        qattrs.insert("acc_bits".into(), AttrValue::Int(cfg.acc_bits));
        let acc = op_call_attrs(qop, vec![ql, qr], qattrs);
        // Combined scale: product of operand scales.
        Some(op_call_attrs(
            "qnn.dequantize",
            vec![acc],
            ir::attrs(&[("scale", AttrValue::Float(l_scale * r_scale))]),
        ))
    })
}

fn strip_simq(e: &E) -> Option<(f64, E)> {
    match &**e {
        Expr::Call { f, args, attrs }
            if matches!(&**f, Expr::Op(n) if n == "qnn.simulated_quantize") =>
        {
            let scale = attrs.get("scale").map(|v| v.as_float()).unwrap_or(1.0 / 16.0);
            Some((scale, args[0].clone()))
        }
        _ => None,
    }
}

/// The whole flow over a module's `main`: annotate -> calibrate -> realize.
pub fn quantize_module(
    module: &Module,
    cfg: QConfig,
    calib_inputs: &[Vec<Value>],
) -> Result<Module, String> {
    let main = module.def("main").ok_or("no @main")?.clone();
    let fe = Arc::new(Expr::Func(main));
    let annotator = Annotator::new(cfg);
    let annotated = annotator.annotate(&fe);
    let calibrated = calibrate(module, &annotated, calib_inputs)?;
    let realized = realize(&calibrated, &cfg);
    let mut out = module.clone();
    if let Expr::Func(f) = &*realized {
        out.add_def("main", f.clone());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_main;
    use crate::ir::{parse_module, print_expr};
    use crate::tensor::{Rng, Tensor};

    fn dense_module() -> Module {
        parse_module(
            "def @main(%x: Tensor[(4, 16), float32], %w: Tensor[(8, 16), float32]) {\n\
               nn.dense(%x, %w)\n\
             }",
        )
        .unwrap()
    }

    fn calib(rng: &mut Rng) -> Vec<Vec<Value>> {
        (0..4)
            .map(|_| {
                vec![
                    Value::Tensor(rng.normal_tensor(&[4, 16], 1.0)),
                    Value::Tensor(rng.normal_tensor(&[8, 16], 0.5)),
                ]
            })
            .collect()
    }

    #[test]
    fn annotate_inserts_simq() {
        let m = dense_module();
        let fe = Arc::new(Expr::Func(m.def("main").unwrap().clone()));
        let a = Annotator::new(QConfig::i8_i32()).annotate(&fe);
        let s = print_expr(&a);
        assert_eq!(s.matches("qnn.simulated_quantize").count(), 2, "{s}");
    }

    #[test]
    fn calibrate_sets_power_of_two_scales() {
        let m = dense_module();
        let fe = Arc::new(Expr::Func(m.def("main").unwrap().clone()));
        let a = Annotator::new(QConfig::i8_i32()).annotate(&fe);
        let mut rng = Rng::new(0);
        let c = calibrate(&m, &a, &calib(&mut rng)).unwrap();
        let s = print_expr(&c);
        // Scales must be powers of two and not the placeholder.
        let mut found = 0;
        for cap in s.split("scale=").skip(1) {
            let num: String = cap
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == '.' || *c == '-' || *c == 'e')
                .collect();
            let v: f64 = num.trim_end_matches('f').parse().unwrap();
            let l = v.log2();
            assert!((l - l.round()).abs() < 1e-9, "scale {v} not power of two");
            found += 1;
        }
        assert_eq!(found, 2);
    }

    #[test]
    fn realized_graph_is_integer_and_close() {
        let m = dense_module();
        let mut rng = Rng::new(1);
        let q = quantize_module(&m, QConfig::i8_i32(), &calib(&mut rng)).unwrap();
        let s = print_expr(&q.def("main").unwrap().body);
        assert!(s.contains("qnn.dense"), "{s}");
        assert!(s.contains("qnn.quantize"), "{s}");
        assert!(s.contains("qnn.dequantize"), "{s}");
        assert!(!s.contains("simulated"), "{s}");

        let x = rng.normal_tensor(&[4, 16], 1.0);
        let w = rng.normal_tensor(&[8, 16], 0.5);
        let exact = eval_main(&m, vec![Value::Tensor(x.clone()), Value::Tensor(w.clone())])
            .unwrap();
        let quant = eval_main(&q, vec![Value::Tensor(x), Value::Tensor(w)]).unwrap();
        // Quantized result approximates the float result.
        let diff = exact.tensor().max_abs_diff(quant.tensor());
        assert!(diff < 0.5, "quantization error too large: {diff}");
        assert!(diff > 0.0, "suspiciously exact");
    }

    #[test]
    fn acc16_saturates_but_acc32_does_not() {
        // Large K makes the i16 accumulator saturate.
        let m = parse_module(
            "def @main(%x: Tensor[(1, 512), float32], %w: Tensor[(1, 512), float32]) {\n\
               nn.dense(%x, %w)\n\
             }",
        )
        .unwrap();
        let big = Tensor::full_f32(&[1, 512], 3.0);
        let calib: Vec<Vec<Value>> =
            vec![vec![Value::Tensor(big.clone()), Value::Tensor(big.clone())]];
        let q32 = quantize_module(&m, QConfig::i8_i32(), &calib).unwrap();
        let q16 = quantize_module(&m, QConfig::i8_i16(), &calib).unwrap();
        let args = vec![Value::Tensor(big.clone()), Value::Tensor(big.clone())];
        let exact = eval_main(&m, args.clone()).unwrap().tensor().f32_value();
        let v32 = eval_main(&q32, args.clone()).unwrap().tensor().f32_value();
        let v16 = eval_main(&q16, args).unwrap().tensor().f32_value();
        assert!((v32 - exact).abs() / exact < 0.05, "i32 acc {v32} vs {exact}");
        assert!(v16 < v32 * 0.5, "i16 acc should saturate: {v16} vs {v32}");
    }

    #[test]
    fn custom_annotate_rule_overrides() {
        // Fig. 9: override conv2d's rule to stochastic-round the weights.
        fn custom(cfg: &QConfig, l: E, r: E, _a: &Attrs) -> (E, E) {
            (super::sim_q(cfg, l, "round"), super::sim_q(cfg, r, "stochastic_round"))
        }
        let m = dense_module();
        let fe = Arc::new(Expr::Func(m.def("main").unwrap().clone()));
        let a = Annotator::new(QConfig::i8_i32())
            .with_rule("nn.dense", custom)
            .annotate(&fe);
        let s = print_expr(&a);
        assert!(s.contains("stochastic_round"), "{s}");
    }
}
