//! Graph runtime (§3.1.3's "TVM graph runtime" analogue): executes fused,
//! first-order, control-flow-free Relay functions as a flat node list over
//! a preallocated slot arena — no environment lookups, no AST walking on
//! the hot path.
//!
//! Programs with control flow / closures / ADTs don't compile here; callers
//! fall back to the interpreter (exactly the paper's executor-selection
//! story). A fused primitive function becomes ONE node (one "kernel
//! launch"), with its inner op sequence flattened into the node's steps.

use std::collections::BTreeMap;

use crate::eval::value::Value;
use crate::eval::LaunchCounter;
use crate::ir::{Attrs, Expr, Function, E};
use crate::op::{self, OpDef};
use crate::tensor::Tensor;

/// One step inside a fused node: run `def` over resolved inputs.
struct Step {
    def: &'static OpDef,
    attrs: Attrs,
    inputs: Vec<SlotRef>,
    out_temp: usize,
}

#[derive(Clone, Copy, Debug)]
enum SlotRef {
    Arena(usize),
    Temp(usize),
    /// Group input i (inside fused nodes).
    Param(usize),
    Const(usize),
}

enum NodeKind {
    /// Single operator call.
    Op { def: &'static OpDef, attrs: Attrs, inputs: Vec<SlotRef> },
    /// Fused primitive function: a sequence of steps; result = last temp.
    Fused { steps: Vec<Step>, n_temps: usize, inputs: Vec<SlotRef> },
    /// Tuple construction / projection / copy (bookkeeping, not kernels).
    Tuple(Vec<SlotRef>),
    Proj(SlotRef, usize),
    Copy(SlotRef),
}

struct Node {
    kind: NodeKind,
    out_slot: usize,
}

pub struct GraphRt {
    nodes: Vec<Node>,
    constants: Vec<Value>,
    n_slots: usize,
    input_slots: Vec<usize>,
    output: SlotRef,
    /// Number of kernel-launch nodes (Op + Fused), the Fig 10/11 metric
    /// (static count per execution).
    pub kernel_nodes: usize,
    /// Dynamic launch counter, bumped once per executed kernel node —
    /// shared/resettable so metrics are comparable across the three
    /// executors ([`crate::eval::Executor`]).
    pub launches: LaunchCounter,
}

#[derive(Debug)]
pub struct CompileError(pub String);

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "graph runtime: {}", self.0)
    }
}

impl std::error::Error for CompileError {}

struct Compiler {
    nodes: Vec<Node>,
    constants: Vec<Value>,
    slot_of_var: BTreeMap<u32, SlotRef>,
    n_slots: usize,
}

type R<T> = Result<T, CompileError>;

fn err<T>(msg: impl Into<String>) -> R<T> {
    Err(CompileError(msg.into()))
}

impl Compiler {
    fn fresh_slot(&mut self) -> usize {
        let s = self.n_slots;
        self.n_slots += 1;
        s
    }

    fn atom(&mut self, e: &E) -> R<SlotRef> {
        match &**e {
            Expr::Var(v) => self
                .slot_of_var
                .get(&v.id)
                .copied()
                .ok_or_else(|| CompileError(format!("unbound {v}"))),
            Expr::Const(t) => {
                self.constants.push(Value::Tensor(t.clone()));
                Ok(SlotRef::Const(self.constants.len() - 1))
            }
            other => err(format!("non-atomic argument {other:?}")),
        }
    }

    fn compile_value(&mut self, value: &E, out_slot: usize) -> R<Node> {
        match &**value {
            Expr::Call { f, args, attrs } => match &**f {
                Expr::Op(name) => {
                    let def = op::lookup(name)
                        .ok_or_else(|| CompileError(format!("unknown op {name}")))?;
                    let inputs: R<Vec<SlotRef>> = args.iter().map(|a| self.atom(a)).collect();
                    Ok(Node {
                        kind: NodeKind::Op { def, attrs: attrs.clone(), inputs: inputs? },
                        out_slot,
                    })
                }
                Expr::Func(func) if func.attrs.primitive => {
                    let inputs: R<Vec<SlotRef>> = args.iter().map(|a| self.atom(a)).collect();
                    let (steps, n_temps) = self.compile_primitive(func)?;
                    Ok(Node {
                        kind: NodeKind::Fused { steps, n_temps, inputs: inputs? },
                        out_slot,
                    })
                }
                other => err(format!("cannot compile call to {other:?}")),
            },
            Expr::Tuple(es) => {
                let parts: R<Vec<SlotRef>> = es.iter().map(|x| self.atom(x)).collect();
                Ok(Node { kind: NodeKind::Tuple(parts?), out_slot })
            }
            Expr::Proj(t, i) => {
                let s = self.atom(t)?;
                Ok(Node { kind: NodeKind::Proj(s, *i), out_slot })
            }
            Expr::Const(_) | Expr::Var(_) => {
                let s = self.atom(value)?;
                Ok(Node { kind: NodeKind::Copy(s), out_slot })
            }
            other => err(format!("unsupported graph value {other:?}")),
        }
    }

    /// Flatten a primitive function's body to steps over temps.
    fn compile_primitive(&mut self, f: &Function) -> R<(Vec<Step>, usize)> {
        let mut local: BTreeMap<u32, SlotRef> = BTreeMap::new();
        for (i, (p, _)) in f.params.iter().enumerate() {
            local.insert(p.id, SlotRef::Param(i));
        }
        let mut steps = Vec::new();
        let mut n_temps = 0usize;
        let mut cur = f.body.clone();
        loop {
            match &*cur.clone() {
                Expr::Let { var, value, body, .. } => {
                    let (def, attrs, args) = match &**value {
                        Expr::Call { f: cf, args, attrs } => match &**cf {
                            Expr::Op(name) => (
                                op::lookup(name).ok_or_else(|| {
                                    CompileError(format!("unknown op {name}"))
                                })?,
                                attrs.clone(),
                                args,
                            ),
                            other => return err(format!("primitive body call {other:?}")),
                        },
                        other => return err(format!("primitive binding {other:?}")),
                    };
                    let mut inputs = Vec::new();
                    for a in args {
                        match &**a {
                            Expr::Var(v) => inputs.push(
                                *local
                                    .get(&v.id)
                                    .ok_or_else(|| CompileError(format!("unbound {v}")))?,
                            ),
                            Expr::Const(t) => {
                                self.constants.push(Value::Tensor(t.clone()));
                                inputs.push(SlotRef::Const(self.constants.len() - 1));
                            }
                            other => return err(format!("non-atom in group {other:?}")),
                        }
                    }
                    let out_temp = n_temps;
                    n_temps += 1;
                    local.insert(var.id, SlotRef::Temp(out_temp));
                    steps.push(Step { def, attrs, inputs, out_temp });
                    cur = body.clone();
                }
                Expr::Var(v) => {
                    match local.get(&v.id) {
                        Some(SlotRef::Temp(t)) if *t + 1 == n_temps => {}
                        other => {
                            return err(format!("primitive result not last step: {other:?}"))
                        }
                    }
                    break;
                }
                other => return err(format!("primitive tail {other:?}")),
            }
        }
        Ok((steps, n_temps))
    }
}

impl GraphRt {
    /// Compile a first-order function (ANF, post-fusion) to a graph.
    pub fn compile(f: &Function) -> R<GraphRt> {
        let mut c = Compiler {
            nodes: Vec::new(),
            constants: Vec::new(),
            slot_of_var: BTreeMap::new(),
            n_slots: 0,
        };
        let mut input_slots = Vec::new();
        for (p, _) in &f.params {
            let s = c.fresh_slot();
            c.slot_of_var.insert(p.id, SlotRef::Arena(s));
            input_slots.push(s);
        }
        let mut cur = f.body.clone();
        loop {
            match &*cur.clone() {
                Expr::Let { var, value, body, .. } => {
                    let out = c.fresh_slot();
                    let node = c.compile_value(value, out)?;
                    c.nodes.push(node);
                    c.slot_of_var.insert(var.id, SlotRef::Arena(out));
                    cur = body.clone();
                }
                _ => break,
            }
        }
        // A non-atomic tail (the common ANF case) compiles into a final node.
        let output = if cur.is_atomic() {
            c.atom(&cur)?
        } else {
            let out = c.fresh_slot();
            let node = c.compile_value(&cur, out)?;
            c.nodes.push(node);
            SlotRef::Arena(out)
        };
        let kernel_nodes = c
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { .. } | NodeKind::Fused { .. }))
            .count();
        Ok(GraphRt {
            nodes: c.nodes,
            constants: c.constants,
            n_slots: c.n_slots,
            input_slots,
            output,
            kernel_nodes,
            launches: LaunchCounter::new(),
        })
    }

    pub fn num_inputs(&self) -> usize {
        self.input_slots.len()
    }

    /// Tensor bytes held resident by the compiled graph's constant table
    /// (the program cache's size-aware eviction metric).
    pub fn const_bytes(&self) -> usize {
        self.constants.iter().map(|v| v.tensor_bytes()).sum()
    }

    /// Execute with the given inputs.
    pub fn run(&self, inputs: &[Value]) -> Result<Value, String> {
        self.run_traced(inputs, &mut |_, _, _| {})
    }

    /// Execute, counting launches on a caller-supplied counter instead of
    /// this runtime's own. The program cache hands one shared `GraphRt` to
    /// many threads, so per-call metrics must not diff a shared counter.
    pub fn run_counted(
        &self,
        inputs: &[Value],
        launches: &LaunchCounter,
    ) -> Result<Value, String> {
        self.run_traced_counted(inputs, &mut |_, _, _| {}, launches)
    }

    /// Execute, invoking `trace(op_name, args, out)` for every operator
    /// application (including the steps inside fused nodes). Used by the
    /// VTA simulator's cycle accounting.
    pub fn run_traced(
        &self,
        inputs: &[Value],
        trace: &mut dyn FnMut(&str, &[Value], &Value),
    ) -> Result<Value, String> {
        self.run_traced_counted(inputs, trace, &self.launches)
    }

    fn run_traced_counted(
        &self,
        inputs: &[Value],
        trace: &mut dyn FnMut(&str, &[Value], &Value),
        launches: &LaunchCounter,
    ) -> Result<Value, String> {
        if inputs.len() != self.input_slots.len() {
            return Err(format!(
                "graph expects {} inputs, got {}",
                self.input_slots.len(),
                inputs.len()
            ));
        }
        let mut slots: Vec<Option<Value>> = vec![None; self.n_slots];
        for (s, v) in self.input_slots.iter().zip(inputs) {
            slots[*s] = Some(v.clone());
        }
        let empty_t: Vec<Option<Value>> = Vec::new();
        let empty_p: Vec<Value> = Vec::new();
        for node in &self.nodes {
            let out = match &node.kind {
                NodeKind::Op { def, attrs, inputs } => {
                    launches.bump();
                    let args: Result<Vec<Value>, String> = inputs
                        .iter()
                        .map(|r| self.read(&slots, &empty_t, &empty_p, r))
                        .collect();
                    let args = args?;
                    let out = (def.eval)(&args, attrs)?;
                    trace(def.name, &args, &out);
                    out
                }
                NodeKind::Fused { steps, n_temps, inputs } => {
                    launches.bump();
                    let group_inputs: Result<Vec<Value>, String> = inputs
                        .iter()
                        .map(|r| self.read(&slots, &empty_t, &empty_p, r))
                        .collect();
                    let group_inputs = group_inputs?;
                    let mut temps: Vec<Option<Value>> = vec![None; *n_temps];
                    for step in steps {
                        let args: Result<Vec<Value>, String> = step
                            .inputs
                            .iter()
                            .map(|r| self.read(&slots, &temps, &group_inputs, r))
                            .collect();
                        let args = args?;
                        let v = (step.def.eval)(&args, &step.attrs)?;
                        trace(step.def.name, &args, &v);
                        temps[step.out_temp] = Some(v);
                    }
                    temps[*n_temps - 1].take().ok_or("empty fused result")?
                }
                NodeKind::Tuple(parts) => {
                    let vs: Result<Vec<Value>, String> = parts
                        .iter()
                        .map(|r| self.read(&slots, &empty_t, &empty_p, r))
                        .collect();
                    Value::Tuple(vs?)
                }
                NodeKind::Proj(r, i) => {
                    let v = self.read(&slots, &empty_t, &empty_p, r)?;
                    v.tuple()
                        .get(*i)
                        .cloned()
                        .ok_or_else(|| format!("proj .{i} out of range"))?
                }
                NodeKind::Copy(r) => self.read(&slots, &empty_t, &empty_p, r)?,
            };
            slots[node.out_slot] = Some(out);
        }
        self.read(&slots, &empty_t, &empty_p, &self.output)
    }

    fn read(
        &self,
        slots: &[Option<Value>],
        temps: &[Option<Value>],
        params: &[Value],
        r: &SlotRef,
    ) -> Result<Value, String> {
        match r {
            SlotRef::Arena(i) => slots[*i].clone().ok_or_else(|| format!("empty slot {i}")),
            SlotRef::Const(i) => Ok(self.constants[*i].clone()),
            SlotRef::Temp(t) => temps[*t].clone().ok_or_else(|| format!("empty temp {t}")),
            SlotRef::Param(i) => Ok(params[*i].clone()),
        }
    }

    /// Convenience: run with tensor inputs.
    pub fn run_tensors(&self, inputs: &[Tensor]) -> Result<Value, String> {
        let vs: Vec<Value> = inputs.iter().map(|t| Value::Tensor(t.clone())).collect();
        self.run(&vs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_main;
    use crate::ir::{parse_module, Module};
    use crate::pass::{optimize, OptLevel};
    use crate::tensor::Rng;

    fn mlp_module() -> Module {
        parse_module(
            "def @main(%x: Tensor[(2, 4), float32], %w1: Tensor[(8, 4), float32], %w2: Tensor[(2, 8), float32]) {\n\
               nn.dense(nn.relu(nn.dense(%x, %w1)), %w2)\n\
             }",
        )
        .unwrap()
    }

    #[test]
    fn matches_interpreter_across_levels() {
        let m = mlp_module();
        let mut rng = Rng::new(0);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let w1 = rng.normal_tensor(&[8, 4], 1.0);
        let w2 = rng.normal_tensor(&[2, 8], 1.0);
        let args = vec![
            Value::Tensor(x.clone()),
            Value::Tensor(w1.clone()),
            Value::Tensor(w2.clone()),
        ];
        let expect = eval_main(&m, args).unwrap();
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O3] {
            let opt = optimize(&m, level, false).unwrap();
            let anfed = crate::pass::anf::run(&opt);
            let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
            let out = g.run_tensors(&[x.clone(), w1.clone(), w2.clone()]).unwrap();
            assert!(
                expect.tensor().allclose(out.tensor(), 1e-4, 1e-4),
                "level {level}"
            );
        }
    }

    #[test]
    fn fusion_reduces_kernel_nodes() {
        let m = mlp_module();
        let unfused = crate::pass::anf::run(&m);
        let g0 = GraphRt::compile(unfused.def("main").unwrap()).unwrap();
        let fused = optimize(&m, OptLevel::O1, false).unwrap();
        let g1 = GraphRt::compile(fused.def("main").unwrap()).unwrap();
        assert!(
            g1.kernel_nodes < g0.kernel_nodes,
            "fused {} vs unfused {}",
            g1.kernel_nodes,
            g0.kernel_nodes
        );
        assert_eq!(g0.kernel_nodes, 3);
        assert_eq!(g1.kernel_nodes, 2); // {dense+relu}, {dense}
    }

    #[test]
    fn control_flow_rejected() {
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) { if (greater(%x, 0f)) { %x } else { negative(%x) } }",
        )
        .unwrap();
        let anfed = crate::pass::anf::run(&m);
        assert!(GraphRt::compile(anfed.def("main").unwrap()).is_err());
    }

    #[test]
    fn tuple_outputs_work() {
        let m = parse_module(
            "def @main(%x: Tensor[(2, 4), float32]) {\n\
               let %s = split(%x, indices_or_sections=2, axis=1);\n\
               add(%s.0, %s.1)\n\
             }",
        )
        .unwrap();
        let anfed = crate::pass::anf::run(&m);
        let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
        let x = Tensor::from_f32(vec![2, 4], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let out = g.run_tensors(&[x]).unwrap();
        assert_eq!(out.tensor().as_f32(), &[4., 6., 12., 14.]);
    }
}
