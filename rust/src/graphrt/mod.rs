//! Graph runtime (§3.1.3's "TVM graph runtime" analogue): executes fused,
//! first-order, control-flow-free Relay functions as a flat node list over
//! a preallocated slot arena — no environment lookups, no AST walking on
//! the hot path.
//!
//! Programs with control flow / closures / ADTs don't compile here; callers
//! fall back to the interpreter (exactly the paper's executor-selection
//! story). A fused primitive function becomes ONE node (one "kernel
//! launch"), with its inner op sequence flattened into the node's steps.
//!
//! # Static memory planning
//!
//! Compilation runs a last-use liveness pass over the flat node list (the
//! analogue of the VM's register-reuse scan): every node input carries a
//! `kill` flag marking whether the referenced slot dies at that read. The
//! planned runner ([`GraphRt::run_in`]) exploits this at execution time:
//! dying slots are **moved** out (`Option::take`) instead of cloned, so a
//! value whose last consumer is an elementwise kernel arrives uniquely
//! owned and the kernel writes into its buffer in place
//! ([`crate::op::inplace`]) instead of allocating. All per-call vectors
//! (slot arena, fused temps, argument scratch) live in a reusable
//! [`Workspace`] — held per worker thread, cleared not reallocated — so
//! steady-state calls perform zero vector allocations outside the kernels.
//! The unplanned clone-everything path survives as [`GraphRt::run_traced`]
//! (the VTA tracer needs intact argument values, and the differential
//! tests use it as the bit-exact baseline).

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::eval::value::Value;
use crate::eval::LaunchCounter;
use crate::ir::{Attrs, Expr, Function, E};
use crate::op::{self, OpDef};
use crate::telemetry;
use crate::tensor::Tensor;

/// One step inside a fused node: run `def` over resolved inputs.
struct Step {
    def: &'static OpDef,
    attrs: Attrs,
    inputs: Vec<SlotRef>,
    out_temp: usize,
    /// Parallel to `inputs`: true when that temp/group-input dies here
    /// (last read inside this fused kernel) and may be consumed by move.
    kills: Vec<bool>,
}

#[derive(Clone, Copy, Debug)]
enum SlotRef {
    Arena(usize),
    Temp(usize),
    /// Group input i (inside fused nodes).
    Param(usize),
    Const(usize),
}

enum NodeKind {
    /// Single operator call.
    Op { def: &'static OpDef, attrs: Attrs, inputs: Vec<SlotRef> },
    /// Fused primitive function: a sequence of steps; result = last temp.
    Fused { steps: Vec<Step>, n_temps: usize, inputs: Vec<SlotRef> },
    /// Tuple construction / projection / copy (bookkeeping, not kernels).
    Tuple(Vec<SlotRef>),
    Proj(SlotRef, usize),
    Copy(SlotRef),
}

struct Node {
    kind: NodeKind,
    out_slot: usize,
    /// Parallel to this kind's input list: true when the referenced arena
    /// slot is last read here (the planner's kill mask). Filled by
    /// [`plan_liveness`] after the node list is complete.
    kills: Vec<bool>,
}

/// Visit this node kind's input references in argument order.
fn for_each_input(kind: &NodeKind, mut f: impl FnMut(&SlotRef)) {
    match kind {
        NodeKind::Op { inputs, .. } | NodeKind::Fused { inputs, .. } => {
            inputs.iter().for_each(&mut f)
        }
        NodeKind::Tuple(parts) => parts.iter().for_each(&mut f),
        NodeKind::Proj(r, _) | NodeKind::Copy(r) => f(r),
    }
}

/// Last-use liveness over the flat node list: for each arena slot, find
/// its final reader and mark that read as a kill. The program output's
/// slot is read after every node, so no node kills it. Duplicate reads of
/// one slot within a node kill only the last occurrence, so the runner
/// can move unconditionally where the mask says so.
fn plan_liveness(nodes: &mut [Node], output: &SlotRef, n_slots: usize) {
    let mut last: Vec<Option<(usize, usize)>> = vec![None; n_slots];
    for (i, node) in nodes.iter().enumerate() {
        let mut pos = 0usize;
        for_each_input(&node.kind, |r| {
            if let SlotRef::Arena(s) = r {
                last[*s] = Some((i, pos));
            }
            pos += 1;
        });
    }
    if let SlotRef::Arena(s) = output {
        last[*s] = None;
    }
    for (i, node) in nodes.iter_mut().enumerate() {
        let mut kills = Vec::new();
        let mut pos = 0usize;
        for_each_input(&node.kind, |r| {
            kills.push(matches!(r, SlotRef::Arena(s) if last[*s] == Some((i, pos))));
            pos += 1;
        });
        node.kills = kills;
    }
}

/// Last-use liveness for the steps inside one fused kernel: temps and
/// group inputs (params) die at their final reading step. The result temp
/// is consumed by the node epilogue, not a step, so it is never killed
/// here.
fn plan_step_kills(steps: &mut [Step], n_temps: usize, n_params: usize) {
    let mut last_t: Vec<Option<(usize, usize)>> = vec![None; n_temps];
    let mut last_p: Vec<Option<(usize, usize)>> = vec![None; n_params];
    for (i, s) in steps.iter().enumerate() {
        for (j, r) in s.inputs.iter().enumerate() {
            match r {
                SlotRef::Temp(t) => last_t[*t] = Some((i, j)),
                SlotRef::Param(p) => last_p[*p] = Some((i, j)),
                _ => {}
            }
        }
    }
    for (i, s) in steps.iter_mut().enumerate() {
        s.kills = s
            .inputs
            .iter()
            .enumerate()
            .map(|(j, r)| match r {
                SlotRef::Temp(t) => last_t[*t] == Some((i, j)),
                SlotRef::Param(p) => last_p[*p] == Some((i, j)),
                _ => false,
            })
            .collect();
    }
}

/// Reusable per-call execution state for the planned runner: the slot
/// arena, fused-kernel temps, the per-step argument buffer, and the fused
/// group-input buffer. Hold one per worker thread and every call clears
/// (never reallocates) the vectors — steady state does no vector
/// allocation outside the kernels themselves.
#[derive(Default)]
pub struct Workspace {
    slots: Vec<Option<Value>>,
    temps: Vec<Option<Value>>,
    args: Vec<Value>,
    group: Vec<Value>,
    /// Dead-buffer arena: uniquely-owned f32 tensors whose last consumer
    /// has run. A later `nn.dense`/`matmul` with a matching output shape
    /// steals one as its destination ([`op::inplace::eval_step_with_donors`])
    /// instead of allocating. Bounded; cleared at the end of every call.
    graveyard: Vec<Tensor>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }
}

thread_local! {
    /// Per-thread default workspace: a serving worker (one thread) reuses
    /// one arena across every request it handles.
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace::new());
}

pub struct GraphRt {
    nodes: Vec<Node>,
    constants: Vec<Value>,
    n_slots: usize,
    input_slots: Vec<usize>,
    output: SlotRef,
    /// Number of kernel-launch nodes (Op + Fused), the Fig 10/11 metric
    /// (static count per execution).
    pub kernel_nodes: usize,
    /// Dynamic launch counter, bumped once per executed kernel node —
    /// shared/resettable so metrics are comparable across the three
    /// executors ([`crate::eval::Executor`]).
    pub launches: LaunchCounter,
}

#[derive(Debug)]
pub struct CompileError(pub String);

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "graph runtime: {}", self.0)
    }
}

impl std::error::Error for CompileError {}

struct Compiler {
    nodes: Vec<Node>,
    constants: Vec<Value>,
    slot_of_var: BTreeMap<u32, SlotRef>,
    n_slots: usize,
}

type R<T> = Result<T, CompileError>;

fn err<T>(msg: impl Into<String>) -> R<T> {
    Err(CompileError(msg.into()))
}

impl Compiler {
    fn fresh_slot(&mut self) -> usize {
        let s = self.n_slots;
        self.n_slots += 1;
        s
    }

    fn atom(&mut self, e: &E) -> R<SlotRef> {
        match &**e {
            Expr::Var(v) => self
                .slot_of_var
                .get(&v.id)
                .copied()
                .ok_or_else(|| CompileError(format!("unbound {v}"))),
            Expr::Const(t) => {
                self.constants.push(Value::Tensor(t.clone()));
                Ok(SlotRef::Const(self.constants.len() - 1))
            }
            other => err(format!("non-atomic argument {other:?}")),
        }
    }

    fn node(kind: NodeKind, out_slot: usize) -> Node {
        // Kill masks are filled by `plan_liveness` once the list is final.
        Node { kind, out_slot, kills: Vec::new() }
    }

    fn compile_value(&mut self, value: &E, out_slot: usize) -> R<Node> {
        match &**value {
            Expr::Call { f, args, attrs } => match &**f {
                Expr::Op(name) => {
                    let def = op::lookup(name)
                        .ok_or_else(|| CompileError(format!("unknown op {name}")))?;
                    let inputs: R<Vec<SlotRef>> = args.iter().map(|a| self.atom(a)).collect();
                    Ok(Self::node(
                        NodeKind::Op { def, attrs: attrs.clone(), inputs: inputs? },
                        out_slot,
                    ))
                }
                Expr::Func(func) if func.attrs.primitive => {
                    let inputs: R<Vec<SlotRef>> = args.iter().map(|a| self.atom(a)).collect();
                    let (steps, n_temps) = self.compile_primitive(func)?;
                    Ok(Self::node(
                        NodeKind::Fused { steps, n_temps, inputs: inputs? },
                        out_slot,
                    ))
                }
                other => err(format!("cannot compile call to {other:?}")),
            },
            Expr::Tuple(es) => {
                let parts: R<Vec<SlotRef>> = es.iter().map(|x| self.atom(x)).collect();
                Ok(Self::node(NodeKind::Tuple(parts?), out_slot))
            }
            Expr::Proj(t, i) => {
                let s = self.atom(t)?;
                Ok(Self::node(NodeKind::Proj(s, *i), out_slot))
            }
            Expr::Const(_) | Expr::Var(_) => {
                let s = self.atom(value)?;
                Ok(Self::node(NodeKind::Copy(s), out_slot))
            }
            other => err(format!("unsupported graph value {other:?}")),
        }
    }

    /// Flatten a primitive function's body to steps over temps.
    fn compile_primitive(&mut self, f: &Function) -> R<(Vec<Step>, usize)> {
        let mut local: BTreeMap<u32, SlotRef> = BTreeMap::new();
        for (i, (p, _)) in f.params.iter().enumerate() {
            local.insert(p.id, SlotRef::Param(i));
        }
        let mut steps = Vec::new();
        let mut n_temps = 0usize;
        let mut cur = f.body.clone();
        loop {
            match &*cur.clone() {
                Expr::Let { var, value, body, .. } => {
                    let (def, attrs, args) = match &**value {
                        Expr::Call { f: cf, args, attrs } => match &**cf {
                            Expr::Op(name) => (
                                op::lookup(name).ok_or_else(|| {
                                    CompileError(format!("unknown op {name}"))
                                })?,
                                attrs.clone(),
                                args,
                            ),
                            other => return err(format!("primitive body call {other:?}")),
                        },
                        other => return err(format!("primitive binding {other:?}")),
                    };
                    let mut inputs = Vec::new();
                    for a in args {
                        match &**a {
                            Expr::Var(v) => inputs.push(
                                *local
                                    .get(&v.id)
                                    .ok_or_else(|| CompileError(format!("unbound {v}")))?,
                            ),
                            Expr::Const(t) => {
                                self.constants.push(Value::Tensor(t.clone()));
                                inputs.push(SlotRef::Const(self.constants.len() - 1));
                            }
                            other => return err(format!("non-atom in group {other:?}")),
                        }
                    }
                    let out_temp = n_temps;
                    n_temps += 1;
                    local.insert(var.id, SlotRef::Temp(out_temp));
                    steps.push(Step { def, attrs, inputs, out_temp, kills: Vec::new() });
                    cur = body.clone();
                }
                Expr::Var(v) => {
                    match local.get(&v.id) {
                        Some(SlotRef::Temp(t)) if *t + 1 == n_temps => {}
                        other => {
                            return err(format!("primitive result not last step: {other:?}"))
                        }
                    }
                    break;
                }
                other => return err(format!("primitive tail {other:?}")),
            }
        }
        plan_step_kills(&mut steps, n_temps, f.params.len());
        Ok((steps, n_temps))
    }
}

impl GraphRt {
    /// Compile a first-order function (ANF, post-fusion) to a graph.
    pub fn compile(f: &Function) -> R<GraphRt> {
        let mut c = Compiler {
            nodes: Vec::new(),
            constants: Vec::new(),
            slot_of_var: BTreeMap::new(),
            n_slots: 0,
        };
        let mut input_slots = Vec::new();
        for (p, _) in &f.params {
            let s = c.fresh_slot();
            c.slot_of_var.insert(p.id, SlotRef::Arena(s));
            input_slots.push(s);
        }
        let mut cur = f.body.clone();
        loop {
            match &*cur.clone() {
                Expr::Let { var, value, body, .. } => {
                    let out = c.fresh_slot();
                    let node = c.compile_value(value, out)?;
                    c.nodes.push(node);
                    c.slot_of_var.insert(var.id, SlotRef::Arena(out));
                    cur = body.clone();
                }
                _ => break,
            }
        }
        // A non-atomic tail (the common ANF case) compiles into a final node.
        let output = if cur.is_atomic() {
            c.atom(&cur)?
        } else {
            let out = c.fresh_slot();
            let node = c.compile_value(&cur, out)?;
            c.nodes.push(node);
            SlotRef::Arena(out)
        };
        let kernel_nodes = c
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Op { .. } | NodeKind::Fused { .. }))
            .count();
        let mut nodes = c.nodes;
        plan_liveness(&mut nodes, &output, c.n_slots);
        Ok(GraphRt {
            nodes,
            constants: c.constants,
            n_slots: c.n_slots,
            input_slots,
            output,
            kernel_nodes,
            launches: LaunchCounter::new(),
        })
    }

    pub fn num_inputs(&self) -> usize {
        self.input_slots.len()
    }

    /// Tensor bytes held resident by the compiled graph's constant table
    /// (the program cache's size-aware eviction metric).
    pub fn const_bytes(&self) -> usize {
        self.constants.iter().map(|v| v.tensor_bytes()).sum()
    }

    /// Execute with the given inputs (planned path).
    pub fn run(&self, inputs: &[Value]) -> Result<Value, String> {
        self.run_counted(inputs, &self.launches)
    }

    /// Execute on the planned path, counting launches on a caller-supplied
    /// counter instead of this runtime's own. The program cache hands one
    /// shared `GraphRt` to many threads, so per-call metrics must not diff
    /// a shared counter. Uses the calling thread's default [`Workspace`].
    pub fn run_counted(
        &self,
        inputs: &[Value],
        launches: &LaunchCounter,
    ) -> Result<Value, String> {
        WORKSPACE.with(|ws| {
            self.run_planned(inputs.iter().cloned(), inputs.len(), launches, &mut ws.borrow_mut())
        })
    }

    /// [`Self::run_counted`] taking the inputs by value: argument tensors
    /// the caller hands over exclusively (refcount 1) become eligible for
    /// in-place reuse at their last use, exactly like intermediates.
    pub fn run_owned(
        &self,
        inputs: Vec<Value>,
        launches: &LaunchCounter,
    ) -> Result<Value, String> {
        WORKSPACE.with(|ws| {
            let n = inputs.len();
            self.run_planned(inputs.into_iter(), n, launches, &mut ws.borrow_mut())
        })
    }

    /// The planned path with an explicit caller-held workspace, for
    /// callers that want to manage arena lifetime themselves. (The serving
    /// workers and `run_counted`/`run_owned` use the per-thread default
    /// workspace — one per worker thread — and don't need this.)
    pub fn run_in(
        &self,
        inputs: Vec<Value>,
        launches: &LaunchCounter,
        ws: &mut Workspace,
    ) -> Result<Value, String> {
        let n = inputs.len();
        self.run_planned(inputs.into_iter(), n, launches, ws)
    }

    /// Execute, invoking `trace(op_name, args, out)` for every operator
    /// application (including the steps inside fused nodes). Used by the
    /// VTA simulator's cycle accounting. This is the **unplanned** legacy
    /// path: every slot read clones and every kernel allocates, so traced
    /// argument values are always intact — and the differential tests use
    /// it as the bit-exact baseline for the planned runner.
    pub fn run_traced(
        &self,
        inputs: &[Value],
        trace: &mut dyn FnMut(&str, &[Value], &Value),
    ) -> Result<Value, String> {
        self.run_traced_counted(inputs, trace, &self.launches)
    }

    /// The planned executor: kill-mask moves out of the slot arena,
    /// in-place elementwise kernels, and workspace reuse. Bit-identical to
    /// [`Self::run_traced`] by construction (the in-place kernels mirror
    /// the allocating arithmetic exactly).
    fn run_planned(
        &self,
        inputs: impl Iterator<Item = Value>,
        n_inputs: usize,
        launches: &LaunchCounter,
        ws: &mut Workspace,
    ) -> Result<Value, String> {
        let out = self.run_planned_inner(inputs, n_inputs, launches, ws);
        // Unconditionally (success or error) drop everything the workspace
        // still holds — capacity kept — so neither a finished call nor a
        // mid-graph kernel error pins this call's tensors in the
        // per-thread arena until the next run.
        let Workspace { slots, temps, args, group, graveyard } = ws;
        slots.clear();
        temps.clear();
        args.clear();
        group.clear();
        graveyard.clear();
        out
    }

    fn run_planned_inner(
        &self,
        inputs: impl Iterator<Item = Value>,
        n_inputs: usize,
        launches: &LaunchCounter,
        ws: &mut Workspace,
    ) -> Result<Value, String> {
        if n_inputs != self.input_slots.len() {
            return Err(format!(
                "graph expects {} inputs, got {}",
                self.input_slots.len(),
                n_inputs
            ));
        }
        let Workspace { slots, temps, args, group, graveyard } = ws;
        slots.clear();
        slots.resize(self.n_slots, None);
        for (s, v) in self.input_slots.iter().zip(inputs) {
            slots[*s] = Some(v);
        }
        for node in &self.nodes {
            let out = match &node.kind {
                NodeKind::Op { def, attrs, inputs } => {
                    launches.bump();
                    telemetry::profiler::note_launch();
                    args.clear();
                    for (j, r) in inputs.iter().enumerate() {
                        args.push(read_owned(slots, &self.constants, r, node.kills[j])?);
                    }
                    let v = op::inplace::eval_step_with_donors(*def, args, attrs, graveyard)?;
                    bury_dead_args(args, graveyard);
                    v
                }
                NodeKind::Fused { steps, n_temps, inputs } => {
                    launches.bump();
                    telemetry::profiler::note_launch();
                    group.clear();
                    for (j, r) in inputs.iter().enumerate() {
                        group.push(read_owned(slots, &self.constants, r, node.kills[j])?);
                    }
                    temps.clear();
                    temps.resize(*n_temps, None);
                    for step in steps {
                        args.clear();
                        for (j, r) in step.inputs.iter().enumerate() {
                            let kill = step.kills[j];
                            let v = match r {
                                SlotRef::Temp(t) => {
                                    (if kill { temps[*t].take() } else { temps[*t].clone() })
                                        .ok_or_else(|| format!("empty temp {t}"))?
                                }
                                SlotRef::Param(i) => {
                                    if kill {
                                        std::mem::replace(&mut group[*i], Value::unit())
                                    } else {
                                        group[*i].clone()
                                    }
                                }
                                SlotRef::Const(c) => self.constants[*c].clone(),
                                SlotRef::Arena(_) => {
                                    return Err("arena ref inside fused kernel".into())
                                }
                            };
                            args.push(v);
                        }
                        let v = op::inplace::eval_step_with_donors(
                            step.def,
                            args,
                            &step.attrs,
                            graveyard,
                        )?;
                        bury_dead_args(args, graveyard);
                        temps[step.out_temp] = Some(v);
                    }
                    temps[*n_temps - 1].take().ok_or("empty fused result")?
                }
                NodeKind::Tuple(parts) => {
                    let mut vs = Vec::with_capacity(parts.len());
                    for (j, r) in parts.iter().enumerate() {
                        vs.push(read_owned(slots, &self.constants, r, node.kills[j])?);
                    }
                    Value::Tuple(vs)
                }
                NodeKind::Proj(r, i) => {
                    let v = read_owned(slots, &self.constants, r, node.kills[0])?;
                    v.tuple()
                        .get(*i)
                        .cloned()
                        .ok_or_else(|| format!("proj .{i} out of range"))?
                }
                NodeKind::Copy(r) => read_owned(slots, &self.constants, r, node.kills[0])?,
            };
            slots[node.out_slot] = Some(out);
        }
        // Take the result; `run_planned` clears the workspace afterwards
        // on every path, success or error.
        read_owned(slots, &self.constants, &self.output, true)
    }

    fn run_traced_counted(
        &self,
        inputs: &[Value],
        trace: &mut dyn FnMut(&str, &[Value], &Value),
        launches: &LaunchCounter,
    ) -> Result<Value, String> {
        if inputs.len() != self.input_slots.len() {
            return Err(format!(
                "graph expects {} inputs, got {}",
                self.input_slots.len(),
                inputs.len()
            ));
        }
        let mut slots: Vec<Option<Value>> = vec![None; self.n_slots];
        for (s, v) in self.input_slots.iter().zip(inputs) {
            slots[*s] = Some(v.clone());
        }
        let empty_t: Vec<Option<Value>> = Vec::new();
        let empty_p: Vec<Value> = Vec::new();
        for node in &self.nodes {
            let out = match &node.kind {
                NodeKind::Op { def, attrs, inputs } => {
                    launches.bump();
                    telemetry::profiler::note_launch();
                    let args: Result<Vec<Value>, String> = inputs
                        .iter()
                        .map(|r| self.read(&slots, &empty_t, &empty_p, r))
                        .collect();
                    let args = args?;
                    let out = (def.eval)(&args, attrs)?;
                    trace(def.name, &args, &out);
                    out
                }
                NodeKind::Fused { steps, n_temps, inputs } => {
                    launches.bump();
                    telemetry::profiler::note_launch();
                    let group_inputs: Result<Vec<Value>, String> = inputs
                        .iter()
                        .map(|r| self.read(&slots, &empty_t, &empty_p, r))
                        .collect();
                    let group_inputs = group_inputs?;
                    let mut temps: Vec<Option<Value>> = vec![None; *n_temps];
                    for step in steps {
                        let args: Result<Vec<Value>, String> = step
                            .inputs
                            .iter()
                            .map(|r| self.read(&slots, &temps, &group_inputs, r))
                            .collect();
                        let args = args?;
                        let v = (step.def.eval)(&args, &step.attrs)?;
                        trace(step.def.name, &args, &v);
                        temps[step.out_temp] = Some(v);
                    }
                    temps[*n_temps - 1].take().ok_or("empty fused result")?
                }
                NodeKind::Tuple(parts) => {
                    let vs: Result<Vec<Value>, String> = parts
                        .iter()
                        .map(|r| self.read(&slots, &empty_t, &empty_p, r))
                        .collect();
                    Value::Tuple(vs?)
                }
                NodeKind::Proj(r, i) => {
                    let v = self.read(&slots, &empty_t, &empty_p, r)?;
                    v.tuple()
                        .get(*i)
                        .cloned()
                        .ok_or_else(|| format!("proj .{i} out of range"))?
                }
                NodeKind::Copy(r) => self.read(&slots, &empty_t, &empty_p, r)?,
            };
            slots[node.out_slot] = Some(out);
        }
        self.read(&slots, &empty_t, &empty_p, &self.output)
    }

    fn read(
        &self,
        slots: &[Option<Value>],
        temps: &[Option<Value>],
        params: &[Value],
        r: &SlotRef,
    ) -> Result<Value, String> {
        match r {
            SlotRef::Arena(i) => slots[*i].clone().ok_or_else(|| format!("empty slot {i}")),
            SlotRef::Const(i) => Ok(self.constants[*i].clone()),
            SlotRef::Temp(t) => temps[*t].clone().ok_or_else(|| format!("empty temp {t}")),
            SlotRef::Param(i) => Ok(params[*i].clone()),
        }
    }

    /// Convenience: run with tensor inputs.
    pub fn run_tensors(&self, inputs: &[Tensor]) -> Result<Value, String> {
        let vs: Vec<Value> = inputs.iter().map(|t| Value::Tensor(t.clone())).collect();
        self.run(&vs)
    }
}

/// Planned-path slot read: a killed arena slot is moved out (its value's
/// last consumer is this read), anything else clones. Constants always
/// clone — the compiled program keeps its pool, so a constant can never be
/// uniquely owned and is never mutated in place.
fn read_owned(
    slots: &mut [Option<Value>],
    constants: &[Value],
    r: &SlotRef,
    kill: bool,
) -> Result<Value, String> {
    match r {
        SlotRef::Arena(i) => (if kill { slots[*i].take() } else { slots[*i].clone() })
            .ok_or_else(|| format!("empty slot {i}")),
        SlotRef::Const(i) => Ok(constants[*i].clone()),
        SlotRef::Temp(_) | SlotRef::Param(_) => {
            Err("temp/param ref outside a fused kernel".to_string())
        }
    }
}

/// Upper bound on retired buffers held per call — enough for the handful
/// of live activation shapes in a real model, small enough that a deep
/// graph never pins more than a few dead tensors.
const MAX_GRAVEYARD: usize = 8;

/// Retire a finished call's dead argument buffers into the graveyard. An
/// argument still uniquely owned *after* the kernel ran has no remaining
/// reader anywhere (kill-mask moved it out of the arena, the kernel didn't
/// keep or steal it), so its buffer can be donated to a later same-shape
/// output instead of being freed here and reallocated there.
fn bury_dead_args(args: &mut Vec<Value>, graveyard: &mut Vec<Tensor>) {
    for v in args.drain(..) {
        if let Value::Tensor(t) = v {
            if t.dtype() == crate::tensor::DType::F32 && t.is_unique() {
                if graveyard.len() >= MAX_GRAVEYARD {
                    graveyard.remove(0);
                }
                graveyard.push(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_main;
    use crate::ir::{parse_module, Module};
    use crate::pass::{optimize, OptLevel};
    use crate::tensor::Rng;

    fn mlp_module() -> Module {
        parse_module(
            "def @main(%x: Tensor[(2, 4), float32], %w1: Tensor[(8, 4), float32], %w2: Tensor[(2, 8), float32]) {\n\
               nn.dense(nn.relu(nn.dense(%x, %w1)), %w2)\n\
             }",
        )
        .unwrap()
    }

    #[test]
    fn matches_interpreter_across_levels() {
        let m = mlp_module();
        let mut rng = Rng::new(0);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let w1 = rng.normal_tensor(&[8, 4], 1.0);
        let w2 = rng.normal_tensor(&[2, 8], 1.0);
        let args = vec![
            Value::Tensor(x.clone()),
            Value::Tensor(w1.clone()),
            Value::Tensor(w2.clone()),
        ];
        let expect = eval_main(&m, args).unwrap();
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O3] {
            let opt = optimize(&m, level, false).unwrap();
            let anfed = crate::pass::anf::run(&opt);
            let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
            let out = g.run_tensors(&[x.clone(), w1.clone(), w2.clone()]).unwrap();
            assert!(
                expect.tensor().allclose(out.tensor(), 1e-4, 1e-4),
                "level {level}"
            );
        }
    }

    #[test]
    fn fusion_reduces_kernel_nodes() {
        let m = mlp_module();
        let unfused = crate::pass::anf::run(&m);
        let g0 = GraphRt::compile(unfused.def("main").unwrap()).unwrap();
        let fused = optimize(&m, OptLevel::O1, false).unwrap();
        let g1 = GraphRt::compile(fused.def("main").unwrap()).unwrap();
        assert!(
            g1.kernel_nodes < g0.kernel_nodes,
            "fused {} vs unfused {}",
            g1.kernel_nodes,
            g0.kernel_nodes
        );
        assert_eq!(g0.kernel_nodes, 3);
        assert_eq!(g1.kernel_nodes, 2); // {dense+relu}, {dense}
    }

    #[test]
    fn planned_path_matches_the_traced_baseline_and_leaves_inputs_intact() {
        let m = mlp_module();
        let mut rng = Rng::new(11);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let w1 = rng.normal_tensor(&[8, 4], 1.0);
        let w2 = rng.normal_tensor(&[2, 8], 1.0);
        let (x0, w10, w20) = (x.to_f32_vec(), w1.to_f32_vec(), w2.to_f32_vec());
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O3] {
            let opt = optimize(&m, level, false).unwrap();
            let anfed = crate::pass::anf::run(&opt);
            let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
            let args: Vec<Value> = [&x, &w1, &w2]
                .iter()
                .map(|t| Value::Tensor((*t).clone()))
                .collect();
            // Unplanned baseline (clone-everything, allocate-everything).
            let baseline = g.run_traced(&args, &mut |_, _, _| {}).unwrap();
            // Planned path, twice (the second run exercises warm workspace
            // reuse), then the owned-argument variant.
            let counter = LaunchCounter::new();
            for _ in 0..2 {
                let planned = g.run_counted(&args, &counter).unwrap();
                assert!(planned.bits_eq(&baseline), "planned diverged at {level}");
            }
            let owned = g.run_owned(args, &counter).unwrap();
            assert!(owned.bits_eq(&baseline), "owned run diverged at {level}");
            // Caller-visible tensors are never mutated by the planner.
            assert_eq!(x.to_f32_vec(), x0);
            assert_eq!(w1.to_f32_vec(), w10);
            assert_eq!(w2.to_f32_vec(), w20);
        }
    }

    #[test]
    fn owned_elementwise_chain_runs_fully_in_place() {
        // Every step's input is a dying, uniquely-owned intermediate (the
        // argument itself is handed over by value), so the whole chain
        // reuses one buffer: zero in-place misses on this thread.
        let m = parse_module(
            "def @main(%x: Tensor[(2, 2), float32]) {\n\
               let %a = tanh(%x);\n\
               let %b = negative(%a);\n\
               sigmoid(%b)\n\
             }",
        )
        .unwrap();
        let anfed = crate::pass::anf::run(&m);
        let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
        let fresh = || Value::Tensor(Tensor::from_f32(vec![2, 2], vec![-1.0, 0.5, 2.0, -0.25]));
        let expect = g.run_traced(&[fresh()], &mut |_, _, _| {}).unwrap();
        let counter = LaunchCounter::new();
        let before = crate::tensor::thread_alloc_snapshot();
        let out = g.run_owned(vec![fresh()], &counter).unwrap();
        let after = crate::tensor::thread_alloc_snapshot();
        assert!(out.bits_eq(&expect));
        assert_eq!(after.misses_since(&before), 0, "chain step fell back to allocating");
        assert_eq!(after.hits_since(&before), 3, "tanh/negative/sigmoid should all reuse");
    }

    #[test]
    fn dense_output_steals_dead_same_shape_buffer() {
        // Chained square denses: by the time the second dense runs, the
        // first one's dead inputs (same 4×4 shape as its output) sit in
        // the workspace graveyard, so its output buffer is donated rather
        // than allocated — exactly one hit, and bit-identical results.
        let m = parse_module(
            "def @main(%x: Tensor[(4, 4), float32], %w1: Tensor[(4, 4), float32], %w2: Tensor[(4, 4), float32]) {\n\
               nn.dense(nn.dense(%x, %w1), %w2)\n\
             }",
        )
        .unwrap();
        let anfed = crate::pass::anf::run(&m);
        let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
        let mk = |seed: f32| {
            Tensor::from_f32(vec![4, 4], (0..16).map(|i| seed + i as f32 * 0.125).collect())
        };
        let fresh = || {
            vec![
                Value::Tensor(mk(-1.0)),
                Value::Tensor(mk(0.5)),
                Value::Tensor(mk(2.0)),
            ]
        };
        let expect = g.run_traced(&fresh(), &mut |_, _, _| {}).unwrap();
        let counter = LaunchCounter::new();
        let before = crate::tensor::thread_alloc_snapshot();
        let out = g.run_owned(fresh(), &counter).unwrap();
        let after = crate::tensor::thread_alloc_snapshot();
        assert!(out.bits_eq(&expect), "donated dense output diverged");
        assert_eq!(
            after.hits_since(&before),
            1,
            "second dense should reuse a graveyard buffer"
        );
    }

    #[test]
    fn control_flow_rejected() {
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) { if (greater(%x, 0f)) { %x } else { negative(%x) } }",
        )
        .unwrap();
        let anfed = crate::pass::anf::run(&m);
        assert!(GraphRt::compile(anfed.def("main").unwrap()).is_err());
    }

    #[test]
    fn tuple_outputs_work() {
        let m = parse_module(
            "def @main(%x: Tensor[(2, 4), float32]) {\n\
               let %s = split(%x, indices_or_sections=2, axis=1);\n\
               add(%s.0, %s.1)\n\
             }",
        )
        .unwrap();
        let anfed = crate::pass::anf::run(&m);
        let g = GraphRt::compile(anfed.def("main").unwrap()).unwrap();
        let x = Tensor::from_f32(vec![2, 4], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let out = g.run_tensors(&[x]).unwrap();
        assert_eq!(out.tensor().as_f32(), &[4., 6., 12., 14.]);
    }
}
