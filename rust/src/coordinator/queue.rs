//! Bounded admission queue for the serving fleet.
//!
//! The front door's replacement for the raw `mpsc` channel the fleet
//! drained before PR 7: admission is **bounded** (`budget` requests may
//! wait at once; the excess is rejected at enqueue time so the caller can
//! shed it with a typed reply instead of letting the queue grow without
//! bound), consumers wait on a condvar (no fixed drain tick), and the
//! queue-depth gauge is updated *inside* the queue's own critical section,
//! so it always equals the actual queue length — it cannot drift when a
//! worker dies between a dequeue and a gauge decrement, which is exactly
//! the failure mode the old add-here/sub-there accounting had.
//!
//! [`close`](AdmissionQueue::close) starts a graceful drain: further
//! pushes are rejected with [`Reject::Closed`], but queued items keep
//! popping until the queue is empty — only then do consumers see
//! [`Pop::Closed`] and exit. Poisoned locks are ignored (a worker that
//! panicked while holding the lock must not wedge the rest of the fleet).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::telemetry::Gauge;

/// Why [`AdmissionQueue::push`] rejected an item.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reject {
    /// The queue already holds `budget` items: shed the load.
    Full,
    /// The queue is draining for shutdown: no new admissions.
    Closed,
}

/// What a consumer got back from a timed pop.
#[derive(Debug)]
pub enum Pop<T> {
    Item(T),
    /// The wait deadline passed with the queue still empty.
    Timeout,
    /// The queue is closed **and** drained: the consumer should exit.
    Closed,
}

struct State<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// A bounded MPMC queue with a budget, close-and-drain semantics, and an
/// always-exact depth gauge. See the module docs for the design.
pub struct AdmissionQueue<T> {
    state: Mutex<State<T>>,
    available: Condvar,
    budget: usize,
    depth: Arc<Gauge>,
}

fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl<T> AdmissionQueue<T> {
    /// `budget` is the admission bound: a push that would make the queue
    /// hold more than `budget` items is rejected ([`Reject::Full`]). A
    /// budget of 0 rejects everything — useful for tests and for draining
    /// a server administratively. `depth` is set to the exact queue length
    /// on every mutation.
    pub fn new(budget: usize, depth: Arc<Gauge>) -> AdmissionQueue<T> {
        depth.set(0);
        AdmissionQueue {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            available: Condvar::new(),
            budget,
            depth,
        }
    }

    /// Admit one item, or hand it back with the reason it was rejected so
    /// the caller still owns it (and can answer its reply channel).
    pub fn push(&self, item: T) -> Result<(), (T, Reject)> {
        let mut st = lock_unpoisoned(&self.state);
        if st.closed {
            return Err((item, Reject::Closed));
        }
        if st.q.len() >= self.budget {
            return Err((item, Reject::Full));
        }
        st.q.push_back(item);
        self.depth.set(st.q.len() as i64);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Wait up to `timeout` for an item.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        self.pop_until(Instant::now() + timeout)
    }

    /// Wait until `deadline` for an item. Items keep coming out of a
    /// closed queue until it is drained; only a closed **empty** queue
    /// returns [`Pop::Closed`].
    pub fn pop_until(&self, deadline: Instant) -> Pop<T> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if let Some(item) = st.q.pop_front() {
                self.depth.set(st.q.len() as i64);
                return Pop::Item(item);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::Timeout;
            }
            let (guard, _) = self
                .available
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    /// Stop admitting; wake every waiting consumer so the queue drains.
    pub fn close(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.closed = true;
        drop(st);
        self.available.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_unpoisoned(&self.state).closed
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.state).q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Re-assert the depth gauge from the actual queue length. The gauge
    /// is already updated on every push/pop under the queue lock; the
    /// supervisor calls this anyway so that even a future accounting bug
    /// (or a gauge shared more widely than intended) converges back to
    /// the truth instead of drifting forever.
    pub fn reconcile_gauge(&self) {
        let st = lock_unpoisoned(&self.state);
        self.depth.set(st.q.len() as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    fn queue(budget: usize) -> (AdmissionQueue<u32>, Arc<Gauge>) {
        let r = Registry::new();
        let g = r.gauge("relay_test_queue_depth");
        (AdmissionQueue::new(budget, g.clone()), g)
    }

    #[test]
    fn budget_bounds_admission_and_rejects_hand_the_item_back() {
        let (q, g) = queue(2);
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        assert_eq!(g.get(), 2);
        let (item, why) = q.push(3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(why, Reject::Full);
        // The rejected push did not change the depth.
        assert_eq!(g.get(), 2);
        assert_eq!(q.len(), 2);
        // Popping frees a slot; admission resumes.
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Item(1)));
        assert_eq!(g.get(), 1);
        assert!(q.push(3).is_ok());
    }

    #[test]
    fn zero_budget_rejects_everything_without_panicking() {
        let (q, g) = queue(0);
        for i in 0..100 {
            let (item, why) = q.push(i).unwrap_err();
            assert_eq!(item, i);
            assert_eq!(why, Reject::Full);
        }
        assert_eq!(g.get(), 0);
        assert!(q.is_empty());
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Timeout));
    }

    #[test]
    fn close_drains_queued_items_then_reports_closed() {
        let (q, g) = queue(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        // New admissions are refused with the shutdown reason...
        let (_, why) = q.push(3).unwrap_err();
        assert_eq!(why, Reject::Closed);
        // ...but queued items still come out, in order.
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Item(1)));
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Item(2)));
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Closed));
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn pop_until_wakes_on_push_from_another_thread() {
        let (q, _) = queue(4);
        let q = Arc::new(q);
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                q.push(7).unwrap();
            })
        };
        // Generous deadline: the pop must return the pushed item well
        // before it, woken by the condvar rather than the timeout.
        match q.pop_timeout(Duration::from_secs(10)) {
            Pop::Item(v) => assert_eq!(v, 7),
            other => panic!("expected an item, got {other:?}"),
        }
        producer.join().unwrap();
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let (q, _) = queue(4);
        let q = Arc::new(q);
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || q.pop_timeout(Duration::from_secs(10)))
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        match consumer.join().unwrap() {
            Pop::Closed => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn gauge_tracks_exact_depth_across_mixed_operations() {
        let (q, g) = queue(16);
        for i in 0..10 {
            q.push(i).unwrap();
            assert_eq!(g.get(), q.len() as i64);
        }
        for _ in 0..4 {
            let _ = q.pop_timeout(Duration::ZERO);
            assert_eq!(g.get(), q.len() as i64);
        }
        q.reconcile_gauge();
        assert_eq!(g.get(), 6);
    }
}
