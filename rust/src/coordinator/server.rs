//! Batched inference server over a compiled artifact.
//!
//! A std-thread dynamic batcher (no tokio in the vendored dep set): client
//! connections write one request per line — comma-separated f32 features —
//! and read back the predicted class. Requests are queued; a batcher
//! thread drains up to `max_batch` requests (waiting at most
//! `batch_timeout` for stragglers), pads to the artifact's batch dimension,
//! executes one PJRT call, and fans results back out. This is the router /
//! dynamic-batcher shape of serving systems, scaled to the thin-driver
//! role the paper's compiler contribution leaves for L3.
//!
//! Backends: the PJRT executable when the AOT artifact directory exists,
//! otherwise a compiled-relay MLP routed through the executor-selection
//! layer ([`crate::eval::Executor`]) — graph runtime, bytecode VM, or
//! interpreter — so serving works without the `xla` feature.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::eval::{run_with, Executor, Value};
use crate::ir::{self, Module, Type, Var};
use crate::runtime::Runtime;
use crate::tensor::{DType, Tensor};

pub struct ServerConfig {
    pub port: u16,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub artifact_dir: std::path::PathBuf,
    /// Execution tier for the compiled-relay backend, used when the AOT
    /// artifact directory is missing (so the server works — batching and
    /// all — without the `xla` feature / Python build path).
    pub executor: Executor,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7474,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            artifact_dir: "artifacts".into(),
            executor: Executor::Auto,
        }
    }
}

/// Fallback model dims for the compiled-relay backend.
const FALLBACK_FEAT: usize = 16;
const FALLBACK_HIDDEN: usize = 32;
const FALLBACK_CLASSES: usize = 4;

/// A small MLP classifier with baked-in deterministic weights, served when
/// no AOT artifact is available. Batch size is fixed so requests pad to
/// one executable shape, like the artifact path.
fn fallback_module(batch: usize) -> Module {
    let mut w = crate::zoo::Weights::new(17);
    let x = Var::fresh("x");
    let h = ir::op_call(
        "nn.relu",
        vec![ir::op_call("nn.dense", vec![ir::var(&x), w.he(&[FALLBACK_HIDDEN, FALLBACK_FEAT])])],
    );
    let logits = ir::op_call("nn.dense", vec![h, w.he(&[FALLBACK_CLASSES, FALLBACK_HIDDEN])]);
    let mut m = Module::with_prelude();
    let ty = Type::tensor(vec![batch, FALLBACK_FEAT], DType::F32);
    m.add_def("main", ir::Function::new(vec![(x, Some(ty))], logits));
    m
}

struct Request {
    features: Vec<f32>,
    respond: Sender<String>,
}

pub struct Stats {
    pub requests: AtomicUsize,
    pub batches: AtomicUsize,
}

/// Serve the `mlp_forward` artifact. Blocks; set `stop` to shut down.
///
/// Note: PJRT handles are `!Send` (the xla crate wraps raw pointers with
/// `Rc`), so the batcher thread owns the client + executable exclusively —
/// a single-executor design, with batching providing the throughput.
pub fn serve(cfg: ServerConfig, stop: Arc<AtomicBool>) -> Result<Arc<Stats>> {
    let stats = Arc::new(Stats {
        requests: AtomicUsize::new(0),
        batches: AtomicUsize::new(0),
    });

    let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
    let (ready_tx, ready_rx) = channel::<Result<()>>();

    // Batcher thread (owns the PJRT client + executable).
    {
        let stats = stats.clone();
        let stop = stop.clone();
        let artifact_dir = cfg.artifact_dir.clone();
        let max_batch = cfg.max_batch;
        let timeout = cfg.batch_timeout;
        let executor = cfg.executor;
        std::thread::spawn(move || {
            // Backend setup: PJRT over the AOT artifact when present,
            // otherwise a compiled-relay MLP routed through the
            // executor-selection layer (graph runtime / VM / interpreter).
            type ExecFn = Box<dyn FnMut(Tensor) -> Result<Vec<i64>>>;
            let setup = (|| -> Result<(usize, usize, ExecFn)> {
                if artifacts_available(&artifact_dir) {
                    let rt = Runtime::cpu()?;
                    let manifest =
                        crate::runtime::manifest::load(&artifact_dir.join("manifest.json"))
                            .map_err(|e| anyhow!("{e}"))?;
                    let entry = manifest
                        .get("mlp_forward")
                        .ok_or_else(|| anyhow!("mlp_forward not in manifest"))?
                        .clone();
                    let exe = rt.load_artifact(&artifact_dir.join("mlp_forward.hlo.txt"))?;
                    let x_spec = entry.inputs.last().unwrap().clone();
                    let (batch_cap, feat) = (x_spec.shape[0], x_spec.shape[1]);
                    let weights: Vec<Tensor> = entry.inputs[..entry.inputs.len() - 1]
                        .iter()
                        .map(|s| {
                            // Deterministic weights (a real deployment would
                            // load trained parameters; see
                            // examples/train_mlp.rs).
                            let mut rng = crate::tensor::Rng::new(17);
                            rng.normal_tensor(&s.shape, 0.1)
                        })
                        .collect();
                    let f: ExecFn = Box::new(move |x: Tensor| {
                        let mut inputs = weights.clone();
                        inputs.push(x);
                        let outs = rt.execute(&exe, &inputs)?;
                        Ok(crate::tensor::argmax(&outs[0], 1).as_i64().to_vec())
                    });
                    Ok((batch_cap, feat, f))
                } else {
                    let batch_cap = max_batch.max(1);
                    let module = fallback_module(batch_cap);
                    // Executor selection happens ONCE here; per-batch work
                    // is pure dispatch on the precompiled backend.
                    enum Backend {
                        Graph(crate::graphrt::GraphRt),
                        Prog(crate::vm::Program),
                        Interp,
                    }
                    let backend = match executor {
                        Executor::Interp => Backend::Interp,
                        Executor::Vm => Backend::Prog(
                            crate::vm::compile(&module).map_err(|e| anyhow!("{e}"))?,
                        ),
                        Executor::GraphRt | Executor::Auto => {
                            let anfed = crate::pass::anf::run(&module);
                            let main = anfed
                                .def("main")
                                .ok_or_else(|| anyhow!("fallback module lost @main"))?;
                            match crate::graphrt::GraphRt::compile(main) {
                                Ok(g) => Backend::Graph(g),
                                Err(e) if executor == Executor::GraphRt => {
                                    return Err(anyhow!("{e}"))
                                }
                                // Mirror run_with's Auto chain exactly:
                                // graphrt -> vm -> interpreter.
                                Err(_) => match crate::vm::compile_normalized(&anfed) {
                                    Ok(p) => Backend::Prog(p),
                                    Err(_) => Backend::Interp,
                                },
                            }
                        }
                    };
                    let f: ExecFn = Box::new(move |x: Tensor| {
                        let v = match &backend {
                            Backend::Graph(g) => g
                                .run(&[Value::Tensor(x)])
                                .map_err(|e| anyhow!("{e}"))?,
                            Backend::Prog(p) => crate::vm::Vm::new(p)
                                .run(vec![Value::Tensor(x)])
                                .map_err(|e| anyhow!("{e}"))?,
                            Backend::Interp => {
                                run_with(&module, Executor::Interp, vec![Value::Tensor(x)])
                                    .map_err(|e| anyhow!("{e}"))?
                                    .value
                            }
                        };
                        Ok(crate::tensor::argmax(v.tensor(), 1).as_i64().to_vec())
                    });
                    Ok((batch_cap, FALLBACK_FEAT, f))
                }
            })();
            let (batch_cap, feat, mut exec_fn) = match setup {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let cfg_batch = max_batch.min(batch_cap);
            while !stop.load(Ordering::Relaxed) {
                let first = match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(r) => r,
                    Err(_) => continue,
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + timeout;
                while batch.len() < cfg_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(_) => break,
                    }
                }
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.requests.fetch_add(batch.len(), Ordering::Relaxed);
                // Pad to the artifact's fixed batch size.
                let mut data = vec![0f32; batch_cap * feat];
                for (i, r) in batch.iter().enumerate() {
                    let row = &r.features[..feat.min(r.features.len())];
                    data[i * feat..i * feat + row.len()].copy_from_slice(row);
                }
                let x = Tensor::from_f32(vec![batch_cap, feat], data);
                let reply: Vec<String> = match exec_fn(x) {
                    Ok(preds) => {
                        (0..batch.len()).map(|i| format!("{}", preds[i])).collect()
                    }
                    Err(e) => batch.iter().map(|_| format!("error: {e}")).collect(),
                };
                for (r, out) in batch.into_iter().zip(reply) {
                    let _ = r.respond.send(out);
                }
            }
        });
    }

    // Wait for the executor to be ready (or fail fast).
    ready_rx
        .recv_timeout(Duration::from_secs(60))
        .map_err(|_| anyhow!("executor thread did not start"))??;

    // Accept loop.
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    listener.set_nonblocking(true)?;
    let stats_out = stats.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = tx.clone();
                    std::thread::spawn(move || handle_client(stream, tx));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });
    Ok(stats_out)
}

fn handle_client(stream: TcpStream, tx: Sender<Request>) {
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let mut writer = match peer {
        Ok(s) => s,
        Err(_) => return,
    };
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let features: Vec<f32> = line
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        let (rtx, rrx) = channel();
        if tx.send(Request { features, respond: rtx }).is_err() {
            break;
        }
        match rrx.recv_timeout(Duration::from_secs(5)) {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Client helper (used by examples/serve.rs and tests).
pub fn classify(port: u16, features: &[f32]) -> Result<i64> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    let line: Vec<String> = features.iter().map(|f| f.to_string()).collect();
    writeln!(stream, "{}", line.join(","))?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    resp.trim().parse().map_err(|e| anyhow!("bad response {resp:?}: {e}"))
}

/// Is the artifact directory present (CI guard)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("mlp_forward.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn fallback_backend_serves_through_the_vm() {
        let port = 7981;
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        // Skip only when this exact address is unusable (no loopback, or
        // the port is held by another process); any serve() error past
        // that (e.g. a backend compile regression) must fail the test.
        match std::net::TcpListener::bind(("127.0.0.1", port)) {
            Ok(probe) => drop(probe),
            Err(_) => return,
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..4i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 7 + j) % 5) as f32 - 2.0)
                .collect();
            let pred = classify(port, &features).expect("classify");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        assert!(stats.requests.load(Ordering::Relaxed) >= 4);
        stop.store(true, Ordering::Relaxed);
    }
}
