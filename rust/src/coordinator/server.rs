//! Batched inference server over a compiled artifact.
//!
//! A std-thread dynamic batcher (no tokio in the vendored dep set): client
//! connections write one request per line — comma-separated f32 features —
//! and read back the predicted class. Requests are queued; a batcher
//! thread drains up to `max_batch` requests (waiting at most
//! `batch_timeout` for stragglers), pads to the artifact's batch dimension,
//! executes one PJRT call, and fans results back out. This is the router /
//! dynamic-batcher shape of serving systems, scaled to the thin-driver
//! role the paper's compiler contribution leaves for L3.
//!
//! Backends: the PJRT executable when the AOT artifact directory exists,
//! otherwise a compiled-relay MLP routed through the executor-selection
//! layer ([`crate::eval::Executor`]) — graph runtime, bytecode VM, or
//! interpreter — so serving works without the `xla` feature.
//!
//! The compiled-relay backend batches into *bucketed* shapes (1, 2, 4, 8,
//! ... up to `max_batch`) instead of padding every batch to the maximum:
//! a lone request at low load runs the batch-1 program, not a padded
//! batch-32 one, cutting tail latency. Each bucket is one entry in a
//! [`crate::eval::ProgramCache`], so every shape compiles exactly once
//! over the server's lifetime (`Stats::compiles` tracks this).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::eval::{run_compiled, Compiled, Executor, ProgramCache, Value};
use crate::ir::{self, Module, Type, Var};
use crate::runtime::Runtime;
use crate::tensor::{DType, Tensor};

pub struct ServerConfig {
    pub port: u16,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub artifact_dir: std::path::PathBuf,
    /// Execution tier for the compiled-relay backend, used when the AOT
    /// artifact directory is missing (so the server works — batching and
    /// all — without the `xla` feature / Python build path).
    pub executor: Executor,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7474,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            artifact_dir: "artifacts".into(),
            executor: Executor::Auto,
        }
    }
}

/// Fallback model dims for the compiled-relay backend.
const FALLBACK_FEAT: usize = 16;
const FALLBACK_HIDDEN: usize = 32;
const FALLBACK_CLASSES: usize = 4;

/// A small MLP classifier with baked-in deterministic weights, served when
/// no AOT artifact is available. Batch size is fixed so requests pad to
/// one executable shape, like the artifact path.
fn fallback_module(batch: usize) -> Module {
    let mut w = crate::zoo::Weights::new(17);
    let x = Var::fresh("x");
    let h = ir::op_call(
        "nn.relu",
        vec![ir::op_call("nn.dense", vec![ir::var(&x), w.he(&[FALLBACK_HIDDEN, FALLBACK_FEAT])])],
    );
    let logits = ir::op_call("nn.dense", vec![h, w.he(&[FALLBACK_CLASSES, FALLBACK_HIDDEN])]);
    let mut m = Module::with_prelude();
    let ty = Type::tensor(vec![batch, FALLBACK_FEAT], DType::F32);
    m.add_def("main", ir::Function::new(vec![(x, Some(ty))], logits));
    m
}

struct Request {
    features: Vec<f32>,
    respond: Sender<String>,
}

/// Zero-pad feature rows into a `(batch, feat)` input tensor. Rows longer
/// than `feat` are truncated, shorter ones zero-filled. Takes borrowed
/// slices so the batcher's hot path copies each row exactly once.
fn pad_rows(rows: &[&[f32]], batch: usize, feat: usize) -> Tensor {
    let mut data = vec![0f32; batch * feat];
    for (i, r) in rows.iter().enumerate().take(batch) {
        let row = &r[..feat.min(r.len())];
        data[i * feat..i * feat + row.len()].copy_from_slice(row);
    }
    Tensor::from_f32(vec![batch, feat], data)
}

pub struct Stats {
    pub requests: AtomicUsize,
    pub batches: AtomicUsize,
    /// Backend compiles performed so far (compiled-relay backend: program-
    /// cache misses — at most one per batch bucket over the server's life).
    pub compiles: AtomicUsize,
}

/// Batch-shape buckets: powers of two up to (and always including) `cap`.
/// A batch of n requests pads to the smallest bucket >= n.
fn bucket_sizes(cap: usize) -> Vec<usize> {
    let cap = cap.max(1);
    let mut out = Vec::new();
    let mut b = 1usize;
    while b < cap {
        out.push(b);
        b *= 2;
    }
    out.push(cap);
    out
}

/// Serve the `mlp_forward` artifact. Blocks; set `stop` to shut down.
///
/// Note: PJRT handles are `!Send` (the xla crate wraps raw pointers with
/// `Rc`), so the batcher thread owns the client + executable exclusively —
/// a single-executor design, with batching providing the throughput.
pub fn serve(cfg: ServerConfig, stop: Arc<AtomicBool>) -> Result<Arc<Stats>> {
    let stats = Arc::new(Stats {
        requests: AtomicUsize::new(0),
        batches: AtomicUsize::new(0),
        compiles: AtomicUsize::new(0),
    });

    let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
    let (ready_tx, ready_rx) = channel::<Result<()>>();

    // Batcher thread (owns the PJRT client + executable).
    {
        let stats = stats.clone();
        let stop = stop.clone();
        let artifact_dir = cfg.artifact_dir.clone();
        let max_batch = cfg.max_batch;
        let timeout = cfg.batch_timeout;
        let executor = cfg.executor;
        std::thread::spawn(move || {
            // Backend setup: PJRT over the AOT artifact when present,
            // otherwise a compiled-relay MLP compiled through the shared
            // executor-selection + program-cache chain ([`crate::eval`]).
            // Each backend consumes the raw feature rows of a batch and
            // returns one prediction per row (padding is backend-specific:
            // PJRT pads to the artifact's fixed batch, the relay backend
            // pads to the nearest bucket).
            type ExecFn = Box<dyn FnMut(&[&[f32]]) -> Result<Vec<i64>>>;
            let setup = (|| -> Result<(usize, ExecFn)> {
                if artifacts_available(&artifact_dir) {
                    let rt = Runtime::cpu()?;
                    let manifest =
                        crate::runtime::manifest::load(&artifact_dir.join("manifest.json"))
                            .map_err(|e| anyhow!("{e}"))?;
                    let entry = manifest
                        .get("mlp_forward")
                        .ok_or_else(|| anyhow!("mlp_forward not in manifest"))?
                        .clone();
                    let exe = rt.load_artifact(&artifact_dir.join("mlp_forward.hlo.txt"))?;
                    let x_spec = entry
                        .inputs
                        .last()
                        .ok_or_else(|| {
                            anyhow!(
                                "manifest entry mlp_forward has an empty inputs \
                                 list (expected [weights..., x])"
                            )
                        })?
                        .clone();
                    if x_spec.shape.len() < 2 {
                        return Err(anyhow!(
                            "mlp_forward input spec must be (batch, feat), got {:?}",
                            x_spec.shape
                        ));
                    }
                    let (batch_cap, feat) = (x_spec.shape[0], x_spec.shape[1]);
                    // Deterministic weights (a real deployment would load
                    // trained parameters; see examples/train_mlp.rs). One
                    // RNG across all weights: re-seeding inside the closure
                    // would hand every tensor the same value stream.
                    let mut rng = crate::tensor::Rng::new(17);
                    let weights: Vec<Tensor> = entry.inputs[..entry.inputs.len() - 1]
                        .iter()
                        .map(|s| rng.normal_tensor(&s.shape, 0.1))
                        .collect();
                    let f: ExecFn = Box::new(move |rows: &[&[f32]]| {
                        let x = pad_rows(rows, batch_cap, feat);
                        let mut inputs = weights.clone();
                        inputs.push(x);
                        let outs = rt.execute(&exe, &inputs)?;
                        Ok(crate::tensor::argmax(&outs[0], 1).as_i64().to_vec())
                    });
                    Ok((batch_cap, f))
                } else {
                    let batch_cap = max_batch.max(1);
                    // One module per batch bucket, all sharing one program
                    // cache: a bucket compiles on first use, then every
                    // batch of that shape is pure dispatch. This is the
                    // same selection+cache chain `run_auto` uses — the
                    // server no longer hand-rolls its own backend enum.
                    let cache = ProgramCache::new();
                    let modules: Vec<(usize, Module)> = bucket_sizes(batch_cap)
                        .into_iter()
                        .map(|b| (b, fallback_module(b)))
                        .collect();
                    // Fail fast at startup: compile the smallest bucket so
                    // a backend regression surfaces before serving.
                    cache
                        .get_or_compile(&modules[0].1, executor)
                        .map_err(|e| anyhow!("{e}"))?;
                    let stats = stats.clone();
                    // Per-bucket memo of the resolved program: the cache
                    // lookup (hash + structural verify) runs once per
                    // bucket; every later batch of that shape is pure
                    // dispatch on the compiled artifact.
                    let mut resolved: Vec<Option<Compiled>> = vec![None; modules.len()];
                    let f: ExecFn = Box::new(move |rows: &[&[f32]]| {
                        let bi = modules
                            .iter()
                            .position(|(b, _)| *b >= rows.len())
                            .unwrap_or(modules.len() - 1);
                        let (bucket, module) = &modules[bi];
                        if resolved[bi].is_none() {
                            resolved[bi] = Some(
                                cache
                                    .get_or_compile(module, executor)
                                    .map_err(|e| anyhow!("{e}"))?,
                            );
                            stats.compiles.store(cache.misses(), Ordering::Relaxed);
                        }
                        let compiled =
                            resolved[bi].as_ref().expect("bucket resolved above");
                        let x = pad_rows(rows, *bucket, FALLBACK_FEAT);
                        let out =
                            run_compiled(compiled, module, vec![Value::Tensor(x)])
                                .map_err(|e| anyhow!("{e}"))?;
                        Ok(crate::tensor::argmax(out.value.tensor(), 1).as_i64().to_vec())
                    });
                    Ok((batch_cap, f))
                }
            })();
            let (batch_cap, mut exec_fn) = match setup {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let cfg_batch = max_batch.min(batch_cap).max(1);
            while !stop.load(Ordering::Relaxed) {
                let first = match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(r) => r,
                    Err(_) => continue,
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + timeout;
                while batch.len() < cfg_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(_) => break,
                    }
                }
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.requests.fetch_add(batch.len(), Ordering::Relaxed);
                let rows: Vec<&[f32]> =
                    batch.iter().map(|r| r.features.as_slice()).collect();
                let reply: Vec<String> = match exec_fn(&rows) {
                    Ok(preds) => {
                        (0..batch.len()).map(|i| format!("{}", preds[i])).collect()
                    }
                    Err(e) => batch.iter().map(|_| format!("error: {e}")).collect(),
                };
                for (r, out) in batch.into_iter().zip(reply) {
                    let _ = r.respond.send(out);
                }
            }
        });
    }

    // Wait for the executor to be ready (or fail fast).
    ready_rx
        .recv_timeout(Duration::from_secs(60))
        .map_err(|_| anyhow!("executor thread did not start"))??;

    // Accept loop.
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    listener.set_nonblocking(true)?;
    let stats_out = stats.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = tx.clone();
                    std::thread::spawn(move || handle_client(stream, tx));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });
    Ok(stats_out)
}

fn handle_client(stream: TcpStream, tx: Sender<Request>) {
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let mut writer = match peer {
        Ok(s) => s,
        Err(_) => return,
    };
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let features: Vec<f32> = line
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        let (rtx, rrx) = channel();
        if tx.send(Request { features, respond: rtx }).is_err() {
            break;
        }
        match rrx.recv_timeout(Duration::from_secs(5)) {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Client helper (used by examples/serve.rs and tests).
pub fn classify(port: u16, features: &[f32]) -> Result<i64> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    let line: Vec<String> = features.iter().map(|f| f.to_string()).collect();
    writeln!(stream, "{}", line.join(","))?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    resp.trim().parse().map_err(|e| anyhow!("bad response {resp:?}: {e}"))
}

/// Is the artifact directory present (CI guard)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("mlp_forward.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn bucket_sizes_are_powers_of_two_up_to_cap() {
        assert_eq!(bucket_sizes(1), vec![1]);
        assert_eq!(bucket_sizes(4), vec![1, 2, 4]);
        assert_eq!(bucket_sizes(8), vec![1, 2, 4, 8]);
        // Non-power-of-two cap is kept as the final bucket.
        assert_eq!(bucket_sizes(6), vec![1, 2, 4, 6]);
        assert_eq!(bucket_sizes(0), vec![1]);
    }

    #[test]
    fn pad_rows_pads_and_truncates() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32];
        let rows: Vec<&[f32]> = vec![&a, &b];
        let t = pad_rows(&rows, 4, 2);
        assert_eq!(t.shape(), &[4, 2]);
        assert_eq!(t.as_f32(), &[1.0, 2.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn fallback_backend_serves_through_the_vm() {
        let port = 7981;
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        // Skip only when this exact address is unusable (no loopback, or
        // the port is held by another process); any serve() error past
        // that (e.g. a backend compile regression) must fail the test.
        match std::net::TcpListener::bind(("127.0.0.1", port)) {
            Ok(probe) => drop(probe),
            Err(_) => return,
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..4i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 7 + j) % 5) as f32 - 2.0)
                .collect();
            let pred = classify(port, &features).expect("classify");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        assert!(stats.requests.load(Ordering::Relaxed) >= 4);
        // Sequential clients mean every batch had size 1, so only the
        // batch-1 bucket compiled: 4 requests, exactly 1 compile — the
        // compile-once serving property of the program cache.
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 1);
        stop.store(true, Ordering::Relaxed);
    }
}
