//! Batched inference server over a compiled artifact.
//!
//! A std-thread dynamic batcher (no tokio in the vendored dep set): client
//! connections write one request per line — comma-separated f32 features —
//! and read back the predicted class. Requests are queued; a batcher
//! thread drains up to `max_batch` requests (waiting at most
//! `batch_timeout` for stragglers), pads to the artifact's batch dimension,
//! executes one PJRT call, and fans results back out. This is the router /
//! dynamic-batcher shape of serving systems, scaled to the thin-driver
//! role the paper's compiler contribution leaves for L3.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct ServerConfig {
    pub port: u16,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub artifact_dir: std::path::PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7474,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            artifact_dir: "artifacts".into(),
        }
    }
}

struct Request {
    features: Vec<f32>,
    respond: Sender<String>,
}

pub struct Stats {
    pub requests: AtomicUsize,
    pub batches: AtomicUsize,
}

/// Serve the `mlp_forward` artifact. Blocks; set `stop` to shut down.
///
/// Note: PJRT handles are `!Send` (the xla crate wraps raw pointers with
/// `Rc`), so the batcher thread owns the client + executable exclusively —
/// a single-executor design, with batching providing the throughput.
pub fn serve(cfg: ServerConfig, stop: Arc<AtomicBool>) -> Result<Arc<Stats>> {
    let stats = Arc::new(Stats {
        requests: AtomicUsize::new(0),
        batches: AtomicUsize::new(0),
    });

    let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
    let (ready_tx, ready_rx) = channel::<Result<()>>();

    // Batcher thread (owns the PJRT client + executable).
    {
        let stats = stats.clone();
        let stop = stop.clone();
        let artifact_dir = cfg.artifact_dir.clone();
        let max_batch = cfg.max_batch;
        let timeout = cfg.batch_timeout;
        std::thread::spawn(move || {
            let setup = (|| -> Result<_> {
                let rt = Runtime::cpu()?;
                let manifest =
                    crate::runtime::manifest::load(&artifact_dir.join("manifest.json"))
                        .map_err(|e| anyhow!("{e}"))?;
                let entry = manifest
                    .get("mlp_forward")
                    .ok_or_else(|| anyhow!("mlp_forward not in manifest"))?
                    .clone();
                let exe = rt.load_artifact(&artifact_dir.join("mlp_forward.hlo.txt"))?;
                Ok((rt, entry, exe))
            })();
            let (rt, entry, exe) = match setup {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let x_spec = entry.inputs.last().unwrap().clone();
            let (batch_cap, feat) = (x_spec.shape[0], x_spec.shape[1]);
            let weights: Vec<Tensor> = entry.inputs[..entry.inputs.len() - 1]
                .iter()
                .map(|s| {
                    // Deterministic weights (a real deployment would load
                    // trained parameters; see examples/train_mlp.rs).
                    let mut rng = crate::tensor::Rng::new(17);
                    rng.normal_tensor(&s.shape, 0.1)
                })
                .collect();
            let cfg_batch = max_batch.min(batch_cap);
            while !stop.load(Ordering::Relaxed) {
                let first = match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(r) => r,
                    Err(_) => continue,
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + timeout;
                while batch.len() < cfg_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(_) => break,
                    }
                }
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.requests.fetch_add(batch.len(), Ordering::Relaxed);
                // Pad to the artifact's fixed batch size.
                let mut data = vec![0f32; batch_cap * feat];
                for (i, r) in batch.iter().enumerate() {
                    let row = &r.features[..feat.min(r.features.len())];
                    data[i * feat..i * feat + row.len()].copy_from_slice(row);
                }
                let x = Tensor::from_f32(vec![batch_cap, feat], data);
                let mut inputs = weights.clone();
                inputs.push(x);
                let reply: Vec<String> = match rt.execute(&exe, &inputs) {
                    Ok(outs) => {
                        let logits = &outs[0];
                        let preds = crate::tensor::argmax(logits, 1);
                        (0..batch.len())
                            .map(|i| format!("{}", preds.as_i64()[i]))
                            .collect()
                    }
                    Err(e) => batch.iter().map(|_| format!("error: {e}")).collect(),
                };
                for (r, out) in batch.into_iter().zip(reply) {
                    let _ = r.respond.send(out);
                }
            }
        });
    }

    // Wait for the executor to be ready (or fail fast).
    ready_rx
        .recv_timeout(Duration::from_secs(60))
        .map_err(|_| anyhow!("executor thread did not start"))??;

    // Accept loop.
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    listener.set_nonblocking(true)?;
    let stats_out = stats.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = tx.clone();
                    std::thread::spawn(move || handle_client(stream, tx));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });
    Ok(stats_out)
}

fn handle_client(stream: TcpStream, tx: Sender<Request>) {
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let mut writer = match peer {
        Ok(s) => s,
        Err(_) => return,
    };
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let features: Vec<f32> = line
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        let (rtx, rrx) = channel();
        if tx.send(Request { features, respond: rtx }).is_err() {
            break;
        }
        match rrx.recv_timeout(Duration::from_secs(5)) {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Client helper (used by examples/serve.rs and tests).
pub fn classify(port: u16, features: &[f32]) -> Result<i64> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    let line: Vec<String> = features.iter().map(|f| f.to_string()).collect();
    writeln!(stream, "{}", line.join(","))?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    resp.trim().parse().map_err(|e| anyhow!("bad response {resp:?}: {e}"))
}

/// Is the artifact directory present (CI guard)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("mlp_forward.hlo.txt").exists()
}
