//! Batched inference server over a compiled artifact.
//!
//! A std-thread dynamic batcher (no tokio in the vendored dep set): client
//! connections write one request per line — comma-separated f32 features —
//! and read back the predicted class. Requests are queued; a fleet of
//! worker threads drains up to `max_batch` requests per batch (waiting at
//! most `batch_timeout` for stragglers), pads to a bucketed batch shape,
//! executes one compiled-program call, and fans results back out. This is
//! the router / dynamic-batcher shape of serving systems, scaled to the
//! thin-driver role the paper's compiler contribution leaves for L3.
//!
//! Backends: the PJRT executable when the AOT artifact directory exists
//! (single worker — PJRT handles are `!Send`), otherwise a compiled-relay
//! MLP ([`RelayBackend`]) routed through the executor-selection layer
//! ([`crate::eval::Executor`]) — graph runtime, bytecode VM, or
//! interpreter — so serving works without the `xla` feature.
//!
//! The compiled-relay backend batches into *bucketed* shapes (1, 2, 4, 8,
//! ... up to `max_batch`) instead of padding every batch to the maximum:
//! a lone request at low load runs the batch-1 program, not a padded
//! batch-32 one, cutting tail latency. Each bucket is one entry in a
//! [`crate::eval::ProgramCache`] **shared by every worker**: values and
//! compiled programs are `Send + Sync` (`Arc`-backed), so the whole
//! N-worker fleet compiles each bucket exactly once over the server's
//! lifetime (`Stats::compiles` tracks this fleet-wide; the cache coalesces
//! two workers racing on the same cold bucket into one compile).
//!
//! Buckets compile **through the full optimizing pipeline** at
//! [`ServerConfig::opt_level`] (default -O3, the `--opt` CLI flag): the
//! fleet serves fused kernels, not the bare ANF the pre-refactor batcher
//! executed. [`Stats::opt_level`] records what the fleet is running.
//!
//! Every request carries a [`RequestSpan`]: queue-wait, batch-form,
//! compile (hit or miss), and execute durations, rolled into the
//! process-wide [`crate::telemetry`] registry (one histogram family per
//! phase, labeled by port so co-resident servers stay separable) and
//! optionally streamed to a [`SpanSink`] ([`ServerConfig::trace`], the
//! `--trace-json` chrome://tracing writer). The same TCP front door that
//! takes CSV feature lines answers `GET /metrics` with the rendered
//! registry, so `curl` and `relay metrics` need no second port.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::eval::{run_compiled, CompileOptions, Executor, ProgramCache, Value};
use crate::ir::{self, Module, Type, Var};
use crate::pass::OptLevel;
use crate::runtime::Runtime;
use crate::telemetry::registry::names;
use crate::telemetry::{Counter, Gauge, Histogram, RequestSpan, SpanSink};
use crate::tensor::{DType, Tensor};

pub struct ServerConfig {
    pub port: u16,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub artifact_dir: std::path::PathBuf,
    /// Execution tier for the compiled-relay backend, used when the AOT
    /// artifact directory is missing (so the server works — batching and
    /// all — without the `xla` feature / Python build path).
    pub executor: Executor,
    /// Optimization level the per-bucket modules compile at (`--opt`,
    /// default -O3: the serving fleet runs fused kernels).
    pub opt_level: OptLevel,
    /// Run the fixpoint FoldConstant/DCE loop when compiling buckets
    /// (`--fixpoint`): more compile time per bucket — paid once per bucket
    /// over the server's life — for a fully-converged artifact. Part of
    /// the program-cache key, so fixpoint and plain artifacts coexist.
    pub fixpoint: bool,
    /// Worker threads draining the request queue (compiled-relay backend).
    /// The PJRT backend is pinned to one worker: its handles are `!Send`.
    pub workers: usize,
    /// Optional sink every completed [`RequestSpan`] is streamed to, on
    /// top of the always-on registry histograms (`--trace-json` wires a
    /// [`crate::telemetry::ChromeTraceWriter`] here; tests use
    /// [`crate::telemetry::MemorySpans`]).
    pub trace: Option<Arc<dyn SpanSink>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7474,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            artifact_dir: "artifacts".into(),
            executor: Executor::Auto,
            opt_level: OptLevel::O3,
            fixpoint: false,
            workers: 4,
            trace: None,
        }
    }
}

/// Fallback model dims for the compiled-relay backend.
const FALLBACK_FEAT: usize = 16;
const FALLBACK_HIDDEN: usize = 32;
const FALLBACK_CLASSES: usize = 4;

/// A small MLP classifier with baked-in deterministic weights, served when
/// no AOT artifact is available. Batch size is fixed so requests pad to
/// one executable shape, like the artifact path.
fn fallback_module(batch: usize) -> Module {
    let mut w = crate::zoo::Weights::new(17);
    let x = Var::fresh("x");
    let h = ir::op_call(
        "nn.relu",
        vec![ir::op_call("nn.dense", vec![ir::var(&x), w.he(&[FALLBACK_HIDDEN, FALLBACK_FEAT])])],
    );
    let logits = ir::op_call("nn.dense", vec![h, w.he(&[FALLBACK_CLASSES, FALLBACK_HIDDEN])]);
    let mut m = Module::with_prelude();
    let ty = Type::tensor(vec![batch, FALLBACK_FEAT], DType::F32);
    m.add_def("main", ir::Function::new(vec![(x, Some(ty))], logits));
    m
}

struct Request {
    /// Process-unique id, carried into the request's span.
    id: u64,
    features: Vec<f32>,
    respond: Sender<String>,
    /// When the client handler put this request on the queue; every span
    /// phase is measured from here.
    enqueued: Instant,
}

fn next_request_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// The fleet's handles into the process-wide telemetry registry, resolved
/// once per [`serve`] call. Every series is labeled by port: two servers
/// in one process (common in tests) each get exact per-port counts
/// instead of one merged stream.
struct ServeTelemetry {
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    /// Requests enqueued but not yet drained by a worker.
    queue_depth: Arc<Gauge>,
    request_h: Arc<Histogram>,
    queue_wait_h: Arc<Histogram>,
    batch_form_h: Arc<Histogram>,
    compile_h: Arc<Histogram>,
    execute_h: Arc<Histogram>,
    sink: Option<Arc<dyn SpanSink>>,
}

impl ServeTelemetry {
    fn register(port: u16, sink: Option<Arc<dyn SpanSink>>) -> ServeTelemetry {
        let r = crate::telemetry::registry();
        let p = port.to_string();
        let labels: &[(&str, &str)] = &[("port", &p)];
        ServeTelemetry {
            requests: r.counter_with(names::REQUESTS_TOTAL, labels),
            batches: r.counter_with(names::BATCHES_TOTAL, labels),
            queue_depth: r.gauge_with(names::QUEUE_DEPTH, labels),
            request_h: r.histogram_with(names::REQUEST_SECONDS, labels),
            queue_wait_h: r.histogram_with(names::QUEUE_WAIT_SECONDS, labels),
            batch_form_h: r.histogram_with(names::BATCH_FORM_SECONDS, labels),
            compile_h: r.histogram_with(names::COMPILE_SECONDS, labels),
            execute_h: r.histogram_with(names::EXECUTE_SECONDS, labels),
            sink,
        }
    }

    /// Record one finished request: histograms always, sink when present.
    /// Compile time lands in the compile histogram only when this batch
    /// actually paid it — cache hits would flood the p50 with zeros.
    fn record(&self, span: &RequestSpan) {
        self.request_h.observe_duration(span.total);
        self.queue_wait_h.observe_duration(span.queue_wait);
        self.batch_form_h.observe_duration(span.batch_form);
        self.execute_h.observe_duration(span.execute);
        if !span.compile_hit {
            self.compile_h.observe_duration(span.compile);
        }
        if let Some(sink) = &self.sink {
            sink.record(span);
        }
    }
}

/// What one backend execution reports back to the batcher: predictions
/// plus where the time went, so the worker can split its wall clock into
/// compile and execute span phases.
pub struct BatchRun {
    pub preds: Vec<i64>,
    /// Compile time this batch paid (zero when its program was already
    /// resolved).
    pub compile: Duration,
    /// True when the program came from a memo or cache rather than being
    /// compiled by this call.
    pub compile_hit: bool,
}

/// Zero-pad feature rows into a `(batch, feat)` input tensor. Rows longer
/// than `feat` are truncated, shorter ones zero-filled. Takes borrowed
/// slices so the batcher's hot path copies each row exactly once.
fn pad_rows(rows: &[&[f32]], batch: usize, feat: usize) -> Tensor {
    let mut data = vec![0f32; batch * feat];
    for (i, r) in rows.iter().enumerate().take(batch) {
        let row = &r[..feat.min(r.len())];
        data[i * feat..i * feat + row.len()].copy_from_slice(row);
    }
    Tensor::from_f32(vec![batch, feat], data)
}

pub struct Stats {
    pub requests: AtomicUsize,
    pub batches: AtomicUsize,
    /// Backend compiles performed so far, fleet-wide (compiled-relay
    /// backend: at most one per batch bucket over the server's life,
    /// no matter how many workers race on a cold bucket). Mirrored into
    /// the registry's `relay_compiles_total`; this per-instance copy keeps
    /// tests exact when several servers share the process.
    pub compiles: AtomicUsize,
    /// Optimization level the backend compiles at (fixed per server).
    pub opt_level: OptLevel,
    /// Whether bucket compiles run the fixpoint cleanup loop.
    pub fixpoint: bool,
    /// Requests served per worker thread (len == worker count).
    pub per_worker: Vec<AtomicUsize>,
    /// Process-wide allocation counters at server start; the memory
    /// planner's hits/misses over the server's lifetime are reported as
    /// deltas from here ([`Stats::inplace_hits`]).
    alloc_base: crate::tensor::AllocSnapshot,
}

impl Stats {
    pub fn new(workers: usize, opt_level: OptLevel) -> Stats {
        Stats {
            requests: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            compiles: AtomicUsize::new(0),
            opt_level,
            fixpoint: false,
            per_worker: (0..workers.max(1)).map(|_| AtomicUsize::new(0)).collect(),
            alloc_base: crate::tensor::alloc_stats().snapshot(),
        }
    }

    /// In-place kernel reuses since the server started (the memory
    /// planner's output-buffer allocations *avoided*). Deltas over the
    /// registry's process-wide `relay_inplace_hits_total` counter, so
    /// co-resident non-serving executions are included.
    pub fn inplace_hits(&self) -> usize {
        crate::tensor::alloc_stats().snapshot().hits_since(&self.alloc_base)
    }

    /// Eligible kernels that fell back to allocating since server start.
    pub fn inplace_misses(&self) -> usize {
        crate::tensor::alloc_stats().snapshot().misses_since(&self.alloc_base)
    }
}

/// Batch-shape buckets: powers of two up to (and always including) `cap`.
/// A batch of n requests pads to the smallest bucket >= n.
fn bucket_sizes(cap: usize) -> Vec<usize> {
    let cap = cap.max(1);
    let mut out = Vec::new();
    let mut b = 1usize;
    while b < cap {
        out.push(b);
        b *= 2;
    }
    out.push(cap);
    out
}

/// The compiled-relay serving backend: one fallback-MLP module per batch
/// bucket, all compiled through one shared [`ProgramCache`].
///
/// `Send + Sync`: any number of worker threads may call [`run_batch`]
/// concurrently — compiled programs are `Arc`-backed immutable data, and
/// the cache coalesces racing misses so each bucket compiles at most once
/// for the whole fleet ([`Stats::compiles`] counts exactly the calls that
/// actually compiled).
///
/// [`run_batch`]: RelayBackend::run_batch
pub struct RelayBackend {
    buckets: Vec<Bucket>,
    cache: Arc<ProgramCache>,
    /// Executor + optimization level every bucket compiles with.
    opts: CompileOptions,
    stats: Arc<Stats>,
}

struct Bucket {
    /// Batch size this bucket's module is fixed to.
    size: usize,
    module: Module,
    /// Memo of the cache-resolved program: after first use, a batch of
    /// this shape is pure dispatch — no cache lock, no structural-hash
    /// lookup, no hit verification.
    resolved: std::sync::OnceLock<crate::eval::Compiled>,
}

impl RelayBackend {
    /// Build the per-bucket modules and fail fast by compiling the
    /// smallest bucket, so a backend regression surfaces before serving.
    /// `opts` sets executor *and* optimization level (a bare [`Executor`]
    /// selects the default -O3).
    pub fn new(
        max_batch: usize,
        opts: impl Into<CompileOptions>,
        cache: Arc<ProgramCache>,
        stats: Arc<Stats>,
    ) -> Result<RelayBackend> {
        let buckets: Vec<Bucket> = bucket_sizes(max_batch.max(1))
            .into_iter()
            .map(|size| Bucket {
                size,
                module: fallback_module(size),
                resolved: std::sync::OnceLock::new(),
            })
            .collect();
        let backend = RelayBackend { buckets, cache, opts: opts.into(), stats };
        backend.compiled_bucket(0)?;
        Ok(backend)
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Resolve one bucket: per-bucket memo first, then the shared cache —
    /// counting a fleet-wide compile only when this call performed it.
    /// Two workers racing on a cold bucket both reach the cache, which
    /// coalesces them into one compile; the memo keeps every later batch
    /// off the cache lock entirely.
    fn compiled_bucket(&self, bi: usize) -> Result<crate::eval::Compiled> {
        self.compiled_bucket_timed(bi).map(|(compiled, _, _)| compiled)
    }

    /// [`compiled_bucket`](Self::compiled_bucket) plus how long resolution
    /// took and whether it was a hit (memo or cache — a racing worker that
    /// blocked on someone else's compile reports the wait as a hit, since
    /// it paid wall time but no compile happened on its behalf twice).
    fn compiled_bucket_timed(
        &self,
        bi: usize,
    ) -> Result<(crate::eval::Compiled, Duration, bool)> {
        let bucket = &self.buckets[bi];
        if let Some(compiled) = bucket.resolved.get() {
            return Ok((compiled.clone(), Duration::ZERO, true));
        }
        let t0 = Instant::now();
        let (compiled, compiled_now) = self
            .cache
            .get_or_compile_traced(&bucket.module, self.opts)
            .map_err(|e| anyhow!("{e}"))?;
        let took = t0.elapsed();
        if compiled_now {
            self.stats.compiles.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::registry().counter(names::COMPILES_TOTAL).inc();
        }
        let _ = bucket.resolved.set(compiled.clone());
        Ok((compiled, took, !compiled_now))
    }

    /// Execute one batch of feature rows; returns one prediction per row.
    /// The batch must fit the largest bucket (`serve`'s workers cap their
    /// batches at `max_batch`, so this only trips for external callers).
    pub fn run_batch(&self, rows: &[&[f32]]) -> Result<Vec<i64>> {
        self.run_batch_timed(rows).map(|b| b.preds)
    }

    /// [`run_batch`](Self::run_batch) with the timing breakdown the
    /// batcher needs for request spans.
    pub fn run_batch_timed(&self, rows: &[&[f32]]) -> Result<BatchRun> {
        let cap = self.buckets.last().map_or(0, |b| b.size);
        if rows.len() > cap {
            return Err(anyhow!(
                "batch of {} rows exceeds the largest bucket ({cap})",
                rows.len()
            ));
        }
        let bi = self
            .buckets
            .iter()
            .position(|b| b.size >= rows.len())
            .unwrap_or(self.buckets.len() - 1);
        let (compiled, compile, compile_hit) = self.compiled_bucket_timed(bi)?;
        let bucket = &self.buckets[bi];
        let x = pad_rows(rows, bucket.size, FALLBACK_FEAT);
        let out = run_compiled(&compiled, vec![Value::Tensor(x)])
            .map_err(|e| anyhow!("{e}"))?;
        let preds = crate::tensor::argmax(out.value.tensor(), 1);
        let preds = preds.as_i64();
        Ok(BatchRun {
            preds: preds[..rows.len().min(preds.len())].to_vec(),
            compile,
            compile_hit,
        })
    }
}

/// One batcher worker: drain a batch from the shared queue (the lock is
/// held only while collecting; execution overlaps across workers), run the
/// backend, fan replies out, then record each request's span.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: usize,
    rx: &Mutex<Receiver<Request>>,
    stop: &AtomicBool,
    stats: &Stats,
    tele: &ServeTelemetry,
    max_batch: usize,
    timeout: Duration,
    mut exec: impl FnMut(&[&[f32]]) -> Result<BatchRun>,
) {
    while !stop.load(Ordering::Relaxed) {
        // Each request is paired with the instant this worker drained it:
        // queue-wait ends and batch-form begins there.
        let (batch, batch_ready) = {
            let queue = crate::eval::value::lock_unpoisoned(rx);
            let first = match queue.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => r,
                Err(_) => continue,
            };
            tele.queue_depth.sub(1);
            let mut batch = vec![(first, Instant::now())];
            let deadline = Instant::now() + timeout;
            while batch.len() < max_batch {
                // `saturating_duration_since`, not `deadline - now`: with a
                // zero-slack `batch_timeout` (or a deadline that passes
                // between the loop check and the subtraction) a bare
                // subtraction panics.
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match queue.recv_timeout(remaining) {
                    Ok(r) => {
                        tele.queue_depth.sub(1);
                        batch.push((r, Instant::now()));
                    }
                    Err(_) => break,
                }
            }
            let ready = Instant::now();
            (batch, ready)
        };
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.requests.fetch_add(batch.len(), Ordering::Relaxed);
        stats.per_worker[worker].fetch_add(batch.len(), Ordering::Relaxed);
        tele.batches.inc();
        tele.requests.add(batch.len() as u64);
        let rows: Vec<&[f32]> =
            batch.iter().map(|(r, _)| r.features.as_slice()).collect();
        let exec_start = Instant::now();
        let run = exec(&rows);
        let exec_total = exec_start.elapsed();
        let (reply, compile, compile_hit): (Vec<String>, Duration, bool) = match &run {
            Ok(b) => (
                (0..batch.len())
                    .map(|i| match b.preds.get(i) {
                        Some(p) => format!("{p}"),
                        None => "error: missing prediction".to_string(),
                    })
                    .collect(),
                b.compile,
                b.compile_hit,
            ),
            Err(e) => (
                batch.iter().map(|_| format!("error: {e}")).collect(),
                Duration::ZERO,
                true,
            ),
        };
        let execute = exec_total.saturating_sub(compile);
        let batch_size = batch.len();
        for ((req, drained), out) in batch.into_iter().zip(reply) {
            // Reply first — telemetry must never sit between a prediction
            // and the client waiting on it.
            let _ = req.respond.send(out);
            let span = RequestSpan {
                id: req.id,
                worker,
                batch_size,
                enqueued_us: crate::telemetry::span::micros_since_epoch(req.enqueued),
                queue_wait: drained.saturating_duration_since(req.enqueued),
                batch_form: batch_ready.saturating_duration_since(drained),
                compile,
                compile_hit,
                execute,
                total: req.enqueued.elapsed(),
            };
            tele.record(&span);
        }
    }
}

/// PJRT executor over the AOT artifact (single-threaded: the xla crate
/// wraps raw pointers in `Rc`, so the handles must stay on one thread).
type ExecFn = Box<dyn FnMut(&[&[f32]]) -> Result<BatchRun>>;

fn pjrt_exec_fn(artifact_dir: &Path) -> Result<(usize, ExecFn)> {
    let rt = Runtime::cpu()?;
    let manifest = crate::runtime::manifest::load(&artifact_dir.join("manifest.json"))
        .map_err(|e| anyhow!("{e}"))?;
    let entry = manifest
        .get("mlp_forward")
        .ok_or_else(|| anyhow!("mlp_forward not in manifest"))?
        .clone();
    let exe = rt.load_artifact(&artifact_dir.join("mlp_forward.hlo.txt"))?;
    let x_spec = entry
        .inputs
        .last()
        .ok_or_else(|| {
            anyhow!(
                "manifest entry mlp_forward has an empty inputs list \
                 (expected [weights..., x])"
            )
        })?
        .clone();
    if x_spec.shape.len() < 2 {
        return Err(anyhow!(
            "mlp_forward input spec must be (batch, feat), got {:?}",
            x_spec.shape
        ));
    }
    let (batch_cap, feat) = (x_spec.shape[0], x_spec.shape[1]);
    // Deterministic weights (a real deployment would load trained
    // parameters; see examples/train_mlp.rs). One RNG across all weights:
    // re-seeding per tensor would hand every weight the same value stream.
    let mut rng = crate::tensor::Rng::new(17);
    let weights: Vec<Tensor> = entry.inputs[..entry.inputs.len() - 1]
        .iter()
        .map(|s| rng.normal_tensor(&s.shape, 0.1))
        .collect();
    let f: ExecFn = Box::new(move |rows: &[&[f32]]| {
        let x = pad_rows(rows, batch_cap, feat);
        let mut inputs = weights.clone();
        inputs.push(x);
        let outs = rt.execute(&exe, &inputs)?;
        Ok(BatchRun {
            preds: crate::tensor::argmax(&outs[0], 1).as_i64().to_vec(),
            // The artifact was compiled ahead of time; serving never pays
            // a compile, so every batch reports a hit with zero cost.
            compile: Duration::ZERO,
            compile_hit: true,
        })
    });
    Ok((batch_cap, f))
}

/// Serve the `mlp_forward` artifact. Blocks; set `stop` to shut down.
pub fn serve(cfg: ServerConfig, stop: Arc<AtomicBool>) -> Result<Arc<Stats>> {
    let pjrt = artifacts_available(&cfg.artifact_dir);
    let workers = if pjrt { 1 } else { cfg.workers.max(1) };
    let mut stats = Stats::new(workers, cfg.opt_level);
    stats.fixpoint = cfg.fixpoint;
    let stats = Arc::new(stats);
    let tele = Arc::new(ServeTelemetry::register(cfg.port, cfg.trace.clone()));

    let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
    let rx = Arc::new(Mutex::new(rx));

    if pjrt {
        // Single batcher thread owning the !Send PJRT client + executable;
        // setup happens inside the thread, readiness reported back.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let stats_w = stats.clone();
        let tele_w = tele.clone();
        let stop_w = stop.clone();
        let rx_w = rx.clone();
        let artifact_dir = cfg.artifact_dir.clone();
        let max_batch = cfg.max_batch;
        let timeout = cfg.batch_timeout;
        std::thread::spawn(move || {
            let (batch_cap, exec_fn) = match pjrt_exec_fn(&artifact_dir) {
                Ok(x) => {
                    let _ = ready_tx.send(Ok(()));
                    x
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let cfg_batch = max_batch.min(batch_cap).max(1);
            worker_loop(
                0, &rx_w, &stop_w, &stats_w, &tele_w, cfg_batch, timeout, exec_fn,
            );
        });
        ready_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow!("executor thread did not start"))??;
    } else {
        // Compiled-relay fleet: one shared backend (one shared program
        // cache), N workers. Backend construction fails fast here, on the
        // caller's thread, before any socket is bound — and every bucket
        // compiles through the optimizing pipeline at cfg.opt_level.
        let cache = Arc::new(ProgramCache::new());
        let backend = Arc::new(RelayBackend::new(
            cfg.max_batch,
            CompileOptions::at(cfg.executor, cfg.opt_level).with_fixpoint(cfg.fixpoint),
            cache,
            stats.clone(),
        )?);
        let cfg_batch = cfg.max_batch.max(1);
        let timeout = cfg.batch_timeout;
        for worker in 0..workers {
            let backend = backend.clone();
            let stats_w = stats.clone();
            let tele_w = tele.clone();
            let stop_w = stop.clone();
            let rx_w = rx.clone();
            std::thread::spawn(move || {
                worker_loop(
                    worker,
                    &rx_w,
                    &stop_w,
                    &stats_w,
                    &tele_w,
                    cfg_batch,
                    timeout,
                    |rows| backend.run_batch_timed(rows),
                );
            });
        }
    }

    // Accept loop.
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    listener.set_nonblocking(true)?;
    let stats_out = stats.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = tx.clone();
                    let tele = tele.clone();
                    std::thread::spawn(move || handle_client(stream, tx, tele));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });
    Ok(stats_out)
}

fn handle_client(stream: TcpStream, tx: Sender<Request>, tele: Arc<ServeTelemetry>) {
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let mut writer = match peer {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut lines = reader.lines();
    loop {
        let line = match lines.next() {
            Some(Ok(l)) => l,
            Some(Err(_)) | None => break,
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(req_line) = trimmed.strip_prefix("GET ") {
            // The metrics endpoint shares the line-protocol front door:
            // drain the HTTP headers, answer once, close.
            for header in lines.by_ref() {
                match header {
                    Ok(h) if !h.trim().is_empty() => continue,
                    _ => break,
                }
            }
            serve_http(&mut writer, req_line);
            return;
        }
        let features: Vec<f32> = trimmed
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        let (rtx, rrx) = channel();
        tele.queue_depth.add(1);
        let req = Request {
            id: next_request_id(),
            features,
            respond: rtx,
            enqueued: Instant::now(),
        };
        if tx.send(req).is_err() {
            tele.queue_depth.sub(1);
            break;
        }
        match rrx.recv_timeout(Duration::from_secs(5)) {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Minimal HTTP/1.0 responder for the front door's `GET` path:
/// `/metrics` renders the telemetry registry, anything else 404s.
fn serve_http(writer: &mut TcpStream, request_line: &str) {
    let path = request_line.split_whitespace().next().unwrap_or("");
    let (status, body) = if path == "/metrics" {
        ("200 OK".to_string(), crate::telemetry::registry().render())
    } else {
        ("404 Not Found".to_string(), format!("no route {path}\n"))
    };
    let _ = write!(
        writer,
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Fetch `/metrics` from a server on localhost over its front-door port
/// (`relay metrics`, the CI smoke test, and unit tests).
pub fn fetch_metrics(port: u16) -> Result<String> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    write!(stream, "GET /metrics HTTP/1.0\r\n\r\n")?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response: {resp:?}"))?;
    if !head.starts_with("HTTP/1.0 200") {
        return Err(anyhow!(
            "unexpected status: {}",
            head.lines().next().unwrap_or("")
        ));
    }
    Ok(body.to_string())
}

/// Client helper (used by examples/serve.rs and tests).
pub fn classify(port: u16, features: &[f32]) -> Result<i64> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    let line: Vec<String> = features.iter().map(|f| f.to_string()).collect();
    writeln!(stream, "{}", line.join(","))?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    resp.trim().parse().map_err(|e| anyhow!("bad response {resp:?}: {e}"))
}

/// Is the artifact directory present (CI guard)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("mlp_forward.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn bucket_sizes_are_powers_of_two_up_to_cap() {
        assert_eq!(bucket_sizes(1), vec![1]);
        assert_eq!(bucket_sizes(4), vec![1, 2, 4]);
        assert_eq!(bucket_sizes(8), vec![1, 2, 4, 8]);
        // Non-power-of-two cap is kept as the final bucket.
        assert_eq!(bucket_sizes(6), vec![1, 2, 4, 6]);
        assert_eq!(bucket_sizes(0), vec![1]);
    }

    #[test]
    fn pad_rows_pads_and_truncates() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32];
        let rows: Vec<&[f32]> = vec![&a, &b];
        let t = pad_rows(&rows, 4, 2);
        assert_eq!(t.shape(), &[4, 2]);
        assert_eq!(t.as_f32(), &[1.0, 2.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn fallback_backend_serves_through_the_vm() {
        let port = 7981;
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        // Skip only when this exact address is unusable (no loopback, or
        // the port is held by another process); any serve() error past
        // that (e.g. a backend compile regression) must fail the test.
        match std::net::TcpListener::bind(("127.0.0.1", port)) {
            Ok(probe) => drop(probe),
            Err(_) => return,
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..4i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 7 + j) % 5) as f32 - 2.0)
                .collect();
            let pred = classify(port, &features).expect("classify");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        assert!(stats.requests.load(Ordering::Relaxed) >= 4);
        // Sequential clients mean every batch had size 1, so only the
        // batch-1 bucket compiled: 4 requests, exactly 1 compile — the
        // compile-once serving property of the program cache.
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 1);
        // The default server optimizes its buckets at -O3.
        assert_eq!(stats.opt_level, OptLevel::O3);
        // Every served request was attributed to some worker.
        let per_worker: usize = stats
            .per_worker
            .iter()
            .map(|w| w.load(Ordering::Relaxed))
            .sum();
        assert_eq!(per_worker, stats.requests.load(Ordering::Relaxed));
        stop.store(true, Ordering::Relaxed);
    }

    /// The acceptance bar for the unified pipeline: a 4-thread fleet over
    /// one shared backend/cache compiles each batch bucket exactly once
    /// for the whole process — **at -O3** — no matter how the threads
    /// interleave, and the compiled buckets run fused kernels (fewer
    /// launches than an -O0 compile of the same bucket).
    #[test]
    fn four_thread_fleet_compiles_each_bucket_exactly_once() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(4, OptLevel::O3));
        let backend = Arc::new(
            RelayBackend::new(
                8,
                CompileOptions::at(Executor::Vm, OptLevel::O3),
                cache.clone(),
                stats.clone(),
            )
            .expect("backend"),
        );
        let buckets = backend.bucket_count(); // 1, 2, 4, 8
        assert_eq!(buckets, 4);
        std::thread::scope(|s| {
            for t in 0..4usize {
                let backend = backend.clone();
                s.spawn(move || {
                    for round in 0..3usize {
                        for n in [1usize, 2, 3, 5, 8] {
                            let rows_data: Vec<Vec<f32>> = (0..n)
                                .map(|i| {
                                    (0..FALLBACK_FEAT)
                                        .map(|j| {
                                            ((t + round + i * 7 + j) % 5) as f32 - 2.0
                                        })
                                        .collect()
                                })
                                .collect();
                            let rows: Vec<&[f32]> =
                                rows_data.iter().map(|r| r.as_slice()).collect();
                            let preds = backend.run_batch(&rows).expect("run_batch");
                            assert_eq!(preds.len(), n, "one prediction per row");
                            for p in preds {
                                assert!(
                                    (0..FALLBACK_CLASSES as i64).contains(&p),
                                    "pred {p}"
                                );
                            }
                        }
                    }
                });
            }
        });
        // 4 threads x 3 rounds x every bucket shape: still exactly one
        // compile per bucket, fleet-wide.
        assert_eq!(stats.compiles.load(Ordering::Relaxed), buckets);
        assert_eq!(cache.misses(), buckets);
        assert_eq!(cache.len(), buckets);

        // The -O3 buckets the fleet served are genuinely fused: the same
        // bucket module compiled at -O0 launches more kernels (the
        // fallback MLP is dense/relu/dense = 3 unfused ops) than the
        // fleet's program did on an identical batch.
        let row: Vec<f32> = (0..FALLBACK_FEAT).map(|j| j as f32 * 0.1 - 0.5).collect();
        let rows: Vec<&[f32]> = vec![&row];
        let x = pad_rows(&rows, backend.buckets[0].size, FALLBACK_FEAT);
        let o3 = run_compiled(
            &backend.compiled_bucket(0).expect("o3 bucket"),
            vec![Value::Tensor(x.clone())],
        )
        .expect("o3 run");
        let (o0_compiled, _) = cache
            .get_or_compile_traced(
                &backend.buckets[0].module,
                CompileOptions::at(Executor::Vm, OptLevel::O0),
            )
            .expect("o0 compile");
        let o0 = run_compiled(&o0_compiled, vec![Value::Tensor(x)]).expect("o0 run");
        assert!(
            o3.launches < o0.launches,
            "fleet bucket not fused: O3 {} launches vs O0 {}",
            o3.launches,
            o0.launches
        );
        // Fusion must not change what the bucket computes.
        assert!(o3.value.bits_eq(&o0.value));
    }

    #[test]
    fn fixpoint_buckets_compile_under_their_own_cache_key_and_serve_identically() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let plain_opts = CompileOptions::at(Executor::Vm, OptLevel::O3);
        let backend = RelayBackend::new(
            2,
            plain_opts.with_fixpoint(true),
            cache.clone(),
            stats.clone(),
        )
        .expect("fixpoint backend");
        let row: Vec<f32> = (0..FALLBACK_FEAT).map(|j| (j % 5) as f32 - 2.0).collect();
        let rows: Vec<&[f32]> = vec![&row];
        let fix_preds = backend.run_batch(&rows).expect("fixpoint batch");
        assert_eq!(fix_preds.len(), 1);
        // The plain (non-fixpoint) compile of the same bucket is a
        // distinct cache entry: requesting it compiles anew...
        let (plain, compiled_now) = cache
            .get_or_compile_traced(&backend.buckets[0].module, plain_opts)
            .expect("plain compile");
        assert!(compiled_now, "fixpoint and plain artifacts shared one cache entry");
        // ...and computes the same predictions.
        let x = pad_rows(&rows, backend.buckets[0].size, FALLBACK_FEAT);
        let out = run_compiled(&plain, vec![Value::Tensor(x)]).expect("plain run");
        let plain_pred = crate::tensor::argmax(out.value.tensor(), 1).as_i64()[0];
        assert_eq!(fix_preds[0], plain_pred);
        // The lifetime counters are wired: serving the MLP's fused
        // dense->relu chain produced at least one in-place reuse
        // (process-wide counter, so only monotonicity is asserted).
        assert!(stats.inplace_hits() >= 1, "no in-place reuse recorded");
    }

    #[test]
    fn batches_larger_than_a_bucket_pad_up_and_results_match_batch_one() {
        // A 3-row batch runs the bucket-4 program; each row's prediction
        // must equal the prediction the batch-1 program gives that row
        // alone (padding rows cannot leak into real rows).
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let backend =
            RelayBackend::new(4, Executor::Vm, cache, stats).expect("backend");
        let rows_data: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                (0..FALLBACK_FEAT)
                    .map(|j| ((i * 11 + j * 3) % 7) as f32 - 3.0)
                    .collect()
            })
            .collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let batched = backend.run_batch(&rows).expect("batched");
        assert_eq!(batched.len(), 3);
        for (i, row) in rows.iter().enumerate() {
            let solo = backend.run_batch(&[row]).expect("solo");
            assert_eq!(solo.len(), 1);
            assert_eq!(batched[i], solo[0], "row {i} diverged under padding");
        }
    }

    /// Bind-probe helper shared by the socket tests: returns false when
    /// this exact address is unusable (no loopback, or the port is held
    /// by another process) — the only condition that may skip a test.
    fn port_free(port: u16) -> bool {
        std::net::TcpListener::bind(("127.0.0.1", port)).is_ok()
    }

    /// Regression for the batcher's deadline arithmetic: with zero slack
    /// the old `deadline - now` subtraction panicked (`Instant` subtraction
    /// underflows) the moment the first request arrived. The fixed loop
    /// saturates and serves batches of one.
    #[test]
    fn zero_slack_batch_timeout_serves_without_panicking() {
        let port = 7983;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            batch_timeout: Duration::ZERO,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..3i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 3 + j) % 5) as f32 - 2.0)
                .collect();
            let pred = classify(port, &features).expect("classify under zero slack");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        assert!(stats.requests.load(Ordering::Relaxed) >= 3);
        stop.store(true, Ordering::Relaxed);
    }

    /// The observability acceptance bar: N requests through the fleet
    /// leave exactly N observations in this port's request histogram, and
    /// every request's span reaches the configured sink with queue-wait
    /// and execute phases filled in.
    #[test]
    fn fleet_records_request_histogram_and_spans() {
        let port = 7987;
        if !port_free(port) {
            return;
        }
        let sink = Arc::new(crate::telemetry::MemorySpans::new());
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            trace: Some(sink.clone()),
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        let n = 6usize;
        for i in 0..n {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i * 7 + j) % 5) as f32 - 2.0)
                .collect();
            classify(port, &features).expect("classify");
        }
        // Spans are recorded after the reply is sent, so the last one can
        // trail the last classify() by a beat.
        let deadline = Instant::now() + Duration::from_secs(5);
        while sink.spans().len() < n && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let spans = sink.spans();
        assert_eq!(spans.len(), n, "one span per request");
        for s in &spans {
            assert!(s.execute > Duration::ZERO, "span {} has no execute time", s.id);
            assert!(s.total >= s.execute, "total below execute in span {}", s.id);
            assert!(s.total >= s.queue_wait, "total below wait in span {}", s.id);
            assert!(s.worker < stats.per_worker.len(), "bad worker {}", s.worker);
            // Sequential clients: every batch held exactly one request,
            // and the precompiled batch-1 bucket means no compile cost.
            assert_eq!(s.batch_size, 1);
            assert!(s.compile_hit, "span {} paid an unexpected compile", s.id);
        }
        // The registry side of the same story, exact because the series
        // are labeled by this test's port.
        let r = crate::telemetry::registry();
        let p = port.to_string();
        let labels: &[(&str, &str)] = &[("port", &p)];
        assert_eq!(r.histogram_with(names::REQUEST_SECONDS, labels).count(), n as u64);
        assert_eq!(
            r.histogram_with(names::QUEUE_WAIT_SECONDS, labels).count(),
            n as u64
        );
        assert_eq!(r.histogram_with(names::EXECUTE_SECONDS, labels).count(), n as u64);
        assert_eq!(r.counter_with(names::REQUESTS_TOTAL, labels).get(), n as u64);
        assert_eq!(r.gauge_with(names::QUEUE_DEPTH, labels).get(), 0);
        stop.store(true, Ordering::Relaxed);
    }

    /// `GET /metrics` on the front-door port returns Prometheus-style text
    /// where every line passes the shared well-formedness check; other
    /// paths 404.
    #[test]
    fn metrics_endpoint_serves_well_formed_prometheus_text() {
        let port = 7989;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..2i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 5 + j) % 5) as f32 - 2.0)
                .collect();
            classify(port, &features).expect("classify");
        }
        let body = fetch_metrics(port).expect("fetch /metrics");
        for line in body.lines() {
            assert!(
                crate::telemetry::registry::line_is_well_formed(line),
                "malformed metrics line: {line:?}"
            );
        }
        assert!(body.contains("relay_request_seconds_bucket"), "{body}");
        assert!(
            body.contains(&format!("relay_requests_total{{port=\"{port}\"}}")),
            "{body}"
        );
        // A wrong path is a 404, not a hang or a batch of garbage.
        let err = {
            let mut stream =
                TcpStream::connect(("127.0.0.1", port)).expect("connect");
            write!(stream, "GET /nope HTTP/1.0\r\n\r\n").expect("send");
            let mut resp = String::new();
            stream.read_to_string(&mut resp).expect("read");
            resp
        };
        assert!(err.starts_with("HTTP/1.0 404"), "{err}");
        stop.store(true, Ordering::Relaxed);
    }
}
