//! Batched inference server over a compiled artifact.
//!
//! A std-thread dynamic batcher (no tokio in the vendored dep set): client
//! connections write one request per line — comma-separated f32 features,
//! optionally prefixed with `deadline_ms=N;` — and read back the predicted
//! class. Requests pass through an admission-controlled front door (a
//! bounded [`AdmissionQueue`]); a fleet of worker threads drains up to
//! `max_batch` requests per batch, executes one compiled-program call at
//! the batch's exact size, and fans results back out. This is the
//! router / dynamic-batcher shape of serving systems, scaled to the
//! thin-driver role the paper's compiler contribution leaves for L3.
//!
//! **Admission control and graceful degradation** (the robustness half of
//! the continuous-batching front door; PR 6 shipped the observability
//! half):
//!
//! - The queue is **bounded** by [`ServerConfig::queue_budget`]. A request
//!   that arrives with the queue at budget is *shed* — answered with a
//!   typed `shed: queue full` line immediately and counted in
//!   `relay_shed_total{reason="queue_full"}` — instead of growing the
//!   queue without bound.
//! - Every request carries a **deadline** (its own `deadline_ms`, or
//!   [`ServerConfig::default_deadline`]). A request still queued past its
//!   deadline is dropped at drain time with an `error: deadline exceeded`
//!   reply rather than wasting a batch slot. Batch formation is
//!   **continuous and deadline-aware**: a batch dispatches when it is
//!   full, when the straggler window (`batch_timeout`) lapses, or when
//!   the tightest member deadline would otherwise be at risk — there is
//!   no fixed drain tick.
//! - Workers are **supervised**: backend execution runs under
//!   `catch_unwind`, so a panicking kernel answers every request in its
//!   batch with a typed `error: worker panicked: ...` reply and bumps
//!   `relay_worker_panics_total` — the worker thread survives. If a
//!   worker thread dies anyway, a supervisor respawns it (capped at
//!   [`MAX_WORKER_RESPAWNS`]) and keeps `relay_workers_alive` truthful.
//!   On shutdown the fleet drains gracefully: admissions stop (late
//!   arrivals get `shed: shutting down`), queued requests are served,
//!   workers are joined, span sinks are flushed.
//!
//! Backends: the PJRT executable when the AOT artifact directory exists
//! (single worker — PJRT handles are `!Send`), otherwise a compiled-relay
//! MLP ([`RelayBackend`]) routed through the executor-selection layer
//! ([`crate::eval::Executor`]) — graph runtime, bytecode VM, or
//! interpreter — so serving works without the `xla` feature.
//!
//! The compiled-relay backend is **shape-polymorphic by default**
//! (`--poly`, paper §3.3.1): the fallback MLP is typed with a symbolic
//! batch dimension (`Dim::Any`), compiled exactly once, and every formed
//! batch dispatches at its *exact* size through that single artifact — no
//! padding rows, no per-bucket compiles, one [`crate::eval::ProgramCache`]
//! entry for the whole fleet (`Stats::compiles == 1` over the server's
//! life). `--poly=off` keeps the previous *bucketed* path as a
//! differential baseline: per-batch-size modules at powers of two up to
//! `max_batch`, each batch padded up to the smallest bucket that fits
//! (padded rows are counted in `relay_padded_rows_total` — always zero on
//! the polymorphic path). Either way the shared cache coalesces racing
//! cold compiles, and compiled programs are `Send + Sync` (`Arc`-backed),
//! so any number of workers dispatch concurrently.
//!
//! Artifacts compile **through the full optimizing pipeline** at
//! [`ServerConfig::opt_level`] (default -O3, the `--opt` CLI flag): the
//! fleet serves fused kernels, not the bare ANF the pre-refactor batcher
//! executed. [`Stats::opt_level`] records what the fleet is running.
//!
//! Every request carries a [`RequestSpan`] with an explicit [`Outcome`]
//! (ok / error / shed / deadline): queue-wait, batch-form, compile (hit or
//! miss), and execute durations, rolled into the process-wide
//! [`crate::telemetry`] registry (one histogram family per phase, labeled
//! by port so co-resident servers stay separable) and optionally streamed
//! to a [`SpanSink`] ([`ServerConfig::trace`], the `--trace-json`
//! chrome://tracing writer). The same TCP front door that takes CSV
//! feature lines answers `GET /metrics` with the rendered registry, so
//! `curl` and `relay metrics` need no second port.
//!
//! **Fault-contained compilation** (PR 10): every artifact carries a
//! per-key [`CircuitBreaker`]. A compile failure — typed error *or*
//! contained panic ([`crate::eval::cache`]'s `catch_unwind` guard) —
//! counts against the breaker; after [`ResilienceConfig::breaker_threshold`]
//! consecutive failures it **opens** and the bucket is served from its
//! last-good artifact (or the `-O0` interpreter floor) without touching
//! the compiler at all. After [`ResilienceConfig::breaker_cooldown`] the
//! breaker **half-opens**: exactly one probe compile runs; success
//! re-closes it, failure re-opens. While the compiler is unhealthy the
//! degradation ladder (requested tier → `-O1` → interpreter) keeps every
//! request answered with bit-identical results — only latency degrades.
//! Breaker state is exported as `relay_breaker_state{bucket,scope}`
//! (0 = closed, 1 = open, 2 = half-open), degraded batches as
//! `relay_degraded_executions_total{level}`, and each degraded batch's
//! spans carry a `compile_fallback` annotation. The wire protocol is
//! hostile-input hardened: request lines are bounded at
//! [`MAX_LINE_BYTES`], non-UTF-8 bytes get a typed reply, and a mid-line
//! disconnect is processed-then-closed — a client can not panic a worker.
//!
//! See `README.md` in this directory for the wire protocol and the
//! admission/shedding semantics in full.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::queue::{AdmissionQueue, Pop, Reject};
use crate::eval::{run_compiled, CompileOptions, Executor, ProgramCache, Value};
use crate::ir::{self, Dim, Module, Type, Var};
use crate::pass::OptLevel;
use crate::runtime::Runtime;
use crate::telemetry::registry::names;
use crate::telemetry::{Counter, Gauge, Histogram, Outcome, RequestSpan, SpanSink};
use crate::tensor::{DType, Tensor};

/// How long an idle worker waits on the queue before re-checking for
/// shutdown (the queue's condvar wakes it immediately when work arrives;
/// this only bounds how long a close can go unnoticed).
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Client deadlines are clamped here (1 hour): `enqueued + allowance`
/// must never overflow `Instant` arithmetic no matter what a client puts
/// on the wire.
const MAX_DEADLINE: Duration = Duration::from_secs(3600);

/// Read/write timeout on the client-side helpers ([`classify`],
/// [`fetch_metrics`]): a hung server fails tests in seconds instead of
/// wedging CI forever.
const CLIENT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// How often the supervisor checks the fleet for dead workers.
const SUPERVISOR_POLL: Duration = Duration::from_millis(20);

/// Lifetime cap on supervisor respawns per fleet. `catch_unwind` means a
/// panicking *backend* never kills a worker, so respawns only happen for
/// pathological failures (e.g. a PJRT setup that dies on every attempt) —
/// the cap keeps that from becoming a spawn loop.
pub const MAX_WORKER_RESPAWNS: usize = 16;

/// Hard cap on one wire-protocol request line (64 KiB). A client that
/// streams an unbounded line gets a typed `error: request line too long`
/// reply and a closed connection instead of growing a worker-side buffer
/// without limit.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

pub struct ServerConfig {
    pub port: u16,
    pub max_batch: usize,
    /// Straggler window for batch formation: once a worker holds one
    /// request it waits at most this long for more before dispatching
    /// (a member deadline can force dispatch sooner; a full batch always
    /// dispatches immediately).
    pub batch_timeout: Duration,
    pub artifact_dir: std::path::PathBuf,
    /// Execution tier for the compiled-relay backend, used when the AOT
    /// artifact directory is missing (so the server works — batching and
    /// all — without the `xla` feature / Python build path).
    pub executor: Executor,
    /// Optimization level the per-bucket modules compile at (`--opt`,
    /// default -O3: the serving fleet runs fused kernels).
    pub opt_level: OptLevel,
    /// Run the fixpoint FoldConstant/DCE loop when compiling buckets
    /// (`--fixpoint`): more compile time per bucket — paid once per bucket
    /// over the server's life — for a fully-converged artifact. Part of
    /// the program-cache key, so fixpoint and plain artifacts coexist.
    pub fixpoint: bool,
    /// Worker threads draining the request queue (compiled-relay backend).
    /// The PJRT backend is pinned to one worker: its handles are `!Send`.
    pub workers: usize,
    /// Admission bound (`--queue-budget`, default 256): how many requests
    /// may wait on the queue at once. Arrivals past the budget are shed
    /// with a typed `shed: queue full` reply and counted in
    /// `relay_shed_total{reason="queue_full"}` — the queue cannot grow
    /// without bound. A budget of 0 sheds everything (admin drain).
    pub queue_budget: usize,
    /// Deadline granted to requests that do not send their own
    /// `deadline_ms` on the request line (`--deadline-ms`, default 1s).
    /// A request still queued past its deadline is answered
    /// `error: deadline exceeded` at drain time instead of occupying a
    /// batch slot nobody is waiting on.
    pub default_deadline: Duration,
    /// Optional sink every completed [`RequestSpan`] is streamed to, on
    /// top of the always-on registry histograms (`--trace-json` wires a
    /// [`crate::telemetry::ChromeTraceWriter`] here; tests use
    /// [`crate::telemetry::MemorySpans`]). Flushed on graceful drain.
    pub trace: Option<Arc<dyn SpanSink>>,
    /// Deterministic fault injection around the compiled-relay backend
    /// (tests and the saturation bench only; `None` in production).
    pub fault: Option<FaultConfig>,
    /// Shape-polymorphic serving (`--poly`, default on): compile the
    /// fallback model once with a symbolic batch dimension and dispatch
    /// every batch at its exact size — no padding, one compile, one
    /// program-cache entry. `--poly=off` restores the bucketed baseline
    /// (powers-of-two modules, batches padded up to the bucket).
    pub poly: bool,
    /// Kernel worker-pool width (`--kernel-threads`, 0 = auto): threads
    /// the tiled tensor kernels fan outer tiles across
    /// ([`crate::tensor::parallel`]). 1 bypasses the pool entirely
    /// (strictly sequential kernels). Applied process-wide at serve
    /// startup; the first kernel launch freezes the value.
    pub kernel_threads: usize,
    /// Fallback rungs a failing artifact compile may take before the
    /// interpreter floor (`--max-opt-retries`, default 1: allow the `-O1`
    /// retry). The interpreter floor itself is always available to the
    /// serving path — a compile failure degrades a request, never errors
    /// it.
    pub max_opt_retries: usize,
    /// Consecutive compile failures on one artifact before its circuit
    /// breaker opens (`--breaker-threshold`, default 3).
    pub breaker_threshold: usize,
    /// How long an open breaker waits before half-opening for a single
    /// probe compile (`--breaker-cooldown-ms`, default 250ms).
    pub breaker_cooldown: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7474,
            max_batch: 32,
            batch_timeout: Duration::from_millis(2),
            artifact_dir: "artifacts".into(),
            executor: Executor::Auto,
            opt_level: OptLevel::O3,
            fixpoint: false,
            workers: 4,
            queue_budget: 256,
            default_deadline: Duration::from_secs(1),
            trace: None,
            fault: None,
            poly: true,
            kernel_threads: 0,
            max_opt_retries: 1,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
        }
    }
}

/// Feature width of the fallback model (rows are padded/truncated here).
pub const FALLBACK_FEAT: usize = 16;
const FALLBACK_HIDDEN: usize = 32;
/// Number of output classes the fallback model predicts.
pub const FALLBACK_CLASSES: usize = 4;

/// A small MLP classifier with baked-in deterministic weights, served when
/// no AOT artifact is available. The batch dimension is whatever the
/// caller passes: `Dim::Any` yields the shape-polymorphic module (one
/// artifact for every batch size, §3.3.1), `Dim::Known(n)` the fixed-shape
/// module the bucketed baseline pads to. Public so the chaos bench can
/// build an interpreter reference for the bit-identical degradation check
/// (deterministic weights: every call returns the same module).
pub fn fallback_module(batch: Dim) -> Module {
    let mut w = crate::zoo::Weights::new(17);
    let x = Var::fresh("x");
    let h = ir::op_call(
        "nn.relu",
        vec![ir::op_call("nn.dense", vec![ir::var(&x), w.he(&[FALLBACK_HIDDEN, FALLBACK_FEAT])])],
    );
    let logits = ir::op_call("nn.dense", vec![h, w.he(&[FALLBACK_CLASSES, FALLBACK_HIDDEN])]);
    let mut m = Module::with_prelude();
    let ty = Type::Tensor {
        shape: vec![batch, Dim::Known(FALLBACK_FEAT)],
        dtype: DType::F32,
    };
    m.add_def("main", ir::Function::new(vec![(x, Some(ty))], logits));
    m
}

struct Request {
    /// Process-unique id, carried into the request's span.
    id: u64,
    features: Vec<f32>,
    respond: Sender<String>,
    /// When the client handler put this request on the queue; every span
    /// phase is measured from here.
    enqueued: Instant,
    /// Absolute deadline (`enqueued` + the request's allowance). Workers
    /// check it at drain time and answer `error: deadline exceeded`
    /// instead of batching a request nobody is waiting on anymore.
    deadline: Instant,
}

fn next_request_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// The fleet's handles into the process-wide telemetry registry, resolved
/// once per [`serve`] call. Every series is labeled by port: two servers
/// in one process (common in tests) each get exact per-port counts
/// instead of one merged stream.
struct ServeTelemetry {
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    /// Requests enqueued but not yet drained by a worker. Owned by the
    /// [`AdmissionQueue`], which updates it under its own lock — the
    /// gauge always equals the exact queue length.
    queue_depth: Arc<Gauge>,
    request_h: Arc<Histogram>,
    queue_wait_h: Arc<Histogram>,
    batch_form_h: Arc<Histogram>,
    compile_h: Arc<Histogram>,
    execute_h: Arc<Histogram>,
    /// `relay_shed_total` by reason: admissions rejected at the door.
    shed_queue_full: Arc<Counter>,
    shed_shutdown: Arc<Counter>,
    /// Deadline drops happen at drain time, not admission, but they are
    /// load shedding all the same — same metric family, own reason.
    shed_deadline: Arc<Counter>,
    /// `relay_request_outcomes_total{outcome=...}`: every request ends in
    /// exactly one of ok / error / shed / deadline.
    outcome_ok: Arc<Counter>,
    outcome_error: Arc<Counter>,
    outcome_shed: Arc<Counter>,
    outcome_deadline: Arc<Counter>,
    worker_panics: Arc<Counter>,
    worker_respawns: Arc<Counter>,
    workers_alive: Arc<Gauge>,
    sink: Option<Arc<dyn SpanSink>>,
}

impl ServeTelemetry {
    fn register(port: u16, sink: Option<Arc<dyn SpanSink>>) -> ServeTelemetry {
        let r = crate::telemetry::registry();
        let p = port.to_string();
        let labels: &[(&str, &str)] = &[("port", &p)];
        ServeTelemetry {
            requests: r.counter_with(names::REQUESTS_TOTAL, labels),
            batches: r.counter_with(names::BATCHES_TOTAL, labels),
            queue_depth: r.gauge_with(names::QUEUE_DEPTH, labels),
            request_h: r.histogram_with(names::REQUEST_SECONDS, labels),
            queue_wait_h: r.histogram_with(names::QUEUE_WAIT_SECONDS, labels),
            batch_form_h: r.histogram_with(names::BATCH_FORM_SECONDS, labels),
            compile_h: r.histogram_with(names::COMPILE_SECONDS, labels),
            execute_h: r.histogram_with(names::EXECUTE_SECONDS, labels),
            shed_queue_full: r
                .counter_with(names::SHED_TOTAL, &[("port", &p), ("reason", "queue_full")]),
            shed_shutdown: r
                .counter_with(names::SHED_TOTAL, &[("port", &p), ("reason", "shutdown")]),
            shed_deadline: r
                .counter_with(names::SHED_TOTAL, &[("port", &p), ("reason", "deadline")]),
            outcome_ok: r.counter_with(
                names::REQUEST_OUTCOMES_TOTAL,
                &[("outcome", "ok"), ("port", &p)],
            ),
            outcome_error: r.counter_with(
                names::REQUEST_OUTCOMES_TOTAL,
                &[("outcome", "error"), ("port", &p)],
            ),
            outcome_shed: r.counter_with(
                names::REQUEST_OUTCOMES_TOTAL,
                &[("outcome", "shed"), ("port", &p)],
            ),
            outcome_deadline: r.counter_with(
                names::REQUEST_OUTCOMES_TOTAL,
                &[("outcome", "deadline"), ("port", &p)],
            ),
            worker_panics: r.counter_with(names::WORKER_PANICS_TOTAL, labels),
            worker_respawns: r.counter_with(names::WORKER_RESPAWNS_TOTAL, labels),
            workers_alive: r.gauge_with(names::WORKERS_ALIVE, labels),
            sink,
        }
    }

    fn outcome_counter(&self, o: Outcome) -> &Counter {
        match o {
            Outcome::Ok => &*self.outcome_ok,
            Outcome::Error => &*self.outcome_error,
            Outcome::Shed => &*self.outcome_shed,
            Outcome::Deadline => &*self.outcome_deadline,
        }
    }

    /// Record one finished request: outcome counter always, histograms by
    /// outcome, sink when present. Shed requests never reached a worker,
    /// so their zeroed phases stay out of the latency histograms (they
    /// would drag every p50 toward zero); deadline drops have a real
    /// queue-wait and total. Compile time lands in the compile histogram
    /// only when a healthy batch actually paid it — cache hits and failed
    /// batches would flood the p50 with zeros.
    fn record(&self, span: &RequestSpan) {
        self.outcome_counter(span.outcome).inc();
        match span.outcome {
            Outcome::Shed => {}
            Outcome::Deadline => {
                self.request_h.observe_duration(span.total);
                self.queue_wait_h.observe_duration(span.queue_wait);
            }
            Outcome::Ok | Outcome::Error => {
                self.request_h.observe_duration(span.total);
                self.queue_wait_h.observe_duration(span.queue_wait);
                self.batch_form_h.observe_duration(span.batch_form);
                self.execute_h.observe_duration(span.execute);
                if span.outcome == Outcome::Ok && !span.compile_hit {
                    self.compile_h.observe_duration(span.compile);
                }
            }
        }
        if let Some(sink) = &self.sink {
            sink.record(span);
        }
    }
}

/// What one backend execution reports back to the batcher: predictions
/// plus where the time went, so the worker can split its wall clock into
/// compile and execute span phases.
pub struct BatchRun {
    pub preds: Vec<i64>,
    /// Compile time this batch paid (zero when its program was already
    /// resolved).
    pub compile: Duration,
    /// True when the program came from a memo or cache rather than being
    /// compiled by this call.
    pub compile_hit: bool,
    /// `Some(level)` when the degradation ladder served this batch below
    /// the requested tier (`O1` = the retry rung, `O0` = the interpreter
    /// floor); `None` on the healthy path. Carried into each member
    /// request's span as the `compile_fallback` annotation.
    pub degraded: Option<OptLevel>,
}

/// Zero-pad feature rows into a `(batch, feat)` input tensor. Rows longer
/// than `feat` are truncated, shorter ones zero-filled. Takes borrowed
/// slices so the batcher's hot path copies each row exactly once.
fn pad_rows(rows: &[&[f32]], batch: usize, feat: usize) -> Tensor {
    let mut data = vec![0f32; batch * feat];
    for (i, r) in rows.iter().enumerate().take(batch) {
        let row = &r[..feat.min(r.len())];
        data[i * feat..i * feat + row.len()].copy_from_slice(row);
    }
    Tensor::from_f32(vec![batch, feat], data)
}

pub struct Stats {
    /// Requests drained into a batch and executed (including batches that
    /// came back as typed errors). Shed and deadline-dropped requests are
    /// counted separately below.
    pub requests: AtomicUsize,
    pub batches: AtomicUsize,
    /// Backend compiles performed so far, fleet-wide: exactly 1 on the
    /// shape-polymorphic path (one symbolic-batch artifact serves every
    /// batch size), at most one per bucket on the `--poly=off` baseline —
    /// no matter how many workers race on a cold artifact. Mirrored into
    /// the registry's `relay_compiles_total`; this per-instance copy keeps
    /// tests exact when several servers share the process.
    pub compiles: AtomicUsize,
    /// Requests rejected at admission (queue over budget or shutting
    /// down) and answered with a typed `shed:` reply.
    pub shed: AtomicUsize,
    /// Requests dropped at drain time because their deadline had already
    /// passed (`error: deadline exceeded`).
    pub deadline_dropped: AtomicUsize,
    /// Backend panics caught by the worker's `catch_unwind` — each one
    /// answered its whole batch with a typed error, and the worker
    /// survived.
    pub panics: AtomicUsize,
    /// Zero-filled rows dispatched to make a batch fit its compiled
    /// shape. Always 0 on the shape-polymorphic path (every batch runs
    /// at exact size); on the bucketed baseline it is the padding waste
    /// the polymorphic artifact retires. Mirrored into the registry's
    /// `relay_padded_rows_total`.
    pub padded_rows: AtomicUsize,
    /// Optimization level the backend compiles at (fixed per server).
    pub opt_level: OptLevel,
    /// Whether bucket compiles run the fixpoint cleanup loop.
    pub fixpoint: bool,
    /// Requests served per worker thread (len == worker count).
    pub per_worker: Vec<AtomicUsize>,
    /// Process-wide allocation counters at server start; the memory
    /// planner's hits/misses over the server's lifetime are reported as
    /// deltas from here ([`Stats::inplace_hits`]).
    alloc_base: crate::tensor::AllocSnapshot,
}

impl Stats {
    pub fn new(workers: usize, opt_level: OptLevel) -> Stats {
        Stats {
            requests: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            compiles: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            deadline_dropped: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            padded_rows: AtomicUsize::new(0),
            opt_level,
            fixpoint: false,
            per_worker: (0..workers.max(1)).map(|_| AtomicUsize::new(0)).collect(),
            alloc_base: crate::tensor::alloc_stats().snapshot(),
        }
    }

    /// In-place kernel reuses since the server started (the memory
    /// planner's output-buffer allocations *avoided*). Deltas over the
    /// registry's process-wide `relay_inplace_hits_total` counter, so
    /// co-resident non-serving executions are included.
    pub fn inplace_hits(&self) -> usize {
        crate::tensor::alloc_stats().snapshot().hits_since(&self.alloc_base)
    }

    /// Eligible kernels that fell back to allocating since server start.
    pub fn inplace_misses(&self) -> usize {
        crate::tensor::alloc_stats().snapshot().misses_since(&self.alloc_base)
    }
}

/// Batch-shape buckets: powers of two up to (and always including) `cap`.
/// A batch of n requests pads to the smallest bucket >= n.
fn bucket_sizes(cap: usize) -> Vec<usize> {
    let cap = cap.max(1);
    let mut out = Vec::new();
    let mut b = 1usize;
    while b < cap {
        out.push(b);
        b *= 2;
    }
    out.push(cap);
    out
}

/// Circuit-breaker states, encoded on the `relay_breaker_state` gauge as
/// 0 / 1 / 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: compiles run normally.
    Closed,
    /// Tripped: the compiler is not touched; the bucket serves its
    /// last-good artifact or the interpreter floor until the cooldown
    /// lapses.
    Open,
    /// Cooldown lapsed: exactly one probe compile is in flight; everyone
    /// else is still served without compiling.
    HalfOpen,
}

impl BreakerState {
    fn gauge_value(self) -> i64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }
}

/// What the breaker tells a resolver that wants to compile.
enum Admission {
    /// Closed: compile normally.
    Allow,
    /// Half-open: this caller won the single probe slot — compile once at
    /// the requested tier; its outcome decides the breaker's fate.
    Probe,
    /// Open (or a probe is already in flight): do not touch the compiler.
    Deny,
}

/// Per-artifact compile circuit breaker (Closed → Open → HalfOpen →
/// Closed). After `threshold` *consecutive* compile failures the breaker
/// opens and [`CircuitBreaker::admit`] denies compiler access; once
/// `cooldown` has passed the first `admit` call wins a half-open probe
/// slot. A probe success re-closes the breaker, a probe failure re-opens
/// it (restarting the cooldown). State changes are mirrored onto the
/// `relay_breaker_state{bucket,scope}` gauge.
pub struct CircuitBreaker {
    threshold: usize,
    cooldown: Duration,
    inner: Mutex<BreakerInner>,
    gauge: Arc<Gauge>,
}

struct BreakerInner {
    state: BreakerState,
    consecutive_failures: usize,
    opened_at: Option<Instant>,
}

impl CircuitBreaker {
    pub fn new(threshold: usize, cooldown: Duration, gauge: Arc<Gauge>) -> CircuitBreaker {
        gauge.set(BreakerState::Closed.gauge_value());
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                opened_at: None,
            }),
            gauge,
        }
    }

    pub fn state(&self) -> BreakerState {
        crate::sync::lock_unpoisoned(&self.inner).state
    }

    fn admit(&self) -> Admission {
        let mut inner = crate::sync::lock_unpoisoned(&self.inner);
        match inner.state {
            BreakerState::Closed => Admission::Allow,
            BreakerState::Open => {
                let cooled = inner
                    .opened_at
                    .is_some_and(|t| t.elapsed() >= self.cooldown);
                if cooled {
                    inner.state = BreakerState::HalfOpen;
                    self.gauge.set(BreakerState::HalfOpen.gauge_value());
                    Admission::Probe
                } else {
                    Admission::Deny
                }
            }
            // Someone else holds the probe slot; wait out their verdict.
            BreakerState::HalfOpen => Admission::Deny,
        }
    }

    fn record_success(&self) {
        let mut inner = crate::sync::lock_unpoisoned(&self.inner);
        inner.state = BreakerState::Closed;
        inner.consecutive_failures = 0;
        inner.opened_at = None;
        self.gauge.set(BreakerState::Closed.gauge_value());
    }

    fn record_failure(&self) {
        let mut inner = crate::sync::lock_unpoisoned(&self.inner);
        inner.consecutive_failures += 1;
        let trip = inner.state == BreakerState::HalfOpen
            || inner.consecutive_failures >= self.threshold;
        if trip {
            inner.state = BreakerState::Open;
            inner.opened_at = Some(Instant::now());
            self.gauge.set(BreakerState::Open.gauge_value());
        }
    }
}

/// Fault-containment knobs for [`RelayBackend`]: the degradation-ladder
/// depth and the per-artifact breaker parameters. `scope` labels the
/// breaker gauges so co-resident backends (tests, benches, two servers in
/// one process) stay separable.
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    /// Fallback rungs before the interpreter floor (0 = no `-O1` retry).
    /// The floor itself is unconditional: serving degrades, never errors.
    pub max_opt_retries: usize,
    /// Consecutive compile failures before the breaker opens.
    pub breaker_threshold: usize,
    /// Open-state dwell time before the half-open probe.
    pub breaker_cooldown: Duration,
    /// `scope` label on `relay_breaker_state{bucket,scope}`.
    pub scope: String,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            max_opt_retries: 1,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            scope: "backend".to_string(),
        }
    }
}

/// What [`RelayBackend`]'s resolver hands the dispatch path: the program
/// to run, what resolution cost, and whether (and how far) it degraded.
struct Resolution {
    compiled: crate::eval::Compiled,
    took: Duration,
    hit: bool,
    /// `Some(level)` when the artifact served is below the requested tier.
    degraded_to: Option<OptLevel>,
}

/// The compiled-relay serving backend. Two dispatch modes:
///
/// * **Shape-polymorphic** ([`RelayBackend::new`], the `--poly` default,
///   §3.3.1): ONE fallback-MLP module typed with a `Dim::Any` batch
///   dimension, compiled once, serving every batch size 1..=`max_batch`
///   at its exact size — no padding rows, one [`ProgramCache`] entry.
/// * **Bucketed** ([`RelayBackend::bucketed`], the `--poly=off`
///   differential baseline): one fixed-shape module per power-of-two
///   bucket, each batch padded up to the smallest bucket that fits
///   (padding counted in [`Stats::padded_rows`] and the registry's
///   `relay_padded_rows_total`).
///
/// `Send + Sync`: any number of worker threads may call [`run_batch`]
/// concurrently — compiled programs are `Arc`-backed immutable data, and
/// the cache coalesces racing misses so each artifact compiles at most
/// once for the whole fleet ([`Stats::compiles`] counts exactly the calls
/// that actually compiled: 1 polymorphic, bucket-count bucketed).
///
/// [`run_batch`]: RelayBackend::run_batch
pub struct RelayBackend {
    mode: BackendMode,
    cache: Arc<ProgramCache>,
    /// Executor + optimization level every artifact compiles with.
    opts: CompileOptions,
    stats: Arc<Stats>,
    resilience: ResilienceConfig,
}

enum BackendMode {
    /// One symbolic-batch artifact; batches up to `max_batch` dispatch at
    /// exact size.
    Poly { max_batch: usize, artifact: Bucket },
    /// Fixed-shape artifacts at powers of two; batches pad up.
    Buckets(Vec<Bucket>),
}

struct Bucket {
    /// Batch size this artifact is fixed to — for the polymorphic
    /// artifact, the `max_batch` admission cap (its module accepts any
    /// batch).
    size: usize,
    module: Module,
    /// Memo of the best program resolved so far and the tier it serves at
    /// (`None` = the requested tier — terminal; `Some(level)` = a
    /// degraded artifact, upgradeable when a later compile lands a higher
    /// tier). Replaces the pre-PR 10 `OnceLock`: a degraded resolution
    /// must not be frozen forever.
    best: Mutex<Option<(crate::eval::Compiled, Option<OptLevel>)>>,
    /// Per-artifact compile circuit breaker.
    breaker: CircuitBreaker,
}

impl Bucket {
    fn at(size: usize, batch: Dim, resilience: &ResilienceConfig) -> Bucket {
        let bucket_label = size.to_string();
        let gauge = crate::telemetry::registry().gauge_with(
            names::BREAKER_STATE,
            &[("bucket", &bucket_label), ("scope", &resilience.scope)],
        );
        Bucket {
            size,
            module: fallback_module(batch),
            best: Mutex::new(None),
            breaker: CircuitBreaker::new(
                resilience.breaker_threshold,
                resilience.breaker_cooldown,
                gauge,
            ),
        }
    }

    /// How much better `candidate` serves than `current` (requested tier
    /// beats `-O1` beats the interpreter floor).
    fn tier_rank(d: &Option<OptLevel>) -> u8 {
        match d {
            None => 3,
            Some(OptLevel::O0) => 1,
            Some(_) => 2,
        }
    }

    /// Install `compiled` as the memoized program if it serves at a
    /// higher tier than what is already there.
    fn offer(&self, compiled: &crate::eval::Compiled, degraded_to: Option<OptLevel>) {
        let mut best = crate::sync::lock_unpoisoned(&self.best);
        let better = match &*best {
            None => true,
            Some((_, have)) => Bucket::tier_rank(&degraded_to) > Bucket::tier_rank(have),
        };
        if better {
            *best = Some((compiled.clone(), degraded_to));
        }
    }

    /// The memoized program, if any.
    fn best(&self) -> Option<(crate::eval::Compiled, Option<OptLevel>)> {
        crate::sync::lock_unpoisoned(&self.best).clone()
    }
}

impl RelayBackend {
    /// The shape-polymorphic backend: type the fallback model with a
    /// symbolic batch (`Dim::Any`), compile it once up front (failing
    /// fast on backend regressions), serve every batch size with it.
    /// `opts` sets executor *and* optimization level (a bare [`Executor`]
    /// selects the default -O3).
    pub fn new(
        max_batch: usize,
        opts: impl Into<CompileOptions>,
        cache: Arc<ProgramCache>,
        stats: Arc<Stats>,
    ) -> Result<RelayBackend> {
        RelayBackend::new_with(max_batch, opts, cache, stats, ResilienceConfig::default())
    }

    /// [`RelayBackend::new`] with explicit fault-containment knobs. The
    /// warm-up compile is *tolerant*: a failure is recorded against the
    /// artifact's breaker and the backend comes up serving degraded — a
    /// broken compiler must not take serving down with it.
    pub fn new_with(
        max_batch: usize,
        opts: impl Into<CompileOptions>,
        cache: Arc<ProgramCache>,
        stats: Arc<Stats>,
        resilience: ResilienceConfig,
    ) -> Result<RelayBackend> {
        let max_batch = max_batch.max(1);
        let backend = RelayBackend {
            mode: BackendMode::Poly {
                max_batch,
                artifact: Bucket::at(max_batch, Dim::Any, &resilience),
            },
            cache,
            opts: opts.into(),
            stats,
            resilience,
        };
        backend.resolve(backend.artifact(0));
        Ok(backend)
    }

    /// The bucketed baseline (`--poly=off`): per-bucket fixed-shape
    /// modules, warming up by compiling the smallest bucket.
    pub fn bucketed(
        max_batch: usize,
        opts: impl Into<CompileOptions>,
        cache: Arc<ProgramCache>,
        stats: Arc<Stats>,
    ) -> Result<RelayBackend> {
        RelayBackend::bucketed_with(
            max_batch,
            opts,
            cache,
            stats,
            ResilienceConfig::default(),
        )
    }

    /// [`RelayBackend::bucketed`] with explicit fault-containment knobs
    /// (see [`RelayBackend::new_with`] for the tolerant-warm-up rationale).
    pub fn bucketed_with(
        max_batch: usize,
        opts: impl Into<CompileOptions>,
        cache: Arc<ProgramCache>,
        stats: Arc<Stats>,
        resilience: ResilienceConfig,
    ) -> Result<RelayBackend> {
        let buckets: Vec<Bucket> = bucket_sizes(max_batch.max(1))
            .into_iter()
            .map(|size| Bucket::at(size, Dim::Known(size), &resilience))
            .collect();
        let backend = RelayBackend {
            mode: BackendMode::Buckets(buckets),
            cache,
            opts: opts.into(),
            stats,
            resilience,
        };
        backend.resolve(backend.artifact(0));
        Ok(backend)
    }

    /// Breaker state of the `bi`-th artifact (tests and the chaos bench).
    pub fn breaker_state(&self, bi: usize) -> BreakerState {
        self.artifact(bi).breaker.state()
    }

    /// Distinct compiled-shape artifacts: 1 in polymorphic mode, the
    /// bucket count in bucketed mode.
    pub fn bucket_count(&self) -> usize {
        match &self.mode {
            BackendMode::Poly { .. } => 1,
            BackendMode::Buckets(b) => b.len(),
        }
    }

    /// The `bi`-th artifact (polymorphic mode has exactly one).
    fn artifact(&self, bi: usize) -> &Bucket {
        match &self.mode {
            BackendMode::Poly { artifact, .. } => artifact,
            BackendMode::Buckets(b) => &b[bi],
        }
    }

    /// Count a cache compile that this resolve call actually performed.
    fn note_compiled(&self, compiled_now: bool) {
        if compiled_now {
            self.stats.compiles.fetch_add(1, Ordering::Relaxed);
            crate::telemetry::registry().counter(names::COMPILES_TOTAL).inc();
        }
    }

    /// Serve `bucket` from its memo, or materialize the interpreter floor
    /// — the rung that cannot fail — when nothing has ever resolved. Never
    /// touches the compiler.
    fn serve_best(&self, bucket: &Bucket, took: Duration) -> Resolution {
        if let Some((compiled, degraded_to)) = bucket.best() {
            return Resolution { compiled, took, hit: true, degraded_to };
        }
        let floor = crate::eval::Compiled::Interp(Arc::new(bucket.module.clone()));
        bucket.offer(&floor, Some(OptLevel::O0));
        Resolution {
            compiled: floor,
            took,
            hit: false,
            degraded_to: Some(OptLevel::O0),
        }
    }

    /// Resolve one artifact: per-artifact memo first, then the shared
    /// cache — gated by the artifact's circuit breaker and backed by the
    /// degradation ladder, so resolution *always* produces a runnable
    /// program:
    ///
    /// * memo holds a requested-tier program → pure dispatch (no cache
    ///   lock, no breaker);
    /// * breaker **denies** (open, or a probe is in flight) → last-good
    ///   memo or the interpreter floor, compiler untouched;
    /// * breaker grants a **probe** → the remembered failure is forgotten
    ///   and exactly one strict requested-tier compile runs; its outcome
    ///   closes or re-opens the breaker;
    /// * breaker **allows** → strict requested-tier compile; on failure
    ///   (recorded against the breaker) the ladder tries `-O1` (when
    ///   `max_opt_retries` ≥ 1), then the floor.
    ///
    /// Racing workers on a cold artifact still coalesce inside the cache;
    /// [`Stats::compiles`] counts only calls that actually compiled.
    fn resolve(&self, bucket: &Bucket) -> Resolution {
        if let Some((compiled, degraded_to @ None)) = bucket.best() {
            return Resolution { compiled, took: Duration::ZERO, hit: true, degraded_to };
        }
        let t0 = Instant::now();
        let admission = bucket.breaker.admit();
        if matches!(admission, Admission::Deny) {
            return self.serve_best(bucket, t0.elapsed());
        }
        if matches!(admission, Admission::Probe) {
            // Half-open: forget the negative-cache entry so the probe is a
            // real compile, then run exactly one strict attempt.
            self.cache.forget_negative(&bucket.module, &self.opts);
        }
        match self.cache.get_or_compile_full(&bucket.module, self.opts) {
            Ok(resolved) => {
                self.note_compiled(resolved.compiled_now);
                bucket.breaker.record_success();
                bucket.offer(&resolved.compiled, None);
                Resolution {
                    compiled: resolved.compiled,
                    took: t0.elapsed(),
                    hit: !resolved.compiled_now,
                    degraded_to: None,
                }
            }
            Err(_) => {
                bucket.breaker.record_failure();
                // Rung 1: the -O1 retry (strict, under its own cache key —
                // never aliased, so a later probe can still recompile the
                // requested tier).
                if self.resilience.max_opt_retries >= 1
                    && self.opts.opt_level > OptLevel::O1
                {
                    let lowered =
                        CompileOptions { opt_level: OptLevel::O1, ..self.opts };
                    if let Ok(resolved) =
                        self.cache.get_or_compile_full(&bucket.module, lowered)
                    {
                        self.note_compiled(resolved.compiled_now);
                        bucket.offer(&resolved.compiled, Some(OptLevel::O1));
                        return Resolution {
                            compiled: resolved.compiled,
                            took: t0.elapsed(),
                            hit: !resolved.compiled_now,
                            degraded_to: Some(OptLevel::O1),
                        };
                    }
                }
                // Rung 2: last-good artifact or the interpreter floor.
                self.serve_best(bucket, t0.elapsed())
            }
        }
    }

    /// Execute one batch of feature rows; returns one prediction per row.
    /// The batch must fit `max_batch` (`serve`'s workers cap their batches
    /// there, so this only trips for external callers).
    pub fn run_batch(&self, rows: &[&[f32]]) -> Result<Vec<i64>> {
        self.run_batch_timed(rows).map(|b| b.preds)
    }

    /// [`run_batch`](Self::run_batch) with the timing breakdown the
    /// batcher needs for request spans.
    pub fn run_batch_timed(&self, rows: &[&[f32]]) -> Result<BatchRun> {
        let (bucket, dispatch_batch) = match &self.mode {
            BackendMode::Poly { max_batch, artifact } => {
                if rows.len() > *max_batch {
                    return Err(anyhow!(
                        "batch of {} rows exceeds max_batch ({max_batch})",
                        rows.len()
                    ));
                }
                // Exact-size dispatch: the polymorphic artifact takes the
                // batch as it arrived. Zero padding, ever.
                (artifact, rows.len().max(1))
            }
            BackendMode::Buckets(buckets) => {
                let cap = buckets.last().map_or(0, |b| b.size);
                if rows.len() > cap {
                    return Err(anyhow!(
                        "batch of {} rows exceeds the largest bucket ({cap})",
                        rows.len()
                    ));
                }
                let bi = buckets
                    .iter()
                    .position(|b| b.size >= rows.len())
                    .unwrap_or(buckets.len() - 1);
                let bucket = &buckets[bi];
                let padded = bucket.size - rows.len().min(bucket.size);
                if padded > 0 {
                    self.stats.padded_rows.fetch_add(padded, Ordering::Relaxed);
                    crate::telemetry::registry()
                        .counter(names::PADDED_ROWS_TOTAL)
                        .add(padded as u64);
                }
                (bucket, bucket.size)
            }
        };
        let resolution = self.resolve(bucket);
        if let Some(level) = resolution.degraded_to {
            crate::telemetry::registry()
                .counter_with(
                    names::DEGRADED_EXECUTIONS_TOTAL,
                    &[("level", level.digit())],
                )
                .inc();
        }
        let x = pad_rows(rows, dispatch_batch, FALLBACK_FEAT);
        let out = run_compiled(&resolution.compiled, vec![Value::Tensor(x)])
            .map_err(|e| anyhow!("{e}"))?;
        let preds = crate::tensor::argmax(out.value.tensor(), 1);
        let preds = preds.as_i64();
        Ok(BatchRun {
            preds: preds[..rows.len().min(preds.len())].to_vec(),
            compile: resolution.took,
            compile_hit: resolution.hit,
            degraded: resolution.degraded_to,
        })
    }
}

/// Deterministic fault plan for [`FaultyBackend`]: every-nth-batch
/// injection (not random), so tests and the saturation bench can assert
/// exact counts.
#[derive(Clone, Debug, Default)]
pub struct FaultConfig {
    /// Panic on every nth batch, fleet-wide (`None`: never). Exercises
    /// the worker's `catch_unwind` + typed-error path.
    pub panic_every: Option<usize>,
    /// Return a backend error on every nth batch (`None`: never).
    pub error_every: Option<usize>,
    /// Extra latency injected into every batch — the knob that turns a
    /// fast in-process backend into one the saturation test can overrun.
    pub latency: Duration,
    /// Panic inside every nth *compile* (`None`: never), installed as a
    /// [`crate::eval::cache::CompileHook`] on the serving cache so the
    /// injected panic exercises the genuine `catch_unwind` containment
    /// path, the negative cache, the degradation ladder, and the breaker.
    /// The counter is shared with `compile_error_every` and 1-indexed.
    pub compile_panic_every: Option<usize>,
    /// Fail every nth compile with a typed error (`None`: never).
    pub compile_error_every: Option<usize>,
}

/// Test/bench-only wrapper around [`RelayBackend`] that injects faults on
/// a deterministic schedule ([`FaultConfig`]). The batch counter is shared
/// across the fleet, so "every nth batch" means the fleet's nth batch no
/// matter which worker runs it.
pub struct FaultyBackend {
    inner: Arc<RelayBackend>,
    faults: FaultConfig,
    batches: AtomicUsize,
}

impl FaultyBackend {
    pub fn new(inner: Arc<RelayBackend>, faults: FaultConfig) -> FaultyBackend {
        FaultyBackend { inner, faults, batches: AtomicUsize::new(0) }
    }

    pub fn run_batch_timed(&self, rows: &[&[f32]]) -> Result<BatchRun> {
        let n = self.batches.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.faults.latency.is_zero() {
            std::thread::sleep(self.faults.latency);
        }
        if self.faults.panic_every.is_some_and(|k| k > 0 && n % k == 0) {
            panic!("injected fault: batch {n}");
        }
        if self.faults.error_every.is_some_and(|k| k > 0 && n % k == 0) {
            return Err(anyhow!("injected fault: batch {n}"));
        }
        self.inner.run_batch_timed(rows)
    }
}

/// Best-effort human message out of a panic payload (panics carry
/// `&'static str` or `String` in practice; anything else gets a marker).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Answer a request whose deadline passed while it sat on the queue:
/// typed reply, shed counter (`reason="deadline"`), and a span whose
/// outcome is [`Outcome::Deadline`] — a real queue-wait, no batch or
/// execute phases, and no batch slot spent.
fn answer_deadline(
    req: Request,
    worker: usize,
    drained: Instant,
    stats: &Stats,
    tele: &ServeTelemetry,
) {
    let _ = req.respond.send("error: deadline exceeded".to_string());
    stats.deadline_dropped.fetch_add(1, Ordering::Relaxed);
    tele.shed_deadline.inc();
    let span = RequestSpan {
        id: req.id,
        worker,
        batch_size: 0,
        enqueued_us: crate::telemetry::span::micros_since_epoch(req.enqueued),
        queue_wait: drained.saturating_duration_since(req.enqueued),
        batch_form: Duration::ZERO,
        compile: Duration::ZERO,
        compile_hit: false,
        execute: Duration::ZERO,
        total: req.enqueued.elapsed(),
        outcome: Outcome::Deadline,
        compile_fallback: None,
    };
    tele.record(&span);
}

/// One batcher worker: drain a batch from the admission queue, run the
/// backend under `catch_unwind`, fan replies out, then record each
/// request's span. Exits when the queue is closed **and** drained — the
/// graceful-shutdown contract: every admitted request gets a reply.
///
/// Batch formation is continuous and deadline-aware: the batch closes
/// when it is full, when `straggler_wait` lapses (measured from draining
/// the first member), or at the tightest member deadline — whichever
/// comes first. A lone request with 250ms of slack dispatches in ~250ms
/// even under a 5s straggler window. Requests that are already past
/// their deadline at drain time are answered and dropped without
/// costing a batch slot.
fn worker_loop(
    worker: usize,
    queue: &AdmissionQueue<Request>,
    stats: &Stats,
    tele: &ServeTelemetry,
    max_batch: usize,
    straggler_wait: Duration,
    mut exec: impl FnMut(&[&[f32]]) -> Result<BatchRun>,
) {
    'serve: loop {
        // Pop the first *live* request (dead-on-arrival ones are answered
        // inline); `Closed` here means closed-and-drained — time to exit.
        let (first, first_drained) = loop {
            match queue.pop_timeout(IDLE_POLL) {
                Pop::Closed => break 'serve,
                Pop::Timeout => continue,
                Pop::Item(req) => {
                    let now = Instant::now();
                    if now >= req.deadline {
                        answer_deadline(req, worker, now, stats, tele);
                        continue;
                    }
                    break (req, now);
                }
            }
        };
        let mut form_deadline = (first_drained + straggler_wait).min(first.deadline);
        let mut batch = vec![(first, first_drained)];
        while batch.len() < max_batch {
            match queue.pop_until(form_deadline) {
                Pop::Item(req) => {
                    let now = Instant::now();
                    if now >= req.deadline {
                        answer_deadline(req, worker, now, stats, tele);
                        continue;
                    }
                    form_deadline = form_deadline.min(req.deadline);
                    batch.push((req, now));
                }
                Pop::Timeout => break,
                // Dispatch what we hold; the next outer pop sees Closed
                // again once the queue is fully drained.
                Pop::Closed => break,
            }
        }
        let batch_ready = Instant::now();
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.requests.fetch_add(batch.len(), Ordering::Relaxed);
        stats.per_worker[worker].fetch_add(batch.len(), Ordering::Relaxed);
        tele.batches.inc();
        tele.requests.add(batch.len() as u64);
        let rows: Vec<&[f32]> =
            batch.iter().map(|(r, _)| r.features.as_slice()).collect();
        let exec_start = Instant::now();
        // A panicking kernel must cost one batch, not one worker: catch
        // it, answer the batch with a typed error, keep serving.
        let run = match catch_unwind(AssertUnwindSafe(|| exec(&rows))) {
            Ok(r) => r,
            Err(payload) => {
                stats.panics.fetch_add(1, Ordering::Relaxed);
                tele.worker_panics.inc();
                Err(anyhow!("worker panicked: {}", panic_message(payload.as_ref())))
            }
        };
        let exec_total = exec_start.elapsed();
        let (reply, compile, compile_hit, outcome, fallback): (Vec<String>, _, _, _, _) =
            match &run {
            Ok(b) => (
                (0..batch.len())
                    .map(|i| match b.preds.get(i) {
                        Some(p) => format!("{p}"),
                        None => "error: missing prediction".to_string(),
                    })
                    .collect(),
                b.compile,
                b.compile_hit,
                Outcome::Ok,
                b.degraded.map(|l| l.digit()),
            ),
            // Failed batches report their outcome honestly: no fake
            // compile-hit, outcome Error on every span.
            Err(e) => (
                batch.iter().map(|_| format!("error: {e}")).collect(),
                Duration::ZERO,
                false,
                Outcome::Error,
                None,
            ),
        };
        let execute = exec_total.saturating_sub(compile);
        let batch_size = batch.len();
        for ((req, drained), out) in batch.into_iter().zip(reply) {
            // Reply first — telemetry must never sit between a prediction
            // and the client waiting on it.
            let _ = req.respond.send(out);
            let span = RequestSpan {
                id: req.id,
                worker,
                batch_size,
                enqueued_us: crate::telemetry::span::micros_since_epoch(req.enqueued),
                queue_wait: drained.saturating_duration_since(req.enqueued),
                batch_form: batch_ready.saturating_duration_since(drained),
                compile,
                compile_hit,
                execute,
                total: req.enqueued.elapsed(),
                outcome,
                compile_fallback: fallback,
            };
            tele.record(&span);
        }
    }
}

/// Respawns dead worker threads and keeps the fleet gauges truthful, then
/// runs the graceful drain when `stop` is raised. Separated from [`serve`]
/// (spawning is injected) so the respawn logic is unit-testable without
/// sockets or backends.
struct Supervisor {
    stop: Arc<AtomicBool>,
    poll: Duration,
    respawns: Arc<Counter>,
    alive: Arc<Gauge>,
}

impl Supervisor {
    /// Poll `handles` for finished threads, respawning via `spawn` (up to
    /// [`MAX_WORKER_RESPAWNS`] lifetime respawns). When `stop` is raised:
    /// `on_stop` (close the queue), join every worker (they drain the
    /// queue first), zero the alive gauge, then `after_drain` (flush
    /// sinks, reconcile the depth gauge).
    fn run(
        &self,
        mut handles: Vec<Option<JoinHandle<()>>>,
        spawn: impl Fn(usize) -> Option<JoinHandle<()>>,
        on_stop: impl FnOnce(),
        after_drain: impl FnOnce(),
    ) {
        let mut respawns_left = MAX_WORKER_RESPAWNS;
        while !self.stop.load(Ordering::Relaxed) {
            for (w, slot) in handles.iter_mut().enumerate() {
                if slot.as_ref().is_some_and(|h| h.is_finished()) {
                    // Reap the corpse first so its panic payload (if any)
                    // is consumed rather than leaked.
                    if let Some(h) = slot.take() {
                        let _ = h.join();
                    }
                    if self.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if respawns_left == 0 {
                        continue;
                    }
                    respawns_left -= 1;
                    self.respawns.inc();
                    *slot = spawn(w);
                }
            }
            let live = handles.iter().filter(|h| h.is_some()).count();
            self.alive.set(live as i64);
            std::thread::sleep(self.poll);
        }
        on_stop();
        for h in handles.iter_mut().filter_map(|s| s.take()) {
            let _ = h.join();
        }
        self.alive.set(0);
        after_drain();
    }
}

/// PJRT executor over the AOT artifact (single-threaded: the xla crate
/// wraps raw pointers in `Rc`, so the handles must stay on one thread).
type ExecFn = Box<dyn FnMut(&[&[f32]]) -> Result<BatchRun>>;

fn pjrt_exec_fn(artifact_dir: &Path) -> Result<(usize, ExecFn)> {
    let rt = Runtime::cpu()?;
    let manifest = crate::runtime::manifest::load(&artifact_dir.join("manifest.json"))
        .map_err(|e| anyhow!("{e}"))?;
    let entry = manifest
        .get("mlp_forward")
        .ok_or_else(|| anyhow!("mlp_forward not in manifest"))?
        .clone();
    let exe = rt.load_artifact(&artifact_dir.join("mlp_forward.hlo.txt"))?;
    let x_spec = entry
        .inputs
        .last()
        .ok_or_else(|| {
            anyhow!(
                "manifest entry mlp_forward has an empty inputs list \
                 (expected [weights..., x])"
            )
        })?
        .clone();
    if x_spec.shape.len() < 2 {
        return Err(anyhow!(
            "mlp_forward input spec must be (batch, feat), got {:?}",
            x_spec.shape
        ));
    }
    let (batch_cap, feat) = (x_spec.shape[0], x_spec.shape[1]);
    // Deterministic weights (a real deployment would load trained
    // parameters; see examples/train_mlp.rs). One RNG across all weights:
    // re-seeding per tensor would hand every weight the same value stream.
    let mut rng = crate::tensor::Rng::new(17);
    let weights: Vec<Tensor> = entry.inputs[..entry.inputs.len() - 1]
        .iter()
        .map(|s| rng.normal_tensor(&s.shape, 0.1))
        .collect();
    let f: ExecFn = Box::new(move |rows: &[&[f32]]| {
        // The AOT artifact is genuinely fixed-shape: padding is the cost
        // of serving it, and it shows up in relay_padded_rows_total.
        let padded = batch_cap.saturating_sub(rows.len());
        if padded > 0 {
            crate::telemetry::registry()
                .counter(names::PADDED_ROWS_TOTAL)
                .add(padded as u64);
        }
        let x = pad_rows(rows, batch_cap, feat);
        let mut inputs = weights.clone();
        inputs.push(x);
        let outs = rt.execute(&exe, &inputs)?;
        Ok(BatchRun {
            preds: crate::tensor::argmax(&outs[0], 1).as_i64().to_vec(),
            // The artifact was compiled ahead of time; serving never pays
            // a compile, so every batch reports a hit with zero cost.
            compile: Duration::ZERO,
            compile_hit: true,
            degraded: None,
        })
    });
    Ok((batch_cap, f))
}

/// A running fleet, as handed back by [`serve_handle`]. Dropping the
/// handle leaves the fleet running (like [`serve`]); [`shutdown`] runs
/// the graceful drain to completion before returning.
///
/// [`shutdown`]: ServerHandle::shutdown
pub struct ServerHandle {
    stats: Arc<Stats>,
    stop: Arc<AtomicBool>,
    supervisor: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stats(&self) -> Arc<Stats> {
        self.stats.clone()
    }

    /// Graceful drain, synchronously: raise `stop`, then join the
    /// supervisor — which closes the queue (late arrivals shed with
    /// `shed: shutting down`), joins every worker after the queue
    /// empties, zeroes the alive gauge, and flushes the span sink.
    /// When this returns, every admitted request has been answered.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }

    /// Leave the fleet running unsupervised by this handle; the caller's
    /// `stop` flag still triggers the same graceful drain, detached.
    pub fn detach(mut self) {
        self.supervisor.take();
    }
}

fn bind_front_door(port: u16) -> Result<TcpListener> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    listener.set_nonblocking(true)?;
    Ok(listener)
}

/// Per-worker spawner: the supervisor calls it to (re)create worker `w`.
/// `None` means the spawn itself failed terminally for this attempt.
type Spawn = Box<dyn Fn(usize) -> Option<JoinHandle<()>> + Send>;

/// Start the fleet and return a [`ServerHandle`]. Non-blocking; see
/// [`serve`] for the fire-and-forget variant the CLI uses.
pub fn serve_handle(cfg: ServerConfig, stop: Arc<AtomicBool>) -> Result<ServerHandle> {
    if cfg.kernel_threads > 0 {
        crate::tensor::parallel::set_kernel_threads(cfg.kernel_threads);
    }
    let pjrt = artifacts_available(&cfg.artifact_dir);
    let workers = if pjrt { 1 } else { cfg.workers.max(1) };
    let mut stats = Stats::new(workers, cfg.opt_level);
    stats.fixpoint = cfg.fixpoint;
    let stats = Arc::new(stats);
    let tele = Arc::new(ServeTelemetry::register(cfg.port, cfg.trace.clone()));
    // The queue owns the depth gauge: exact-length updates under its lock.
    let queue = Arc::new(AdmissionQueue::new(cfg.queue_budget, tele.queue_depth.clone()));
    let max_batch = cfg.max_batch.max(1);
    let straggler_wait = cfg.batch_timeout;

    let (spawn, initial): (Spawn, Vec<Option<JoinHandle<()>>>) = if pjrt {
        // Single batcher thread owning the !Send PJRT client + executable;
        // setup happens inside the thread. Only the very first worker
        // reports readiness (the slot is taken once); respawned ones
        // either come up or die and are respawned again, up to the cap.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let ready_slot = Arc::new(Mutex::new(Some(ready_tx)));
        let artifact_dir = cfg.artifact_dir.clone();
        let stats_s = stats.clone();
        let tele_s = tele.clone();
        let queue_s = queue.clone();
        let spawn: Spawn = Box::new(move |_worker| {
            let artifact_dir = artifact_dir.clone();
            let stats = stats_s.clone();
            let tele = tele_s.clone();
            let queue = queue_s.clone();
            let ready = crate::eval::value::lock_unpoisoned(&ready_slot).take();
            Some(std::thread::spawn(move || {
                let (batch_cap, exec_fn) = match pjrt_exec_fn(&artifact_dir) {
                    Ok(x) => {
                        if let Some(tx) = &ready {
                            let _ = tx.send(Ok(()));
                        }
                        x
                    }
                    Err(e) => {
                        if let Some(tx) = &ready {
                            let _ = tx.send(Err(e));
                        }
                        return;
                    }
                };
                worker_loop(
                    0,
                    &queue,
                    &stats,
                    &tele,
                    max_batch.min(batch_cap).max(1),
                    straggler_wait,
                    exec_fn,
                );
            }))
        });
        let first = spawn(0);
        // Readiness handshake before any socket exists: a missing or
        // broken artifact fails serve_handle() on the caller's thread
        // instead of surfacing as client timeouts.
        let verdict = ready_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow!("executor thread did not start"))
            .and_then(|r| r);
        if let Err(e) = verdict {
            queue.close();
            if let Some(h) = first {
                let _ = h.join();
            }
            return Err(e);
        }
        (spawn, vec![first])
    } else {
        // Compiled-relay fleet: one shared backend (one shared program
        // cache), N workers. Backend construction fails fast here, on the
        // caller's thread, before any socket is bound — and every artifact
        // compiles through the optimizing pipeline at cfg.opt_level.
        // cfg.poly picks shape-polymorphic (one symbolic-batch artifact)
        // vs the bucketed baseline.
        let cache = Arc::new(ProgramCache::new());
        // Compile-fault injection must be installed *before* the backend's
        // warm-up compile so even the first compile can fail — the backend
        // tolerates that (breaker + ladder) by design.
        if let Some(f) = &cfg.fault {
            let (panic_every, error_every) =
                (f.compile_panic_every, f.compile_error_every);
            if panic_every.is_some() || error_every.is_some() {
                let attempts = AtomicUsize::new(0);
                cache.set_compile_hook(Arc::new(move |_m, _o| {
                    let n = attempts.fetch_add(1, Ordering::Relaxed) + 1;
                    if panic_every.is_some_and(|k| k > 0 && n % k == 0) {
                        panic!("injected compile panic: attempt {n}");
                    }
                    if error_every.is_some_and(|k| k > 0 && n % k == 0) {
                        return Err(format!("injected compile error: attempt {n}"));
                    }
                    Ok(())
                }));
            }
        }
        let resilience = ResilienceConfig {
            max_opt_retries: cfg.max_opt_retries,
            breaker_threshold: cfg.breaker_threshold,
            breaker_cooldown: cfg.breaker_cooldown,
            scope: format!("port-{}", cfg.port),
        };
        let opts = CompileOptions::at(cfg.executor, cfg.opt_level).with_fixpoint(cfg.fixpoint);
        let backend = Arc::new(if cfg.poly {
            RelayBackend::new_with(max_batch, opts, cache, stats.clone(), resilience)?
        } else {
            RelayBackend::bucketed_with(max_batch, opts, cache, stats.clone(), resilience)?
        });
        let exec: Arc<dyn Fn(&[&[f32]]) -> Result<BatchRun> + Send + Sync> =
            match &cfg.fault {
                Some(f) => {
                    let faulty = Arc::new(FaultyBackend::new(backend, f.clone()));
                    Arc::new(move |rows: &[&[f32]]| faulty.run_batch_timed(rows))
                }
                None => Arc::new(move |rows: &[&[f32]]| backend.run_batch_timed(rows)),
            };
        let stats_s = stats.clone();
        let tele_s = tele.clone();
        let queue_s = queue.clone();
        let spawn: Spawn = Box::new(move |worker| {
            let exec = exec.clone();
            let stats = stats_s.clone();
            let tele = tele_s.clone();
            let queue = queue_s.clone();
            Some(std::thread::spawn(move || {
                worker_loop(
                    worker,
                    &queue,
                    &stats,
                    &tele,
                    max_batch,
                    straggler_wait,
                    move |rows: &[&[f32]]| exec(rows),
                );
            }))
        });
        let mut initial = Vec::with_capacity(workers);
        for w in 0..workers {
            initial.push(spawn(w));
        }
        (spawn, initial)
    };
    tele.workers_alive.set(initial.iter().filter(|h| h.is_some()).count() as i64);

    let listener = match bind_front_door(cfg.port) {
        Ok(l) => l,
        Err(e) => {
            // The workers are already up; drain them before reporting the
            // bind failure so serve_handle never leaks a fleet.
            queue.close();
            for h in initial.into_iter().flatten() {
                let _ = h.join();
            }
            tele.workers_alive.set(0);
            return Err(e);
        }
    };

    // Supervisor: respawn dead workers while running; on stop, close the
    // queue, join the drained workers, flush the span sink, and leave the
    // depth gauge reconciled with reality.
    let sup = Supervisor {
        stop: stop.clone(),
        poll: SUPERVISOR_POLL,
        respawns: tele.worker_respawns.clone(),
        alive: tele.workers_alive.clone(),
    };
    let queue_sup = queue.clone();
    let sink = cfg.trace.clone();
    let supervisor = std::thread::spawn(move || {
        sup.run(
            initial,
            spawn,
            || queue_sup.close(),
            || {
                if let Some(s) = &sink {
                    s.flush();
                }
                queue_sup.reconcile_gauge();
            },
        );
    });

    // Accept loop.
    let default_deadline = cfg.default_deadline.min(MAX_DEADLINE);
    let queue_acc = queue.clone();
    let tele_acc = tele.clone();
    let stats_acc = stats.clone();
    let stop_acc = stop.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop_acc.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let queue = queue_acc.clone();
                    let tele = tele_acc.clone();
                    let stats = stats_acc.clone();
                    std::thread::spawn(move || {
                        handle_client(stream, queue, tele, stats, default_deadline)
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    Ok(ServerHandle { stats, stop, supervisor: Some(supervisor) })
}

/// Serve the `mlp_forward` artifact, detached (the CLI entrypoint shape):
/// returns the live [`Stats`]; raising `stop` later triggers the same
/// graceful drain, unobserved. Embedders that want to *wait* for the
/// drain use [`serve_handle`] + [`ServerHandle::shutdown`].
pub fn serve(cfg: ServerConfig, stop: Arc<AtomicBool>) -> Result<Arc<Stats>> {
    let handle = serve_handle(cfg, stop)?;
    let stats = handle.stats();
    handle.detach();
    Ok(stats)
}

/// Split an optional `deadline_ms=N;` prefix off a request line. Returns
/// the allowance (clamped to [`MAX_DEADLINE`]) and the remaining CSV
/// payload; a malformed prefix is a typed error reply, not a guess.
fn parse_deadline<'a>(
    line: &'a str,
    default_deadline: Duration,
) -> std::result::Result<(Duration, &'a str), String> {
    let Some(rest) = line.strip_prefix("deadline_ms=") else {
        return Ok((default_deadline.min(MAX_DEADLINE), line));
    };
    let Some((ms, payload)) = rest.split_once(';') else {
        return Err(
            "error: malformed deadline prefix (expected deadline_ms=N;features)"
                .to_string(),
        );
    };
    match ms.trim().parse::<u64>() {
        Ok(v) => Ok((Duration::from_millis(v).min(MAX_DEADLINE), payload)),
        Err(_) => Err(format!("error: bad deadline_ms {ms:?}")),
    }
}

/// One bounded read off the wire: at most [`MAX_LINE_BYTES`] of request
/// line (newline excluded). The byte budget is enforced *while reading* —
/// an attacker streaming an endless line cannot grow a worker-side buffer
/// past the cap.
enum WireLine {
    /// A complete line (possibly without its trailing newline when the
    /// client disconnected mid-line — processed all the same, then the
    /// next read sees EOF and closes cleanly).
    Ok(Vec<u8>),
    /// Clean end of stream.
    Eof,
    /// The line exceeded [`MAX_LINE_BYTES`]: typed reply, then close.
    TooLong,
    /// Transport error: close without a reply (there is no one to hear it).
    Io,
}

fn read_wire_line(reader: &mut BufReader<TcpStream>) -> WireLine {
    let mut buf = Vec::new();
    // +1 so a line of exactly MAX_LINE_BYTES plus its newline still fits,
    // while anything longer is detectably over budget.
    match Read::by_ref(reader).take(MAX_LINE_BYTES as u64 + 1).read_until(b'\n', &mut buf)
    {
        Ok(0) => WireLine::Eof,
        Ok(_) => {
            if buf.len() > MAX_LINE_BYTES && !buf.ends_with(b"\n") {
                WireLine::TooLong
            } else {
                WireLine::Ok(buf)
            }
        }
        Err(_) => WireLine::Io,
    }
}

fn handle_client(
    stream: TcpStream,
    queue: Arc<AdmissionQueue<Request>>,
    tele: Arc<ServeTelemetry>,
    stats: Arc<Stats>,
    default_deadline: Duration,
) {
    let peer = stream.try_clone();
    let mut reader = BufReader::new(stream);
    let mut writer = match peer {
        Ok(s) => s,
        Err(_) => return,
    };
    loop {
        let raw = match read_wire_line(&mut reader) {
            WireLine::Ok(raw) => raw,
            WireLine::Eof | WireLine::Io => break,
            WireLine::TooLong => {
                let _ = writeln!(writer, "error: request line too long");
                break;
            }
        };
        // Hostile bytes are a typed reply, never a worker panic: the
        // request stays bytes until it proves to be UTF-8.
        let line = match std::str::from_utf8(&raw) {
            Ok(l) => l,
            Err(_) => {
                if writeln!(writer, "error: request is not valid utf-8").is_err() {
                    break;
                }
                continue;
            }
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(req_line) = trimmed.strip_prefix("GET ") {
            // The metrics endpoint shares the line-protocol front door:
            // drain the HTTP headers (bounded reads, same cap), answer
            // once, close.
            loop {
                match read_wire_line(&mut reader) {
                    WireLine::Ok(h) => {
                        if String::from_utf8_lossy(&h).trim().is_empty() {
                            break;
                        }
                    }
                    _ => break,
                }
            }
            serve_http(&mut writer, req_line);
            return;
        }
        let (allowance, payload) = match parse_deadline(trimmed, default_deadline) {
            Ok(x) => x,
            Err(reply) => {
                if writeln!(writer, "{reply}").is_err() {
                    break;
                }
                continue;
            }
        };
        let features: Vec<f32> = payload
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        let (rtx, rrx) = channel();
        let enqueued = Instant::now();
        let req = Request {
            id: next_request_id(),
            features,
            respond: rtx,
            enqueued,
            deadline: enqueued + allowance,
        };
        if let Err((req, why)) = queue.push(req) {
            // Shed at the door: typed reply, reasoned counter, and a span
            // that never reached a worker (zero phases, outcome Shed).
            let (reason, counter) = match why {
                Reject::Full => ("queue full", &tele.shed_queue_full),
                Reject::Closed => ("shutting down", &tele.shed_shutdown),
            };
            stats.shed.fetch_add(1, Ordering::Relaxed);
            counter.inc();
            let span = RequestSpan {
                id: req.id,
                worker: 0,
                batch_size: 0,
                enqueued_us: crate::telemetry::span::micros_since_epoch(req.enqueued),
                queue_wait: Duration::ZERO,
                batch_form: Duration::ZERO,
                compile: Duration::ZERO,
                compile_hit: false,
                execute: Duration::ZERO,
                total: req.enqueued.elapsed(),
                outcome: Outcome::Shed,
                compile_fallback: None,
            };
            tele.record(&span);
            if writeln!(writer, "shed: {reason}").is_err() {
                break;
            }
            continue;
        }
        // Admitted requests always get an answer by their deadline (plus
        // execution time); the margin here only guards against a fleet
        // that died mid-request.
        match rrx.recv_timeout(allowance + Duration::from_secs(10)) {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Minimal HTTP/1.0 responder for the front door's `GET` path:
/// `/metrics` renders the telemetry registry, anything else 404s.
fn serve_http(writer: &mut TcpStream, request_line: &str) {
    let path = request_line.split_whitespace().next().unwrap_or("");
    let (status, body) = if path == "/metrics" {
        ("200 OK".to_string(), crate::telemetry::registry().render())
    } else {
        ("404 Not Found".to_string(), format!("no route {path}\n"))
    };
    let _ = write!(
        writer,
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Connect to a local server with read/write timeouts: a hung server
/// fails the caller in [`CLIENT_IO_TIMEOUT`], never wedges it.
fn client_stream(port: u16) -> Result<TcpStream> {
    let stream = TcpStream::connect(("127.0.0.1", port))?;
    stream.set_read_timeout(Some(CLIENT_IO_TIMEOUT))?;
    stream.set_write_timeout(Some(CLIENT_IO_TIMEOUT))?;
    Ok(stream)
}

/// Fetch `/metrics` from a server on localhost over its front-door port
/// (`relay metrics`, the CI smoke test, and unit tests).
pub fn fetch_metrics(port: u16) -> Result<String> {
    let mut stream = client_stream(port)?;
    write!(stream, "GET /metrics HTTP/1.0\r\n\r\n")?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response: {resp:?}"))?;
    if !head.starts_with("HTTP/1.0 200") {
        return Err(anyhow!(
            "unexpected status: {}",
            head.lines().next().unwrap_or("")
        ));
    }
    Ok(body.to_string())
}

/// One request, raw reply line: the full wire protocol (optional
/// `deadline_ms`), returning typed replies (`shed: ...`, `error: ...`)
/// verbatim. Tests and the saturation bench assert on these.
pub fn classify_line(
    port: u16,
    features: &[f32],
    deadline_ms: Option<u64>,
) -> Result<String> {
    let mut stream = client_stream(port)?;
    let csv: Vec<String> = features.iter().map(|f| f.to_string()).collect();
    let csv = csv.join(",");
    match deadline_ms {
        Some(d) => writeln!(stream, "deadline_ms={d};{csv}")?,
        None => writeln!(stream, "{csv}")?,
    }
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Ok(resp.trim().to_string())
}

/// Client helper (used by examples and tests): one request, parsed
/// prediction. Typed `shed:`/`error:` replies surface as `Err`.
pub fn classify(port: u16, features: &[f32]) -> Result<i64> {
    let resp = classify_line(port, features, None)?;
    resp.parse().map_err(|e| anyhow!("bad response {resp:?}: {e}"))
}

/// Bounded exponential backoff with deterministic jitter for the client
/// helpers. Retries cover *transient* failures only: `shed:` replies
/// (overload passes) and transport errors (connect/read failures). Typed
/// `error:` replies are definitive — the server answered; retrying would
/// just repeat the answer.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts, first one included (1 = no retry).
    pub max_attempts: usize,
    /// Backoff before the second attempt; doubles each retry after.
    pub base: Duration,
    /// Ceiling on the exponential term.
    pub cap: Duration,
    /// Seed for the deterministic jitter hash — same seed, same schedule,
    /// so tests can assert exact delays.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(250),
            jitter_seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// Delay to sleep before `attempt` (1-indexed): zero before the first
    /// attempt, then `min(base * 2^(attempt-2), cap)` plus a deterministic
    /// jitter in `[0, exp/2]` — jitter spreads synchronized retriers
    /// without `rand`, and the fixed seed keeps schedules reproducible.
    pub fn delay_before(&self, attempt: usize) -> Duration {
        if attempt <= 1 {
            return Duration::ZERO;
        }
        let doublings = (attempt - 2).min(32) as u32;
        let exp = self
            .base
            .saturating_mul(1u32.checked_shl(doublings).unwrap_or(u32::MAX))
            .min(self.cap);
        let half = exp.as_micros() as u64 / 2;
        let jitter_us = if half == 0 {
            0
        } else {
            // splitmix64 over (seed, attempt): cheap, stateless, stable.
            let mut z = self
                .jitter_seed
                .wrapping_add(attempt as u64)
                .wrapping_add(0x9e37_79b9_7f4a_7c15);
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            (z ^ (z >> 31)) % (half + 1)
        };
        exp + Duration::from_micros(jitter_us)
    }
}

/// A retried call's result plus how many attempts it took — callers (the
/// chaos bench, saturation clients) surface attempt counts instead of
/// hiding the retries.
#[derive(Debug)]
pub struct Attempted<T> {
    pub value: T,
    pub attempts: usize,
}

/// [`classify`] with bounded retry under `policy`: `shed:` replies and
/// transport errors back off and retry; typed `error:` replies return
/// immediately (never retried). The error message always names the
/// attempt count.
pub fn classify_with_retry(
    port: u16,
    features: &[f32],
    deadline_ms: Option<u64>,
    policy: &RetryPolicy,
) -> Result<Attempted<i64>> {
    let attempts = policy.max_attempts.max(1);
    let mut last = String::new();
    for attempt in 1..=attempts {
        let delay = policy.delay_before(attempt);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        match classify_line(port, features, deadline_ms) {
            Ok(reply) => {
                if reply.starts_with("shed:") {
                    // Transient overload: the request was never admitted;
                    // retrying is safe and is the point of the policy.
                    last = reply;
                    continue;
                }
                if reply.starts_with("error:") {
                    return Err(anyhow!("{reply} (attempt {attempt}, not retried)"));
                }
                let value = reply
                    .parse()
                    .map_err(|e| anyhow!("bad response {reply:?}: {e}"))?;
                return Ok(Attempted { value, attempts: attempt });
            }
            Err(e) => {
                last = format!("transport: {e}");
                continue;
            }
        }
    }
    Err(anyhow!("{last} (after {attempts} attempts)"))
}

/// [`fetch_metrics`] with bounded retry for transport errors (a server
/// mid-restart, a listener backlog hiccup). Metrics replies have no
/// `shed:` form; any well-formed response returns immediately.
pub fn fetch_metrics_with_retry(
    port: u16,
    policy: &RetryPolicy,
) -> Result<Attempted<String>> {
    let attempts = policy.max_attempts.max(1);
    let mut last = String::new();
    for attempt in 1..=attempts {
        let delay = policy.delay_before(attempt);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        match fetch_metrics(port) {
            Ok(body) => return Ok(Attempted { value: body, attempts: attempt }),
            Err(e) => {
                last = format!("{e}");
                continue;
            }
        }
    }
    Err(anyhow!("{last} (after {attempts} attempts)"))
}

/// Is the artifact directory present (CI guard)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("mlp_forward.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn bucket_sizes_are_powers_of_two_up_to_cap() {
        assert_eq!(bucket_sizes(1), vec![1]);
        assert_eq!(bucket_sizes(4), vec![1, 2, 4]);
        assert_eq!(bucket_sizes(8), vec![1, 2, 4, 8]);
        // Non-power-of-two cap is kept as the final bucket.
        assert_eq!(bucket_sizes(6), vec![1, 2, 4, 6]);
        assert_eq!(bucket_sizes(0), vec![1]);
    }

    #[test]
    fn pad_rows_pads_and_truncates() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32];
        let rows: Vec<&[f32]> = vec![&a, &b];
        let t = pad_rows(&rows, 4, 2);
        assert_eq!(t.shape(), &[4, 2]);
        assert_eq!(t.as_f32(), &[1.0, 2.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn deadline_prefix_parses_and_clamps() {
        let default = Duration::from_secs(1);
        let (d, rest) = parse_deadline("deadline_ms=250;1,2,3", default).unwrap();
        assert_eq!(d, Duration::from_millis(250));
        assert_eq!(rest, "1,2,3");
        // No prefix: the server default applies, payload untouched.
        let (d, rest) = parse_deadline("1,2,3", default).unwrap();
        assert_eq!(d, default);
        assert_eq!(rest, "1,2,3");
        // Absurd client deadlines clamp instead of overflowing Instant
        // arithmetic an hour of slack is indistinguishable from forever.
        let (d, _) =
            parse_deadline("deadline_ms=18446744073709551615;1", default).unwrap();
        assert_eq!(d, MAX_DEADLINE);
        assert!(parse_deadline("deadline_ms=;1,2", default).is_err());
        assert!(parse_deadline("deadline_ms=abc;1,2", default).is_err());
        // Prefix without a payload separator is malformed, not a guess.
        assert!(parse_deadline("deadline_ms=5", default).is_err());
    }

    #[test]
    fn fallback_backend_serves_through_the_vm() {
        let port = 7981;
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        // Skip only when this exact address is unusable (no loopback, or
        // the port is held by another process); any serve() error past
        // that (e.g. a backend compile regression) must fail the test.
        match std::net::TcpListener::bind(("127.0.0.1", port)) {
            Ok(probe) => drop(probe),
            Err(_) => return,
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..4i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 7 + j) % 5) as f32 - 2.0)
                .collect();
            let pred = classify(port, &features).expect("classify");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        assert!(stats.requests.load(Ordering::Relaxed) >= 4);
        // Sequential clients mean every batch had size 1, so only the
        // batch-1 bucket compiled: 4 requests, exactly 1 compile — the
        // compile-once serving property of the program cache.
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 1);
        // The default server optimizes its buckets at -O3.
        assert_eq!(stats.opt_level, OptLevel::O3);
        // Every served request was attributed to some worker.
        let per_worker: usize = stats
            .per_worker
            .iter()
            .map(|w| w.load(Ordering::Relaxed))
            .sum();
        assert_eq!(per_worker, stats.requests.load(Ordering::Relaxed));
        stop.store(true, Ordering::Relaxed);
    }

    /// The acceptance bar for the bucketed baseline (`--poly=off`): a
    /// 4-thread fleet over one shared backend/cache compiles each batch
    /// bucket exactly once for the whole process — **at -O3** — no matter
    /// how the threads interleave, and the compiled buckets run fused
    /// kernels (fewer launches than an -O0 compile of the same bucket).
    #[test]
    fn four_thread_fleet_compiles_each_bucket_exactly_once() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(4, OptLevel::O3));
        let backend = Arc::new(
            RelayBackend::bucketed(
                8,
                CompileOptions::at(Executor::Vm, OptLevel::O3),
                cache.clone(),
                stats.clone(),
            )
            .expect("backend"),
        );
        let buckets = backend.bucket_count(); // 1, 2, 4, 8
        assert_eq!(buckets, 4);
        std::thread::scope(|s| {
            for t in 0..4usize {
                let backend = backend.clone();
                s.spawn(move || {
                    for round in 0..3usize {
                        for n in [1usize, 2, 3, 5, 8] {
                            let rows_data: Vec<Vec<f32>> = (0..n)
                                .map(|i| {
                                    (0..FALLBACK_FEAT)
                                        .map(|j| {
                                            ((t + round + i * 7 + j) % 5) as f32 - 2.0
                                        })
                                        .collect()
                                })
                                .collect();
                            let rows: Vec<&[f32]> =
                                rows_data.iter().map(|r| r.as_slice()).collect();
                            let preds = backend.run_batch(&rows).expect("run_batch");
                            assert_eq!(preds.len(), n, "one prediction per row");
                            for p in preds {
                                assert!(
                                    (0..FALLBACK_CLASSES as i64).contains(&p),
                                    "pred {p}"
                                );
                            }
                        }
                    }
                });
            }
        });
        // 4 threads x 3 rounds x every bucket shape: still exactly one
        // compile per bucket, fleet-wide.
        assert_eq!(stats.compiles.load(Ordering::Relaxed), buckets);
        assert_eq!(cache.misses(), buckets);
        assert_eq!(cache.len(), buckets);
        // Batches of 3 and 5 padded up to buckets 4 and 8: the baseline's
        // padding waste is visible (4 threads x 3 rounds x (1 + 3) rows).
        assert_eq!(stats.padded_rows.load(Ordering::Relaxed), 4 * 3 * 4);

        // The -O3 buckets the fleet served are genuinely fused: the same
        // bucket module compiled at -O0 launches more kernels (the
        // fallback MLP is dense/relu/dense = 3 unfused ops) than the
        // fleet's program did on an identical batch.
        let row: Vec<f32> = (0..FALLBACK_FEAT).map(|j| j as f32 * 0.1 - 0.5).collect();
        let rows: Vec<&[f32]> = vec![&row];
        let x = pad_rows(&rows, backend.artifact(0).size, FALLBACK_FEAT);
        let o3_resolution = backend.resolve(backend.artifact(0));
        assert!(o3_resolution.degraded_to.is_none(), "healthy bucket degraded");
        let o3 = run_compiled(&o3_resolution.compiled, vec![Value::Tensor(x.clone())])
            .expect("o3 run");
        let (o0_compiled, _) = cache
            .get_or_compile_traced(
                &backend.artifact(0).module,
                CompileOptions::at(Executor::Vm, OptLevel::O0),
            )
            .expect("o0 compile");
        let o0 = run_compiled(&o0_compiled, vec![Value::Tensor(x)]).expect("o0 run");
        assert!(
            o3.launches < o0.launches,
            "fleet bucket not fused: O3 {} launches vs O0 {}",
            o3.launches,
            o0.launches
        );
        // Fusion must not change what the bucket computes.
        assert!(o3.value.bits_eq(&o0.value));
    }

    #[test]
    fn fixpoint_buckets_compile_under_their_own_cache_key_and_serve_identically() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let plain_opts = CompileOptions::at(Executor::Vm, OptLevel::O3);
        let backend = RelayBackend::new(
            2,
            plain_opts.with_fixpoint(true),
            cache.clone(),
            stats.clone(),
        )
        .expect("fixpoint backend");
        let row: Vec<f32> = (0..FALLBACK_FEAT).map(|j| (j % 5) as f32 - 2.0).collect();
        let rows: Vec<&[f32]> = vec![&row];
        let fix_preds = backend.run_batch(&rows).expect("fixpoint batch");
        assert_eq!(fix_preds.len(), 1);
        // The plain (non-fixpoint) compile of the same module is a
        // distinct cache entry: requesting it compiles anew...
        let (plain, compiled_now) = cache
            .get_or_compile_traced(&backend.artifact(0).module, plain_opts)
            .expect("plain compile");
        assert!(compiled_now, "fixpoint and plain artifacts shared one cache entry");
        // ...and computes the same predictions (the polymorphic module
        // runs this one-row batch at exact size).
        let x = pad_rows(&rows, rows.len(), FALLBACK_FEAT);
        let out = run_compiled(&plain, vec![Value::Tensor(x)]).expect("plain run");
        let plain_pred = crate::tensor::argmax(out.value.tensor(), 1).as_i64()[0];
        assert_eq!(fix_preds[0], plain_pred);
        // The lifetime counters are wired: serving the MLP's fused
        // dense->relu chain produced at least one in-place reuse
        // (process-wide counter, so only monotonicity is asserted).
        assert!(stats.inplace_hits() >= 1, "no in-place reuse recorded");
    }

    #[test]
    fn batches_larger_than_a_bucket_pad_up_and_results_match_batch_one() {
        // Bucketed baseline: a 3-row batch runs the bucket-4 program; each
        // row's prediction must equal the prediction the batch-1 program
        // gives that row alone (padding rows cannot leak into real rows).
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let backend =
            RelayBackend::bucketed(4, Executor::Vm, cache, stats.clone())
                .expect("backend");
        let rows_data: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                (0..FALLBACK_FEAT)
                    .map(|j| ((i * 11 + j * 3) % 7) as f32 - 3.0)
                    .collect()
            })
            .collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let batched = backend.run_batch(&rows).expect("batched");
        assert_eq!(batched.len(), 3);
        for (i, row) in rows.iter().enumerate() {
            let solo = backend.run_batch(&[row]).expect("solo");
            assert_eq!(solo.len(), 1);
            assert_eq!(batched[i], solo[0], "row {i} diverged under padding");
        }
        // The 3-row batch padded one row up to bucket 4; the solo runs fit
        // bucket 1 exactly.
        assert_eq!(stats.padded_rows.load(Ordering::Relaxed), 1);
    }

    /// The tentpole acceptance test: ONE symbolic-batch artifact serves
    /// every batch size 1..=max_batch — exactly one compile, one
    /// program-cache entry, zero padded rows.
    #[test]
    fn poly_backend_serves_every_batch_size_with_one_compile() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let backend = RelayBackend::new(
            8,
            CompileOptions::at(Executor::Vm, OptLevel::O3),
            cache.clone(),
            stats.clone(),
        )
        .expect("poly backend");
        assert_eq!(backend.bucket_count(), 1);
        for n in 1..=8usize {
            let rows_data: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    (0..FALLBACK_FEAT)
                        .map(|j| ((i * 13 + j * 5) % 9) as f32 - 4.0)
                        .collect()
                })
                .collect();
            let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
            let preds = backend.run_batch(&rows).expect("poly batch");
            assert_eq!(preds.len(), n, "one prediction per row at batch {n}");
        }
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 1, "one compile for all sizes");
        assert_eq!(cache.len(), 1, "one program-cache entry for all sizes");
        assert_eq!(stats.padded_rows.load(Ordering::Relaxed), 0, "poly never pads");
        // Over-cap batches are refused, not silently truncated.
        let big_row: Vec<f32> = vec![0.0; FALLBACK_FEAT];
        let too_many: Vec<&[f32]> = (0..9).map(|_| big_row.as_slice()).collect();
        assert!(backend.run_batch(&too_many).is_err());
    }

    /// Differential: the polymorphic artifact is bit-identical to the
    /// bucketed/padded baseline at every batch size (same argmax bits —
    /// both run the same fused -O3 kernels, padding rows must not leak).
    #[test]
    fn poly_and_bucketed_backends_agree_at_every_batch_size() {
        let poly = RelayBackend::new(
            8,
            CompileOptions::at(Executor::Vm, OptLevel::O3),
            Arc::new(ProgramCache::new()),
            Arc::new(Stats::new(1, OptLevel::O3)),
        )
        .expect("poly backend");
        let bucketed_stats = Arc::new(Stats::new(1, OptLevel::O3));
        let bucketed = RelayBackend::bucketed(
            8,
            CompileOptions::at(Executor::Vm, OptLevel::O3),
            Arc::new(ProgramCache::new()),
            bucketed_stats.clone(),
        )
        .expect("bucketed backend");
        for n in 1..=8usize {
            let rows_data: Vec<Vec<f32>> = (0..n)
                .map(|i| {
                    (0..FALLBACK_FEAT)
                        .map(|j| ((n * 3 + i * 7 + j * 2) % 11) as f32 - 5.0)
                        .collect()
                })
                .collect();
            let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
            let p = poly.run_batch(&rows).expect("poly");
            let b = bucketed.run_batch(&rows).expect("bucketed");
            assert_eq!(p, b, "poly and bucketed diverged at batch {n}");
        }
        // Sanity that this really was a differential: the baseline padded
        // (batches 3,5,6,7 round up), the poly path never does.
        assert!(bucketed_stats.padded_rows.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn faulty_backend_faults_are_deterministic() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let backend =
            Arc::new(RelayBackend::new(2, Executor::Vm, cache, stats).expect("backend"));
        let faulty = FaultyBackend::new(
            backend,
            FaultConfig { error_every: Some(3), ..Default::default() },
        );
        let row: Vec<f32> = (0..FALLBACK_FEAT).map(|j| j as f32).collect();
        let rows: Vec<&[f32]> = vec![&row];
        for n in 1..=6 {
            let got = faulty.run_batch_timed(&rows);
            if n % 3 == 0 {
                assert!(got.is_err(), "batch {n} should be an injected error");
            } else {
                assert_eq!(got.expect("batch").preds.len(), 1);
            }
        }
    }

    /// Bind-probe helper shared by the socket tests: returns false when
    /// this exact address is unusable (no loopback, or the port is held
    /// by another process) — the only condition that may skip a test.
    fn port_free(port: u16) -> bool {
        std::net::TcpListener::bind(("127.0.0.1", port)).is_ok()
    }

    /// Regression for the batcher's deadline arithmetic: with zero slack
    /// the old `deadline - now` subtraction panicked (`Instant` subtraction
    /// underflows) the moment the first request arrived. The fixed loop
    /// saturates and serves batches of one.
    #[test]
    fn zero_slack_batch_timeout_serves_without_panicking() {
        let port = 7983;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            batch_timeout: Duration::ZERO,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..3i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 3 + j) % 5) as f32 - 2.0)
                .collect();
            let pred = classify(port, &features).expect("classify under zero slack");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        assert!(stats.requests.load(Ordering::Relaxed) >= 3);
        stop.store(true, Ordering::Relaxed);
    }

    /// The observability acceptance bar: N requests through the fleet
    /// leave exactly N observations in this port's request histogram, and
    /// every request's span reaches the configured sink with queue-wait
    /// and execute phases filled in — and an explicit Ok outcome.
    #[test]
    fn fleet_records_request_histogram_and_spans() {
        let port = 7987;
        if !port_free(port) {
            return;
        }
        let sink = Arc::new(crate::telemetry::MemorySpans::new());
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            trace: Some(sink.clone()),
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let stats = serve(cfg, stop.clone()).expect("serve failed to start");
        let n = 6usize;
        for i in 0..n {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i * 7 + j) % 5) as f32 - 2.0)
                .collect();
            classify(port, &features).expect("classify");
        }
        // Spans are recorded after the reply is sent, so the last one can
        // trail the last classify() by a beat.
        let deadline = Instant::now() + Duration::from_secs(5);
        while sink.spans().len() < n && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let spans = sink.spans();
        assert_eq!(spans.len(), n, "one span per request");
        for s in &spans {
            assert!(s.execute > Duration::ZERO, "span {} has no execute time", s.id);
            assert!(s.total >= s.execute, "total below execute in span {}", s.id);
            assert!(s.total >= s.queue_wait, "total below wait in span {}", s.id);
            assert!(s.worker < stats.per_worker.len(), "bad worker {}", s.worker);
            // Sequential clients: every batch held exactly one request,
            // and the precompiled batch-1 bucket means no compile cost.
            assert_eq!(s.batch_size, 1);
            assert!(s.compile_hit, "span {} paid an unexpected compile", s.id);
            assert_eq!(s.outcome, Outcome::Ok);
        }
        // The registry side of the same story, exact because the series
        // are labeled by this test's port.
        let r = crate::telemetry::registry();
        let p = port.to_string();
        let labels: &[(&str, &str)] = &[("port", &p)];
        assert_eq!(r.histogram_with(names::REQUEST_SECONDS, labels).count(), n as u64);
        assert_eq!(
            r.histogram_with(names::QUEUE_WAIT_SECONDS, labels).count(),
            n as u64
        );
        assert_eq!(r.histogram_with(names::EXECUTE_SECONDS, labels).count(), n as u64);
        assert_eq!(r.counter_with(names::REQUESTS_TOTAL, labels).get(), n as u64);
        assert_eq!(
            r.counter_with(names::REQUEST_OUTCOMES_TOTAL, &[("outcome", "ok"), ("port", &p)])
                .get(),
            n as u64
        );
        assert_eq!(r.gauge_with(names::QUEUE_DEPTH, labels).get(), 0);
        stop.store(true, Ordering::Relaxed);
    }

    /// `GET /metrics` on the front-door port returns Prometheus-style text
    /// where every line passes the shared well-formedness check; other
    /// paths 404.
    #[test]
    fn metrics_endpoint_serves_well_formed_prometheus_text() {
        let port = 7989;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        serve(cfg, stop.clone()).expect("serve failed to start");
        for i in 0..2i64 {
            let features: Vec<f32> = (0..FALLBACK_FEAT)
                .map(|j| ((i as usize * 5 + j) % 5) as f32 - 2.0)
                .collect();
            classify(port, &features).expect("classify");
        }
        let body = fetch_metrics(port).expect("fetch /metrics");
        for line in body.lines() {
            assert!(
                crate::telemetry::registry::line_is_well_formed(line),
                "malformed metrics line: {line:?}"
            );
        }
        assert!(body.contains("relay_request_seconds_bucket"), "{body}");
        assert!(
            body.contains(&format!("relay_requests_total{{port=\"{port}\"}}")),
            "{body}"
        );
        // A wrong path is a 404, not a hang or a batch of garbage.
        let err = {
            let mut stream =
                TcpStream::connect(("127.0.0.1", port)).expect("connect");
            write!(stream, "GET /nope HTTP/1.0\r\n\r\n").expect("send");
            let mut resp = String::new();
            stream.read_to_string(&mut resp).expect("read");
            resp
        };
        assert!(err.starts_with("HTTP/1.0 404"), "{err}");
        stop.store(true, Ordering::Relaxed);
    }

    /// Admission invariant: a zero-budget queue sheds every request with
    /// the typed reply — exact shed counts, depth pinned at 0, and the
    /// fleet never panics or hangs.
    #[test]
    fn zero_budget_queue_sheds_every_request_with_a_typed_reply() {
        let port = 7990;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            queue_budget: 0,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone()).expect("serve failed to start");
        let features: Vec<f32> = (0..FALLBACK_FEAT).map(|j| j as f32).collect();
        for _ in 0..5 {
            let reply = classify_line(port, &features, None).expect("reply");
            assert_eq!(reply, "shed: queue full");
        }
        // The parsed helper surfaces a shed as Err, never as a prediction.
        assert!(classify(port, &features).is_err());
        let stats = handle.stats();
        assert_eq!(stats.shed.load(Ordering::Relaxed), 6);
        assert_eq!(stats.requests.load(Ordering::Relaxed), 0);
        let r = crate::telemetry::registry();
        let p = port.to_string();
        assert_eq!(
            r.counter_with(names::SHED_TOTAL, &[("port", &p), ("reason", "queue_full")])
                .get(),
            6
        );
        assert_eq!(
            r.counter_with(
                names::REQUEST_OUTCOMES_TOTAL,
                &[("outcome", "shed"), ("port", &p)]
            )
            .get(),
            6
        );
        assert_eq!(r.gauge_with(names::QUEUE_DEPTH, &[("port", &p)]).get(), 0);
        handle.shutdown();
        assert_eq!(r.gauge_with(names::WORKERS_ALIVE, &[("port", &p)]).get(), 0);
    }

    /// A request with zero slack is answered `error: deadline exceeded`
    /// at drain time — and the fleet stays healthy for the next request.
    #[test]
    fn zero_slack_deadline_is_answered_deadline_exceeded() {
        let port = 7991;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone()).expect("serve failed to start");
        let features: Vec<f32> =
            (0..FALLBACK_FEAT).map(|j| (j % 5) as f32 - 2.0).collect();
        let reply = classify_line(port, &features, Some(0)).expect("reply");
        assert_eq!(reply, "error: deadline exceeded");
        // The drop cost no batch slot and broke nothing: a request with
        // real slack serves normally right after.
        let pred = classify(port, &features).expect("classify after deadline drop");
        assert!((0..FALLBACK_CLASSES as i64).contains(&pred));
        let stats = handle.stats();
        assert_eq!(stats.deadline_dropped.load(Ordering::Relaxed), 1);
        let r = crate::telemetry::registry();
        let p = port.to_string();
        assert_eq!(
            r.counter_with(names::SHED_TOTAL, &[("port", &p), ("reason", "deadline")])
                .get(),
            1
        );
        handle.shutdown();
    }

    /// A member deadline caps batch formation: under a 5s straggler
    /// window, a lone request with 250ms of slack is answered in well
    /// under the window — continuous deadline-aware dispatch, not a
    /// fixed tick.
    #[test]
    fn deadline_caps_straggler_wait_not_the_fixed_tick() {
        let port = 7994;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 8,
            batch_timeout: Duration::from_secs(5),
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone()).expect("serve failed to start");
        let features: Vec<f32> = (0..FALLBACK_FEAT).map(|j| (j % 3) as f32).collect();
        let t0 = Instant::now();
        let reply = classify_line(port, &features, Some(250)).expect("reply");
        let took = t0.elapsed();
        let pred: i64 = reply.parse().expect("prediction, not a timeout");
        assert!((0..FALLBACK_CLASSES as i64).contains(&pred));
        assert!(
            took < Duration::from_secs(4),
            "lone request waited the full 5s straggler window: {took:?}"
        );
        handle.shutdown();
    }

    /// Worker supervision, panic half: a backend that panics on every
    /// second batch answers those batches with a typed error while the
    /// fleet keeps its full worker count — `catch_unwind` eats the panic,
    /// no thread dies, no respawn happens, and the queue drains to zero.
    #[test]
    fn worker_panic_answers_the_batch_and_leaves_the_fleet_intact() {
        let port = 7992;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            workers: 2,
            fault: Some(FaultConfig { panic_every: Some(2), ..Default::default() }),
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone()).expect("serve failed to start");
        let features: Vec<f32> =
            (0..FALLBACK_FEAT).map(|j| ((j * 3) % 5) as f32 - 2.0).collect();
        let (mut oks, mut panics) = (0, 0);
        for _ in 0..6 {
            let reply = classify_line(port, &features, None).expect("reply");
            if reply.starts_with("error: worker panicked") {
                panics += 1;
            } else {
                let pred: i64 = reply.parse().expect("prediction");
                assert!((0..FALLBACK_CLASSES as i64).contains(&pred));
                oks += 1;
            }
        }
        // Sequential clients, shared fault counter: batches 2, 4, 6
        // panic, 1, 3, 5 serve — exactly.
        assert_eq!((oks, panics), (3, 3));
        let stats = handle.stats();
        assert_eq!(stats.panics.load(Ordering::Relaxed), 3);
        assert_eq!(stats.requests.load(Ordering::Relaxed), 6);
        let r = crate::telemetry::registry();
        let p = port.to_string();
        let labels: &[(&str, &str)] = &[("port", &p)];
        assert_eq!(r.counter_with(names::WORKER_PANICS_TOTAL, labels).get(), 3);
        // The panics never killed a thread: full fleet, zero respawns.
        assert_eq!(r.gauge_with(names::WORKERS_ALIVE, labels).get(), 2);
        assert_eq!(r.counter_with(names::WORKER_RESPAWNS_TOTAL, labels).get(), 0);
        assert_eq!(
            r.counter_with(
                names::REQUEST_OUTCOMES_TOTAL,
                &[("outcome", "error"), ("port", &p)]
            )
            .get(),
            3
        );
        assert_eq!(r.gauge_with(names::QUEUE_DEPTH, labels).get(), 0);
        handle.shutdown();
        assert_eq!(r.gauge_with(names::WORKERS_ALIVE, labels).get(), 0);
    }

    /// Graceful drain: shutting down mid-stream answers every admitted
    /// request (predictions for the drained queue, typed sheds for late
    /// arrivals), flushes the span sink, joins every worker, and leaves
    /// both gauges at zero. No client hangs, no dropped connection.
    #[test]
    fn graceful_shutdown_drains_queued_requests_and_flushes_the_sink() {
        let port = 7993;
        if !port_free(port) {
            return;
        }
        let sink = Arc::new(crate::telemetry::MemorySpans::new());
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 1,
            workers: 1,
            trace: Some(sink.clone()),
            // One slow worker (30ms/batch): the clients below queue up
            // behind it, so the shutdown genuinely drains a backlog.
            fault: Some(FaultConfig {
                latency: Duration::from_millis(30),
                ..Default::default()
            }),
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone()).expect("serve failed to start");
        let stats = handle.stats();
        let clients: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let features: Vec<f32> = (0..FALLBACK_FEAT)
                        .map(|j| ((i * 7 + j) % 5) as f32 - 2.0)
                        .collect();
                    classify_line(port, &features, None)
                })
            })
            .collect();
        // Wait until all 4 requests are accounted for — drained, queued,
        // shed, or deadline-dropped — so none is stranded unaccepted in
        // the listener backlog when the accept loop stops.
        let r = crate::telemetry::registry();
        let p = port.to_string();
        let labels: &[(&str, &str)] = &[("port", &p)];
        let depth = r.gauge_with(names::QUEUE_DEPTH, labels);
        let deadline = Instant::now() + Duration::from_secs(10);
        while Instant::now() < deadline {
            let seen = stats.requests.load(Ordering::Relaxed)
                + stats.shed.load(Ordering::Relaxed)
                + stats.deadline_dropped.load(Ordering::Relaxed)
                + depth.get().max(0) as usize;
            if seen >= 4 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        handle.shutdown();
        // Every client got a definitive reply.
        for c in clients {
            let reply = c.join().expect("client thread").expect("reply");
            let definitive = reply.parse::<i64>().is_ok()
                || reply == "shed: shutting down"
                || reply == "error: deadline exceeded";
            assert!(definitive, "unexpected reply {reply:?}");
        }
        assert!(sink.flushes() >= 1, "graceful drain must flush the span sink");
        assert_eq!(depth.get(), 0);
        assert_eq!(r.gauge_with(names::WORKERS_ALIVE, labels).get(), 0);
        // Each of the 4 requests ended in exactly one outcome.
        let outcomes: u64 = ["ok", "error", "shed", "deadline"]
            .iter()
            .map(|o| {
                r.counter_with(
                    names::REQUEST_OUTCOMES_TOTAL,
                    &[("outcome", o), ("port", &p)],
                )
                .get()
            })
            .sum();
        assert_eq!(outcomes, 4);
    }

    /// The supervisor respawns dead workers (counting each respawn) until
    /// one survives, and zeroes the alive gauge after the drain. Uses an
    /// injected spawn closure — no sockets, no backend.
    #[test]
    fn supervisor_respawns_dead_workers_until_one_survives() {
        let r = Registry::new();
        let respawns = r.counter("relay_test_supervisor_respawns");
        let alive = r.gauge("relay_test_supervisor_alive");
        let stop = Arc::new(AtomicBool::new(false));
        let sup = Supervisor {
            stop: stop.clone(),
            poll: Duration::from_millis(2),
            respawns: respawns.clone(),
            alive: alive.clone(),
        };
        let attempts = Arc::new(AtomicUsize::new(0));
        let stop_w = stop.clone();
        let attempts_s = attempts.clone();
        let spawn = move |_w: usize| {
            let n = attempts_s.fetch_add(1, Ordering::Relaxed);
            let stop = stop_w.clone();
            Some(std::thread::spawn(move || {
                if n < 2 {
                    // First two attempts die at birth: the supervisor
                    // must notice and respawn.
                    return;
                }
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }))
        };
        let first = spawn(0);
        let closed = Arc::new(AtomicBool::new(false));
        let drained = Arc::new(AtomicBool::new(false));
        let sup_thread = {
            let closed = closed.clone();
            let drained = drained.clone();
            std::thread::spawn(move || {
                sup.run(
                    vec![first],
                    spawn,
                    || closed.store(true, Ordering::Relaxed),
                    || drained.store(true, Ordering::Relaxed),
                )
            })
        };
        let deadline = Instant::now() + Duration::from_secs(10);
        while respawns.get() < 2 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(respawns.get(), 2, "supervisor stopped respawning early");
        stop.store(true, Ordering::Relaxed);
        sup_thread.join().expect("supervisor thread");
        // Three spawn attempts total; the third survived until stop.
        assert_eq!(attempts.load(Ordering::Relaxed), 3);
        assert_eq!(alive.get(), 0);
        assert!(closed.load(Ordering::Relaxed), "on_stop did not run");
        assert!(drained.load(Ordering::Relaxed), "after_drain did not run");
    }

    /// The breaker's full state machine: Closed → (threshold failures) →
    /// Open → (cooldown) → HalfOpen with exactly one probe slot →
    /// re-Open on probe failure / re-Closed on probe success — with the
    /// gauge tracking 0/1/2 throughout.
    #[test]
    fn circuit_breaker_state_machine() {
        let r = Registry::new();
        let gauge = r.gauge("relay_test_breaker_state");
        let b = CircuitBreaker::new(2, Duration::from_millis(20), gauge.clone());
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(gauge.get(), 0);
        assert!(matches!(b.admit(), Admission::Allow));
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed, "one failure is below threshold");
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(gauge.get(), 1);
        assert!(matches!(b.admit(), Admission::Deny), "open denies before cooldown");
        std::thread::sleep(Duration::from_millis(25));
        assert!(matches!(b.admit(), Admission::Probe), "cooldown grants one probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(gauge.get(), 2);
        assert!(matches!(b.admit(), Admission::Deny), "only one probe slot");
        // A failed probe re-opens (restarting the cooldown)...
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(25));
        assert!(matches!(b.admit(), Admission::Probe));
        // ...a successful probe re-closes and resets the failure streak.
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(gauge.get(), 0);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed, "streak reset on success");
    }

    #[test]
    fn retry_backoff_schedule_is_deterministic_and_capped() {
        let p = RetryPolicy {
            max_attempts: 6,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(80),
            jitter_seed: 7,
        };
        // No delay before the first attempt.
        assert_eq!(p.delay_before(1), Duration::ZERO);
        for attempt in 2..=6usize {
            let d = p.delay_before(attempt);
            let exp = p
                .base
                .saturating_mul(1u32 << (attempt as u32 - 2))
                .min(p.cap);
            assert!(d >= exp, "attempt {attempt}: {d:?} below the exponential floor");
            assert!(
                d <= exp + exp / 2,
                "attempt {attempt}: jitter exceeded exp/2 ({d:?} vs {exp:?})"
            );
            assert_eq!(d, p.delay_before(attempt), "schedule must be deterministic");
        }
        // The exponential term is capped: attempt 6 would be 160ms uncapped.
        assert!(p.delay_before(6) <= Duration::from_millis(120));
        // A different seed moves the jitter but never dips below the floor.
        let q = RetryPolicy { jitter_seed: 8, ..p.clone() };
        assert!(q.delay_before(4) >= Duration::from_millis(40));
    }

    /// Client retry semantics against a real (zero-budget, all-shedding)
    /// server: `shed:` replies are retried to exhaustion with the attempt
    /// count surfaced, while metrics fetches succeed first try.
    #[test]
    fn shed_replies_are_retried_and_attempt_counts_surface() {
        let port = 7995;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            queue_budget: 0,
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone()).expect("serve failed to start");
        let features: Vec<f32> = (0..FALLBACK_FEAT).map(|j| j as f32).collect();
        let policy = RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(4),
            jitter_seed: 1,
        };
        let err = classify_with_retry(port, &features, None, &policy)
            .expect_err("an all-shedding server must exhaust the retries");
        let msg = format!("{err}");
        assert!(msg.contains("shed"), "retries must end on the shed reply: {msg}");
        assert!(msg.contains("after 3 attempts"), "attempt count missing: {msg}");
        // Every attempt really hit the server.
        assert_eq!(handle.stats().shed.load(Ordering::Relaxed), 3);
        // Metrics fetches are healthy on the same port: one attempt.
        let got = fetch_metrics_with_retry(port, &policy).expect("metrics");
        assert_eq!(got.attempts, 1);
        assert!(got.value.contains("relay_shed_total"));
        handle.shutdown();
    }

    /// Serving under a hostile compiler: with *every* compile failing, the
    /// fleet still answers every request with a real prediction — the
    /// interpreter floor serves, the degradation shows up in the metrics,
    /// and a definitive `error:` reply is never retried by the client
    /// helper.
    #[test]
    fn compile_faults_degrade_serving_but_every_request_is_answered() {
        let port = 7996;
        if !port_free(port) {
            return;
        }
        let cfg = ServerConfig {
            port,
            artifact_dir: "definitely-missing-artifacts".into(),
            executor: Executor::Vm,
            max_batch: 4,
            workers: 2,
            fault: Some(FaultConfig {
                compile_error_every: Some(1), // every compile fails
                ..Default::default()
            }),
            ..Default::default()
        };
        let stop = Arc::new(AtomicBool::new(false));
        let handle = serve_handle(cfg, stop.clone())
            .expect("a broken compiler must not stop serve from starting");
        let features: Vec<f32> =
            (0..FALLBACK_FEAT).map(|j| ((j * 3) % 5) as f32 - 2.0).collect();
        for _ in 0..4 {
            let pred = classify(port, &features).expect("degraded classify");
            assert!((0..FALLBACK_CLASSES as i64).contains(&pred), "pred {pred}");
        }
        // Nothing ever compiled; the interpreter floor carried the fleet.
        assert_eq!(handle.stats().compiles.load(Ordering::Relaxed), 0);
        let body = fetch_metrics(port).expect("metrics");
        assert!(
            body.contains("relay_compile_failures_total"),
            "compile failures unrecorded: {body}"
        );
        assert!(
            body.contains("relay_degraded_executions_total{level=\"0\"}"),
            "degraded executions unrecorded: {body}"
        );
        assert!(
            body.contains(&format!("scope=\"port-{port}\"")),
            "breaker gauge missing its scope label: {body}"
        );
        // A typed error reply is definitive: exactly one attempt.
        let policy = RetryPolicy { base: Duration::from_millis(1), ..Default::default() };
        let err = classify_with_retry(port, &features, Some(0), &policy)
            .expect_err("deadline 0 must be a typed error");
        let msg = format!("{err}");
        assert!(msg.contains("error: deadline exceeded"), "{msg}");
        assert!(msg.contains("attempt 1, not retried"), "{msg}");
        handle.shutdown();
    }

    /// The per-key breaker's full serving lifecycle, deterministically:
    /// consecutive compile failures open it; while open the bucket serves
    /// the interpreter floor (bit-identical to the interpreter) without
    /// touching the compiler; after the cooldown a single probe compile
    /// re-closes it — `Stats::compiles` moves by exactly one.
    #[test]
    fn breaker_opens_serves_degraded_and_recloses_after_one_probe() {
        let cache = Arc::new(ProgramCache::new());
        let stats = Arc::new(Stats::new(1, OptLevel::O3));
        let fail = Arc::new(AtomicBool::new(true));
        let fail_h = fail.clone();
        cache.set_compile_hook(Arc::new(move |_m, _o| {
            if fail_h.load(Ordering::Relaxed) {
                Err("chaos: compiler disabled".to_string())
            } else {
                Ok(())
            }
        }));
        let resilience = ResilienceConfig {
            max_opt_retries: 1,
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(150),
            scope: "test-breaker-lifecycle".to_string(),
        };
        let backend = RelayBackend::new_with(
            2,
            CompileOptions::at(Executor::Vm, OptLevel::O3),
            cache.clone(),
            stats.clone(),
            resilience,
        )
        .expect("tolerant construction");
        // Warm-up compile failed (failure 1 of 2); nothing compiled yet.
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 0);
        assert_eq!(backend.breaker_state(0), BreakerState::Closed);
        let row: Vec<f32> = (0..FALLBACK_FEAT).map(|j| (j % 5) as f32 - 2.0).collect();
        let rows: Vec<&[f32]> = vec![&row];
        // Failure 2 trips the breaker; the batch is still answered, from
        // the interpreter floor.
        let run = backend.run_batch_timed(&rows).expect("degraded batch");
        assert_eq!(run.degraded, Some(OptLevel::O0));
        assert_eq!(backend.breaker_state(0), BreakerState::Open);
        // Bit-identical to the interpreter on the same module and input.
        let x = pad_rows(&rows, 1, FALLBACK_FEAT);
        let interp = crate::eval::Compiled::Interp(Arc::new(
            backend.artifact(0).module.clone(),
        ));
        let reference = run_compiled(&interp, vec![Value::Tensor(x)]).expect("interp");
        let expected = crate::tensor::argmax(reference.value.tensor(), 1).as_i64()[0];
        assert_eq!(run.preds, vec![expected], "degraded preds diverged from interp");
        // Open: served without touching the compiler (no new negative-cache
        // replays, no compiles).
        let replays = cache.negative_hits();
        let run = backend.run_batch_timed(&rows).expect("open-state batch");
        assert_eq!(run.degraded, Some(OptLevel::O0));
        assert_eq!(cache.negative_hits(), replays, "open breaker touched the compiler");
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 0);
        // Heal the compiler, wait out the cooldown: the next resolve wins
        // the half-open probe, compiles exactly once, and re-closes.
        fail.store(false, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(200));
        let run = backend.run_batch_timed(&rows).expect("probe batch");
        assert_eq!(run.degraded, None, "probe success must serve the real tier");
        assert_eq!(backend.breaker_state(0), BreakerState::Closed);
        assert_eq!(
            stats.compiles.load(Ordering::Relaxed),
            1,
            "exactly one probe compile"
        );
        // Healthy steady state: memo hit, no further compiles.
        let run = backend.run_batch_timed(&rows).expect("healthy batch");
        assert_eq!(run.degraded, None);
        assert!(run.compile_hit);
        assert_eq!(stats.compiles.load(Ordering::Relaxed), 1);
    }
}
