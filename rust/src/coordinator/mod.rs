//! Layer-3 coordinator: the CLI driver and a batched inference server.
//!
//! The paper's contribution is the compiler, so this layer is deliberately
//! thin (per DESIGN.md): process lifecycle, a request loop, and metrics.
//! The server demonstrates deployment of a compiled artifact — a dynamic
//! batcher over the PJRT executable, Python long gone — behind a resilient
//! front door: bounded admission ([`queue`]), per-request deadlines, load
//! shedding, and worker supervision (see `README.md` in this directory).
//! Compilation itself is fault-contained: a panicking or failing compile
//! degrades the affected bucket down the -O3 → -O1 → interpreter ladder
//! and trips a per-bucket circuit breaker instead of erroring requests
//! (`README.md`, "Failure containment").
//!
//! Every command routes through the same optimizing driver the executors
//! use (`eval::CompileOptions` -> `pass::optimize_traced`): `run` compiles
//! through the process-wide program cache, `dump-passes` prints what the
//! driver did, and `serve` compiles its batch buckets at `--opt`
//! (default -O3).

pub mod queue;
pub mod server;

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::eval::{run_with, CompileOptions, Executor, Value};
use crate::pass::{OptLevel, PipelineConfig};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// `relay compile <file.relay> [-O n]`: parse, typecheck, optimize, print.
pub fn cmd_compile(path: &str, level: OptLevel) -> Result<String> {
    let src = std::fs::read_to_string(path)?;
    let m = crate::ir::parse_module(&src).map_err(|e| anyhow!("{e}"))?;
    crate::ty::check_module(&m).map_err(|e| anyhow!("{e}"))?;
    let opt = crate::pass::optimize(&m, level, true).map_err(|e| anyhow!("{e}"))?;
    Ok(crate::ir::print_module(&opt))
}

/// `relay run <file.relay> [-O n] [--executor interp|graph|vm|auto]
/// [--profile]`: evaluate @main() with random tensors for annotated
/// params, compiled through the unified optimizing driver + program cache
/// ([`crate::eval::run_with`] with explicit [`CompileOptions`] — the
/// pipeline runs inside `compile_for`, not as a separate CLI step). With
/// `--profile`, execution runs under a
/// [`crate::telemetry::ProfileScope`] and the per-(op, shape) table is
/// appended; its launch total equals the printed `launches=` value.
pub fn cmd_run(
    path: &str,
    level: OptLevel,
    executor: Executor,
    profile: bool,
) -> Result<String> {
    let src = std::fs::read_to_string(path)?;
    let m = crate::ir::parse_module(&src).map_err(|e| anyhow!("{e}"))?;
    let main = m.def("main").ok_or_else(|| anyhow!("no @main"))?;
    let mut rng = crate::tensor::Rng::new(0);
    let args: Result<Vec<Value>> = main
        .params
        .iter()
        .map(|(p, ty)| match ty {
            Some(t) => {
                let shape = t
                    .concrete_shape()
                    .ok_or_else(|| anyhow!("param {p} needs concrete type"))?;
                Ok(Value::Tensor(rng.normal_tensor(&shape, 1.0)))
            }
            None => Err(anyhow!("param {p} needs a type annotation")),
        })
        .collect();
    let opts = CompileOptions::at(executor, level);
    let out = if profile {
        crate::eval::run_with_profile(&m, opts, args?)
    } else {
        run_with(&m, opts, args?)
    }
    .map_err(|e| anyhow!("{e}"))?;
    let mut text = format!(
        "{:?}  [executor={}, launches={}, opt={}]",
        out.value, out.executor, out.launches, level
    );
    if let Some(p) = &out.profile {
        text.push_str("\n\nper-op profile:\n");
        text.push_str(&p.render());
    }
    Ok(text)
}

/// `relay metrics [--port 7474]`: fetch a running server's `/metrics`
/// text (the telemetry registry rendered Prometheus-style) and print it.
pub fn cmd_metrics(port: u16) -> Result<String> {
    server::fetch_metrics(port)
        .map_err(|e| anyhow!("fetch /metrics from 127.0.0.1:{port}: {e}"))
}

/// `relay dump-passes <file.relay> [-O n] [--fixpoint]`: run the
/// instrumented pass driver and print the per-pass table — wall time, IR
/// node counts before/after, and rounds (fixpoint re-runs FoldConstant /
/// DCE to convergence) — followed by the tile schedules the `TuneKernels`
/// pass decided, one row per (op, shape).
pub fn cmd_dump_passes(path: &str, level: OptLevel, fixpoint: bool) -> Result<String> {
    let src = std::fs::read_to_string(path)?;
    let m = crate::ir::parse_module(&src).map_err(|e| anyhow!("{e}"))?;
    let cfg = PipelineConfig { level, typecheck: false, fixpoint };
    let (opt, trace) =
        crate::pass::optimize_with(&m, &cfg).map_err(|e| anyhow!("{e}"))?;
    let mut text = format!(
        "pass pipeline for {path} at {level}{}:\n{}",
        if fixpoint { " (fixpoint)" } else { "" },
        trace.render()
    );
    // Match the driver: TuneKernels only runs at -O1 and above.
    let tuned = if level >= OptLevel::O1 {
        crate::pass::tune_kernels::tune_module(&opt)
    } else {
        Vec::new()
    };
    if !tuned.is_empty() {
        text.push_str("\ntuned kernel schedules:\n");
        for t in &tuned {
            text.push_str("  ");
            text.push_str(&t.render());
            text.push('\n');
        }
    }
    Ok(text)
}

/// `relay dump-bytecode <file.relay> [-O n]`: parse, optimize, compile to
/// VM bytecode, and print the disassembly plus a summary of what the
/// compile-time optimizations did (constant/kernel pool sizes after dedup,
/// tail calls eliminated, fused compare-branches).
pub fn cmd_dump_bytecode(path: &str, level: OptLevel) -> Result<String> {
    let src = std::fs::read_to_string(path)?;
    let m = crate::ir::parse_module(&src).map_err(|e| anyhow!("{e}"))?;
    let opt = crate::pass::optimize(&m, level, false).map_err(|e| anyhow!("{e}"))?;
    let program = crate::vm::compile(&opt).map_err(|e| anyhow!("{e}"))?;
    let tail_calls = program.count_instrs(|i| {
        matches!(
            i,
            crate::vm::Instr::TailInvokeFunc { .. }
                | crate::vm::Instr::TailInvokeClosure { .. }
        )
    });
    let fused_branches =
        program.count_instrs(|i| matches!(i, crate::vm::Instr::IfCmp { .. }));
    Ok(format!(
        "{program}\n; {} instrs, {} tail calls, {} fused compare-branches\n\
         ; const pool: {} entries (deduped), packed kernels: {} (deduped)",
        program.num_instrs(),
        tail_calls,
        fused_branches,
        program.consts.len(),
        program.packed.len(),
    ))
}

/// `relay artifact <name>`: run an AOT artifact once with zero inputs and
/// report output shapes (smoke check of the python -> rust path).
pub fn cmd_artifact(dir: &Path, name: &str) -> Result<String> {
    let rt = Runtime::cpu()?;
    let manifest = crate::runtime::manifest::load(&dir.join("manifest.json"))
        .map_err(|e| anyhow!("{e}"))?;
    let entry = manifest
        .get(name)
        .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
    let exe = rt.load_artifact(&dir.join(format!("{name}.hlo.txt")))?;
    let inputs: Vec<Tensor> = entry
        .inputs
        .iter()
        .map(|spec| Tensor::zeros(&spec.shape, spec.dtype))
        .collect();
    let outs = rt.execute(&exe, &inputs)?;
    let shapes: Vec<String> = outs.iter().map(|t| format!("{:?}", t.shape())).collect();
    Ok(format!("{name}: {} outputs, shapes {shapes:?}", outs.len()))
}

pub fn usage() -> &'static str {
    "relay — Relay IR reproduction (Roesch et al. 2019)\n\
     \n\
     USAGE:\n\
       relay compile <file.relay> [-O 0|1|2|3]   parse, check, optimize, print\n\
       relay run <file.relay> [-O 0|1|2|3] [--executor interp|graph|vm|auto]\n\
                   [--profile] [--kernel-threads N]\n\
                                                 optimize and evaluate @main\n\
       relay dump-passes <file.relay> [-O 0|1|2|3] [--fixpoint]\n\
                                                 per-pass wall time + node deltas\n\
                                                 + tuned kernel schedules\n\
       relay dump-bytecode <file.relay> [-O 0|1|2|3]\n\
                                                 disassemble the VM program\n\
       relay artifact <name> [--dir artifacts]   execute an AOT artifact\n\
       relay serve [--port 7474] [--workers 4] [--opt 0|1|2|3] [--fixpoint]\n\
                   [--queue-budget 256] [--deadline-ms 1000]\n\
                   [--poly on|off] [--trace-json PATH] [--kernel-threads N]\n\
                   [--max-opt-retries 1] [--breaker-threshold 3]\n\
                   [--breaker-cooldown-ms 250]\n\
                                                 batched inference server\n\
                                                 (--poly=off: bucketed baseline;\n\
                                                  retries/breaker: see\n\
                                                  coordinator/README.md)\n\
       relay metrics [--port 7474]           dump a running server's /metrics\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_and_run_roundtrip() {
        let tmp = std::env::temp_dir().join("relay_cli_test.relay");
        std::fs::write(
            &tmp,
            "def @main(%x: Tensor[(2, 2), float32]) { nn.relu(add(%x, 1f)) }",
        )
        .unwrap();
        let printed = cmd_compile(tmp.to_str().unwrap(), OptLevel::O2).unwrap();
        assert!(printed.contains("@main"));
        let out =
            cmd_run(tmp.to_str().unwrap(), OptLevel::O2, Executor::Auto, false).unwrap();
        assert!(out.contains("Tensor"), "{out}");
        assert!(out.contains("executor=graphrt"), "{out}");
        assert!(out.contains("opt=-O2"), "{out}");
        assert!(!out.contains("per-op profile"), "{out}");
        // Same program forced onto each tier agrees.
        for exec in [Executor::Interp, Executor::Vm] {
            let o = cmd_run(tmp.to_str().unwrap(), OptLevel::O2, exec, false).unwrap();
            assert!(o.contains(&format!("executor={}", exec.name())), "{o}");
        }
    }

    #[test]
    fn cmd_run_profile_prints_a_launch_matched_table() {
        let tmp = std::env::temp_dir().join("relay_cli_profile_test.relay");
        std::fs::write(
            &tmp,
            "def @main(%x: Tensor[(2, 2), float32]) { nn.relu(add(%x, 1f)) }",
        )
        .unwrap();
        let out =
            cmd_run(tmp.to_str().unwrap(), OptLevel::O2, Executor::Auto, true).unwrap();
        assert!(out.contains("per-op profile"), "{out}");
        // The header's launches= value and the table footer's launch total
        // are the same number — the profiler counts at the same sites as
        // the LaunchCounter.
        let launches: usize = out
            .split("launches=")
            .nth(1)
            .and_then(|s| s.split(',').next())
            .and_then(|s| s.trim().parse().ok())
            .expect("launches= in header");
        assert!(out.contains(&format!("over {launches} launches")), "{out}");
    }

    #[test]
    fn dump_passes_prints_the_driver_table() {
        let tmp = std::env::temp_dir().join("relay_dump_passes_test.relay");
        std::fs::write(
            &tmp,
            "def @main(%x: Tensor[(2, 2), float32]) {\n\
               nn.relu(add(multiply(%x, 2f), add(1f, 1f)))\n\
             }",
        )
        .unwrap();
        let out = cmd_dump_passes(tmp.to_str().unwrap(), OptLevel::O3, false).unwrap();
        assert!(out.contains("FoldConstantPostLayout"), "{out}");
        assert!(out.contains("FuseOps"), "{out}");
        assert!(out.contains("total (-O3)"), "{out}");
        // The fixpoint spelling runs too and reports rounds.
        let fix = cmd_dump_passes(tmp.to_str().unwrap(), OptLevel::O2, true).unwrap();
        assert!(fix.contains("(fixpoint)"), "{fix}");
        assert!(fix.contains("rounds"), "{fix}");
    }

    #[test]
    fn dump_passes_lists_tuned_kernel_schedules() {
        let tmp = std::env::temp_dir().join("relay_dump_tuned_test.relay");
        std::fs::write(
            &tmp,
            "def @main(%x: Tensor[(8, 16), float32], %w: Tensor[(32, 16), float32]) {\n\
               nn.dense(%x, %w)\n\
             }",
        )
        .unwrap();
        let out = cmd_dump_passes(tmp.to_str().unwrap(), OptLevel::O3, false).unwrap();
        assert!(out.contains("TuneKernels"), "{out}");
        assert!(out.contains("tuned kernel schedules:"), "{out}");
        assert!(out.contains("nn.dense [8, 16, 32] -> mc"), "{out}");
        // -O0 runs no passes, so nothing is tuned and the section is
        // omitted.
        let o0 = cmd_dump_passes(tmp.to_str().unwrap(), OptLevel::O0, false).unwrap();
        assert!(!o0.contains("tuned kernel schedules:"), "{o0}");
    }

    #[test]
    fn dump_bytecode_disassembles_and_reports_optimizations() {
        let tmp = std::env::temp_dir().join("relay_dump_test.relay");
        std::fs::write(
            &tmp,
            "def @main(%x: Tensor[(), float32]) {\n\
               let %loop = fn (%i, %acc) {\n\
                 if (greater(%i, 0f)) { %loop(subtract(%i, 1f), add(%acc, %i)) }\n\
                 else { %acc }\n\
               };\n\
               %loop(%x, 0f)\n\
             }",
        )
        .unwrap();
        let out = cmd_dump_bytecode(tmp.to_str().unwrap(), OptLevel::O0).unwrap();
        assert!(out.contains("program:"), "{out}");
        // The recursive loop must show both hot-path optimizations in the
        // disassembly: a frame-reusing tail call and a fused compare-branch.
        assert!(out.contains("tail_invoke"), "{out}");
        assert!(out.contains("if !("), "{out}");
        assert!(out.contains("tail calls"), "{out}");
    }
}
