//! NNVM-style JSON dataflow-graph importer + the Fig. 2 `while_loop`
//! conversion.
//!
//! The JSON schema is the classic static computation graph: a node list
//! (`op`, `inputs` as node indices, `attrs`), `arg_nodes` marking
//! placeholders, and a `head` output index. Graphs of this shape are what
//! "straightforward to translate" frameworks (§4.1) exchange; richer
//! constructs (TF control flow) come in through [`convert_while_loop`],
//! which rebuilds a `tf.while_loop(cond, body, loop_vars)` as a Relay
//! tail-recursive function — the exact transformation shown in Fig. 2.

use std::collections::BTreeMap;

use crate::ir::{self, Function, Var, E};
use crate::runtime::manifest::{parse_json, Json};

#[derive(Debug)]
pub struct ImportError(pub String);

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json graph import: {}", self.0)
    }
}

impl std::error::Error for ImportError {}

type R<T> = Result<T, ImportError>;

/// Import a JSON graph as a Relay function.
pub fn import_json(src: &str) -> R<Function> {
    let root = parse_json(src).map_err(ImportError)?;
    let obj = match &root {
        Json::Object(o) => o,
        _ => return Err(ImportError("root must be an object".into())),
    };
    let nodes = match obj.get("nodes") {
        Some(Json::Array(a)) => a,
        _ => return Err(ImportError("missing nodes".into())),
    };
    let arg_nodes: Vec<usize> = match obj.get("arg_nodes") {
        Some(Json::Array(a)) => a
            .iter()
            .map(|v| match v {
                Json::Num(n) => Ok(*n as usize),
                _ => Err(ImportError("bad arg node".into())),
            })
            .collect::<R<Vec<_>>>()?,
        _ => vec![],
    };
    let head = match obj.get("head") {
        Some(Json::Num(n)) => *n as usize,
        _ => nodes.len() - 1,
    };

    let mut params: Vec<(Var, Option<ir::Type>)> = Vec::new();
    let mut atoms: BTreeMap<usize, E> = BTreeMap::new();
    let mut bindings: Vec<(Var, E)> = Vec::new();

    for (i, node) in nodes.iter().enumerate() {
        let no = match node {
            Json::Object(o) => o,
            _ => return Err(ImportError(format!("node {i} not an object"))),
        };
        let op = match no.get("op") {
            Some(Json::Str(s)) => s.clone(),
            _ => return Err(ImportError(format!("node {i} missing op"))),
        };
        if op == "null" || arg_nodes.contains(&i) {
            let name = match no.get("name") {
                Some(Json::Str(s)) => s.clone(),
                _ => format!("arg{i}"),
            };
            let v = Var::fresh(name);
            params.push((v.clone(), None));
            atoms.insert(i, ir::var(&v));
            continue;
        }
        let inputs: Vec<E> = match no.get("inputs") {
            Some(Json::Array(a)) => a
                .iter()
                .map(|v| match v {
                    Json::Num(n) => atoms
                        .get(&(*n as usize))
                        .cloned()
                        .ok_or_else(|| ImportError(format!("node {i}: input {n} undefined"))),
                    _ => Err(ImportError("bad input ref".into())),
                })
                .collect::<R<Vec<_>>>()?,
            _ => vec![],
        };
        let mut attrs = ir::Attrs::new();
        if let Some(Json::Object(a)) = no.get("attrs") {
            for (k, v) in a {
                let av = match v {
                    Json::Num(n) => {
                        if n.fract() == 0.0 {
                            ir::AttrValue::Int(*n as i64)
                        } else {
                            ir::AttrValue::Float(*n)
                        }
                    }
                    Json::Str(s) => ir::AttrValue::Str(s.clone()),
                    Json::Array(xs) => ir::AttrValue::IntVec(
                        xs.iter()
                            .map(|x| match x {
                                Json::Num(n) => *n as i64,
                                _ => 0,
                            })
                            .collect(),
                    ),
                    _ => continue,
                };
                attrs.insert(k.clone(), av);
            }
        }
        let call = ir::op_call_attrs(&op, inputs, attrs);
        let v = Var::fresh(format!("n{i}"));
        bindings.push((v.clone(), call));
        atoms.insert(i, ir::var(&v));
    }

    let rootv = atoms
        .get(&head)
        .cloned()
        .ok_or_else(|| ImportError(format!("head {head} undefined")))?;
    let body = bindings
        .into_iter()
        .rev()
        .fold(rootv, |acc, (v, val)| ir::let_(v, val, acc));
    Ok(Function::new(params, body))
}

/// Fig. 2: convert a `tf.while_loop(cond, body, loop_vars)` into a Relay
/// tail-recursive function and an application to the initial state.
///
/// `cond` and `body` are builders receiving the loop variables; `init` is
/// the initial state. The result corresponds exactly to the paper's
/// `%while_loop` encoding.
pub fn convert_while_loop(
    n_vars: usize,
    cond: impl Fn(&[E]) -> E,
    body: impl Fn(&[E]) -> Vec<E>,
    init: Vec<E>,
) -> E {
    assert_eq!(init.len(), n_vars);
    let loop_fn = Var::fresh("while_loop");
    let params: Vec<Var> = (0..n_vars)
        .map(|i| Var::fresh(format!("loop_var{i}")))
        .collect();
    let param_atoms: Vec<E> = params.iter().map(ir::var).collect();
    let recur = ir::call(ir::var(&loop_fn), body(&param_atoms));
    let state = ir::tuple(param_atoms.clone());
    let fn_body = ir::if_(cond(&param_atoms), recur, state);
    let func = ir::func(params.into_iter().map(|p| (p, None)).collect(), fn_body);
    ir::let_(loop_fn.clone(), func, ir::call(ir::var(&loop_fn), init))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_expr, eval_main, Value};
    use crate::ir::Module;
    use crate::tensor::{Rng, Tensor};

    #[test]
    fn imports_static_graph() {
        let src = r#"{
          "nodes": [
            {"op": "null", "name": "x"},
            {"op": "null", "name": "w"},
            {"op": "nn.dense", "inputs": [0, 1]},
            {"op": "nn.relu", "inputs": [2]}
          ],
          "arg_nodes": [0, 1],
          "head": 3
        }"#;
        let f = import_json(src).unwrap();
        assert_eq!(f.params.len(), 2);
        let mut m = Module::with_prelude();
        m.add_def("main", f);
        let mut rng = Rng::new(0);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let w = rng.normal_tensor(&[3, 4], 1.0);
        let out = eval_main(&m, vec![Value::Tensor(x.clone()), Value::Tensor(w.clone())])
            .unwrap();
        // relu(dense) reference
        let expect = crate::tensor::unary(
            crate::tensor::UnaryOp::Relu,
            &crate::tensor::dense(&x, &w),
        );
        assert!(expect.allclose(out.tensor(), 1e-5, 1e-5));
    }

    #[test]
    fn fig2_while_loop_converts_and_runs() {
        // The paper's Fig. 2 loop:
        //   i=1, j=1, k=5
        //   while equal(not_equal(i+j < 10, j*k < 100), k >= i+j):
        //     i, j, k = i+j, j+k, k+1
        let scalar = |v: f32| ir::constant(Tensor::scalar_f32(v));
        let e = convert_while_loop(
            3,
            |vs| {
                let i = vs[0].clone();
                let j = vs[1].clone();
                let k = vs[2].clone();
                let c1 = ir::op_call(
                    "less",
                    vec![ir::op_call("add", vec![i.clone(), j.clone()]), scalar(10.0)],
                );
                let c2 = ir::op_call(
                    "less",
                    vec![ir::op_call("multiply", vec![j.clone(), k.clone()]), scalar(100.0)],
                );
                let c3 = ir::op_call(
                    "greater_equal",
                    vec![k, ir::op_call("add", vec![i, j])],
                );
                ir::op_call(
                    "equal",
                    vec![ir::op_call("not_equal", vec![c1, c2]), c3],
                )
            },
            |vs| {
                let i = vs[0].clone();
                let j = vs[1].clone();
                let k = vs[2].clone();
                vec![
                    ir::op_call("add", vec![i, j.clone()]),
                    ir::op_call("add", vec![j, k.clone()]),
                    ir::op_call("add", vec![k, scalar(1.0)]),
                ]
            },
            vec![scalar(1.0), scalar(1.0), scalar(5.0)],
        );
        let s = crate::ir::print_expr(&e);
        assert!(s.contains("while_loop"), "{s}");
        let m = Module::with_prelude();
        let out = eval_expr(&m, &e).unwrap();
        let vals: Vec<f32> = out.tuple().iter().map(|v| v.tensor().f32_value()).collect();
        // Reference simulation in Rust:
        let (mut i, mut j, mut k) = (1f32, 1f32, 5f32);
        while ((i + j < 10.0) != (j * k < 100.0)) == (k >= i + j) {
            let (ni, nj, nk) = (i + j, j + k, k + 1.0);
            i = ni;
            j = nj;
            k = nk;
        }
        assert_eq!(vals, vec![i, j, k]);
    }
}
