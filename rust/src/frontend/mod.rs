//! Model importers (§4.1). Three frontends:
//!
//! * the **Relay text** format — [`crate::ir::parse_module`];
//! * **HLO text** — [`hlo`]: imports XLA/JAX-lowered modules (this stack's
//!   native interchange format, standing in for the paper's
//!   TensorFlow/ONNX importers);
//! * **JSON graphs** — [`json_graph`]: an NNVM-style static dataflow-graph
//!   format, plus the TF-`while_loop` -> tail-recursive-function
//!   conversion of Fig. 2 ([`json_graph::convert_while_loop`]).

pub mod hlo;
pub mod json_graph;
