//! HLO-text frontend: import an XLA entry computation into Relay IR.
//!
//! Covers the instruction subset jax emits for straight-line numeric
//! programs (parameter, constant, dot, elementwise arithmetic, broadcast,
//! reshape, transpose, maximum/minimum, compare-free select-free core).
//! Control flow (`while`, `call` to fusions) is out of scope — those
//! artifacts execute through the PJRT runtime directly instead.

use std::collections::BTreeMap;

use crate::ir::{self, Expr, Function, Type, Var, E};
use crate::tensor::{DType, Tensor};

#[derive(Debug)]
pub struct ImportError(pub String);

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "hlo import: {}", self.0)
    }
}

impl std::error::Error for ImportError {}

type R<T> = Result<T, ImportError>;

fn err<T>(m: impl Into<String>) -> R<T> {
    Err(ImportError(m.into()))
}

#[derive(Debug)]
struct Instr {
    name: String,
    shape: Vec<usize>,
    dtype: DType,
    opcode: String,
    operands: Vec<String>,
    /// Raw attribute text after the operand list (e.g. `dimensions={1}`).
    attrs: String,
    /// Literal payload for constants.
    literal: Option<String>,
    is_root: bool,
}

/// Parse `f32[2,3]` style type strings.
fn parse_ty(s: &str) -> Option<(DType, Vec<usize>)> {
    let (dts, rest) = s.split_once('[')?;
    let dt = match dts {
        "f32" => DType::F32,
        "f64" => DType::F64,
        "s64" => DType::I64,
        "s32" => DType::I32,
        "s16" => DType::I16,
        "s8" => DType::I8,
        "u8" => DType::U8,
        "pred" => DType::Bool,
        _ => return None,
    };
    let dims_part = rest.split(']').next()?;
    let shape: Vec<usize> = if dims_part.is_empty() {
        vec![]
    } else {
        dims_part
            .split(',')
            .map(|d| d.trim().parse().ok())
            .collect::<Option<Vec<_>>>()?
    };
    Some((dt, shape))
}

fn parse_instr(line: &str) -> Option<Instr> {
    let line = line.trim();
    let is_root = line.starts_with("ROOT ");
    let line = line.strip_prefix("ROOT ").unwrap_or(line);
    // Newer HLO text omits the leading '%'.
    let line = line.strip_prefix('%').unwrap_or(line);
    let (name, rest) = line.split_once(" = ")?;
    let rest = rest.trim();
    // Type prefix: maybe a tuple `(f32[..], ...)` for the root.
    let (tystr, rest) = if rest.starts_with('(') {
        let close = rest.find(") ")?;
        (&rest[..close + 1], rest[close + 2..].trim())
    } else {
        let sp = rest.find(' ')?;
        (&rest[..sp], rest[sp + 1..].trim())
    };
    let (dtype, shape) = if tystr.starts_with('(') {
        (DType::F32, vec![]) // tuple type: recorded loosely, root only
    } else {
        // strip layout `{1,0}`
        let t = tystr.split('{').next().unwrap();
        parse_ty(t)?
    };
    let opcode_end = rest.find('(')?;
    let opcode = rest[..opcode_end].trim().to_string();
    // operand list up to matching paren
    let mut depth = 0;
    let mut end = opcode_end;
    for (i, ch) in rest.char_indices().skip(opcode_end) {
        match ch {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    end = i;
                    break;
                }
            }
            _ => {}
        }
    }
    let inner = &rest[opcode_end + 1..end];
    let attrs = rest[end + 1..].trim_start_matches(',').trim().to_string();
    let mut operands = Vec::new();
    let mut literal = None;
    if opcode == "constant" || opcode == "parameter" {
        literal = Some(inner.to_string());
    } else {
        for part in split_top_level(inner) {
            // operands look like `f32[2,2]{1,0} %dot.3`, `f32[] dot.3`, or
            // a bare name.
            if let Some(ix) = part.rfind('%') {
                operands.push(part[ix + 1..].trim().to_string());
            } else if let Some(tok) = part.split_whitespace().last() {
                if !tok.is_empty() {
                    operands.push(tok.to_string());
                }
            }
        }
    }
    Some(Instr { name: name.trim().to_string(), shape, dtype, opcode, operands, attrs, literal, is_root })
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '(' | '{' | '[' => {
                depth += 1;
                cur.push(ch);
            }
            ')' | '}' | ']' => {
                depth -= 1;
                cur.push(ch);
            }
            ',' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(ch),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

fn attr_int_list(attrs: &str, key: &str) -> Option<Vec<i64>> {
    let ix = attrs.find(&format!("{key}={{"))?;
    let rest = &attrs[ix + key.len() + 2..];
    let end = rest.find('}')?;
    let inner = &rest[..end];
    if inner.trim().is_empty() {
        return Some(vec![]);
    }
    inner.split(',').map(|d| d.trim().parse().ok()).collect()
}

fn parse_literal(text: &str, dtype: DType, shape: &[usize]) -> R<Tensor> {
    // Forms: `2`, `{1, 2, 3}`, `{ {1, 2}, {3, 4} }`.
    let nums: Vec<f64> = text
        .chars()
        .map(|c| if c == '{' || c == '}' || c == ',' { ' ' } else { c })
        .collect::<String>()
        .split_whitespace()
        .map(|t| t.parse::<f64>().map_err(|e| ImportError(format!("literal {t}: {e}"))))
        .collect::<R<Vec<_>>>()?;
    let numel: usize = shape.iter().product();
    if nums.len() != numel {
        return err(format!("literal has {} values for shape {shape:?}", nums.len()));
    }
    Ok(crate::tensor::cast(
        &Tensor::from_f32(shape.to_vec(), nums.iter().map(|&v| v as f32).collect()),
        dtype,
    ))
}

/// Import the ENTRY computation of an HLO text module as a Relay function.
pub fn import_hlo_text(src: &str) -> R<Function> {
    // Find the ENTRY block.
    let entry_ix = src.find("ENTRY").ok_or(ImportError("no ENTRY computation".into()))?;
    let block = &src[entry_ix..];
    let open = block.find('{').ok_or(ImportError("no ENTRY body".into()))?;
    let close = block.rfind('}').ok_or(ImportError("unterminated ENTRY".into()))?;
    let body = &block[open + 1..close];

    let mut instrs = Vec::new();
    for line in body.lines() {
        let l = line.trim();
        if l.is_empty() {
            continue;
        }
        match parse_instr(l) {
            Some(i) => instrs.push(i),
            None => return err(format!("unparseable instruction: {l}")),
        }
    }

    let mut env: BTreeMap<String, (E, Vec<usize>, DType)> = BTreeMap::new();
    // Parameters keyed by their parameter(N) index — file order can differ.
    let mut params_by_index: BTreeMap<usize, (Var, Option<Type>)> = BTreeMap::new();
    let mut bindings: Vec<(Var, E)> = Vec::new();
    let mut root: Option<E> = None;

    for ins in &instrs {
        let operand = |i: usize| -> R<(E, Vec<usize>, DType)> {
            env.get(&ins.operands[i])
                .cloned()
                .ok_or_else(|| ImportError(format!("unknown operand {}", ins.operands[i])))
        };
        let e: E = match ins.opcode.as_str() {
            "parameter" => {
                let v = Var::fresh(ins.name.replace('.', "_"));
                let index: usize = ins
                    .literal
                    .clone()
                    .unwrap_or_default()
                    .trim()
                    .parse()
                    .unwrap_or(params_by_index.len());
                params_by_index.insert(
                    index,
                    (v.clone(), Some(Type::tensor(ins.shape.clone(), ins.dtype))),
                );
                ir::var(&v)
            }
            "constant" => {
                let t = parse_literal(ins.literal.as_deref().unwrap_or("0"), ins.dtype, &ins.shape)?;
                ir::constant(t)
            }
            "add" => ir::op_call("add", vec![operand(0)?.0, operand(1)?.0]),
            "subtract" => ir::op_call("subtract", vec![operand(0)?.0, operand(1)?.0]),
            "multiply" => ir::op_call("multiply", vec![operand(0)?.0, operand(1)?.0]),
            "divide" => ir::op_call("divide", vec![operand(0)?.0, operand(1)?.0]),
            "maximum" => ir::op_call("maximum", vec![operand(0)?.0, operand(1)?.0]),
            "minimum" => ir::op_call("minimum", vec![operand(0)?.0, operand(1)?.0]),
            "negate" => ir::op_call("negative", vec![operand(0)?.0]),
            "exponential" => ir::op_call("exp", vec![operand(0)?.0]),
            "log" => ir::op_call("log", vec![operand(0)?.0]),
            "tanh" => ir::op_call("tanh", vec![operand(0)?.0]),
            "logistic" => ir::op_call("sigmoid", vec![operand(0)?.0]),
            "sqrt" => ir::op_call("sqrt", vec![operand(0)?.0]),
            "dot" => {
                // jax matmul: lhs_contracting={1}, rhs_contracting={0}.
                let lc = attr_int_list(&ins.attrs, "lhs_contracting_dims").unwrap_or_default();
                let rc = attr_int_list(&ins.attrs, "rhs_contracting_dims").unwrap_or_default();
                let (l, _, _) = operand(0)?;
                let (r, _, _) = operand(1)?;
                match (lc.as_slice(), rc.as_slice()) {
                    ([1], [0]) => ir::op_call("matmul", vec![l, r]),
                    ([1], [1]) => ir::op_call("nn.dense", vec![l, r]),
                    other => return err(format!("unsupported dot dims {other:?}")),
                }
            }
            "broadcast" => {
                let dims = attr_int_list(&ins.attrs, "dimensions").unwrap_or_default();
                let (x, in_shape, _) = operand(0)?;
                // Insert 1s so numpy broadcasting reproduces the semantics.
                let mut newshape: Vec<i64> = vec![1; ins.shape.len()];
                for (i, &d) in dims.iter().enumerate() {
                    newshape[d as usize] = in_shape[i] as i64;
                }
                let reshaped = if in_shape.iter().product::<usize>() == 1 && dims.is_empty() {
                    x
                } else {
                    ir::op_call_attrs(
                        "reshape",
                        vec![x],
                        ir::attrs(&[("newshape", ir::AttrValue::IntVec(newshape))]),
                    )
                };
                // Multiply by zeros+? No: rely on implicit broadcast at the
                // consumer. But a bare broadcast result must have the full
                // shape (e.g. it may be the root): force it with add of
                // zeros of the target shape.
                ir::op_call(
                    "add",
                    vec![
                        reshaped,
                        ir::op_call_attrs(
                            "zeros",
                            vec![],
                            ir::attrs(&[
                                (
                                    "shape",
                                    ir::AttrValue::IntVec(
                                        ins.shape.iter().map(|&d| d as i64).collect(),
                                    ),
                                ),
                                ("dtype", ir::AttrValue::Str(ins.dtype.to_string())),
                            ]),
                        ),
                    ],
                )
            }
            "reshape" => ir::op_call_attrs(
                "reshape",
                vec![operand(0)?.0],
                ir::attrs(&[(
                    "newshape",
                    ir::AttrValue::IntVec(ins.shape.iter().map(|&d| d as i64).collect()),
                )]),
            ),
            "transpose" => {
                let dims = attr_int_list(&ins.attrs, "dimensions").unwrap_or_default();
                ir::op_call_attrs(
                    "transpose",
                    vec![operand(0)?.0],
                    ir::attrs(&[("axes", ir::AttrValue::IntVec(dims))]),
                )
            }
            "tuple" => {
                let parts: R<Vec<E>> =
                    (0..ins.operands.len()).map(|i| operand(i).map(|o| o.0)).collect();
                ir::tuple(parts?)
            }
            other => return err(format!("unsupported HLO opcode {other}")),
        };
        // Bind non-atomic instructions so sharing is explicit.
        let atom = if e.is_atomic() {
            e
        } else {
            let v = Var::fresh(ins.name.replace('.', "_"));
            bindings.push((v.clone(), e));
            ir::var(&v)
        };
        if ins.is_root {
            root = Some(atom.clone());
        }
        env.insert(ins.name.clone(), (atom, ins.shape.clone(), ins.dtype));
    }

    let root = root.ok_or(ImportError("no ROOT instruction".into()))?;
    let body = bindings
        .into_iter()
        .rev()
        .fold(root, |acc, (v, val)| ir::let_(v, val, acc));
    Ok(Function::new(params_by_index.into_values().collect(), body))
}

/// Import from a file into a fresh module's `@main`.
pub fn import_hlo_file(path: &std::path::Path) -> R<crate::ir::Module> {
    let src = std::fs::read_to_string(path).map_err(|e| ImportError(e.to_string()))?;
    let f = import_hlo_text(&src)?;
    let mut m = crate::ir::Module::with_prelude();
    m.add_def("main", f);
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_main, Value};

    const SAMPLE: &str = r#"
HloModule jit_fn

ENTRY %main.7 (Arg_0.1: f32[2,2], Arg_1.2: f32[2,2]) -> (f32[2,2]) {
  %Arg_0.1 = f32[2,2]{1,0} parameter(0)
  %Arg_1.2 = f32[2,2]{1,0} parameter(1)
  %dot.3 = f32[2,2]{1,0} dot(f32[2,2]{1,0} %Arg_0.1, f32[2,2]{1,0} %Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %constant.4 = f32[] constant(2)
  %broadcast.5 = f32[2,2]{1,0} broadcast(f32[] %constant.4), dimensions={}
  %add.6 = f32[2,2]{1,0} add(f32[2,2]{1,0} %dot.3, f32[2,2]{1,0} %broadcast.5)
  ROOT %tuple.7 = (f32[2,2]{1,0}) tuple(f32[2,2]{1,0} %add.6)
}
"#;

    #[test]
    fn imports_the_reference_module() {
        // The same computation as /opt/xla-example's round-trip demo:
        // matmul(x, y) + 2.
        let f = import_hlo_text(SAMPLE).unwrap();
        assert_eq!(f.params.len(), 2);
        let mut m = crate::ir::Module::with_prelude();
        m.add_def("main", f);
        crate::ty::check_module(&m).unwrap();
        let x = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let y = Tensor::from_f32(vec![2, 2], vec![1., 1., 1., 1.]);
        let out = eval_main(&m, vec![Value::Tensor(x), Value::Tensor(y)]).unwrap();
        // result is the 1-tuple (matmul + 2)
        let t = &out.tuple()[0];
        assert_eq!(t.tensor().as_f32(), &[5., 5., 9., 9.]);
    }

    #[test]
    fn rejects_unknown_opcodes() {
        let src = "ENTRY %m (x: f32[1]) -> f32[1] {\n  ROOT %y.1 = f32[1]{0} mystery(f32[1]{0} %x)\n}";
        assert!(import_hlo_text(src).is_err());
    }

    #[test]
    fn parses_array_literals() {
        let t = parse_literal("{1, 2, 3}", DType::F32, &[3]).unwrap();
        assert_eq!(t.as_f32(), &[1., 2., 3.]);
        let t2 = parse_literal("{ {1, 2}, {3, 4} }", DType::F32, &[2, 2]).unwrap();
        assert_eq!(t2.as_f32(), &[1., 2., 3., 4.]);
    }
}
