//! Reductions, softmax family, argmax.

use std::sync::Arc;

use super::shape::norm_axis;
use super::{Storage, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceKind {
    Sum,
    Mean,
    Max,
    Min,
    Prod,
    All,
    Any,
}

/// Reduce over `axes` (empty = all axes). `keepdims` keeps size-1 dims.
pub fn reduce(x: &Tensor, kind: ReduceKind, axes: &[i64], keepdims: bool) -> Tensor {
    let rank = x.rank();
    let axes: Vec<usize> = if axes.is_empty() {
        (0..rank).collect()
    } else {
        axes.iter().map(|&a| norm_axis(a, rank)).collect()
    };
    let reduce_mask: Vec<bool> = (0..rank).map(|i| axes.contains(&i)).collect();
    let out_shape_full: Vec<usize> = x
        .shape()
        .iter()
        .enumerate()
        .map(|(i, &d)| if reduce_mask[i] { 1 } else { d })
        .collect();
    let out_numel: usize = out_shape_full.iter().product();
    let reduced_count: usize = x
        .shape()
        .iter()
        .enumerate()
        .filter(|(i, _)| reduce_mask[*i])
        .map(|(_, &d)| d)
        .product();

    // Bool reductions.
    if matches!(kind, ReduceKind::All | ReduceKind::Any) {
        let xv = x.as_bool();
        let mut acc = vec![matches!(kind, ReduceKind::All); out_numel];
        let strides = super::shape::row_major_strides(&out_shape_full);
        for (i, &v) in xv.iter().enumerate() {
            let oi = out_index(i, x.shape(), &reduce_mask, &strides);
            acc[oi] = match kind {
                ReduceKind::All => acc[oi] && v,
                ReduceKind::Any => acc[oi] || v,
                _ => unreachable!(),
            };
        }
        let shape = final_shape(&out_shape_full, &reduce_mask, keepdims);
        return Tensor::new(shape, Storage::Bool(Arc::new(acc)));
    }

    let init = match kind {
        ReduceKind::Sum | ReduceKind::Mean => 0.0,
        ReduceKind::Max => f64::NEG_INFINITY,
        ReduceKind::Min => f64::INFINITY,
        ReduceKind::Prod => 1.0,
        _ => unreachable!(),
    };
    let mut acc = vec![init; out_numel];
    let strides = super::shape::row_major_strides(&out_shape_full);
    for i in 0..x.numel() {
        let v = x.get_f64(i);
        let oi = out_index(i, x.shape(), &reduce_mask, &strides);
        acc[oi] = match kind {
            ReduceKind::Sum | ReduceKind::Mean => acc[oi] + v,
            ReduceKind::Max => acc[oi].max(v),
            ReduceKind::Min => acc[oi].min(v),
            ReduceKind::Prod => acc[oi] * v,
            _ => unreachable!(),
        };
    }
    if kind == ReduceKind::Mean {
        for a in acc.iter_mut() {
            *a /= reduced_count as f64;
        }
    }
    let shape = final_shape(&out_shape_full, &reduce_mask, keepdims);
    super::elementwise::from_f64_as(x.dtype(), shape, &acc)
}

fn out_index(flat: usize, in_shape: &[usize], mask: &[bool], out_strides: &[usize]) -> usize {
    let mut rem = flat;
    let mut oi = 0;
    // Decompose flat index; reduced axes contribute 0.
    for ax in (0..in_shape.len()).rev() {
        let d = in_shape[ax];
        let coord = rem % d;
        rem /= d;
        if !mask[ax] {
            oi += coord * out_strides[ax];
        }
    }
    oi
}

fn final_shape(full: &[usize], mask: &[bool], keepdims: bool) -> Vec<usize> {
    if keepdims {
        full.to_vec()
    } else {
        full.iter()
            .enumerate()
            .filter(|(i, _)| !mask[*i])
            .map(|(_, &d)| d)
            .collect()
    }
}

/// Numerically-stable softmax along `axis`.
pub fn softmax(x: &Tensor, axis: i64) -> Tensor {
    let ax = norm_axis(axis, x.rank());
    map_lanes(x, ax, |lane, out| {
        let m = lane.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (o, &v) in out.iter_mut().zip(lane.iter()) {
            *o = (v - m).exp();
            sum += *o;
        }
        for o in out.iter_mut() {
            *o /= sum;
        }
    })
}

/// `log_softmax` along `axis`.
pub fn log_softmax(x: &Tensor, axis: i64) -> Tensor {
    let ax = norm_axis(axis, x.rank());
    map_lanes(x, ax, |lane, out| {
        let m = lane.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = lane.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
        for (o, &v) in out.iter_mut().zip(lane.iter()) {
            *o = v - lse;
        }
    })
}

/// Apply `f` to each 1-d lane along `axis` of an f32 tensor.
///
/// Large tensors are parallelized over the *outer* dimension: every lane
/// is a disjoint set of output elements and `f` runs per-lane, so the
/// split cannot change any result bit (softmax/log_softmax stay exact
/// under `RELAY_KERNEL_THREADS > 1`).
fn map_lanes(x: &Tensor, axis: usize, f: impl Fn(&[f32], &mut [f32]) + Sync) -> Tensor {
    let xv = x.as_f32();
    let d = x.shape()[axis];
    let inner: usize = x.shape()[axis + 1..].iter().product();
    let outer: usize = x.shape()[..axis].iter().product();
    let mut out = vec![0f32; x.numel()];
    let slab = d * inner;
    let run = |out_slab: &mut [f32], o: usize| {
        let mut lane = vec![0f32; d];
        let mut res = vec![0f32; d];
        for i in 0..inner {
            for j in 0..d {
                lane[j] = xv[(o * d + j) * inner + i];
            }
            f(&lane, &mut res);
            for j in 0..d {
                out_slab[j * inner + i] = res[j];
            }
        }
    };
    const PAR_MIN_ELEMS: usize = 1 << 15;
    if outer <= 1
        || outer * slab < PAR_MIN_ELEMS
        || super::parallel::kernel_threads() <= 1
    {
        for o in 0..outer {
            run(&mut out[o * slab..(o + 1) * slab], o);
        }
    } else {
        let grain = super::parallel::chunk_size(outer, 1);
        let n_chunks = outer.div_ceil(grain);
        let shared = super::parallel::SplitMut::new(&mut out);
        super::parallel::parallel_for(n_chunks, |ci| {
            let lo = ci * grain;
            let hi = (lo + grain).min(outer);
            for o in lo..hi {
                // Safety: outer slabs are disjoint across chunks.
                let out_slab = unsafe { shared.slice(o * slab, slab) };
                run(out_slab, o);
            }
        });
    }
    Tensor::new(x.shape().to_vec(), Storage::F32(Arc::new(out)))
}

/// Argmax along `axis` -> i64 tensor with that axis removed.
pub fn argmax(x: &Tensor, axis: i64) -> Tensor {
    let ax = norm_axis(axis, x.rank());
    let d = x.shape()[ax];
    let inner: usize = x.shape()[ax + 1..].iter().product();
    let outer: usize = x.shape()[..ax].iter().product();
    let mut out = Vec::with_capacity(outer * inner);
    for o in 0..outer {
        for i in 0..inner {
            let mut best = f64::NEG_INFINITY;
            let mut arg = 0i64;
            for j in 0..d {
                let v = x.get_f64((o * d + j) * inner + i);
                if v > best {
                    best = v;
                    arg = j as i64;
                }
            }
            out.push(arg);
        }
    }
    let mut shape = x.shape().to_vec();
    shape.remove(ax);
    Tensor::new(shape, Storage::I64(Arc::new(out)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_all() {
        let x = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let s = reduce(&x, ReduceKind::Sum, &[], false);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.f32_value(), 10.0);
    }

    #[test]
    fn sum_axis0_and_1() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(reduce(&x, ReduceKind::Sum, &[0], false).as_f32(), &[5., 7., 9.]);
        assert_eq!(reduce(&x, ReduceKind::Sum, &[1], false).as_f32(), &[6., 15.]);
        assert_eq!(reduce(&x, ReduceKind::Sum, &[-1], false).as_f32(), &[6., 15.]);
    }

    #[test]
    fn mean_keepdims() {
        let x = Tensor::from_f32(vec![2, 2], vec![1., 3., 5., 7.]);
        let m = reduce(&x, ReduceKind::Mean, &[1], true);
        assert_eq!(m.shape(), &[2, 1]);
        assert_eq!(m.as_f32(), &[2., 6.]);
    }

    #[test]
    fn max_min_prod() {
        let x = Tensor::from_f32(vec![3], vec![2., 8., 4.]);
        assert_eq!(reduce(&x, ReduceKind::Max, &[], false).f32_value(), 8.0);
        assert_eq!(reduce(&x, ReduceKind::Min, &[], false).f32_value(), 2.0);
        assert_eq!(reduce(&x, ReduceKind::Prod, &[], false).f32_value(), 64.0);
    }

    #[test]
    fn bool_all_any() {
        let x = Tensor::from_bool(vec![3], vec![true, false, true]);
        assert!(!reduce(&x, ReduceKind::All, &[], false).bool_value());
        assert!(reduce(&x, ReduceKind::Any, &[], false).bool_value());
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 0., 0., 0.]);
        let s = softmax(&x, -1);
        let v = s.as_f32();
        assert!((v[0] + v[1] + v[2] - 1.0).abs() < 1e-6);
        assert!((v[3] - 1.0 / 3.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }

    #[test]
    fn log_softmax_matches_log_of_softmax() {
        let x = Tensor::from_f32(vec![1, 4], vec![0.5, -1., 2., 0.]);
        let a = log_softmax(&x, -1);
        let b = softmax(&x, -1);
        for i in 0..4 {
            assert!((a.as_f32()[i] - b.as_f32()[i].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_extreme_values_stable() {
        let x = Tensor::from_f32(vec![1, 2], vec![1000.0, 1000.0]);
        let s = softmax(&x, -1);
        assert!((s.as_f32()[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn argmax_axis() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 5., 2., 9., 0., 3.]);
        let a = argmax(&x, 1);
        assert_eq!(a.shape(), &[2]);
        assert_eq!(a.as_i64(), &[1, 0]);
    }
}
