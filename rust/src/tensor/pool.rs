//! Spatial pooling (NCHW): max / avg / global-avg.

use std::sync::Arc;

use super::{Storage, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Pool x (N,C,H,W) with a (k,k) window and given stride/padding.
pub fn pool2d(x: &Tensor, kind: PoolKind, k: usize, stride: usize, padding: usize) -> Tensor {
    assert_eq!(x.rank(), 4, "pool2d input rank");
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * padding - k) / stride + 1;
    let ow = (w + 2 * padding - k) / stride + 1;
    let xv = x.as_f32();
    let mut out = Vec::with_capacity(n * c * oh * ow);
    for img in 0..n * c {
        let base = img * h * w;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = match kind {
                    PoolKind::Max => f32::NEG_INFINITY,
                    PoolKind::Avg => 0.0,
                };
                let mut count = 0usize;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - padding as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - padding as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let v = xv[base + iy as usize * w + ix as usize];
                        match kind {
                            PoolKind::Max => acc = acc.max(v),
                            PoolKind::Avg => acc += v,
                        }
                        count += 1;
                    }
                }
                out.push(match kind {
                    PoolKind::Max => acc,
                    // TVM convention: divide by window size incl. padding?
                    // We divide by the number of *valid* elements (count),
                    // matching count_include_pad=False.
                    PoolKind::Avg => acc / count.max(1) as f32,
                });
            }
        }
    }
    Tensor::new(vec![n, c, oh, ow], Storage::F32(Arc::new(out)))
}

/// Global average pool (N,C,H,W) -> (N,C,1,1).
pub fn global_avg_pool2d(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let xv = x.as_f32();
    let mut out = Vec::with_capacity(n * c);
    for img in 0..n * c {
        let base = img * h * w;
        let s: f32 = xv[base..base + h * w].iter().sum();
        out.push(s / (h * w) as f32);
    }
    Tensor::new(vec![n, c, 1, 1], Storage::F32(Arc::new(out)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_pool_2x2() {
        let x = Tensor::from_f32(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let out = pool2d(&x, PoolKind::Max, 2, 2, 0);
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.as_f32(), &[4.0]);
    }

    #[test]
    fn avg_pool_2x2() {
        let x = Tensor::from_f32(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let out = pool2d(&x, PoolKind::Avg, 2, 2, 0);
        assert_eq!(out.as_f32(), &[2.5]);
    }

    #[test]
    fn max_pool_stride() {
        let x = Tensor::from_f32(vec![1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let out = pool2d(&x, PoolKind::Max, 2, 2, 0);
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        assert_eq!(out.as_f32(), &[5., 7., 13., 15.]);
    }

    #[test]
    fn avg_pool_padding_excludes_pad() {
        // 1x1 input, 3x3 window with padding 1: only one valid element.
        let x = Tensor::from_f32(vec![1, 1, 1, 1], vec![6.0]);
        let out = pool2d(&x, PoolKind::Avg, 3, 1, 1);
        assert_eq!(out.as_f32(), &[6.0]);
    }

    #[test]
    fn global_avg() {
        let x = Tensor::from_f32(vec![1, 2, 2, 2], vec![1., 1., 1., 1., 2., 2., 2., 2.]);
        let out = global_avg_pool2d(&x);
        assert_eq!(out.shape(), &[1, 2, 1, 1]);
        assert_eq!(out.as_f32(), &[1.0, 2.0]);
    }
}
