//! Dense tensor substrate: the "operator library" under the Relay compiler.
//!
//! The paper delegates kernels to TVM; this reproduction has two kernel
//! providers — the XLA backend ([`crate::backend::xla`]) for compiled
//! execution and this module for the reference interpreter, the quantized
//! ("ARM") path of Fig. 13, and the VTA simulator's host-side compute.
//!
//! Tensors are contiguous row-major buffers tagged with a shape and a dtype.
//! The dtype set mirrors the paper's base types (§3.3.1): floats and
//! integers of specific bit widths plus bool.

mod conv;
mod dtype;
mod elementwise;
mod linalg;
mod manip;
pub mod parallel;
mod pool;
mod quantized;
mod random;
mod reduce;
pub mod shape;
pub mod tune;

pub use conv::*;
pub use dtype::DType;
pub use elementwise::*;
pub use linalg::*;
pub use manip::*;
pub use pool::*;
pub use quantized::*;
pub use random::Rng;
pub use reduce::*;
pub use shape::{broadcast_shapes, Shape};

use std::cell::Cell;
use std::fmt;
use std::sync::{Arc, OnceLock};

use crate::telemetry::registry::names;
use crate::telemetry::Counter;

// ---------------------------------------------------------------------------
// Allocation accounting for the memory planner.
// ---------------------------------------------------------------------------

/// Process-wide counters for the in-place kernel fast path (the analogue of
/// [`crate::eval::LaunchCounter`] for memory planning): every *eligible*
/// hot kernel execution (elementwise binary/unary, bias-add, clip) either
/// reuses a dying input buffer (`hit`) or falls back to allocating a fresh
/// output (`miss`). GEMM outputs join the hit column only when they steal
/// a dead same-shape donor buffer ([`crate::op::inplace`]'s graveyard path
/// and the VM's `AllocTensor` rezero) — donation never counts a miss,
/// since those ops are outside the planner's eligible set.
///
/// Counters are bumped on the executing thread into BOTH a global pair and
/// a thread-local pair ([`thread_alloc_snapshot`]) so single-threaded tests
/// and benches can measure their own executions without racing parallel
/// test threads. The global pair IS the telemetry registry's
/// `relay_inplace_hits_total` / `relay_inplace_misses_total` counters —
/// one source of truth shared with the serving fleet's `Stats` and the
/// `/metrics` endpoint.
#[derive(Debug)]
pub struct AllocStats {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
}

impl AllocStats {
    fn from_registry() -> AllocStats {
        let r = crate::telemetry::registry();
        AllocStats {
            hits: r.counter(names::INPLACE_HITS_TOTAL),
            misses: r.counter(names::INPLACE_MISSES_TOTAL),
        }
    }

    /// In-place reuses so far (no output buffer allocated).
    pub fn hits(&self) -> usize {
        self.hits.get() as usize
    }

    /// Eligible kernels that had to allocate their output.
    pub fn misses(&self) -> usize {
        self.misses.get() as usize
    }

    pub fn snapshot(&self) -> AllocSnapshot {
        AllocSnapshot { hits: self.hits(), misses: self.misses() }
    }
}

/// A point-in-time copy of hit/miss counters; subtract two to get a delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub hits: usize,
    pub misses: usize,
}

impl AllocSnapshot {
    pub fn hits_since(&self, earlier: &AllocSnapshot) -> usize {
        self.hits - earlier.hits
    }

    pub fn misses_since(&self, earlier: &AllocSnapshot) -> usize {
        self.misses - earlier.misses
    }
}

static ALLOC_STATS: OnceLock<AllocStats> = OnceLock::new();

/// The process-wide allocation counters (registry-backed).
pub fn alloc_stats() -> &'static AllocStats {
    ALLOC_STATS.get_or_init(AllocStats::from_registry)
}

thread_local! {
    static TL_HITS: Cell<usize> = const { Cell::new(0) };
    static TL_MISSES: Cell<usize> = const { Cell::new(0) };
}

/// This thread's own hit/miss counters (what the calling thread's kernel
/// executions did, unpolluted by other threads).
pub fn thread_alloc_snapshot() -> AllocSnapshot {
    AllocSnapshot {
        hits: TL_HITS.with(|c| c.get()),
        misses: TL_MISSES.with(|c| c.get()),
    }
}

/// Record one in-place reuse (called by the in-place kernel glue).
pub fn note_inplace_hit() {
    alloc_stats().hits.inc();
    TL_HITS.with(|c| c.set(c.get() + 1));
}

/// Record one eligible kernel that allocated its output.
pub fn note_inplace_miss() {
    alloc_stats().misses.inc();
    TL_MISSES.with(|c| c.set(c.get() + 1));
}

/// Raw buffer behind a tensor. `Arc` makes clones O(1); all mutating ops
/// produce fresh buffers (value semantics, like Relay's pure fragment).
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Arc<Vec<f32>>),
    F64(Arc<Vec<f64>>),
    I64(Arc<Vec<i64>>),
    I32(Arc<Vec<i32>>),
    I16(Arc<Vec<i16>>),
    I8(Arc<Vec<i8>>),
    U8(Arc<Vec<u8>>),
    Bool(Arc<Vec<bool>>),
}

impl Storage {
    pub fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::I64(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I16(v) => v.len(),
            Storage::I8(v) => v.len(),
            Storage::U8(v) => v.len(),
            Storage::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Storage::F32(_) => DType::F32,
            Storage::F64(_) => DType::F64,
            Storage::I64(_) => DType::I64,
            Storage::I32(_) => DType::I32,
            Storage::I16(_) => DType::I16,
            Storage::I8(_) => DType::I8,
            Storage::U8(_) => DType::U8,
            Storage::Bool(_) => DType::Bool,
        }
    }

    /// Is this the only live reference to the underlying buffer? When true,
    /// mutating in place is unobservable (value semantics preserved) — the
    /// memory planner's legality condition.
    pub fn is_unique(&self) -> bool {
        match self {
            Storage::F32(v) => Arc::strong_count(v) == 1,
            Storage::F64(v) => Arc::strong_count(v) == 1,
            Storage::I64(v) => Arc::strong_count(v) == 1,
            Storage::I32(v) => Arc::strong_count(v) == 1,
            Storage::I16(v) => Arc::strong_count(v) == 1,
            Storage::I8(v) => Arc::strong_count(v) == 1,
            Storage::U8(v) => Arc::strong_count(v) == 1,
            Storage::Bool(v) => Arc::strong_count(v) == 1,
        }
    }

    /// Mutable access to an f32 buffer iff this is the sole owner
    /// (`Arc::get_mut` probe). `None` when shared or not f32 — callers fall
    /// back to the allocating kernel, so value semantics stay observable.
    pub fn try_unique_f32(&mut self) -> Option<&mut [f32]> {
        match self {
            Storage::F32(v) => Arc::get_mut(v).map(|v| v.as_mut_slice()),
            _ => None,
        }
    }

    /// [`Self::try_unique_f32`] for f64 buffers.
    pub fn try_unique_f64(&mut self) -> Option<&mut [f64]> {
        match self {
            Storage::F64(v) => Arc::get_mut(v).map(|v| v.as_mut_slice()),
            _ => None,
        }
    }
}

/// A dense, row-major tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Storage,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Storage) -> Self {
        let numel: usize = shape.iter().product();
        assert_eq!(
            numel,
            data.len(),
            "shape {:?} does not match buffer length {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn from_f32(shape: Vec<usize>, v: Vec<f32>) -> Self {
        Tensor::new(shape, Storage::F32(Arc::new(v)))
    }

    pub fn from_i32(shape: Vec<usize>, v: Vec<i32>) -> Self {
        Tensor::new(shape, Storage::I32(Arc::new(v)))
    }

    pub fn from_i64(shape: Vec<usize>, v: Vec<i64>) -> Self {
        Tensor::new(shape, Storage::I64(Arc::new(v)))
    }

    pub fn from_i16(shape: Vec<usize>, v: Vec<i16>) -> Self {
        Tensor::new(shape, Storage::I16(Arc::new(v)))
    }

    pub fn from_i8(shape: Vec<usize>, v: Vec<i8>) -> Self {
        Tensor::new(shape, Storage::I8(Arc::new(v)))
    }

    pub fn from_bool(shape: Vec<usize>, v: Vec<bool>) -> Self {
        Tensor::new(shape, Storage::Bool(Arc::new(v)))
    }

    /// Rank-0 f32 scalar.
    pub fn scalar_f32(v: f32) -> Self {
        Tensor::from_f32(vec![], vec![v])
    }

    /// Rank-0 boolean (Relay `if` guards are rank-0 bool tensors, §3.2.3).
    pub fn scalar_bool(v: bool) -> Self {
        Tensor::from_bool(vec![], vec![v])
    }

    pub fn scalar_i64(v: i64) -> Self {
        Tensor::from_i64(vec![], vec![v])
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Self {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => Storage::F32(Arc::new(vec![0.0; n])),
            DType::F64 => Storage::F64(Arc::new(vec![0.0; n])),
            DType::I64 => Storage::I64(Arc::new(vec![0; n])),
            DType::I32 => Storage::I32(Arc::new(vec![0; n])),
            DType::I16 => Storage::I16(Arc::new(vec![0; n])),
            DType::I8 => Storage::I8(Arc::new(vec![0; n])),
            DType::U8 => Storage::U8(Arc::new(vec![0; n])),
            DType::Bool => Storage::Bool(Arc::new(vec![false; n])),
        };
        Tensor::new(shape.to_vec(), data)
    }

    pub fn ones(shape: &[usize], dtype: DType) -> Self {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => Storage::F32(Arc::new(vec![1.0; n])),
            DType::F64 => Storage::F64(Arc::new(vec![1.0; n])),
            DType::I64 => Storage::I64(Arc::new(vec![1; n])),
            DType::I32 => Storage::I32(Arc::new(vec![1; n])),
            DType::I16 => Storage::I16(Arc::new(vec![1; n])),
            DType::I8 => Storage::I8(Arc::new(vec![1; n])),
            DType::U8 => Storage::U8(Arc::new(vec![1; n])),
            DType::Bool => Storage::Bool(Arc::new(vec![true; n])),
        };
        Tensor::new(shape.to_vec(), data)
    }

    pub fn full_f32(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor::from_f32(shape.to_vec(), vec![v; n])
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn storage(&self) -> &Storage {
        &self.data
    }

    /// Mutable access to this tensor's f32 buffer iff the storage is
    /// uniquely owned (see [`Storage::try_unique_f32`]).
    pub fn try_unique_f32(&mut self) -> Option<&mut [f32]> {
        self.data.try_unique_f32()
    }

    /// Is this tensor's buffer uniquely owned (safe to mutate in place)?
    pub fn is_unique(&self) -> bool {
        self.data.is_unique()
    }


    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Storage::F32(v) => v,
            other => panic!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match &self.data {
            Storage::F64(v) => v,
            other => panic!("expected f64 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i64(&self) -> &[i64] {
        match &self.data {
            Storage::I64(v) => v,
            other => panic!("expected i64 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Storage::I32(v) => v,
            other => panic!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i16(&self) -> &[i16] {
        match &self.data {
            Storage::I16(v) => v,
            other => panic!("expected i16 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i8(&self) -> &[i8] {
        match &self.data {
            Storage::I8(v) => v,
            other => panic!("expected i8 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_bool(&self) -> &[bool] {
        match &self.data {
            Storage::Bool(v) => v,
            other => panic!("expected bool tensor, got {:?}", other.dtype()),
        }
    }

    /// The single element of a rank-0 bool tensor.
    pub fn bool_value(&self) -> bool {
        assert!(self.numel() == 1, "bool_value on non-scalar {:?}", self.shape);
        self.as_bool()[0]
    }

    pub fn f32_value(&self) -> f32 {
        assert!(self.numel() == 1, "f32_value on non-scalar {:?}", self.shape);
        self.as_f32()[0]
    }

    pub fn i64_value(&self) -> i64 {
        assert!(self.numel() == 1, "i64_value on non-scalar {:?}", self.shape);
        self.as_i64()[0]
    }

    /// Lossy conversion of any element to f64 (for printing / calibration).
    pub fn get_f64(&self, idx: usize) -> f64 {
        match &self.data {
            Storage::F32(v) => v[idx] as f64,
            Storage::F64(v) => v[idx],
            Storage::I64(v) => v[idx] as f64,
            Storage::I32(v) => v[idx] as f64,
            Storage::I16(v) => v[idx] as f64,
            Storage::I8(v) => v[idx] as f64,
            Storage::U8(v) => v[idx] as f64,
            Storage::Bool(v) => v[idx] as u8 as f64,
        }
    }

    /// Row-major strides for this tensor's shape.
    pub fn strides(&self) -> Vec<usize> {
        shape::row_major_strides(&self.shape)
    }

    /// All elements as f32 (casting), used by calibration and tests.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        (0..self.numel()).map(|i| self.get_f64(i) as f32).collect()
    }

    /// Maximum absolute difference against another tensor (f32 semantics).
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        (0..self.numel())
            .map(|i| (self.get_f64(i) - other.get_f64(i)).abs())
            .fold(0.0, f64::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f64, rtol: f64) -> bool {
        if self.shape != other.shape {
            return false;
        }
        (0..self.numel()).all(|i| {
            let a = self.get_f64(i);
            let b = other.get_f64(i);
            (a - b).abs() <= atol + rtol * b.abs()
        })
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor[{:?}, {}]", self.shape, self.dtype())?;
        if self.numel() <= 8 {
            let vals: Vec<String> = (0..self.numel())
                .map(|i| format!("{:.4}", self.get_f64(i)))
                .collect();
            write!(f, " {{{}}}", vals.join(", "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_inspect() {
        let t = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.strides(), vec![3, 1]);
    }

    #[test]
    #[should_panic(expected = "does not match buffer length")]
    fn shape_mismatch_panics() {
        Tensor::from_f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn zeros_ones_all_dtypes() {
        for dt in [
            DType::F32,
            DType::F64,
            DType::I64,
            DType::I32,
            DType::I16,
            DType::I8,
            DType::U8,
            DType::Bool,
        ] {
            let z = Tensor::zeros(&[2, 2], dt);
            let o = Tensor::ones(&[2, 2], dt);
            assert_eq!(z.dtype(), dt);
            assert_eq!(o.get_f64(3), 1.0);
            assert_eq!(z.get_f64(0), 0.0);
        }
    }

    #[test]
    fn scalar_bool_roundtrip() {
        assert!(Tensor::scalar_bool(true).bool_value());
        assert!(!Tensor::scalar_bool(false).bool_value());
    }

    #[test]
    fn uniqueness_probe_respects_sharing() {
        let mut t = Tensor::from_f32(vec![2], vec![1.0, 2.0]);
        assert!(t.is_unique());
        assert!(t.try_unique_f32().is_some());
        let alias = t.clone();
        assert!(!t.is_unique());
        assert!(t.try_unique_f32().is_none(), "shared buffer handed out mutably");
        drop(alias);
        assert!(t.try_unique_f32().is_some());
        // Non-f32 storage refuses the f32 probe even when unique.
        let mut i = Tensor::from_i32(vec![1], vec![3]);
        assert!(i.is_unique());
        assert!(i.try_unique_f32().is_none());
        // The f64 probe mirrors the f32 one.
        let mut d = Tensor::new(vec![1], Storage::F64(Arc::new(vec![1.0])));
        assert!(d.data.try_unique_f64().is_some());
        let alias = d.clone();
        assert!(d.data.try_unique_f64().is_none());
        drop(alias);
    }

    #[test]
    fn alloc_stats_thread_snapshot_tracks_this_thread() {
        let global_before = alloc_stats().snapshot();
        let before = thread_alloc_snapshot();
        note_inplace_hit();
        note_inplace_miss();
        let after = thread_alloc_snapshot();
        assert_eq!(after.hits_since(&before), 1);
        assert_eq!(after.misses_since(&before), 1);
        // The global aggregate moved by at least as much (other test
        // threads may also be bumping it).
        let global_after = alloc_stats().snapshot();
        assert!(global_after.hits_since(&global_before) >= 1);
        assert!(global_after.misses_since(&global_before) >= 1);
    }

    #[test]
    fn allclose_works() {
        let a = Tensor::from_f32(vec![2], vec![1.0, 2.0]);
        let b = Tensor::from_f32(vec![2], vec![1.0 + 1e-7, 2.0]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::from_f32(vec![2], vec![1.5, 2.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }
}
