//! Process-wide kernel worker pool: chunked work-stealing over an atomic
//! index (std-only — no rayon, no crossbeam).
//!
//! The tiled kernels in [`super::linalg`] / [`super::conv`] split their
//! outer tile loop into independent chunks and run them through
//! [`parallel_for`]. The pool is **lazily initialized** on the first call
//! that actually wants more than one thread: `N-1` detached workers park
//! on a condvar; each parallel region publishes one job (a chunk count
//! plus a borrowed closure) and every participant — the caller included —
//! claims chunks with a `fetch_add` on a shared atomic until the range is
//! exhausted. The caller returns only after every chunk has *completed*
//! (not merely been claimed), which is what makes lending the closure by
//! reference sound.
//!
//! ## Thread-count resolution (once per process)
//!
//! 1. [`set_kernel_threads`] — the `--kernel-threads` CLI flag /
//!    `ServerConfig::kernel_threads`, highest priority;
//! 2. the `RELAY_KERNEL_THREADS` environment variable;
//! 3. `std::thread::available_parallelism()`, capped at [`MAX_THREADS`].
//!
//! `N = 1` **bypasses the pool entirely** — no threads are spawned, every
//! chunk runs inline on the caller — so single-threaded runs are exactly
//! the sequential kernels (the deterministic mode CI uses). Parallel runs
//! are *also* bit-identical to sequential ones for every kernel in this
//! crate, because chunks partition disjoint output regions and the
//! per-element accumulation order never depends on the split; the pool
//! merely makes that property easy to audit (see `tensor/README.md`).
//!
//! The resolved width is exported as the `relay_kernel_pool_threads`
//! gauge.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::sync::{lock_unpoisoned, wait_unpoisoned};

/// Upper bound on pool width: tensor kernels stop scaling long before
/// this on the shapes the zoo serves, and a runaway env value must not
/// spawn hundreds of threads.
pub const MAX_THREADS: usize = 16;

/// Programmatic override (0 = unset). Wins over the environment; must be
/// set before the first parallel kernel runs to take effect (the CLI and
/// the serving fleet set it at startup).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

static RESOLVED: OnceLock<usize> = OnceLock::new();

/// Set the kernel-pool width (the `--kernel-threads` flag). Values are
/// clamped to `1..=MAX_THREADS`. Calls after the width has been resolved
/// (first parallel kernel) are ignored.
pub fn set_kernel_threads(n: usize) {
    OVERRIDE.store(n.clamp(1, MAX_THREADS), Ordering::SeqCst);
}

/// The resolved pool width (participants per parallel region, caller
/// included). Resolution happens once and also publishes the
/// `relay_kernel_pool_threads` gauge.
pub fn kernel_threads() -> usize {
    *RESOLVED.get_or_init(|| {
        let n = resolve();
        crate::telemetry::registry()
            .gauge(crate::telemetry::registry::names::KERNEL_POOL_THREADS)
            .set(n as i64);
        n
    })
}

fn resolve() -> usize {
    let o = OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    if let Ok(s) = std::env::var("RELAY_KERNEL_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(MAX_THREADS);
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// A borrowed chunk closure smuggled to the workers as a raw fat pointer.
/// Soundness: the publishing caller blocks until `done == n_chunks`, and
/// `done` counts *completed* chunks, so no worker can be inside the
/// closure once the caller's borrow ends; workers that claim an index past
/// the range never dereference the pointer at all.
struct TaskPtr(*const (dyn Fn(usize) + Sync));
unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

struct Job {
    task: TaskPtr,
    /// Next chunk to claim (the work-stealing index).
    next: AtomicUsize,
    n_chunks: usize,
    /// Chunks fully executed — the caller's completion barrier.
    done: AtomicUsize,
}

impl Job {
    /// Claim-and-run until the chunk range is exhausted.
    fn run_chunks(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.n_chunks {
                return;
            }
            // Safety: see `TaskPtr` — the closure outlives every
            // dereference because completion gates the caller's return.
            unsafe { (*self.task.0)(i) };
            self.done.fetch_add(1, Ordering::SeqCst);
        }
    }
}

struct Pool {
    /// (generation, current job). Workers watch the generation so a
    /// republished slot is never run twice by the same thread.
    slot: Mutex<(u64, Option<std::sync::Arc<Job>>)>,
    work: Condvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let p = Pool { slot: Mutex::new((0, None)), work: Condvar::new() };
        for w in 0..kernel_threads().saturating_sub(1) {
            std::thread::Builder::new()
                .name(format!("relay-kernel-{w}"))
                .spawn(worker_loop)
                .expect("spawn kernel worker");
        }
        p
    })
}

fn worker_loop() {
    // Workers are spawned from inside POOL's get_or_init closure, so the
    // cell may not be set yet when a worker gets scheduled — wait for it.
    let p = loop {
        if let Some(p) = POOL.get() {
            break p;
        }
        std::thread::yield_now();
    };
    let mut seen = 0u64;
    loop {
        let job = {
            // Ride through poison: a chunk closure that panicked on some
            // other thread poisons the slot mutex, but the (generation,
            // job) pair is always written atomically under the lock, so
            // the pool keeps serving later regions.
            let mut g = lock_unpoisoned(&p.slot);
            loop {
                if g.0 != seen {
                    seen = g.0;
                    if let Some(j) = g.1.clone() {
                        break j;
                    }
                }
                g = wait_unpoisoned(&p.work, g);
            }
        };
        job.run_chunks();
    }
}

/// Run `chunk(0..n_chunks)` across the pool. The caller always
/// participates; with a pool width of 1 (or a single chunk) everything
/// runs inline and the pool is never even initialized. Chunks must write
/// disjoint output — the kernels split over output rows / channels, so
/// each element is produced by exactly one chunk in an order independent
/// of the split.
pub fn parallel_for(n_chunks: usize, chunk: impl Fn(usize) + Sync) {
    if n_chunks <= 1 || kernel_threads() <= 1 {
        for i in 0..n_chunks {
            chunk(i);
        }
        return;
    }
    let p = pool();
    let task: &(dyn Fn(usize) + Sync) = &chunk;
    let job = std::sync::Arc::new(Job {
        task: TaskPtr(task as *const _),
        next: AtomicUsize::new(0),
        n_chunks,
        done: AtomicUsize::new(0),
    });
    {
        let mut g = lock_unpoisoned(&p.slot);
        g.0 += 1;
        g.1 = Some(job.clone());
        p.work.notify_all();
    }
    job.run_chunks();
    // Completion barrier: claimed != completed, so spin until the last
    // helper finishes its chunk (chunks are kernel-sized, never tiny).
    while job.done.load(Ordering::SeqCst) < job.n_chunks {
        std::thread::yield_now();
    }
    let mut g = lock_unpoisoned(&p.slot);
    // Retire only our own job: a concurrent caller may have published a
    // newer one into the slot (it still completes — its caller runs every
    // chunk itself if no worker picks it up).
    if let Some(cur) = &g.1 {
        if std::sync::Arc::ptr_eq(cur, &job) {
            g.1 = None;
        }
    }
}

/// A mutable slice shared across parallel chunks. Each chunk carves out
/// its own sub-slice with [`SplitMut::slice`]; the *caller* guarantees the
/// ranges are disjoint (the kernels split by output rows / planes, so this
/// is structural, not dynamic).
pub struct SplitMut<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [f32]>,
}
unsafe impl Send for SplitMut<'_> {}
unsafe impl Sync for SplitMut<'_> {}

impl<'a> SplitMut<'a> {
    pub fn new(s: &'a mut [f32]) -> Self {
        SplitMut { ptr: s.as_mut_ptr(), len: s.len(), _marker: std::marker::PhantomData }
    }

    /// Carve out `start..start + len`.
    ///
    /// # Safety
    /// Concurrent `slice` calls must cover disjoint ranges.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [f32] {
        assert!(start + len <= self.len, "SplitMut range out of bounds");
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

/// Split `n` items into chunks of at least `grain`, at most
/// `4 * kernel_threads()` chunks (enough slack for stealing to balance
/// without drowning in tiny chunks). Returns the chunk size.
pub fn chunk_size(n: usize, grain: usize) -> usize {
    let max_chunks = 4 * kernel_threads();
    n.div_ceil(max_chunks).max(grain).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_chunk_exactly_once() {
        let n = 97;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {i}");
        }
    }

    #[test]
    fn nested_and_concurrent_regions_complete() {
        // Two threads racing parallel regions: both must complete even
        // when one publish overwrites the other in the pool slot.
        let total = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let local = AtomicU64::new(0);
                        parallel_for(13, |i| {
                            local.fetch_add(i as u64 + 1, Ordering::SeqCst);
                        });
                        assert_eq!(local.load(Ordering::SeqCst), (13 * 14) / 2);
                        total.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn chunk_size_respects_grain_and_width() {
        assert!(chunk_size(1000, 8) >= 8);
        assert!(chunk_size(3, 1) >= 1);
        assert_eq!(chunk_size(0, 4), 4);
    }
}
