//! Base types (paper §3.3.1): floats / ints of specific bit widths + bool.
//!
//! The paper parameterizes base types by lanes for vectorized dtypes; we fix
//! lanes = 1 (scalar elements) and note where the grammar would extend.

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F32,
    F64,
    I64,
    I32,
    I16,
    I8,
    U8,
    Bool,
}

impl DType {
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F64)
    }

    pub fn is_int(self) -> bool {
        matches!(
            self,
            DType::I64 | DType::I32 | DType::I16 | DType::I8 | DType::U8
        )
    }

    pub fn bits(self) -> usize {
        match self {
            DType::F64 | DType::I64 => 64,
            DType::F32 | DType::I32 => 32,
            DType::I16 => 16,
            DType::I8 | DType::U8 | DType::Bool => 8,
        }
    }

    pub fn size_bytes(self) -> usize {
        self.bits() / 8
    }

    /// Parse the Relay-text spelling (`float32`, `int8`, `uint8`, `bool`).
    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "float32" => DType::F32,
            "float64" => DType::F64,
            "int64" => DType::I64,
            "int32" => DType::I32,
            "int16" => DType::I16,
            "int8" => DType::I8,
            "uint8" => DType::U8,
            "bool" => DType::Bool,
            _ => return None,
        })
    }

    /// Type-promotion lattice for mixed binary ops (numpy-like, restricted
    /// to the pairs the operator registry actually produces).
    pub fn promote(a: DType, b: DType) -> DType {
        use DType::*;
        if a == b {
            return a;
        }
        match (a, b) {
            (F64, _) | (_, F64) => F64,
            (F32, _) | (_, F32) => F32,
            (I64, _) | (_, I64) => I64,
            (I32, _) | (_, I32) => I32,
            (I16, _) | (_, I16) => I16,
            (I8, U8) | (U8, I8) => I16,
            (I8, _) | (_, I8) => I8,
            (U8, _) | (_, U8) => U8,
            (Bool, Bool) => Bool,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::F32 => "float32",
            DType::F64 => "float64",
            DType::I64 => "int64",
            DType::I32 => "int32",
            DType::I16 => "int16",
            DType::I8 => "int8",
            DType::U8 => "uint8",
            DType::Bool => "bool",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_roundtrip() {
        for dt in [
            DType::F32,
            DType::F64,
            DType::I64,
            DType::I32,
            DType::I16,
            DType::I8,
            DType::U8,
            DType::Bool,
        ] {
            assert_eq!(DType::parse(&dt.to_string()), Some(dt));
        }
        assert_eq!(DType::parse("float16"), None);
    }

    #[test]
    fn promotion_lattice() {
        assert_eq!(DType::promote(DType::I8, DType::I32), DType::I32);
        assert_eq!(DType::promote(DType::F32, DType::I64), DType::F32);
        assert_eq!(DType::promote(DType::I8, DType::U8), DType::I16);
        assert_eq!(DType::promote(DType::Bool, DType::Bool), DType::Bool);
    }

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::I8.bits(), 8);
        assert!(DType::F32.is_float() && !DType::F32.is_int());
        assert!(DType::I16.is_int());
    }
}
