//! Deterministic PRNG for synthetic workloads (xoshiro256**-lite).
//!
//! The paper evaluates inference with random inputs (§5.1); every benchmark
//! and test in this repo seeds this generator so runs are reproducible.

use super::Tensor;

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn randint(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn normal_tensor(&mut self, shape: &[usize], scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let v: Vec<f32> = (0..n).map(|_| self.normal() * scale).collect();
        Tensor::from_f32(shape.to_vec(), v)
    }

    pub fn uniform_tensor(&mut self, shape: &[usize], lo: f32, hi: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let v: Vec<f32> = (0..n).map(|_| lo + self.uniform() * (hi - lo)).collect();
        Tensor::from_f32(shape.to_vec(), v)
    }

    pub fn labels_tensor(&mut self, n: usize, classes: i64) -> Tensor {
        let v: Vec<i64> = (0..n).map(|_| self.randint(0, classes)).collect();
        Tensor::from_i64(vec![n], v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn labels_in_range() {
        let mut r = Rng::new(3);
        let l = r.labels_tensor(100, 10);
        assert!(l.as_i64().iter().all(|&x| (0..10).contains(&x)));
    }
}
