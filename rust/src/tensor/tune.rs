//! Per-(op, shape) tile-size tuning — a lightweight take on TVM's
//! schedule search (the machinery Relay §4 leans on for its CPU numbers).
//!
//! The tiled GEMM/conv kernels in [`super::linalg`] / [`super::conv`] are
//! parameterized by a [`Schedule`] (cache-block extents; the register
//! micro-tile is fixed). This module owns:
//!
//! * a small **candidate lattice** ([`gemm_candidates`]) of tile configs;
//! * a **static heuristic** ([`heuristic`]) that picks one from the
//!   problem geometry — the default, used when probing is off;
//! * an optional **one-shot probe** (`RELAY_TUNE_PROBE=1`): time each
//!   candidate once on a clamped copy of the problem and keep the
//!   fastest — a compile-time cost paid once per (op, shape);
//! * the process-wide **schedule registry**: the `TuneKernels` pass seeds
//!   it at compile time for every statically-shaped dense/matmul/conv
//!   call it finds, the kernels consult it at launch, and
//!   `eval::ProgramCache` snapshots the decisions next to the compiled
//!   artifact (visible in `relay dump-passes` and `relay run --profile`).
//!
//! A schedule only changes *blocking*, never the per-element accumulation
//! order, so every candidate computes bit-identical results — tuning is
//! purely a performance decision and deliberately not part of the
//! program-cache key.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::Tensor;
use crate::sync::lock_unpoisoned;

/// Cache-block extents for the tiled GEMM kernels: `mc` rows of the
/// output are processed per parallel chunk, over `kc`-deep slices of the
/// inner dimension and `nc`-wide column blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

/// The tuned schedule for one kernel family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// matmul / dense / batch_matmul blocking.
    Gemm(TileConfig),
    /// Direct conv: output-channel block per parallel chunk.
    Conv { oc_block: usize },
}

impl Schedule {
    /// Compact label for pass traces and profiler rows.
    pub fn label(&self) -> String {
        match self {
            Schedule::Gemm(t) => format!("mc{}·kc{}·nc{}", t.mc, t.kc, t.nc),
            Schedule::Conv { oc_block } => format!("ocb{oc_block}"),
        }
    }
}

/// One tuning decision, as cached in the `ProgramCache` entry.
#[derive(Clone, Debug)]
pub struct TunedKernel {
    pub op: &'static str,
    /// GEMM: `[m, k, n]`; conv: `[n, c, h, w, oc, kh, kw]`. A leading 0
    /// marks a symbolic (batch-polymorphic) dimension.
    pub dims: Vec<usize>,
    pub schedule: Schedule,
}

impl TunedKernel {
    pub fn render(&self) -> String {
        format!("{} {:?} -> {}", self.op, self.dims, self.schedule.label())
    }
}

/// Kernels below this many multiply-adds never consult the registry or
/// the pool — a fixed small schedule is fastest and keeps tiny-op
/// dispatch overhead at zero.
pub const TUNE_MIN_MACS: usize = 1 << 12;

type Key = (&'static str, Vec<usize>);

fn registry() -> &'static Mutex<HashMap<Key, Schedule>> {
    static REG: OnceLock<Mutex<HashMap<Key, Schedule>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// The candidate lattice the probe searches (the heuristic picks inside
/// the same space, so probing can only refine, never diverge).
pub fn gemm_candidates() -> Vec<TileConfig> {
    let mut v = Vec::new();
    for &mc in &[32usize, 64, 128] {
        for &kc in &[128usize, 256] {
            for &nc in &[256usize, 512] {
                v.push(TileConfig { mc, kc, nc });
            }
        }
    }
    v
}

/// Static schedule choice from problem geometry: `kc` sized so a packed
/// panel stays L1-resident, `nc` so the streamed block stays L2-resident,
/// `mc` as the parallel grain.
pub fn heuristic(op: &'static str, dims: &[usize]) -> Schedule {
    match op {
        "nn.conv2d" | "nn.conv2d_transpose" => {
            // dims[4] = output channels when known; one channel per chunk
            // is plenty below ~64 channels, then block by 4.
            let oc = dims.get(4).copied().unwrap_or(0);
            Schedule::Conv { oc_block: if oc >= 64 { 4 } else { 1 } }
        }
        _ => {
            let (m, k, n) = gemm_dims_of(dims);
            let kc = k.clamp(1, 256);
            let nc = n.clamp(1, if k >= 512 { 256 } else { 512 });
            let mc = if m == 0 { 64 } else { m.clamp(1, 64) };
            Schedule::Gemm(TileConfig { mc, kc, nc })
        }
    }
}

fn gemm_dims_of(dims: &[usize]) -> (usize, usize, usize) {
    match dims {
        [m, k, n] => (*m, *k, *n),
        _ => (0, 0, 0),
    }
}

/// The schedule a kernel should run with *right now*: exact-shape registry
/// entry, then the batch-polymorphic entry (`m = 0`), then the heuristic.
/// Never blocks compile-time probing into the launch path.
pub fn schedule_for(op: &'static str, dims: &[usize]) -> Schedule {
    let reg = lock_unpoisoned(registry());
    if let Some(s) = reg.get(&(op, dims.to_vec())) {
        return *s;
    }
    if dims.len() == 3 {
        let poly = vec![0, dims[1], dims[2]];
        if let Some(s) = reg.get(&(op, poly)) {
            return *s;
        }
    }
    drop(reg);
    heuristic(op, dims)
}

/// The registered schedule's label, if this (op, shape) was tuned at
/// compile time — `None` falls back to the heuristic label at the caller.
pub fn tuned_label(op: &'static str, dims: &[usize]) -> Option<String> {
    let reg = lock_unpoisoned(registry());
    reg.get(&(op, dims.to_vec()))
        .or_else(|| {
            if dims.len() == 3 {
                reg.get(&(op, vec![0, dims[1], dims[2]]))
            } else {
                None
            }
        })
        .map(|s| s.label())
}

/// Ensure a tuning decision exists for `(op, dims)`: registry hit returns
/// the cached choice (idempotent — re-compiles and cache snapshots never
/// re-probe); a miss runs the probe (when `RELAY_TUNE_PROBE=1`) or the
/// heuristic, stores the decision, and bumps
/// `relay_tuned_schedules_total`.
pub fn ensure(op: &'static str, dims: Vec<usize>) -> TunedKernel {
    if let Some(s) = lock_unpoisoned(registry()).get(&(op, dims.clone())) {
        return TunedKernel { op, dims, schedule: *s };
    }
    let schedule = if probe_enabled() && is_gemm(op) {
        probe_gemm(&dims)
    } else {
        heuristic(op, &dims)
    };
    let mut reg = lock_unpoisoned(registry());
    let fresh = reg.insert((op, dims.clone()), schedule).is_none();
    drop(reg);
    if fresh {
        crate::telemetry::registry()
            .counter(crate::telemetry::registry::names::TUNED_SCHEDULES_TOTAL)
            .inc();
    }
    TunedKernel { op, dims, schedule }
}

/// Number of decisions currently in the registry (test/bench hook).
pub fn tuned_count() -> usize {
    lock_unpoisoned(registry()).len()
}

fn is_gemm(op: &str) -> bool {
    matches!(op, "nn.dense" | "matmul" | "nn.batch_matmul")
}

fn probe_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        std::env::var("RELAY_TUNE_PROBE").map(|v| v == "1").unwrap_or(false)
    })
}

/// One-shot probe: run every lattice candidate once on a clamped version
/// of the problem (so compile time stays bounded on huge shapes — tile
/// choice is governed by cache footprints, which saturate well below the
/// clamp) and keep the fastest. Candidates are bit-identical, so this is
/// timing-only.
fn probe_gemm(dims: &[usize]) -> Schedule {
    let (m, k, n) = gemm_dims_of(dims);
    let (pm, pk, pn) = (m.clamp(1, 256), k.clamp(1, 512), n.clamp(1, 512));
    let a = Tensor::from_f32(vec![pm, pk], vec![1.0; pm * pk]);
    let b = Tensor::from_f32(vec![pk, pn], vec![1.0; pk * pn]);
    let mut best: Option<(std::time::Duration, TileConfig)> = None;
    let mut out = vec![0f32; pm * pn];
    for cand in gemm_candidates() {
        out.fill(0.0);
        let t0 = std::time::Instant::now();
        super::linalg::matmul_into_with(&a, &b, &mut out, cand);
        let dt = t0.elapsed();
        if best.map(|(bt, _)| dt < bt).unwrap_or(true) {
            best = Some((dt, cand));
        }
    }
    let picked = best.expect("non-empty candidate lattice").1;
    // Re-clamp to the real geometry (the probe ran on clipped dims).
    Schedule::Gemm(TileConfig {
        mc: picked.mc.min(if m == 0 { picked.mc } else { m.max(1) }),
        kc: picked.kc.min(k.max(1)),
        nc: picked.nc.min(n.max(1)),
    })
}

/// Snapshot type stored in each `ProgramCache` entry.
pub type ScheduleSet = Arc<Vec<TunedKernel>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_stays_inside_problem_bounds() {
        let Schedule::Gemm(t) = heuristic("nn.dense", &[3, 5, 7]) else {
            panic!("gemm op got a non-gemm schedule");
        };
        assert!(t.mc <= 3 && t.kc <= 5 && t.nc <= 7);
        let Schedule::Gemm(big) = heuristic("matmul", &[1024, 1024, 1024]) else {
            panic!()
        };
        assert!(big.kc <= 256 && big.nc <= 512);
        assert!(matches!(
            heuristic("nn.conv2d", &[1, 3, 32, 32, 64, 3, 3]),
            Schedule::Conv { .. }
        ));
    }

    #[test]
    fn ensure_is_idempotent_and_counts_once() {
        let c = crate::telemetry::registry()
            .counter(crate::telemetry::registry::names::TUNED_SCHEDULES_TOTAL);
        // The counter is process-global and other tests may insert fresh
        // keys concurrently; retry with a new key until an attempt sees a
        // clean window. A genuine double-count makes every attempt read
        // `before + 2`, so the regression still fails deterministically.
        let mut observed_exactly_one = false;
        for salt in 0..10 {
            let dims = vec![17 + salt, 19, 23];
            let before = c.get();
            let first = ensure("nn.dense", dims.clone());
            let again = ensure("nn.dense", dims.clone());
            assert_eq!(first.schedule, again.schedule);
            assert_eq!(schedule_for("nn.dense", &dims), first.schedule);
            if c.get() == before + 1 {
                observed_exactly_one = true;
                break;
            }
        }
        assert!(observed_exactly_one, "second ensure must not re-count");
    }

    #[test]
    fn poly_batch_entry_serves_concrete_batches() {
        let tuned = ensure("matmul", vec![0, 31, 37]);
        // A concrete batch with no exact entry falls through to the
        // symbolic one.
        assert_eq!(schedule_for("matmul", &[9, 31, 37]), tuned.schedule);
        assert!(tuned_label("matmul", &[9, 31, 37]).is_some());
        assert!(tuned_label("matmul", &[9, 31, 38]).is_none());
    }
}
