//! 2-d convolution (NCHW / OIHW), with grouped support for MobileNet-style
//! depthwise blocks, plus transposed conv for the DCGAN workload of Fig 14.
//!
//! `conv2d` is data-parallelized over output planes — each `(batch, out
//! channel)` plane is a disjoint output region computed by exactly one
//! chunk of [`super::parallel`]'s pool, with the per-plane loop order
//! unchanged from the direct kernel — so results are bitwise identical to
//! the sequential reference at any thread count. The in-plane row/column
//! bounds are hoisted out of the hot loop analytically (no per-pixel
//! padding branches); the parallel grain (`oc_block`) comes from
//! [`super::tune`].

use std::sync::Arc;

use super::parallel;
use super::tune::{self, Schedule};
use super::{Storage, Tensor};

/// Below this many multiply-adds the kernel stays sequential.
const PAR_MIN_MACS: usize = 1 << 16;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dParams {
    pub stride: (usize, usize),
    pub padding: (usize, usize),
    pub groups: usize,
}

impl Default for Conv2dParams {
    fn default() -> Self {
        Conv2dParams { stride: (1, 1), padding: (0, 0), groups: 1 }
    }
}

pub fn conv2d_out_hw(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    p: &Conv2dParams,
) -> (usize, usize) {
    (
        (h + 2 * p.padding.0 - kh) / p.stride.0 + 1,
        (w + 2 * p.padding.1 - kw) / p.stride.1 + 1,
    )
}

/// Direct NCHW conv: x (N,C,H,W), w (O, C/groups, KH, KW) -> (N,O,OH,OW).
/// Parallel over output planes, bitwise identical to [`conv2d_naive`].
pub fn conv2d(x: &Tensor, w: &Tensor, p: &Conv2dParams) -> Tensor {
    assert_eq!(x.rank(), 4, "conv2d input rank");
    assert_eq!(w.rank(), 4, "conv2d weight rank");
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (o, cg, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(c, cg * p.groups, "conv2d channels {c} vs {cg}x{}", p.groups);
    assert_eq!(o % p.groups, 0, "out channels divisible by groups");
    let (oh, ow) = conv2d_out_hw(h, wd, kh, kw, p);
    let og = o / p.groups;

    let xv = x.as_f32();
    let wv = w.as_f32();
    let mut out = vec![0f32; n * o * oh * ow];

    let planes = n * o;
    let macs = planes * oh * ow * cg * kh * kw;
    let oc_block = if macs >= tune::TUNE_MIN_MACS {
        match tune::schedule_for("nn.conv2d", &[n, c, h, wd, o, kh, kw]) {
            Schedule::Conv { oc_block } => oc_block.max(1),
            Schedule::Gemm(_) => 1,
        }
    } else {
        1
    };

    let plane = |out_plane: &mut [f32], idx: usize| {
        let (ni, ocabs) = (idx / o, idx % o);
        let g = ocabs / og;
        for ic in 0..cg {
            let icabs = g * cg + ic;
            let xbase = (ni * c + icabs) * h * wd;
            let wbase = (ocabs * cg + ic) * kh * kw;
            for ky in 0..kh {
                // Hoisted row bounds: iy = oy*s + ky - pad must land in
                // [0, h).
                let (oy0, oy1) = valid_range(oh, h, p.stride.0, ky, p.padding.0);
                for kx in 0..kw {
                    let wval = wv[wbase + ky * kw + kx];
                    if wval == 0.0 {
                        continue;
                    }
                    let (ox0, ox1) = valid_range(ow, wd, p.stride.1, kx, p.padding.1);
                    for oy in oy0..oy1 {
                        let iy = oy * p.stride.0 + ky - p.padding.0;
                        let xrow = xbase + iy * wd;
                        let orow = &mut out_plane[oy * ow..oy * ow + ow];
                        if p.stride.1 == 1 {
                            let ibase = xrow + ox0 + kx - p.padding.1;
                            for (i, ov) in orow[ox0..ox1].iter_mut().enumerate() {
                                *ov += wval * xv[ibase + i];
                            }
                        } else {
                            for (ov, ox) in orow[ox0..ox1].iter_mut().zip(ox0..) {
                                let ix = ox * p.stride.1 + kx - p.padding.1;
                                *ov += wval * xv[xrow + ix];
                            }
                        }
                    }
                }
            }
        }
    };

    let plane_len = oh * ow;
    if macs < PAR_MIN_MACS || planes <= 1 || parallel::kernel_threads() <= 1 {
        for idx in 0..planes {
            plane(&mut out[idx * plane_len..(idx + 1) * plane_len], idx);
        }
    } else {
        let grain = parallel::chunk_size(planes, oc_block);
        let n_chunks = planes.div_ceil(grain);
        let shared = parallel::SplitMut::new(&mut out);
        parallel::parallel_for(n_chunks, |ci| {
            let lo = ci * grain;
            let hi = (lo + grain).min(planes);
            for idx in lo..hi {
                // Safety: plane ranges are disjoint across chunks.
                let out_plane = unsafe { shared.slice(idx * plane_len, plane_len) };
                plane(out_plane, idx);
            }
        });
    }
    Tensor::new(vec![n, o, oh, ow], Storage::F32(Arc::new(out)))
}

/// `out` indices whose input coordinate `o*stride + k - pad` lands in
/// `[0, extent)` — the padding test, solved once per kernel tap instead of
/// per pixel.
#[inline]
fn valid_range(
    out_extent: usize,
    extent: usize,
    stride: usize,
    k: usize,
    pad: usize,
) -> (usize, usize) {
    let lo = pad.saturating_sub(k).div_ceil(stride).min(out_extent);
    let hi_num = (extent + pad) as isize - 1 - k as isize;
    let hi = if hi_num < 0 {
        0
    } else {
        ((hi_num as usize) / stride + 1).min(out_extent)
    };
    (lo, hi.max(lo))
}

/// The original direct loop (per-pixel padding branches, sequential): the
/// differential baseline for [`conv2d`] and the fig17 "naive" column.
pub fn conv2d_naive(x: &Tensor, w: &Tensor, p: &Conv2dParams) -> Tensor {
    assert_eq!(x.rank(), 4, "conv2d input rank");
    assert_eq!(w.rank(), 4, "conv2d weight rank");
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (o, cg, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(c, cg * p.groups, "conv2d channels {c} vs {cg}x{}", p.groups);
    assert_eq!(o % p.groups, 0, "out channels divisible by groups");
    let (oh, ow) = conv2d_out_hw(h, wd, kh, kw, p);
    let og = o / p.groups;

    let xv = x.as_f32();
    let wv = w.as_f32();
    let mut out = vec![0f32; n * o * oh * ow];

    for ni in 0..n {
        for g in 0..p.groups {
            for oc in 0..og {
                let ocabs = g * og + oc;
                for ic in 0..cg {
                    let icabs = g * cg + ic;
                    let xbase = (ni * c + icabs) * h * wd;
                    let wbase = (ocabs * cg + ic) * kh * kw;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let wval = wv[wbase + ky * kw + kx];
                            if wval == 0.0 {
                                continue;
                            }
                            for oy in 0..oh {
                                let iy = (oy * p.stride.0 + ky) as isize
                                    - p.padding.0 as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                let obase = ((ni * o + ocabs) * oh + oy) * ow;
                                let xrow = xbase + iy as usize * wd;
                                for ox in 0..ow {
                                    let ix = (ox * p.stride.1 + kx) as isize
                                        - p.padding.1 as isize;
                                    if ix < 0 || ix >= wd as isize {
                                        continue;
                                    }
                                    out[obase + ox] += wval * xv[xrow + ix as usize];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(vec![n, o, oh, ow], Storage::F32(Arc::new(out)))
}

/// im2col: extract conv patches of x (N,C,H,W) into a GEMM-ready matrix
/// (N*OH*OW, C*KH*KW). Pairing this with the cache-blocked matmul is the
/// AlterOpLayout strategy used at -O3 (see pass::alter_op_layout): the
/// same data-layout-change-for-locality idea the paper applies, realized
/// as conv-as-GEMM.
pub fn im2col(x: &Tensor, kh: usize, kw: usize, p: &Conv2dParams) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (oh, ow) = conv2d_out_hw(h, wd, kh, kw, p);
    let xv = x.as_f32();
    let cols = c * kh * kw;
    let mut out = vec![0f32; n * oh * ow * cols];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ci in 0..c {
                    let xbase = (ni * c + ci) * h * wd;
                    for ky in 0..kh {
                        let iy = (oy * p.stride.0 + ky) as isize - p.padding.0 as isize;
                        for kx in 0..kw {
                            let ix =
                                (ox * p.stride.1 + kx) as isize - p.padding.1 as isize;
                            let v = if iy < 0 || iy >= h as isize || ix < 0 || ix >= wd as isize
                            {
                                0.0
                            } else {
                                xv[xbase + iy as usize * wd + ix as usize]
                            };
                            out[row + (ci * kh + ky) * kw + kx] = v;
                        }
                    }
                }
            }
        }
    }
    Tensor::new(vec![n * oh * ow, cols], Storage::F32(Arc::new(out)))
}

/// Transposed conv (stride-s upsampling), NCHW / IOHW weight layout.
pub fn conv2d_transpose(x: &Tensor, w: &Tensor, stride: usize, padding: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (c2, o, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(c, c2);
    let oh = (h - 1) * stride + kh - 2 * padding;
    let ow = (wd - 1) * stride + kw - 2 * padding;
    let xv = x.as_f32();
    let wv = w.as_f32();
    let mut out = vec![0f32; n * o * oh * ow];
    for ni in 0..n {
        for ic in 0..c {
            for oc in 0..o {
                let wbase = (ic * o + oc) * kh * kw;
                for iy in 0..h {
                    for ix in 0..wd {
                        let xval = xv[((ni * c + ic) * h + iy) * wd + ix];
                        if xval == 0.0 {
                            continue;
                        }
                        for ky in 0..kh {
                            let oy = iy * stride + ky;
                            if oy < padding || oy - padding >= oh {
                                continue;
                            }
                            for kx in 0..kw {
                                let ox = ix * stride + kx;
                                if ox < padding || ox - padding >= ow {
                                    continue;
                                }
                                out[((ni * o + oc) * oh + (oy - padding)) * ow
                                    + (ox - padding)] += xval * wv[wbase + ky * kw + kx];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(vec![n, o, oh, ow], Storage::F32(Arc::new(out)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(stride: usize, padding: usize) -> Conv2dParams {
        Conv2dParams { stride: (stride, stride), padding: (padding, padding), groups: 1 }
    }

    #[test]
    fn identity_kernel() {
        // 1x1 kernel of 1.0 copies the input.
        let x = Tensor::from_f32(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::from_f32(vec![1, 1, 1, 1], vec![1.]);
        assert_eq!(conv2d(&x, &w, &params(1, 0)).as_f32(), x.as_f32());
    }

    #[test]
    fn box_filter_3x3() {
        let x = Tensor::from_f32(vec![1, 1, 3, 3], vec![1.; 9]);
        let w = Tensor::from_f32(vec![1, 1, 3, 3], vec![1.; 9]);
        let out = conv2d(&x, &w, &params(1, 0));
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.as_f32(), &[9.0]);
    }

    #[test]
    fn padding_same() {
        let x = Tensor::from_f32(vec![1, 1, 3, 3], vec![1.; 9]);
        let w = Tensor::from_f32(vec![1, 1, 3, 3], vec![1.; 9]);
        let out = conv2d(&x, &w, &params(1, 1));
        assert_eq!(out.shape(), &[1, 1, 3, 3]);
        // Center sees 9 ones, corner sees 4.
        assert_eq!(out.as_f32()[4], 9.0);
        assert_eq!(out.as_f32()[0], 4.0);
    }

    #[test]
    fn stride_two() {
        let x = Tensor::from_f32(vec![1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let w = Tensor::from_f32(vec![1, 1, 1, 1], vec![1.]);
        let out = conv2d(&x, &w, &params(2, 0));
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        assert_eq!(out.as_f32(), &[0., 2., 8., 10.]);
    }

    #[test]
    fn multi_channel_sum() {
        // Two input channels, kernel of ones sums them.
        let x = Tensor::from_f32(vec![1, 2, 1, 1], vec![3., 4.]);
        let w = Tensor::from_f32(vec![1, 2, 1, 1], vec![1., 1.]);
        assert_eq!(conv2d(&x, &w, &params(1, 0)).as_f32(), &[7.]);
    }

    #[test]
    fn grouped_is_blockwise() {
        // groups=2: each output channel sees only its group's input channel.
        let x = Tensor::from_f32(vec![1, 2, 1, 1], vec![3., 4.]);
        let w = Tensor::from_f32(vec![2, 1, 1, 1], vec![10., 100.]);
        let p = Conv2dParams { stride: (1, 1), padding: (0, 0), groups: 2 };
        assert_eq!(conv2d(&x, &w, &p).as_f32(), &[30., 400.]);
    }

    #[test]
    fn transpose_upsamples() {
        let x = Tensor::from_f32(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::from_f32(vec![1, 1, 2, 2], vec![1.; 4]);
        let out = conv2d_transpose(&x, &w, 2, 0);
        assert_eq!(out.shape(), &[1, 1, 4, 4]);
        // Each input pixel stamps a 2x2 block of its value.
        assert_eq!(out.as_f32()[0], 1.0);
        assert_eq!(out.as_f32()[15], 4.0);
    }
}
