//! Narrow-integer kernels: the realized form of the quantization flow
//! (paper §4.5) and the "ARM" measurement substrate for Fig 13.
//!
//! i8 x i8 matmul/conv with a choice of i16 (saturating) or i32 accumulator;
//! requantization (scale shift back to i8); dequantize.

use std::sync::Arc;

use super::conv::{conv2d_out_hw, Conv2dParams};
use super::{Storage, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccBits {
    I16,
    I32,
}

/// Quantize f32 -> i8 with power-of-two `scale` (value = round(x / scale)).
pub fn quantize_i8(x: &Tensor, scale: f32) -> Tensor {
    let out: Vec<i8> = x
        .as_f32()
        .iter()
        .map(|&v| (v / scale).round().clamp(-128.0, 127.0) as i8)
        .collect();
    Tensor::new(x.shape().to_vec(), Storage::I8(Arc::new(out)))
}

/// Dequantize an integer tensor back to f32 with `scale`.
pub fn dequantize(x: &Tensor, scale: f32) -> Tensor {
    let out: Vec<f32> = (0..x.numel()).map(|i| x.get_f64(i) as f32 * scale).collect();
    Tensor::from_f32(x.shape().to_vec(), out)
}

/// Requantize a wide accumulator to i8 by a right shift (power-of-2 scale),
/// rounding to nearest, saturating — VTA's only rescaling primitive.
pub fn requantize_shift(x: &Tensor, shift: u32) -> Tensor {
    let half = if shift == 0 { 0 } else { 1i64 << (shift - 1) };
    let out: Vec<i8> = (0..x.numel())
        .map(|i| {
            let v = x.get_f64(i) as i64;
            (((v + half) >> shift).clamp(-128, 127)) as i8
        })
        .collect();
    Tensor::new(x.shape().to_vec(), Storage::I8(Arc::new(out)))
}

#[inline]
fn sat16(v: i32) -> i32 {
    v.clamp(i16::MIN as i32, i16::MAX as i32)
}

/// i8 matmul with i32 or saturating-i16 accumulation.
pub fn quant_matmul(a: &Tensor, b: &Tensor, acc: AccBits) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let av = a.as_i8();
    let bv = b.as_i8();
    let mut out = vec![0i32; m * n];
    match acc {
        AccBits::I32 => {
            for i in 0..m {
                let arow = &av[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0 {
                        continue;
                    }
                    let aik = aik as i32;
                    let brow = &bv[kk * n..(kk + 1) * n];
                    for (o, &bj) in orow.iter_mut().zip(brow.iter()) {
                        *o += aik * bj as i32;
                    }
                }
            }
        }
        AccBits::I16 => {
            // Saturate after every partial product (hardware-faithful i16
            // accumulator; matches the Pallas quant kernel's per-step clip).
            for i in 0..m {
                let arow = &av[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    let aik = aik as i32;
                    let brow = &bv[kk * n..(kk + 1) * n];
                    for (o, &bj) in orow.iter_mut().zip(brow.iter()) {
                        *o = sat16(*o + aik * bj as i32);
                    }
                }
            }
        }
    }
    Tensor::from_i32(vec![m, n], out)
}

/// i8 NCHW conv with i32 or saturating-i16 accumulation.
pub fn quant_conv2d(x: &Tensor, w: &Tensor, p: &Conv2dParams, acc: AccBits) -> Tensor {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (o, cg, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(c, cg * p.groups);
    let (oh, ow) = conv2d_out_hw(h, wd, kh, kw, p);
    let og = o / p.groups;
    let xv = x.as_i8();
    let wv = w.as_i8();
    let mut out = vec![0i32; n * o * oh * ow];
    for ni in 0..n {
        for g in 0..p.groups {
            for oc in 0..og {
                let ocabs = g * og + oc;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc_v: i32 = 0;
                        for ic in 0..cg {
                            let icabs = g * cg + ic;
                            for ky in 0..kh {
                                let iy = (oy * p.stride.0 + ky) as isize
                                    - p.padding.0 as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for kx in 0..kw {
                                    let ix = (ox * p.stride.1 + kx) as isize
                                        - p.padding.1 as isize;
                                    if ix < 0 || ix >= wd as isize {
                                        continue;
                                    }
                                    let xval = xv
                                        [((ni * c + icabs) * h + iy as usize) * wd
                                            + ix as usize]
                                        as i32;
                                    let wval =
                                        wv[((ocabs * cg + ic) * kh + ky) * kw + kx] as i32;
                                    acc_v += xval * wval;
                                    if acc == AccBits::I16 {
                                        acc_v = sat16(acc_v);
                                    }
                                }
                            }
                        }
                        out[((ni * o + ocabs) * oh + oy) * ow + ox] = acc_v;
                    }
                }
            }
        }
    }
    Tensor::from_i32(vec![n, o, oh, ow], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip() {
        let x = Tensor::from_f32(vec![4], vec![0.5, -0.25, 1.0, -1.0]);
        let q = quantize_i8(&x, 0.25);
        assert_eq!(q.as_i8(), &[2, -1, 4, -4]);
        let d = dequantize(&q, 0.25);
        assert_eq!(d.as_f32(), x.as_f32());
    }

    #[test]
    fn quantize_saturates() {
        let x = Tensor::from_f32(vec![2], vec![100.0, -100.0]);
        let q = quantize_i8(&x, 0.5);
        assert_eq!(q.as_i8(), &[127, -128]);
    }

    #[test]
    fn qmatmul_i32_exact() {
        let a = Tensor::from_i8(vec![1, 3], vec![1, 2, 3]);
        let b = Tensor::from_i8(vec![3, 1], vec![4, 5, 6]);
        let out = quant_matmul(&a, &b, AccBits::I32);
        assert_eq!(out.as_i32(), &[32]);
    }

    #[test]
    fn qmatmul_i16_saturates() {
        // 127*127*4 = 64516 > 32767: i16 accumulation must clip.
        let a = Tensor::from_i8(vec![1, 4], vec![127; 4]);
        let b = Tensor::from_i8(vec![4, 1], vec![127; 4]);
        let out = quant_matmul(&a, &b, AccBits::I16);
        assert_eq!(out.as_i32(), &[32767]);
        let exact = quant_matmul(&a, &b, AccBits::I32);
        assert_eq!(exact.as_i32(), &[64516]);
    }

    #[test]
    fn qconv_matches_float_conv_small() {
        use super::super::conv::conv2d;
        let xq = Tensor::from_i8(vec![1, 1, 2, 2], vec![1, 2, 3, 4]);
        let wq = Tensor::from_i8(vec![1, 1, 2, 2], vec![1, 1, 1, 1]);
        let p = Conv2dParams::default();
        let qo = quant_conv2d(&xq, &wq, &p, AccBits::I32);
        assert_eq!(qo.as_i32(), &[10]);
        // float path agrees
        let xf = Tensor::from_f32(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let wf = Tensor::from_f32(vec![1, 1, 2, 2], vec![1.; 4]);
        assert_eq!(conv2d(&xf, &wf, &p).as_f32(), &[10.0]);
    }

    #[test]
    fn requantize_shift_rounds() {
        let x = Tensor::from_i32(vec![3], vec![256, 300, -300]);
        let q = requantize_shift(&x, 8); // divide by 256, round
        assert_eq!(q.as_i8(), &[1, 1, -1]);
    }
}
