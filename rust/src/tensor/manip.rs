//! Shape/layout manipulation: reshape, transpose, concat, split, pad, take,
//! one_hot, layout transforms (NCHW <-> NHWC <-> NCHWc), flatten.

use std::sync::Arc;

use super::elementwise::from_f64_as;
use super::shape::{norm_axis, row_major_strides};
use super::{Storage, Tensor};

/// Reshape (numel must match; -1 infers one dim).
pub fn reshape(x: &Tensor, new_shape: &[i64]) -> Tensor {
    let numel = x.numel();
    let neg = new_shape.iter().filter(|&&d| d == -1).count();
    assert!(neg <= 1, "at most one -1 in reshape");
    let known: usize = new_shape.iter().filter(|&&d| d != -1).map(|&d| d as usize).product();
    let shape: Vec<usize> = new_shape
        .iter()
        .map(|&d| if d == -1 { numel / known.max(1) } else { d as usize })
        .collect();
    assert_eq!(shape.iter().product::<usize>(), numel, "reshape numel");
    Tensor::new(shape, x.storage().clone())
}

/// Transpose with explicit axis permutation (empty = reverse).
pub fn transpose(x: &Tensor, axes: &[usize]) -> Tensor {
    let rank = x.rank();
    let perm: Vec<usize> = if axes.is_empty() {
        (0..rank).rev().collect()
    } else {
        axes.to_vec()
    };
    assert_eq!(perm.len(), rank);
    let out_shape: Vec<usize> = perm.iter().map(|&p| x.shape()[p]).collect();
    let in_strides = row_major_strides(x.shape());
    let perm_strides: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
    let n = x.numel();
    let mut src = Vec::with_capacity(n);
    // Odometer over the output shape, accumulating the source offset.
    let mut counter = vec![0usize; rank];
    let mut off = 0usize;
    for _ in 0..n {
        src.push(off);
        for ax in (0..rank).rev() {
            counter[ax] += 1;
            off += perm_strides[ax];
            if counter[ax] < out_shape[ax] {
                break;
            }
            off -= perm_strides[ax] * out_shape[ax];
            counter[ax] = 0;
        }
    }
    gather_flat(x, out_shape, &src)
}

/// Build a tensor by gathering flat source indices (dtype-preserving).
pub(crate) fn gather_flat(x: &Tensor, shape: Vec<usize>, idx: &[usize]) -> Tensor {
    macro_rules! go {
        ($v:expr, $ctor:path) => {
            $ctor(Arc::new(idx.iter().map(|&i| $v[i]).collect()))
        };
    }
    let data = match x.storage() {
        Storage::F32(v) => go!(v, Storage::F32),
        Storage::F64(v) => go!(v, Storage::F64),
        Storage::I64(v) => go!(v, Storage::I64),
        Storage::I32(v) => go!(v, Storage::I32),
        Storage::I16(v) => go!(v, Storage::I16),
        Storage::I8(v) => go!(v, Storage::I8),
        Storage::U8(v) => go!(v, Storage::U8),
        Storage::Bool(v) => go!(v, Storage::Bool),
    };
    Tensor::new(shape, data)
}

/// Concatenate along `axis`.
pub fn concat(parts: &[Tensor], axis: i64) -> Tensor {
    assert!(!parts.is_empty());
    let rank = parts[0].rank();
    let ax = norm_axis(axis, rank);
    let mut out_shape = parts[0].shape().to_vec();
    out_shape[ax] = parts.iter().map(|p| p.shape()[ax]).sum();
    for p in parts {
        assert_eq!(p.rank(), rank);
        for d in 0..rank {
            if d != ax {
                assert_eq!(p.shape()[d], parts[0].shape()[d], "concat dim {d}");
            }
        }
    }
    let outer: usize = out_shape[..ax].iter().product();
    let inner: usize = out_shape[ax + 1..].iter().product();
    // Gather indices per output element.
    let mut src_part = Vec::with_capacity(out_shape.iter().product());
    let mut src_idx = Vec::with_capacity(src_part.capacity());
    for o in 0..outer {
        for (pi, p) in parts.iter().enumerate() {
            let d = p.shape()[ax];
            for j in 0..d * inner {
                src_part.push(pi);
                src_idx.push(o * d * inner + j);
            }
        }
    }
    // Materialize as f64 only if dtypes differ; otherwise preserve.
    let dt = parts[0].dtype();
    if parts.iter().all(|p| p.dtype() == dt) {
        // Per-part gather then splice; simple two-pass construction.
        let total: usize = out_shape.iter().product();
        let vals: Vec<f64> = (0..total)
            .map(|i| parts[src_part[i]].get_f64(src_idx[i]))
            .collect();
        from_f64_as(dt, out_shape, &vals)
    } else {
        panic!("concat dtype mismatch");
    }
}

/// Split into `sections` equal parts along `axis`.
pub fn split(x: &Tensor, sections: usize, axis: i64) -> Vec<Tensor> {
    let ax = norm_axis(axis, x.rank());
    let d = x.shape()[ax];
    assert_eq!(d % sections, 0, "split must be even");
    let part = d / sections;
    let outer: usize = x.shape()[..ax].iter().product();
    let inner: usize = x.shape()[ax + 1..].iter().product();
    let mut out_shape = x.shape().to_vec();
    out_shape[ax] = part;
    (0..sections)
        .map(|s| {
            let mut idx = Vec::with_capacity(outer * part * inner);
            for o in 0..outer {
                let base = (o * d + s * part) * inner;
                idx.extend(base..base + part * inner);
            }
            gather_flat(x, out_shape.clone(), &idx)
        })
        .collect()
}

/// Zero-pad: `pads` is (before, after) per axis.
pub fn pad(x: &Tensor, pads: &[(usize, usize)]) -> Tensor {
    assert_eq!(pads.len(), x.rank());
    let out_shape: Vec<usize> = x
        .shape()
        .iter()
        .zip(pads)
        .map(|(&d, &(b, a))| d + b + a)
        .collect();
    let out_n: usize = out_shape.iter().product();
    let in_strides = row_major_strides(x.shape());
    let mut vals = vec![0f64; out_n];
    let out_strides = row_major_strides(&out_shape);
    for i in 0..x.numel() {
        // Decompose input index, shift by pads, recompose in output space.
        let mut rem = i;
        let mut oi = 0usize;
        for ax in 0..x.rank() {
            let coord = rem / in_strides[ax];
            rem %= in_strides[ax];
            oi += (coord + pads[ax].0) * out_strides[ax];
        }
        vals[oi] = x.get_f64(i);
    }
    from_f64_as(x.dtype(), out_shape, &vals)
}

/// `take` rows of `x` (2-d: (v, d)) by i64 `indices` (any shape) -> shape
/// indices.shape + [d]. This is `embedding lookup`.
pub fn take_rows(x: &Tensor, indices: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 2);
    let d = x.shape()[1];
    let idx = indices.as_i64();
    let mut flat = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        let i = i as usize;
        flat.extend((i * d)..(i * d + d));
    }
    let mut shape = indices.shape().to_vec();
    shape.push(d);
    gather_flat(x, shape, &flat)
}

/// One-hot encode i64 `labels` to (len, depth) f32.
pub fn one_hot(labels: &Tensor, depth: usize) -> Tensor {
    let idx = labels.as_i64();
    let mut out = vec![0f32; idx.len() * depth];
    for (r, &i) in idx.iter().enumerate() {
        out[r * depth + i as usize] = 1.0;
    }
    let mut shape = labels.shape().to_vec();
    shape.push(depth);
    Tensor::from_f32(shape, out)
}

/// Flatten to 2-d (batch, features).
pub fn batch_flatten(x: &Tensor) -> Tensor {
    let b = x.shape()[0];
    let f: usize = x.shape()[1..].iter().product();
    Tensor::new(vec![b, f], x.storage().clone())
}

/// Expand dims at `axis`.
pub fn expand_dims(x: &Tensor, axis: i64) -> Tensor {
    let ax = if axis < 0 {
        (x.rank() as i64 + 1 + axis) as usize
    } else {
        axis as usize
    };
    let mut shape = x.shape().to_vec();
    shape.insert(ax, 1);
    Tensor::new(shape, x.storage().clone())
}

/// Squeeze all size-1 dims (or a specific axis).
pub fn squeeze(x: &Tensor, axis: Option<i64>) -> Tensor {
    let shape: Vec<usize> = match axis {
        Some(a) => {
            let ax = norm_axis(a, x.rank());
            assert_eq!(x.shape()[ax], 1);
            let mut s = x.shape().to_vec();
            s.remove(ax);
            s
        }
        None => x.shape().iter().cloned().filter(|&d| d != 1).collect(),
    };
    Tensor::new(shape, x.storage().clone())
}

/// NCHW -> NHWC.
pub fn nchw_to_nhwc(x: &Tensor) -> Tensor {
    transpose(x, &[0, 2, 3, 1])
}

/// NHWC -> NCHW.
pub fn nhwc_to_nchw(x: &Tensor) -> Tensor {
    transpose(x, &[0, 3, 1, 2])
}

/// NCHW -> NCHWc: split the channel axis into blocks of `c` (the
/// AlterOpLayout target layout; also VTA's packed layout).
pub fn nchw_to_nchwc(x: &Tensor, c: usize) -> Tensor {
    let (n, ch, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert_eq!(ch % c, 0, "channels {ch} not divisible by block {c}");
    let r = reshape(x, &[n as i64, (ch / c) as i64, c as i64, h as i64, w as i64]);
    transpose(&r, &[0, 1, 3, 4, 2])
}

/// NCHWc -> NCHW.
pub fn nchwc_to_nchw(x: &Tensor) -> Tensor {
    let (n, cb, h, w, c) = (
        x.shape()[0],
        x.shape()[1],
        x.shape()[2],
        x.shape()[3],
        x.shape()[4],
    );
    let t = transpose(x, &[0, 1, 4, 2, 3]);
    reshape(&t, &[n as i64, (cb * c) as i64, h as i64, w as i64])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_infers() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = reshape(&x, &[3, -1]);
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn transpose_2d() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = transpose(&x, &[]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.as_f32(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_permutation() {
        let x = Tensor::from_f32(vec![1, 2, 3], (0..6).map(|i| i as f32).collect());
        let t = transpose(&x, &[2, 0, 1]);
        assert_eq!(t.shape(), &[3, 1, 2]);
        assert_eq!(t.as_f32(), &[0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn concat_axis0_and_1() {
        let a = Tensor::from_f32(vec![1, 2], vec![1., 2.]);
        let b = Tensor::from_f32(vec![1, 2], vec![3., 4.]);
        assert_eq!(concat(&[a.clone(), b.clone()], 0).shape(), &[2, 2]);
        let c = concat(&[a, b], 1);
        assert_eq!(c.shape(), &[1, 4]);
        assert_eq!(c.as_f32(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn split_round_trips_concat() {
        let x = Tensor::from_f32(vec![2, 4], (0..8).map(|i| i as f32).collect());
        let parts = split(&x, 2, 1);
        assert_eq!(parts[0].shape(), &[2, 2]);
        assert_eq!(parts[0].as_f32(), &[0., 1., 4., 5.]);
        let back = concat(&parts, 1);
        assert_eq!(back.as_f32(), x.as_f32());
    }

    #[test]
    fn pad_2d() {
        let x = Tensor::from_f32(vec![1, 1], vec![5.]);
        let p = pad(&x, &[(1, 0), (0, 1)]);
        assert_eq!(p.shape(), &[2, 2]);
        assert_eq!(p.as_f32(), &[0., 0., 5., 0.]);
    }

    #[test]
    fn take_rows_embedding() {
        let table = Tensor::from_f32(vec![3, 2], vec![0., 0., 1., 1., 2., 2.]);
        let idx = Tensor::from_i64(vec![2], vec![2, 0]);
        let e = take_rows(&table, &idx);
        assert_eq!(e.shape(), &[2, 2]);
        assert_eq!(e.as_f32(), &[2., 2., 0., 0.]);
    }

    #[test]
    fn one_hot_encodes() {
        let l = Tensor::from_i64(vec![2], vec![1, 0]);
        let o = one_hot(&l, 3);
        assert_eq!(o.shape(), &[2, 3]);
        assert_eq!(o.as_f32(), &[0., 1., 0., 1., 0., 0.]);
    }

    #[test]
    fn layout_nchw_nhwc_roundtrip() {
        let x = Tensor::from_f32(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let y = nhwc_to_nchw(&nchw_to_nhwc(&x));
        assert_eq!(y.as_f32(), x.as_f32());
    }

    #[test]
    fn layout_nchwc_roundtrip() {
        let x = Tensor::from_f32(vec![1, 4, 2, 2], (0..16).map(|i| i as f32).collect());
        let packed = nchw_to_nchwc(&x, 2);
        assert_eq!(packed.shape(), &[1, 2, 2, 2, 2]);
        let back = nchwc_to_nchw(&packed);
        assert_eq!(back.as_f32(), x.as_f32());
    }

    #[test]
    fn squeeze_expand() {
        let x = Tensor::from_f32(vec![1, 3, 1], vec![1., 2., 3.]);
        assert_eq!(squeeze(&x, None).shape(), &[3]);
        assert_eq!(squeeze(&x, Some(0)).shape(), &[3, 1]);
        assert_eq!(expand_dims(&x, 0).shape(), &[1, 1, 3, 1]);
        assert_eq!(expand_dims(&x, -1).shape(), &[1, 3, 1, 1]);
    }
}
