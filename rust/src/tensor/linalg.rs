//! Dense linear algebra: matmul, dense (w transposed), bias add.
//!
//! The f32 GEMMs are cache-blocked, register-tiled, packed-panel kernels
//! (the schedule family TVM derives for CPUs, hand-applied): the inner
//! dimension is sliced into `kc`-deep blocks whose A/B panels are packed
//! into contiguous, zero-padded scratch, and a fixed `MR x NR` register
//! micro-kernel walks the panels. Outer row blocks (`mc` rows each) are
//! data-parallelized across [`super::parallel`]'s worker pool; block
//! extents come from [`super::tune`] (per-(op, shape) schedule registry,
//! seeded at compile time by the `TuneKernels` pass).
//!
//! **Bit-exactness invariant:** every path — naive reference, tiled,
//! tiled + parallel, any tile config — performs each output element's
//! additions in ascending-`k` order starting from the destination value,
//! and parallel chunks partition output *rows*, so results are bitwise
//! identical across schedules and thread counts (asserted by
//! `tests/kernels.rs`). Keep it that way: the micro-kernel loads its
//! accumulator from the destination and stores it back, continuing the
//! same chain across `kc` blocks.

use std::cell::RefCell;
use std::sync::Arc;

use super::parallel;
use super::tune::{self, Schedule, TileConfig};
use super::{Storage, Tensor};

/// Register micro-tile: MR destination rows by NR columns (NR is the
/// auto-vectorized lane count).
const MR: usize = 4;
const NR: usize = 8;

/// Below this many multiply-adds the blocked kernel runs in its simple
/// single-block form and never consults the tuner or the pool.
const PAR_MIN_MACS: usize = 1 << 16;

thread_local! {
    /// Packed A/B panel scratch, reused across kernel launches per thread.
    static PANELS: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// `a (m,k) @ b (k,n) -> (m,n)` for f32.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, n) = matmul_dims(a, b);
    let mut out = vec![0f32; m * n];
    matmul_into(a, b, &mut out);
    Tensor::new(vec![m, n], Storage::F32(Arc::new(out)))
}

fn matmul_dims(a: &Tensor, b: &Tensor) -> (usize, usize) {
    assert_eq!(a.rank(), 2, "matmul lhs rank");
    assert_eq!(b.rank(), 2, "matmul rhs rank");
    let (k, k2) = (a.shape()[1], b.shape()[0]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    (a.shape()[0], b.shape()[1])
}

/// The accumulate step of [`matmul`], writing into a caller-supplied
/// zeroed `(m*n)` destination instead of allocating — the memory planner's
/// in-place variant (a reused steady-state buffer skips the allocator).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut [f32]) {
    let (m, n) = matmul_dims(a, b);
    let k = a.shape()[1];
    assert_eq!(out.len(), m * n, "matmul destination length");
    let cfg = gemm_schedule("matmul", m, k, n);
    let (av, bv) = (a.as_f32(), b.as_f32());
    gemm(av, bv, out, m, k, n, BLayout::RowMajorKxN, cfg);
}

/// [`matmul_into`] with an explicit tile config, sequential — the tuner's
/// probe hook (every config is bit-identical; only timing differs).
pub fn matmul_into_with(a: &Tensor, b: &Tensor, out: &mut [f32], cfg: TileConfig) {
    let (m, n) = matmul_dims(a, b);
    let k = a.shape()[1];
    assert_eq!(out.len(), m * n, "matmul destination length");
    gemm_rows(a.as_f32(), b.as_f32(), out, 0, m, k, n, BLayout::RowMajorKxN, cfg);
}

/// Textbook triple-nest reference (ascending-`k` accumulation): the
/// differential baseline for the tiled kernels and the fig17 "naive"
/// column. Accumulates into `out` like [`matmul_into`].
pub fn matmul_naive_into(a: &Tensor, b: &Tensor, out: &mut [f32]) {
    let (m, n) = matmul_dims(a, b);
    let k = a.shape()[1];
    assert_eq!(out.len(), m * n, "matmul destination length");
    let (av, bv) = (a.as_f32(), b.as_f32());
    for i in 0..m {
        for j in 0..n {
            let mut acc = out[i * n + j];
            for kk in 0..k {
                acc += av[i * k + kk] * bv[kk * n + j];
            }
            out[i * n + j] = acc;
        }
    }
}

/// Batched matmul `a (b,m,k) @ w (b,k,n)`, per-batch through the tiled
/// kernel directly on the buffer slices (no per-batch tensor copies).
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 3);
    assert_eq!(b.rank(), 3);
    let (bs, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let (bs2, k2, n) = (b.shape()[0], b.shape()[1], b.shape()[2]);
    assert_eq!(bs, bs2);
    assert_eq!(k, k2);
    let cfg = gemm_schedule("nn.batch_matmul", m, k, n);
    let (av, bv) = (a.as_f32(), b.as_f32());
    let mut out = vec![0f32; bs * m * n];
    for i in 0..bs {
        gemm(
            &av[i * m * k..(i + 1) * m * k],
            &bv[i * k * n..(i + 1) * k * n],
            &mut out[i * m * n..(i + 1) * m * n],
            m,
            k,
            n,
            BLayout::RowMajorKxN,
            cfg,
        );
    }
    Tensor::new(vec![bs, m, n], Storage::F32(Arc::new(out)))
}

/// `nn.dense`: `x (m,k) @ w^T` where `w` is `(n,k)` — TVM/Relay convention.
pub fn dense(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, n) = dense_dims(x, w);
    let mut out = vec![0f32; m * n];
    dense_into(x, w, &mut out);
    Tensor::new(vec![m, n], Storage::F32(Arc::new(out)))
}

fn dense_dims(x: &Tensor, w: &Tensor) -> (usize, usize) {
    assert_eq!(x.rank(), 2, "dense input rank");
    assert_eq!(w.rank(), 2, "dense weight rank");
    let (k, k2) = (x.shape()[1], w.shape()[1]);
    assert_eq!(k, k2, "dense inner dims {k} vs {k2}");
    (x.shape()[0], w.shape()[0])
}

/// The accumulate step of [`dense`], writing into a caller-supplied zeroed
/// `(m*n)` destination instead of allocating. The `(n,k)` weight is
/// transpose-packed into the same panel layout the matmul uses, so both
/// share one micro-kernel.
pub fn dense_into(x: &Tensor, w: &Tensor, out: &mut [f32]) {
    let (m, n) = dense_dims(x, w);
    let k = x.shape()[1];
    assert_eq!(out.len(), m * n, "dense destination length");
    let cfg = gemm_schedule("nn.dense", m, k, n);
    gemm(x.as_f32(), w.as_f32(), out, m, k, n, BLayout::RowMajorNxK, cfg);
}

/// Triple-nest dense reference (dot products, ascending-`k`): the
/// differential baseline. Accumulates into `out` like [`dense_into`].
pub fn dense_naive_into(x: &Tensor, w: &Tensor, out: &mut [f32]) {
    let (m, n) = dense_dims(x, w);
    let k = x.shape()[1];
    assert_eq!(out.len(), m * n, "dense destination length");
    let (xv, wv) = (x.as_f32(), w.as_f32());
    for i in 0..m {
        for j in 0..n {
            let mut acc = out[i * n + j];
            for kk in 0..k {
                acc += xv[i * k + kk] * wv[j * k + kk];
            }
            out[i * n + j] = acc;
        }
    }
}

/// How the `(k x n)` logical B matrix is stored.
#[derive(Clone, Copy)]
enum BLayout {
    /// matmul: `b[kk * n + j]`.
    RowMajorKxN,
    /// dense: the weight is `(n, k)`, so `b[j * k + kk]`.
    RowMajorNxK,
}

impl BLayout {
    #[inline(always)]
    fn at(self, bv: &[f32], k: usize, n: usize, kk: usize, j: usize) -> f32 {
        match self {
            BLayout::RowMajorKxN => bv[kk * n + j],
            BLayout::RowMajorNxK => bv[j * k + kk],
        }
    }
}

/// The tuned (or heuristic) schedule for a GEMM launch.
fn gemm_schedule(op: &'static str, m: usize, k: usize, n: usize) -> TileConfig {
    if m * k * n < tune::TUNE_MIN_MACS {
        return TileConfig { mc: m.max(1), kc: k.max(1), nc: n.max(1) };
    }
    match tune::schedule_for(op, &[m, k, n]) {
        Schedule::Gemm(t) => t,
        Schedule::Conv { .. } => TileConfig { mc: 64, kc: 256, nc: 256 },
    }
}

/// Top-level GEMM: split output rows into `mc`-row slabs and fan the slabs
/// out across the kernel pool. Each slab is computed independently by
/// [`gemm_rows`]; splitting by rows means every output element is produced
/// by exactly one chunk with an unchanged accumulation order, so the
/// result is bitwise independent of the thread count.
#[allow(clippy::too_many_arguments)]
fn gemm(
    av: &[f32],
    bv: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    blayout: BLayout,
    cfg: TileConfig,
) {
    if m == 0 || n == 0 {
        return;
    }
    let mc = cfg.mc.clamp(1, m);
    let n_slabs = m.div_ceil(mc);
    if m * k * n < PAR_MIN_MACS || n_slabs <= 1 || parallel::kernel_threads() <= 1 {
        gemm_rows(av, bv, out, 0, m, k, n, blayout, cfg);
        return;
    }
    let shared = parallel::SplitMut::new(out);
    parallel::parallel_for(n_slabs, |slab| {
        let i0 = slab * mc;
        let rows = mc.min(m - i0);
        // Safety: slabs cover disjoint row ranges of `out`.
        let slice = unsafe { shared.slice(i0 * n, rows * n) };
        gemm_rows(av, bv, slice, i0, rows, k, n, blayout, cfg);
    });
}

/// One row-slab of the blocked GEMM: `out_slab` holds rows
/// `i0 .. i0 + rows` of the destination. Loop order kc -> (pack A) ->
/// nc -> (pack B) -> MR-strip micro-kernels; the accumulator is loaded
/// from and stored to the destination, so the per-element chain stays
/// ascending-`k` across `kc` blocks.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    av: &[f32],
    bv: &[f32],
    out_slab: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    blayout: BLayout,
    cfg: TileConfig,
) {
    let kc = cfg.kc.clamp(1, k.max(1));
    let nc = cfg.nc.clamp(1, n.max(1));
    PANELS.with(|cell| {
        let (ap, bp) = &mut *cell.borrow_mut();
        for k0 in (0..k).step_by(kc) {
            let kcur = kc.min(k - k0);
            pack_a(av, ap, i0, rows, k, k0, kcur);
            for j0 in (0..n).step_by(nc) {
                let ncur = nc.min(n - j0);
                let panels = ncur.div_ceil(NR);
                pack_b(bv, bp, blayout, k, n, k0, kcur, j0, ncur);
                for s in 0..rows.div_ceil(MR) {
                    let r0 = s * MR;
                    let rcur = MR.min(rows - r0);
                    let a_strip = &ap[s * kcur * MR..];
                    for p in 0..panels {
                        let j = j0 + p * NR;
                        let jcur = NR.min(n - j);
                        micro_kernel(
                            a_strip,
                            &bp[p * kcur * NR..],
                            kcur,
                            out_slab,
                            n,
                            r0,
                            rcur,
                            j,
                            jcur,
                        );
                    }
                }
            }
        }
    });
}

/// Pack rows `i0..i0+rows`, columns `k0..k0+kcur` of A into MR-row strips:
/// strip `s` is stored `[kk][r]`-major so the micro-kernel's broadcast
/// loads are contiguous. Short strips are zero-padded.
fn pack_a(
    av: &[f32],
    ap: &mut Vec<f32>,
    i0: usize,
    rows: usize,
    k: usize,
    k0: usize,
    kcur: usize,
) {
    let strips = rows.div_ceil(MR);
    // Strips sit at a kcur-sized stride; gemm_rows indexes by the same
    // kcur when it slices strip `s` out for the micro-kernel.
    let kc_stride = kcur.max(1);
    ap.clear();
    ap.resize(strips * kc_stride * MR, 0.0);
    for s in 0..strips {
        let r0 = s * MR;
        let rcur = MR.min(rows - r0);
        let base = s * kc_stride * MR;
        for r in 0..rcur {
            let arow = &av[(i0 + r0 + r) * k + k0..];
            for kk in 0..kcur {
                ap[base + kk * MR + r] = arow[kk];
            }
        }
    }
}

/// Pack the `(k0..k0+kcur) x (j0..j0+ncur)` block of B into NR-wide
/// panels, `[kk][c]`-major, zero-padding the last panel. For dense this is
/// where the `(n,k)` weight gets transposed into the matmul layout — once
/// per block, amortized over every row strip.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    bv: &[f32],
    bp: &mut Vec<f32>,
    blayout: BLayout,
    k: usize,
    n: usize,
    k0: usize,
    kcur: usize,
    j0: usize,
    ncur: usize,
) {
    let panels = ncur.div_ceil(NR);
    let kc_stride = kcur.max(1);
    bp.clear();
    bp.resize(panels * kc_stride * NR, 0.0);
    for p in 0..panels {
        let j = j0 + p * NR;
        let jcur = NR.min(j0 + ncur - j);
        let base = p * kc_stride * NR;
        for kk in 0..kcur {
            for c in 0..jcur {
                bp[base + kk * NR + c] = blayout.at(bv, k, n, k0 + kk, j + c);
            }
        }
    }
}

/// The register micro-kernel: an `MR x NR` accumulator block, loaded from
/// the destination, updated with `kcur` rank-1 steps in ascending-`k`
/// order, stored back. The fixed-extent inner loops auto-vectorize; the
/// zero-padded panel lanes compute garbage that is never stored.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    a_strip: &[f32],
    b_panel: &[f32],
    kcur: usize,
    out_slab: &mut [f32],
    n: usize,
    r0: usize,
    rcur: usize,
    j: usize,
    jcur: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    for r in 0..rcur {
        let orow = &out_slab[(r0 + r) * n + j..];
        acc[r][..jcur].copy_from_slice(&orow[..jcur]);
    }
    for kk in 0..kcur {
        let b = &b_panel[kk * NR..kk * NR + NR];
        let a = &a_strip[kk * MR..kk * MR + MR];
        for r in 0..MR {
            let ar = a[r];
            for c in 0..NR {
                acc[r][c] += ar * b[c];
            }
        }
    }
    for r in 0..rcur {
        let orow = &mut out_slab[(r0 + r) * n + j..];
        orow[..jcur].copy_from_slice(&acc[r][..jcur]);
    }
}

/// `nn.bias_add`: add a 1-d bias along `axis` of `x`.
pub fn bias_add(x: &Tensor, bias: &Tensor, axis: i64) -> Tensor {
    let axis = bias_add_axis(x, bias, axis);
    let xv = x.as_f32();
    let bv = bias.as_f32();
    let outer: usize = x.shape()[..axis].iter().product();
    let mid = x.shape()[axis];
    let inner: usize = x.shape()[axis + 1..].iter().product();
    let mut out = Vec::with_capacity(x.numel());
    for o in 0..outer {
        for m in 0..mid {
            let base = (o * mid + m) * inner;
            let b = bv[m];
            out.extend(xv[base..base + inner].iter().map(|&v| v + b));
        }
    }
    Tensor::new(x.shape().to_vec(), Storage::F32(Arc::new(out)))
}

fn bias_add_axis(x: &Tensor, bias: &Tensor, axis: i64) -> usize {
    assert_eq!(bias.rank(), 1, "bias rank");
    let axis = super::shape::norm_axis(axis, x.rank());
    assert_eq!(x.shape()[axis], bias.shape()[0], "bias length");
    axis
}

/// In-place [`bias_add`]: `x[..] += bias` along `axis` when `x`'s buffer is
/// uniquely owned and f32. Returns false (caller allocates) otherwise.
pub fn bias_add_assign(x: &mut Tensor, bias: &Tensor, axis: i64) -> bool {
    if x.dtype() != super::DType::F32 || bias.dtype() != super::DType::F32 {
        return false;
    }
    let axis = bias_add_axis(x, bias, axis);
    let outer: usize = x.shape()[..axis].iter().product();
    let mid = x.shape()[axis];
    let inner: usize = x.shape()[axis + 1..].iter().product();
    let bv = bias.as_f32();
    let Some(xv) = x.try_unique_f32() else { return false };
    for o in 0..outer {
        for m in 0..mid {
            let base = (o * mid + m) * inner;
            let b = bv[m];
            for v in &mut xv[base..base + inner] {
                *v += b;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        let a = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_f32(vec![2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(matmul(&a, &b).as_f32(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_rectangular() {
        // (1x3) @ (3x2)
        let a = Tensor::from_f32(vec![1, 3], vec![1., 2., 3.]);
        let b = Tensor::from_f32(vec![3, 2], vec![1., 4., 2., 5., 3., 6.]);
        assert_eq!(matmul(&a, &b).as_f32(), &[14., 32.]);
    }

    #[test]
    fn matmul_bitwise_matches_naive_large() {
        // Exercise the blocked/packed path (dims past every tile edge)
        // against the triple-nest reference — bit-for-bit, the invariant
        // the whole schedule family is built on.
        let m = 70;
        let k = 65;
        let n = 80;
        let av: Vec<f32> = (0..m * k).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let bv: Vec<f32> = (0..k * n).map(|i| ((i * 5 % 11) as f32) - 5.0).collect();
        let a = Tensor::from_f32(vec![m, k], av.clone());
        let b = Tensor::from_f32(vec![k, n], bv.clone());
        let got = matmul(&a, &b);
        let mut naive = vec![0f32; m * n];
        matmul_naive_into(&a, &b, &mut naive);
        assert_eq!(got.as_f32(), &naive[..]);
    }

    #[test]
    fn every_tile_config_is_bit_identical() {
        let m = 37;
        let k = 53;
        let n = 41;
        let a = Tensor::from_f32(
            vec![m, k],
            (0..m * k).map(|i| ((i * 11 % 23) as f32) - 11.0).collect(),
        );
        let b = Tensor::from_f32(
            vec![k, n],
            (0..k * n).map(|i| ((i * 3 % 17) as f32) - 8.0).collect(),
        );
        let mut reference = vec![0f32; m * n];
        matmul_naive_into(&a, &b, &mut reference);
        for cfg in crate::tensor::tune::gemm_candidates() {
            let mut out = vec![0f32; m * n];
            matmul_into_with(&a, &b, &mut out, cfg);
            assert_eq!(out, reference, "config {cfg:?} diverged");
        }
        // Degenerate tile extents still cover the matrix.
        let mut out = vec![0f32; m * n];
        matmul_into_with(&a, &b, &mut out, TileConfig { mc: 1, kc: 1, nc: 1 });
        assert_eq!(out, reference);
    }

    #[test]
    fn dense_is_matmul_transposed() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let w = Tensor::from_f32(vec![2, 3], vec![1., 0., 0., 0., 1., 0.]);
        // w rows pick out columns 0 and 1 of x.
        assert_eq!(dense(&x, &w).as_f32(), &[1., 2., 4., 5.]);
    }

    #[test]
    fn bias_add_axis1() {
        let x = Tensor::from_f32(vec![2, 3], vec![0.; 6]);
        let b = Tensor::from_f32(vec![3], vec![1., 2., 3.]);
        assert_eq!(bias_add(&x, &b, 1).as_f32(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn bias_add_nchw_channel_axis() {
        // (1, 2, 2, 2) with bias on axis 1.
        let x = Tensor::from_f32(vec![1, 2, 2, 2], vec![0.; 8]);
        let b = Tensor::from_f32(vec![2], vec![1., 2.]);
        let out = bias_add(&x, &b, 1);
        assert_eq!(out.as_f32(), &[1., 1., 1., 1., 2., 2., 2., 2.]);
    }

    #[test]
    fn into_variants_match_the_allocating_kernels() {
        let a = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_f32(vec![3, 2], vec![1., 4., 2., 5., 3., 6.]);
        let mut out = vec![0f32; 4];
        matmul_into(&a, &b, &mut out);
        assert_eq!(&out[..], matmul(&a, &b).as_f32());

        let w = Tensor::from_f32(vec![2, 3], vec![1., 0., 0., 0., 1., 0.]);
        let mut dout = vec![0f32; 4];
        dense_into(&a, &w, &mut dout);
        assert_eq!(&dout[..], dense(&a, &w).as_f32());
    }

    #[test]
    fn bias_add_assign_matches_and_respects_uniqueness() {
        let bias = Tensor::from_f32(vec![3], vec![1., 2., 3.]);
        let expect = bias_add(&Tensor::from_f32(vec![2, 3], vec![0.; 6]), &bias, 1);
        let mut x = Tensor::from_f32(vec![2, 3], vec![0.; 6]);
        assert!(bias_add_assign(&mut x, &bias, 1));
        assert_eq!(x.as_f32(), expect.as_f32());
        // Shared input refuses, leaving the alias untouched.
        let mut shared = Tensor::from_f32(vec![2, 3], vec![0.; 6]);
        let alias = shared.clone();
        assert!(!bias_add_assign(&mut shared, &bias, 1));
        assert_eq!(alias.as_f32(), &[0.; 6]);
    }

    #[test]
    fn batch_matmul_two_batches() {
        let a = Tensor::from_f32(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_f32(vec![2, 2, 1], vec![1., 1., 1., 1.]);
        assert_eq!(batch_matmul(&a, &b).as_f32(), &[3., 7.]);
    }
}
