//! Dense linear algebra: matmul, dense (w transposed), bias add.
//!
//! The f32 matmul is the interpreter's hot loop, so it is cache-blocked
//! (i-k-j loop order over 64x64x64 tiles) — the same schedule idea the
//! paper's TVM backend derives, hand-applied.

use std::sync::Arc;

use super::{Storage, Tensor};

const TILE: usize = 64;

/// `a (m,k) @ b (k,n) -> (m,n)` for f32.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, n) = matmul_dims(a, b);
    let mut out = vec![0f32; m * n];
    matmul_into(a, b, &mut out);
    Tensor::new(vec![m, n], Storage::F32(Arc::new(out)))
}

fn matmul_dims(a: &Tensor, b: &Tensor) -> (usize, usize) {
    assert_eq!(a.rank(), 2, "matmul lhs rank");
    assert_eq!(b.rank(), 2, "matmul rhs rank");
    let (k, k2) = (a.shape()[1], b.shape()[0]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    (a.shape()[0], b.shape()[1])
}

/// The accumulate step of [`matmul`], writing into a caller-supplied
/// zeroed `(m*n)` destination instead of allocating — the memory planner's
/// in-place variant (a reused steady-state buffer skips the allocator).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut [f32]) {
    let (m, n) = matmul_dims(a, b);
    let k = a.shape()[1];
    assert_eq!(out.len(), m * n, "matmul destination length");
    let av = a.as_f32();
    let bv = b.as_f32();
    // i-k-j over tiles: the innermost j loop is a contiguous FMA that the
    // compiler auto-vectorizes.
    for i0 in (0..m).step_by(TILE) {
        let i1 = (i0 + TILE).min(m);
        for k0 in (0..k).step_by(TILE) {
            let k1 = (k0 + TILE).min(k);
            for i in i0..i1 {
                let arow = &av[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bv[kk * n..(kk + 1) * n];
                    for (o, &bj) in orow.iter_mut().zip(brow.iter()) {
                        *o += aik * bj;
                    }
                }
            }
        }
    }
}

/// Batched matmul `a (b,m,k) @ w (b,k,n)`.
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 3);
    assert_eq!(b.rank(), 3);
    let (bs, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let (bs2, k2, n) = (b.shape()[0], b.shape()[1], b.shape()[2]);
    assert_eq!(bs, bs2);
    assert_eq!(k, k2);
    let mut out = Vec::with_capacity(bs * m * n);
    for i in 0..bs {
        let sa = Tensor::from_f32(
            vec![m, k],
            a.as_f32()[i * m * k..(i + 1) * m * k].to_vec(),
        );
        let sb = Tensor::from_f32(
            vec![k, n],
            b.as_f32()[i * k * n..(i + 1) * k * n].to_vec(),
        );
        out.extend_from_slice(matmul(&sa, &sb).as_f32());
    }
    Tensor::new(vec![bs, m, n], Storage::F32(Arc::new(out)))
}

/// `nn.dense`: `x (m,k) @ w^T` where `w` is `(n,k)` — TVM/Relay convention.
pub fn dense(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, n) = dense_dims(x, w);
    let mut out = vec![0f32; m * n];
    dense_into(x, w, &mut out);
    Tensor::new(vec![m, n], Storage::F32(Arc::new(out)))
}

fn dense_dims(x: &Tensor, w: &Tensor) -> (usize, usize) {
    assert_eq!(x.rank(), 2, "dense input rank");
    assert_eq!(w.rank(), 2, "dense weight rank");
    let (k, k2) = (x.shape()[1], w.shape()[1]);
    assert_eq!(k, k2, "dense inner dims {k} vs {k2}");
    (x.shape()[0], w.shape()[0])
}

/// The accumulate step of [`dense`], writing into a caller-supplied zeroed
/// `(m*n)` destination instead of allocating.
pub fn dense_into(x: &Tensor, w: &Tensor, out: &mut [f32]) {
    let (m, n) = dense_dims(x, w);
    let k = x.shape()[1];
    assert_eq!(out.len(), m * n, "dense destination length");
    let xv = x.as_f32();
    let wv = w.as_f32();
    for i in 0..m {
        let xrow = &xv[i * k..(i + 1) * k];
        for j in 0..n {
            let wrow = &wv[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (xk, wk) in xrow.iter().zip(wrow.iter()) {
                acc += xk * wk;
            }
            out[i * n + j] = acc;
        }
    }
}

/// `nn.bias_add`: add a 1-d bias along `axis` of `x`.
pub fn bias_add(x: &Tensor, bias: &Tensor, axis: i64) -> Tensor {
    let axis = bias_add_axis(x, bias, axis);
    let xv = x.as_f32();
    let bv = bias.as_f32();
    let outer: usize = x.shape()[..axis].iter().product();
    let mid = x.shape()[axis];
    let inner: usize = x.shape()[axis + 1..].iter().product();
    let mut out = Vec::with_capacity(x.numel());
    for o in 0..outer {
        for m in 0..mid {
            let base = (o * mid + m) * inner;
            let b = bv[m];
            out.extend(xv[base..base + inner].iter().map(|&v| v + b));
        }
    }
    Tensor::new(x.shape().to_vec(), Storage::F32(Arc::new(out)))
}

fn bias_add_axis(x: &Tensor, bias: &Tensor, axis: i64) -> usize {
    assert_eq!(bias.rank(), 1, "bias rank");
    let axis = super::shape::norm_axis(axis, x.rank());
    assert_eq!(x.shape()[axis], bias.shape()[0], "bias length");
    axis
}

/// In-place [`bias_add`]: `x[..] += bias` along `axis` when `x`'s buffer is
/// uniquely owned and f32. Returns false (caller allocates) otherwise.
pub fn bias_add_assign(x: &mut Tensor, bias: &Tensor, axis: i64) -> bool {
    if x.dtype() != super::DType::F32 || bias.dtype() != super::DType::F32 {
        return false;
    }
    let axis = bias_add_axis(x, bias, axis);
    let outer: usize = x.shape()[..axis].iter().product();
    let mid = x.shape()[axis];
    let inner: usize = x.shape()[axis + 1..].iter().product();
    let bv = bias.as_f32();
    let Some(xv) = x.try_unique_f32() else { return false };
    for o in 0..outer {
        for m in 0..mid {
            let base = (o * mid + m) * inner;
            let b = bv[m];
            for v in &mut xv[base..base + inner] {
                *v += b;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        let a = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_f32(vec![2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(matmul(&a, &b).as_f32(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_rectangular() {
        // (1x3) @ (3x2)
        let a = Tensor::from_f32(vec![1, 3], vec![1., 2., 3.]);
        let b = Tensor::from_f32(vec![3, 2], vec![1., 4., 2., 5., 3., 6.]);
        assert_eq!(matmul(&a, &b).as_f32(), &[14., 32.]);
    }

    #[test]
    fn matmul_matches_naive_large() {
        // Exercise the tiling path (dims > TILE).
        let m = 70;
        let k = 65;
        let n = 80;
        let av: Vec<f32> = (0..m * k).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let bv: Vec<f32> = (0..k * n).map(|i| ((i * 5 % 11) as f32) - 5.0).collect();
        let a = Tensor::from_f32(vec![m, k], av.clone());
        let b = Tensor::from_f32(vec![k, n], bv.clone());
        let got = matmul(&a, &b);
        for i in [0, 1, m - 1] {
            for j in [0, n / 2, n - 1] {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += av[i * k + kk] * bv[kk * n + j];
                }
                assert!((got.as_f32()[i * n + j] - acc).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn dense_is_matmul_transposed() {
        let x = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let w = Tensor::from_f32(vec![2, 3], vec![1., 0., 0., 0., 1., 0.]);
        // w rows pick out columns 0 and 1 of x.
        assert_eq!(dense(&x, &w).as_f32(), &[1., 2., 4., 5.]);
    }

    #[test]
    fn bias_add_axis1() {
        let x = Tensor::from_f32(vec![2, 3], vec![0.; 6]);
        let b = Tensor::from_f32(vec![3], vec![1., 2., 3.]);
        assert_eq!(bias_add(&x, &b, 1).as_f32(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn bias_add_nchw_channel_axis() {
        // (1, 2, 2, 2) with bias on axis 1.
        let x = Tensor::from_f32(vec![1, 2, 2, 2], vec![0.; 8]);
        let b = Tensor::from_f32(vec![2], vec![1., 2.]);
        let out = bias_add(&x, &b, 1);
        assert_eq!(out.as_f32(), &[1., 1., 1., 1., 2., 2., 2., 2.]);
    }

    #[test]
    fn into_variants_match_the_allocating_kernels() {
        let a = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_f32(vec![3, 2], vec![1., 4., 2., 5., 3., 6.]);
        let mut out = vec![0f32; 4];
        matmul_into(&a, &b, &mut out);
        assert_eq!(&out[..], matmul(&a, &b).as_f32());

        let w = Tensor::from_f32(vec![2, 3], vec![1., 0., 0., 0., 1., 0.]);
        let mut dout = vec![0f32; 4];
        dense_into(&a, &w, &mut dout);
        assert_eq!(&dout[..], dense(&a, &w).as_f32());
    }

    #[test]
    fn bias_add_assign_matches_and_respects_uniqueness() {
        let bias = Tensor::from_f32(vec![3], vec![1., 2., 3.]);
        let expect = bias_add(&Tensor::from_f32(vec![2, 3], vec![0.; 6]), &bias, 1);
        let mut x = Tensor::from_f32(vec![2, 3], vec![0.; 6]);
        assert!(bias_add_assign(&mut x, &bias, 1));
        assert_eq!(x.as_f32(), expect.as_f32());
        // Shared input refuses, leaving the alias untouched.
        let mut shared = Tensor::from_f32(vec![2, 3], vec![0.; 6]);
        let alias = shared.clone();
        assert!(!bias_add_assign(&mut shared, &bias, 1));
        assert_eq!(alias.as_f32(), &[0.; 6]);
    }

    #[test]
    fn batch_matmul_two_batches() {
        let a = Tensor::from_f32(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_f32(vec![2, 2, 1], vec![1., 1., 1., 1.]);
        assert_eq!(batch_matmul(&a, &b).as_f32(), &[3., 7.]);
    }
}
