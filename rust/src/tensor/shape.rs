//! Shape utilities: strides, broadcasting (numpy rules), index math.
//!
//! The broadcast rule implemented here is the same one registered as the
//! `Broadcast` *type relation* in [`crate::ty::relations`]; keeping a single
//! authoritative implementation shared by runtime and type checker is
//! exactly the paper's argument for relations as reusable constraints.

pub type Shape = Vec<usize>;

/// Row-major strides for `shape`.
pub fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![0; shape.len()];
    let mut acc = 1;
    for (i, &d) in shape.iter().enumerate().rev() {
        strides[i] = acc;
        acc *= d;
    }
    strides
}

/// Numpy-style broadcast of two shapes; `None` if incompatible.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Shape> {
    let rank = a.len().max(b.len());
    let mut out = vec![0; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return None;
        };
    }
    Some(out)
}

/// Strides of `shape` when broadcast up to `out_shape`: broadcast axes get
/// stride 0 so the same element is re-read.
pub fn broadcast_strides(shape: &[usize], out_shape: &[usize]) -> Vec<usize> {
    let strides = row_major_strides(shape);
    let offset = out_shape.len() - shape.len();
    let mut out = vec![0; out_shape.len()];
    for i in 0..shape.len() {
        out[offset + i] = if shape[i] == 1 && out_shape[offset + i] != 1 {
            0
        } else {
            strides[i]
        };
    }
    out
}

/// Iterate the flat source offsets of a broadcast operand across the output
/// iteration space. Linear-time, no per-element div/mod: maintains a
/// multi-dimensional counter.
pub struct BroadcastIter {
    counter: Vec<usize>,
    out_shape: Vec<usize>,
    strides: Vec<usize>,
    offset: usize,
    remaining: usize,
}

impl BroadcastIter {
    pub fn new(shape: &[usize], out_shape: &[usize]) -> Self {
        let strides = broadcast_strides(shape, out_shape);
        let remaining = out_shape.iter().product();
        BroadcastIter {
            counter: vec![0; out_shape.len()],
            out_shape: out_shape.to_vec(),
            strides,
            offset: 0,
            remaining,
        }
    }
}

impl Iterator for BroadcastIter {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.remaining == 0 {
            return None;
        }
        let cur = self.offset;
        self.remaining -= 1;
        // Increment the odometer from the innermost axis.
        for ax in (0..self.out_shape.len()).rev() {
            self.counter[ax] += 1;
            self.offset += self.strides[ax];
            if self.counter[ax] < self.out_shape[ax] {
                break;
            }
            self.offset -= self.strides[ax] * self.out_shape[ax];
            self.counter[ax] = 0;
        }
        Some(cur)
    }
}

/// Flat index for multi-index `idx` under `strides`.
pub fn flat_index(idx: &[usize], strides: &[usize]) -> usize {
    idx.iter().zip(strides).map(|(i, s)| i * s).sum()
}

/// Normalize a possibly-negative axis.
pub fn norm_axis(axis: i64, rank: usize) -> usize {
    if axis < 0 {
        (rank as i64 + axis) as usize
    } else {
        axis as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(row_major_strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(row_major_strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn broadcast_basic() {
        assert_eq!(broadcast_shapes(&[2, 3], &[3]), Some(vec![2, 3]));
        assert_eq!(broadcast_shapes(&[2, 1], &[1, 4]), Some(vec![2, 4]));
        assert_eq!(broadcast_shapes(&[], &[5]), Some(vec![5]));
        assert_eq!(broadcast_shapes(&[2, 3], &[4]), None);
    }

    #[test]
    fn broadcast_iter_scalar() {
        let offs: Vec<usize> = BroadcastIter::new(&[], &[2, 2]).collect();
        assert_eq!(offs, vec![0, 0, 0, 0]);
    }

    #[test]
    fn broadcast_iter_row() {
        // shape [3] broadcast to [2,3]: offsets 0,1,2,0,1,2
        let offs: Vec<usize> = BroadcastIter::new(&[3], &[2, 3]).collect();
        assert_eq!(offs, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn broadcast_iter_col() {
        // shape [2,1] broadcast to [2,3]: offsets 0,0,0,1,1,1
        let offs: Vec<usize> = BroadcastIter::new(&[2, 1], &[2, 3]).collect();
        assert_eq!(offs, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn broadcast_iter_identity() {
        let offs: Vec<usize> = BroadcastIter::new(&[2, 2], &[2, 2]).collect();
        assert_eq!(offs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn axis_normalization() {
        assert_eq!(norm_axis(-1, 3), 2);
        assert_eq!(norm_axis(1, 3), 1);
    }
}
