//! Broadcasting elementwise operators (unary + binary + comparisons + select).

use std::sync::Arc;

use super::shape::{broadcast_shapes, BroadcastIter};
use super::{DType, Storage, Tensor};

/// Binary arithmetic op tags shared by the runtime and the XLA lowering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Maximum,
    Minimum,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnaryOp {
    Neg,
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Tanh,
    Sigmoid,
    Relu,
    Abs,
    Floor,
    Ceil,
    Round,
    Erf,
    LogicalNot,
}

fn apply_f64(op: BinOp, a: f64, b: f64) -> f64 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Pow => a.powf(b),
        BinOp::Maximum => a.max(b),
        BinOp::Minimum => a.min(b),
    }
}

fn apply_i64(op: BinOp, a: i64, b: i64) -> i64 {
    match op {
        BinOp::Add => a.wrapping_add(b),
        BinOp::Sub => a.wrapping_sub(b),
        BinOp::Mul => a.wrapping_mul(b),
        BinOp::Div => {
            if b == 0 {
                0
            } else {
                a / b
            }
        }
        BinOp::Pow => (a as f64).powf(b as f64) as i64,
        BinOp::Maximum => a.max(b),
        BinOp::Minimum => a.min(b),
    }
}

macro_rules! bin_same_dtype {
    ($op:expr, $la:expr, $lb:expr, $ia:expr, $ib:expr, $ctor:path, $conv:ident, $back:expr) => {{
        let out: Vec<_> = $ia
            .zip($ib)
            .map(|(i, j)| {
                let r = $conv($op, $la[i] as _, $lb[j] as _);
                ($back)(r)
            })
            .collect();
        $ctor(Arc::new(out))
    }};
}

/// Broadcasting binary arithmetic. Operands are cast to their promoted
/// dtype first (the `Broadcast` type relation guarantees this is legal).
pub fn binary(op: BinOp, a: &Tensor, b: &Tensor) -> Tensor {
    let dt = DType::promote(a.dtype(), b.dtype());
    let a = cast(a, dt);
    let b = cast(b, dt);
    let out_shape = broadcast_shapes(a.shape(), b.shape())
        .unwrap_or_else(|| panic!("cannot broadcast {:?} with {:?}", a.shape(), b.shape()));
    let ia = BroadcastIter::new(a.shape(), &out_shape);
    let ib = BroadcastIter::new(b.shape(), &out_shape);
    let data = match (a.storage(), b.storage()) {
        (Storage::F32(la), Storage::F32(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::F32, apply_f64, |r: f64| r as f32)
        }
        (Storage::F64(la), Storage::F64(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::F64, apply_f64, |r: f64| r)
        }
        (Storage::I64(la), Storage::I64(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::I64, apply_i64, |r: i64| r)
        }
        (Storage::I32(la), Storage::I32(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::I32, apply_i64, |r: i64| r as i32)
        }
        (Storage::I16(la), Storage::I16(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::I16, apply_i64, |r: i64| r as i16)
        }
        (Storage::I8(la), Storage::I8(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::I8, apply_i64, |r: i64| r as i8)
        }
        (Storage::U8(la), Storage::U8(lb)) => {
            bin_same_dtype!(op, la, lb, ia, ib, Storage::U8, apply_i64, |r: i64| r as u8)
        }
        (Storage::Bool(la), Storage::Bool(lb)) => {
            // Bool arithmetic: And for Mul/Minimum, Or for Add/Maximum.
            let out: Vec<bool> = ia
                .zip(ib)
                .map(|(i, j)| match op {
                    BinOp::Mul | BinOp::Minimum => la[i] && lb[j],
                    BinOp::Add | BinOp::Maximum => la[i] || lb[j],
                    _ => panic!("unsupported bool arithmetic {op:?}"),
                })
                .collect();
            Storage::Bool(Arc::new(out))
        }
        _ => unreachable!("operands were cast to a common dtype"),
    };
    Tensor::new(out_shape, data)
}

/// Broadcasting comparison -> bool tensor.
pub fn compare(op: CmpOp, a: &Tensor, b: &Tensor) -> Tensor {
    let dt = DType::promote(a.dtype(), b.dtype());
    let a = cast(a, dt);
    let b = cast(b, dt);
    let out_shape = broadcast_shapes(a.shape(), b.shape())
        .unwrap_or_else(|| panic!("cannot broadcast {:?} with {:?}", a.shape(), b.shape()));
    let ia = BroadcastIter::new(a.shape(), &out_shape);
    let ib = BroadcastIter::new(b.shape(), &out_shape);
    let out: Vec<bool> = ia
        .zip(ib)
        .map(|(i, j)| {
            let (x, y) = (a.get_f64(i), b.get_f64(j));
            match op {
                CmpOp::Eq => x == y,
                CmpOp::Ne => x != y,
                CmpOp::Lt => x < y,
                CmpOp::Le => x <= y,
                CmpOp::Gt => x > y,
                CmpOp::Ge => x >= y,
            }
        })
        .collect();
    Tensor::new(out_shape, Storage::Bool(Arc::new(out)))
}

/// Unary elementwise.
pub fn unary(op: UnaryOp, a: &Tensor) -> Tensor {
    if op == UnaryOp::LogicalNot {
        let out: Vec<bool> = a.as_bool().iter().map(|&b| !b).collect();
        return Tensor::new(a.shape().to_vec(), Storage::Bool(Arc::new(out)));
    }
    match a.storage() {
        Storage::F32(v) => {
            let out: Vec<f32> = v.iter().map(|&x| unary_f64(op, x as f64) as f32).collect();
            Tensor::new(a.shape().to_vec(), Storage::F32(Arc::new(out)))
        }
        Storage::F64(v) => {
            let out: Vec<f64> = v.iter().map(|&x| unary_f64(op, x)).collect();
            Tensor::new(a.shape().to_vec(), Storage::F64(Arc::new(out)))
        }
        _ if op == UnaryOp::Neg || op == UnaryOp::Abs || op == UnaryOp::Relu => {
            let out: Vec<f64> = (0..a.numel())
                .map(|i| {
                    let x = a.get_f64(i);
                    match op {
                        UnaryOp::Neg => -x,
                        UnaryOp::Abs => x.abs(),
                        UnaryOp::Relu => x.max(0.0),
                        _ => unreachable!(),
                    }
                })
                .collect();
            from_f64_as(a.dtype(), a.shape().to_vec(), &out)
        }
        other => panic!("unary {op:?} unsupported on {:?}", other.dtype()),
    }
}

fn unary_f64(op: UnaryOp, x: f64) -> f64 {
    match op {
        UnaryOp::Neg => -x,
        UnaryOp::Exp => x.exp(),
        UnaryOp::Log => x.ln(),
        UnaryOp::Sqrt => x.sqrt(),
        UnaryOp::Rsqrt => 1.0 / x.sqrt(),
        UnaryOp::Tanh => x.tanh(),
        UnaryOp::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        UnaryOp::Relu => x.max(0.0),
        UnaryOp::Abs => x.abs(),
        UnaryOp::Floor => x.floor(),
        UnaryOp::Ceil => x.ceil(),
        UnaryOp::Round => x.round(),
        UnaryOp::Erf => erf(x),
        UnaryOp::LogicalNot => unreachable!(),
    }
}

/// Abramowitz & Stegun 7.1.26 rational approximation (|err| < 1.5e-7).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// `where(cond, a, b)` with broadcasting.
pub fn select(cond: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
    let dt = DType::promote(a.dtype(), b.dtype());
    let a = cast(a, dt);
    let b = cast(b, dt);
    let s1 = broadcast_shapes(cond.shape(), a.shape()).expect("select broadcast");
    let out_shape = broadcast_shapes(&s1, b.shape()).expect("select broadcast");
    let ic = BroadcastIter::new(cond.shape(), &out_shape);
    let ia = BroadcastIter::new(a.shape(), &out_shape);
    let ib = BroadcastIter::new(b.shape(), &out_shape);
    let cv = cond.as_bool();
    let out: Vec<f64> = ic
        .zip(ia.zip(ib))
        .map(|(c, (i, j))| if cv[c] { a.get_f64(i) } else { b.get_f64(j) })
        .collect();
    from_f64_as(dt, out_shape, &out)
}

/// Cast to another dtype (saturating for narrow ints, like the realized
/// quantization ops of §4.5).
pub fn cast(a: &Tensor, dt: DType) -> Tensor {
    if a.dtype() == dt {
        return a.clone();
    }
    let n = a.numel();
    let vals: Vec<f64> = (0..n).map(|i| a.get_f64(i)).collect();
    from_f64_as(dt, a.shape().to_vec(), &vals)
}

pub(crate) fn from_f64_as(dt: DType, shape: Vec<usize>, vals: &[f64]) -> Tensor {
    let data = match dt {
        DType::F32 => Storage::F32(Arc::new(vals.iter().map(|&v| v as f32).collect())),
        DType::F64 => Storage::F64(Arc::new(vals.to_vec())),
        DType::I64 => Storage::I64(Arc::new(vals.iter().map(|&v| v as i64).collect())),
        DType::I32 => Storage::I32(Arc::new(
            vals.iter().map(|&v| v.clamp(i32::MIN as f64, i32::MAX as f64) as i32).collect(),
        )),
        DType::I16 => Storage::I16(Arc::new(
            vals.iter().map(|&v| v.clamp(i16::MIN as f64, i16::MAX as f64) as i16).collect(),
        )),
        DType::I8 => Storage::I8(Arc::new(
            vals.iter().map(|&v| v.clamp(i8::MIN as f64, i8::MAX as f64) as i8).collect(),
        )),
        DType::U8 => Storage::U8(Arc::new(
            vals.iter().map(|&v| v.clamp(0.0, u8::MAX as f64) as u8).collect(),
        )),
        DType::Bool => Storage::Bool(Arc::new(vals.iter().map(|&v| v != 0.0).collect())),
    };
    Tensor::new(shape, data)
}

/// Clip every element into `[lo, hi]`.
pub fn clip(a: &Tensor, lo: f64, hi: f64) -> Tensor {
    let vals: Vec<f64> = (0..a.numel()).map(|i| a.get_f64(i).clamp(lo, hi)).collect();
    from_f64_as(a.dtype(), a.shape().to_vec(), &vals)
}

// ---------------------------------------------------------------------------
// In-place variants (the memory planner's hot-kernel fast path).
//
// Each `*_assign` writes the result into an operand whose storage is
// uniquely owned (probed via `Storage::try_unique_f32`), returning `true`
// on success; any shape/dtype/uniqueness mismatch returns `false` and the
// caller runs the allocating kernel. The arithmetic mirrors the allocating
// path bit-for-bit (same f64 round trip), so planned and unplanned
// execution are indistinguishable — asserted by the differential tests.
// ---------------------------------------------------------------------------

/// Can `out[i] = f(dst[i], other broadcast)` legally land in `dst`'s buffer?
/// True when both are f32 (promotion is identity) and the broadcast result
/// shape equals `dst`'s shape: equal shapes, or `other` a one-element
/// tensor of rank <= dst's (scalar broadcast indexes it at 0 everywhere).
fn fits_in_place(dst: &Tensor, other: &Tensor) -> bool {
    dst.dtype() == DType::F32
        && other.dtype() == DType::F32
        && (dst.shape() == other.shape()
            || (other.numel() == 1 && other.rank() <= dst.rank()))
}

/// `a <- op(a, b)` in place. Requires `a` uniquely owned, f32, and the
/// broadcast output shape to equal `a`'s ([`fits_in_place`]).
pub fn binary_assign(op: BinOp, a: &mut Tensor, b: &Tensor) -> bool {
    if !fits_in_place(a, b) {
        return false;
    }
    let bv = b.as_f32();
    let scalar = b.numel() == 1 && a.shape() != b.shape();
    let Some(av) = a.try_unique_f32() else { return false };
    if scalar {
        let y = bv[0] as f64;
        for x in av.iter_mut() {
            *x = apply_f64(op, *x as f64, y) as f32;
        }
    } else {
        for (x, &y) in av.iter_mut().zip(bv.iter()) {
            *x = apply_f64(op, *x as f64, y as f64) as f32;
        }
    }
    true
}

/// `b <- op(a, b)` in place (operand order preserved — matters for
/// subtract/divide/power). Requires `b` uniquely owned, f32, and the
/// broadcast output shape to equal `b`'s.
pub fn binary_assign_rhs(op: BinOp, a: &Tensor, b: &mut Tensor) -> bool {
    if !fits_in_place(b, a) {
        return false;
    }
    let av = a.as_f32();
    let scalar = a.numel() == 1 && a.shape() != b.shape();
    let Some(bv) = b.try_unique_f32() else { return false };
    if scalar {
        let x = av[0] as f64;
        for y in bv.iter_mut() {
            *y = apply_f64(op, x, *y as f64) as f32;
        }
    } else {
        for (&x, y) in av.iter().zip(bv.iter_mut()) {
            *y = apply_f64(op, x as f64, *y as f64) as f32;
        }
    }
    true
}

/// `a <- op(a)` in place for the f32 unary kernels. `LogicalNot` is bool
/// and excluded.
pub fn unary_assign(op: UnaryOp, a: &mut Tensor) -> bool {
    if op == UnaryOp::LogicalNot || a.dtype() != DType::F32 {
        return false;
    }
    let Some(av) = a.try_unique_f32() else { return false };
    for x in av.iter_mut() {
        *x = unary_f64(op, *x as f64) as f32;
    }
    true
}

/// `a <- clamp(a, lo, hi)` in place (f32, uniquely owned).
pub fn clip_assign(a: &mut Tensor, lo: f64, hi: f64) -> bool {
    if a.dtype() != DType::F32 {
        return false;
    }
    let Some(av) = a.try_unique_f32() else { return false };
    for x in av.iter_mut() {
        *x = (*x as f64).clamp(lo, hi) as f32;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_broadcast_row() {
        let a = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_f32(vec![3], vec![10., 20., 30.]);
        let c = binary(BinOp::Add, &a, &b);
        assert_eq!(c.as_f32(), &[11., 22., 33., 14., 25., 36.]);
    }

    #[test]
    fn mixed_dtype_promotes() {
        let a = Tensor::from_i32(vec![2], vec![1, 2]);
        let b = Tensor::from_f32(vec![2], vec![0.5, 0.5]);
        let c = binary(BinOp::Mul, &a, &b);
        assert_eq!(c.dtype(), DType::F32);
        assert_eq!(c.as_f32(), &[0.5, 1.0]);
    }

    #[test]
    fn compare_produces_bool() {
        let a = Tensor::from_f32(vec![3], vec![1., 2., 3.]);
        let b = Tensor::from_f32(vec![3], vec![2., 2., 2.]);
        assert_eq!(compare(CmpOp::Lt, &a, &b).as_bool(), &[true, false, false]);
        assert_eq!(compare(CmpOp::Ge, &a, &b).as_bool(), &[false, true, true]);
    }

    #[test]
    fn unary_ops() {
        let a = Tensor::from_f32(vec![3], vec![-1., 0., 4.]);
        assert_eq!(unary(UnaryOp::Relu, &a).as_f32(), &[0., 0., 4.]);
        assert_eq!(unary(UnaryOp::Neg, &a).as_f32(), &[1., 0., -4.]);
        let s = unary(UnaryOp::Sqrt, &Tensor::from_f32(vec![1], vec![16.0]));
        assert_eq!(s.as_f32(), &[4.0]);
    }

    #[test]
    fn sigmoid_tanh_sane() {
        let a = Tensor::from_f32(vec![1], vec![0.0]);
        assert!((unary(UnaryOp::Sigmoid, &a).as_f32()[0] - 0.5).abs() < 1e-6);
        assert!(unary(UnaryOp::Tanh, &a).as_f32()[0].abs() < 1e-6);
    }

    #[test]
    fn erf_reference_points() {
        assert!(erf(0.0).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
    }

    #[test]
    fn cast_saturates_to_i8() {
        let a = Tensor::from_f32(vec![3], vec![300.0, -300.0, 7.0]);
        let c = cast(&a, DType::I8);
        assert_eq!(c.as_i8(), &[127, -128, 7]);
    }

    #[test]
    fn select_broadcasts() {
        let c = Tensor::from_bool(vec![2], vec![true, false]);
        let a = Tensor::from_f32(vec![2], vec![1., 1.]);
        let b = Tensor::from_f32(vec![2], vec![9., 9.]);
        assert_eq!(select(&c, &a, &b).as_f32(), &[1., 9.]);
    }

    #[test]
    fn clip_clamps() {
        let a = Tensor::from_f32(vec![4], vec![-5., 0., 5., 10.]);
        assert_eq!(clip(&a, -1.0, 6.0).as_f32(), &[-1., 0., 5., 6.]);
    }

    #[test]
    fn inplace_binary_matches_allocating_kernel_bitwise() {
        let b = Tensor::from_f32(vec![3], vec![0.5, -2.0, 3.0]);
        let make_a = || Tensor::from_f32(vec![3], vec![1.0, 2.0, -3.5]);
        for op in [
            BinOp::Add,
            BinOp::Sub,
            BinOp::Mul,
            BinOp::Div,
            BinOp::Pow,
            BinOp::Maximum,
            BinOp::Minimum,
        ] {
            let expect = binary(op, &make_a(), &b);
            let mut a = make_a();
            assert!(binary_assign(op, &mut a, &b), "{op:?} lhs refused");
            assert_eq!(a.as_f32(), expect.as_f32(), "{op:?} lhs diverged");
            let mut b2 = Tensor::from_f32(vec![3], vec![0.5, -2.0, 3.0]);
            assert!(binary_assign_rhs(op, &make_a(), &mut b2), "{op:?} rhs refused");
            assert_eq!(b2.as_f32(), expect.as_f32(), "{op:?} rhs diverged");
        }
    }

    #[test]
    fn inplace_scalar_broadcast_and_refusals() {
        let s = Tensor::scalar_f32(2.0);
        let mut a = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]);
        let expect = binary(BinOp::Mul, &a, &s);
        assert!(binary_assign(BinOp::Mul, &mut a, &s));
        assert_eq!(a.as_f32(), expect.as_f32());
        // Shared storage refuses (value semantics must stay observable).
        let mut shared = Tensor::from_f32(vec![2], vec![1., 2.]);
        let alias = shared.clone();
        assert!(!binary_assign(BinOp::Add, &mut shared, &Tensor::from_f32(vec![2], vec![1., 1.])));
        assert_eq!(alias.as_f32(), &[1., 2.]);
        // A broadcast that grows the destination refuses.
        let mut small = Tensor::scalar_f32(1.0);
        let big = Tensor::from_f32(vec![2], vec![1., 2.]);
        assert!(!binary_assign(BinOp::Add, &mut small, &big));
        // Mixed dtype refuses.
        let mut f = Tensor::from_f32(vec![2], vec![1., 2.]);
        let i = Tensor::from_i32(vec![2], vec![1, 2]);
        assert!(!binary_assign(BinOp::Add, &mut f, &i));
    }

    #[test]
    fn inplace_unary_and_clip_match() {
        for op in [
            UnaryOp::Neg,
            UnaryOp::Exp,
            UnaryOp::Tanh,
            UnaryOp::Relu,
            UnaryOp::Sigmoid,
            UnaryOp::Erf,
        ] {
            let src = Tensor::from_f32(vec![3], vec![-1.0, 0.25, 2.0]);
            let expect = unary(op, &src);
            let mut a = Tensor::from_f32(vec![3], vec![-1.0, 0.25, 2.0]);
            assert!(unary_assign(op, &mut a), "{op:?} refused");
            assert_eq!(a.as_f32(), expect.as_f32(), "{op:?} diverged");
        }
        let mut c = Tensor::from_f32(vec![3], vec![-5.0, 0.5, 9.0]);
        let expect = clip(&c, -1.0, 1.0);
        assert!(clip_assign(&mut c, -1.0, 1.0));
        assert_eq!(c.as_f32(), expect.as_f32());
        // Non-f32 refuses.
        let mut i = Tensor::from_i32(vec![2], vec![1, 2]);
        assert!(!unary_assign(UnaryOp::Neg, &mut i));
        assert!(!clip_assign(&mut i, 0.0, 1.0));
    }

    #[test]
    fn bool_logic() {
        let a = Tensor::from_bool(vec![2], vec![true, false]);
        let b = Tensor::from_bool(vec![2], vec![true, true]);
        assert_eq!(binary(BinOp::Mul, &a, &b).as_bool(), &[true, false]); // and
        assert_eq!(binary(BinOp::Add, &a, &b).as_bool(), &[true, true]); // or
        assert_eq!(unary(UnaryOp::LogicalNot, &a).as_bool(), &[false, true]);
    }
}
