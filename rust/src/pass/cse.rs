//! Common-subexpression elimination over let-bound pure values (the
//! CommonSubexprElim of the -O3 tier, §5.2).
//!
//! Walks let chains keeping a scope-stacked table from structural hash to
//! the first variable bound to an alpha-equivalent pure value; later
//! bindings are replaced by references to the first.

use std::collections::BTreeMap;
use std::collections::HashMap;

use super::purity::is_pure;
use crate::ir::{alpha_eq, map_children, structural_hash, var, Expr, Module, Var, E};

pub fn cse(e: &E) -> E {
    let mut table: HashMap<u64, Vec<(E, Var)>> = HashMap::new();
    go(e, &mut table)
}

fn go(e: &E, table: &mut HashMap<u64, Vec<(E, Var)>>) -> E {
    match &**e {
        Expr::Let { var: v, ty, value, body } => {
            let value = go(value, table);
            if is_pure(&value) && !value.is_atomic() {
                let h = structural_hash(&value);
                if let Some(entries) = table.get(&h) {
                    for (prev, pv) in entries {
                        if alpha_eq(prev, &value) {
                            // Replace v with pv in the body.
                            let mut m = BTreeMap::new();
                            m.insert(v.clone(), var(pv));
                            let body = crate::ir::subst(&body.clone(), &m);
                            return go(&body, table);
                        }
                    }
                }
                table.entry(h).or_default().push((value.clone(), v.clone()));
                let body = go(body, table);
                // Pop the entry on scope exit.
                if let Some(entries) = table.get_mut(&structural_hash(&value)) {
                    entries.pop();
                }
                return std::sync::Arc::new(Expr::Let {
                    var: v.clone(),
                    ty: ty.clone(),
                    value,
                    body,
                });
            }
            let body = go(body, table);
            std::sync::Arc::new(Expr::Let { var: v.clone(), ty: ty.clone(), value, body })
        }
        // Don't share across function boundaries (evaluation counts could
        // change); start a fresh table inside.
        Expr::Func(_) => map_children(e, |c| {
            let mut inner = HashMap::new();
            go(c, &mut inner)
        }),
        _ => map_children(e, |c| go(c, table)),
    }
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = cse(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, print_expr};

    #[test]
    fn shares_identical_bindings() {
        let e = parse_expr(
            "fn (%x) {\n\
               let %a = add(%x, 1f);\n\
               let %b = add(%x, 1f);\n\
               multiply(%a, %b)\n\
             }",
        )
        .unwrap();
        let out = super::super::dce::dce(&cse(&e));
        let s = print_expr(&out);
        // Only one add remains.
        assert_eq!(s.matches("add(").count(), 1, "{s}");
    }

    #[test]
    fn different_values_not_shared() {
        let e = parse_expr(
            "fn (%x) { let %a = add(%x, 1f); let %b = add(%x, 2f); multiply(%a, %b) }",
        )
        .unwrap();
        let out = cse(&e);
        let s = print_expr(&out);
        assert_eq!(s.matches("add(").count(), 2, "{s}");
    }

    #[test]
    fn impure_not_shared() {
        let e = parse_expr(
            "let %a = ref(1f); let %b = ref(1f); (!%a, !%b)",
        )
        .unwrap();
        let out = cse(&e);
        let s = print_expr(&out);
        assert_eq!(s.matches("ref(").count(), 2, "{s}");
    }
}
