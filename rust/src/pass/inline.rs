//! Global inlining: replace `@f(args)` calls with the (alpha-refreshed)
//! body of `@f`. Used before fusion so operator chains cross function
//! boundaries, and by the AoT path which compiles one flat `@main`.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::ir::{map_children, refresh, Expr, Function, Module, E};

/// Inline all global calls in `e` up to `depth` levels (recursion-safe).
pub fn inline_globals(m: &Module, e: &E, depth: usize) -> E {
    if depth == 0 {
        return e.clone();
    }
    let rebuilt = map_children(e, |c| inline_globals(m, c, depth));
    match &*rebuilt {
        Expr::Call { f, args, attrs } => {
            if let Expr::Global(g) = &**f {
                if let Some(def) = m.def(g) {
                    // Don't inline self-recursive functions.
                    if !calls_global(&def.body, g) && def.params.len() == args.len() {
                        let fresh = refresh(&Arc::new(Expr::Func(def.clone())));
                        if let Expr::Func(Function { params, body, .. }) = &*fresh {
                            let mut sub = BTreeMap::new();
                            for ((p, _), a) in params.iter().zip(args) {
                                sub.insert(p.clone(), a.clone());
                            }
                            let inlined = crate::ir::subst(body, &sub);
                            return inline_globals(m, &inlined, depth - 1);
                        }
                    }
                }
            }
            let _ = attrs;
            rebuilt
        }
        _ => rebuilt,
    }
}

fn calls_global(e: &E, name: &str) -> bool {
    let mut found = false;
    crate::ir::collect(
        e,
        &|n| matches!(&**n, Expr::Global(g) if g == name),
        &mut Vec::new(),
    );
    // collect() already walked; cheaper variant:
    fn go(e: &E, name: &str, found: &mut bool) {
        if *found {
            return;
        }
        if matches!(&**e, Expr::Global(g) if g == name) {
            *found = true;
            return;
        }
        crate::ir::visit_children(e, |c| go(c, name, found));
    }
    go(e, name, &mut found);
    found
}

/// Inline every non-main def into main; returns the new module.
pub fn run(m: &Module) -> Module {
    m.map_defs(|name, f| {
        if name == "main" {
            let mut nf = f.clone();
            nf.body = inline_globals(m, &f.body, 8);
            nf
        } else {
            f.clone()
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_main;
    use crate::eval::Value;
    use crate::ir::{parse_module, print_expr};
    use crate::tensor::Tensor;

    #[test]
    fn inlines_simple_global() {
        let m = parse_module(
            "def @double(%x) { multiply(%x, 2f) }\n\
             def @main(%x) { @double(@double(%x)) }",
        )
        .unwrap();
        let out = run(&m);
        let s = print_expr(&out.def("main").unwrap().body);
        assert!(!s.contains("@double"), "{s}");
        let r = eval_main(&out, vec![Value::Tensor(Tensor::scalar_f32(3.0))]).unwrap();
        assert_eq!(r.tensor().f32_value(), 12.0);
    }

    #[test]
    fn recursive_global_not_inlined() {
        let m = parse_module(
            "def @fact(%n) { if (greater(%n, 1f)) { multiply(%n, @fact(subtract(%n, 1f))) } else { 1f } }\n\
             def @main(%n) { @fact(%n) }",
        )
        .unwrap();
        let out = run(&m);
        let s = print_expr(&out.def("main").unwrap().body);
        assert!(s.contains("@fact"), "{s}");
    }
}
