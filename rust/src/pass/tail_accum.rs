//! Accumulator-passing tail-recursion rewrite (the -O2 tier's loop
//! conversion; ROADMAP "TCO follow-ups" item).
//!
//! The VM's tail-call elimination flattens calls whose result flows
//! straight to `Ret` — but a fold like TreeLSTM's child-sum,
//!
//! ```text
//! let %sum = fn (%l) {
//!   match (%l) { Cons(%h, %t) -> add(%h, %sum(%t)), Nil -> 0f }
//! };
//! ```
//!
//! is genuinely non-tail: every `Cons` frame must stay live to apply the
//! pending `add`, so the frame stack grows linearly with the list. This
//! pass converts such folds to accumulator-passing style,
//!
//! ```text
//! let %sum_acc = fn (%l, %acc) {
//!   match (%l) { Cons(%h, %t) -> %sum_acc(%t, add(%acc, %h)),
//!                Nil -> add(%acc, 0f) }
//! };
//! let %sum = fn (%l) {
//!   // entry copy: performs the FIRST fold step itself, seeding the
//!   // accumulator with the first element — no identity constant is
//!   // ever injected, so the fold's dtype is untouched.
//!   match (%l) { Cons(%h, %t) -> %sum_acc(%t, %h), Nil -> 0f }
//! };
//! ```
//!
//! which the VM's `TailInvokeFunc`/`TailInvokeClosure` then run in O(1)
//! frame-stack depth (`Vm::max_depth` stays ≤ 2 on a 10k-element fold).
//!
//! Scope and soundness:
//! * Both `let %f = fn ...` recursion and self-recursive global defs
//!   (`def @sum_h`) are rewritten; the original name becomes an entry
//!   copy of the function whose wrapped arms hand off to the accumulator
//!   version with the first element as the seed (base and direct-tail
//!   arms are kept verbatim), so external callers — and first-class uses
//!   of the name — see identical arity, dtype, and base-case behavior.
//! * Only calls wrapped in an **associative, commutative** operator
//!   (`add`, `multiply`) qualify, the same operator at every wrapped
//!   site, with the non-recursive operand pure (the rewrite reorders its
//!   evaluation relative to the recursion).
//! * Like any reassociation (cf. FoldScaleAxis), the rewrite can change
//!   floating-point rounding: the fold becomes left-to-right instead of
//!   right-to-left. That is why it lives at -O2+, not -O1.
//! * Arms where the function doesn't appear, appears as a direct tail
//!   call, or appears as a one-level ANF binding (`let %s = %f(%t);
//!   add(%h, %s)`) are all handled; anything else (two recursive calls
//!   in one arm, the function escaping as a value, a non-qualifying
//!   wrapper op) leaves the function untouched.

use std::sync::Arc;

use super::purity::is_pure;
use crate::ir::{call, global, op_call, var, Expr, Function, Module, Var, E};

/// Is `op` an associative + commutative combine operator the rewrite may
/// reassociate? (No identity element is needed: the entry copy seeds the
/// accumulator with the first element instead.)
fn foldable_op(op: &str) -> bool {
    matches!(op, "add" | "multiply")
}

/// How the function refers to itself: a let-bound variable or a global.
#[derive(Clone)]
enum SelfRef {
    Local(Var),
    Global(String),
}

impl SelfRef {
    fn matches(&self, e: &E) -> bool {
        match (self, &**e) {
            (SelfRef::Local(v), Expr::Var(w)) => v == w,
            (SelfRef::Global(n), Expr::Global(g)) => n == g,
            _ => false,
        }
    }
}

/// Does the self-reference occur anywhere in `e`? (Variable ids are
/// globally unique, so no shadowing analysis is needed.)
fn occurs(e: &E, f: &SelfRef) -> bool {
    fn go(e: &E, f: &SelfRef, found: &mut bool) {
        if *found || f.matches(e) {
            *found = true;
            return;
        }
        crate::ir::visit_children(e, |c| go(c, f, found));
    }
    let mut found = false;
    go(e, f, &mut found);
    found
}

fn mentions_var(e: &E, v: &Var) -> bool {
    fn go(e: &E, v: &Var, found: &mut bool) {
        if *found || matches!(&**e, Expr::Var(w) if w == v) {
            *found = true;
            return;
        }
        crate::ir::visit_children(e, |c| go(c, v, found));
    }
    let mut found = false;
    go(e, v, &mut found);
    found
}

/// `let %r = e; %r`  =>  `e` — the shape ANF leaves at arm tails.
fn peel_ret(e: &E) -> E {
    if let Expr::Let { var: r, value, body, .. } = &**e {
        if matches!(&**body, Expr::Var(v) if v == r) {
            return value.clone();
        }
    }
    e.clone()
}

/// A tail position classified against the self-reference.
enum Tail {
    /// No occurrence of `f`: a base case.
    Base,
    /// `f(args)` (directly or through a `let`-move): stays a tail call.
    Direct(Vec<E>),
    /// `op(other, f(args))` / `op(f(args), other)` (directly or through
    /// one level of ANF): the fold step.
    Wrapped { op: String, recursive_args: Vec<E>, other: E },
}

/// Classify one tail expression, or `None` if it disqualifies the rewrite
/// (f in non-tail position, escaping, wrong arity, impure operand, ...).
fn classify_tail(e: &E, f: &SelfRef, arity: usize) -> Option<Tail> {
    // A saturated call to `f` with f-free arguments.
    let as_self_call = |e: &E| -> Option<Vec<E>> {
        if let Expr::Call { f: callee, args, .. } = &**e {
            if f.matches(callee)
                && args.len() == arity
                && args.iter().all(|a| !occurs(a, f))
            {
                return Some(args.clone());
            }
        }
        None
    };
    // `op(a, b)` for a qualifying combine operator with no attrs.
    let as_combine = |e: &E| -> Option<(String, E, E)> {
        if let Expr::Call { f: op_e, args, attrs } = &**e {
            if let Expr::Op(name) = &**op_e {
                if args.len() == 2 && attrs.is_empty() && foldable_op(name) {
                    return Some((name.clone(), args[0].clone(), args[1].clone()));
                }
            }
        }
        None
    };
    let wrapped = |op: String, rec: &E, other: &E| -> Option<Tail> {
        let recursive_args = as_self_call(rec)?;
        if occurs(other, f) || !is_pure(other) {
            return None;
        }
        Some(Tail::Wrapped { op, recursive_args, other: other.clone() })
    };

    if !occurs(e, f) {
        return Some(Tail::Base);
    }
    if let Some(args) = as_self_call(e) {
        return Some(Tail::Direct(args));
    }
    if let Some((op, a, b)) = as_combine(e) {
        // Exactly one operand recurses; `wrapped` rejects the other cases.
        if as_self_call(&b).is_some() {
            return wrapped(op, &b, &a);
        }
        if as_self_call(&a).is_some() {
            return wrapped(op, &a, &b);
        }
        return None;
    }
    // One-level ANF: `let %s = f(args); <%s | op-combine of %s>`.
    if let Expr::Let { var: s, value, body, .. } = &**e {
        if let Some(recursive_args) = as_self_call(value) {
            let combine = peel_ret(body);
            if occurs(&combine, f) {
                return None;
            }
            if matches!(&*combine, Expr::Var(v) if v == s) {
                return Some(Tail::Direct(recursive_args));
            }
            if let Some((op, a, b)) = as_combine(&combine) {
                let other = if matches!(&*a, Expr::Var(v) if v == s) {
                    b
                } else if matches!(&*b, Expr::Var(v) if v == s) {
                    a
                } else {
                    return None;
                };
                if mentions_var(&other, s) || !is_pure(&other) {
                    return None;
                }
                return Some(Tail::Wrapped { op, recursive_args, other });
            }
        }
    }
    None
}

/// Phase 1: walk the tail positions of `body` and decide whether the
/// rewrite applies. Returns the combine operator iff every occurrence of
/// `f` qualifies and at least one is op-wrapped (a pure tail loop gains
/// nothing — the VM already flattens it).
fn scan_tail(
    e: &E,
    f: &SelfRef,
    arity: usize,
    op: &mut Option<String>,
    any_wrapped: &mut bool,
) -> bool {
    match &**e {
        Expr::If { cond, then_, else_ } => {
            !occurs(cond, f)
                && scan_tail(then_, f, arity, op, any_wrapped)
                && scan_tail(else_, f, arity, op, any_wrapped)
        }
        Expr::Match { scrut, arms } => {
            !occurs(scrut, f)
                && arms.iter().all(|(_, a)| scan_tail(a, f, arity, op, any_wrapped))
        }
        // A let whose value doesn't recurse just scopes the tail.
        Expr::Let { value, body, .. }
            if !occurs(value, f) && classify_tail(e, f, arity).is_none() =>
        {
            scan_tail(body, f, arity, op, any_wrapped)
        }
        _ => match classify_tail(e, f, arity) {
            Some(Tail::Base) | Some(Tail::Direct(_)) => true,
            Some(Tail::Wrapped { op: o, .. }) => {
                match op {
                    Some(prev) if *prev != o => return false,
                    _ => *op = Some(o),
                }
                *any_wrapped = true;
                true
            }
            None => false,
        },
    }
}

/// Phase 2: rebuild `body` in accumulator-passing style. Mirrors
/// [`scan_tail`] exactly; `None` only if the two phases fell out of sync
/// (callers then leave the function untouched).
fn rewrite_tail(
    e: &E,
    f: &SelfRef,
    arity: usize,
    op: &str,
    new_callee: &E,
    acc: &Var,
) -> Option<E> {
    match &**e {
        Expr::If { cond, then_, else_ } if occurs(e, f) => Some(Arc::new(Expr::If {
            cond: cond.clone(),
            then_: rewrite_tail(then_, f, arity, op, new_callee, acc)?,
            else_: rewrite_tail(else_, f, arity, op, new_callee, acc)?,
        })),
        Expr::Match { scrut, arms } if occurs(e, f) => {
            let arms = arms
                .iter()
                .map(|(p, a)| {
                    Some((p.clone(), rewrite_tail(a, f, arity, op, new_callee, acc)?))
                })
                .collect::<Option<Vec<_>>>()?;
            Some(Arc::new(Expr::Match { scrut: scrut.clone(), arms }))
        }
        Expr::Let { var: s, ty, value, body }
            if !occurs(value, f) && classify_tail(e, f, arity).is_none() =>
        {
            Some(Arc::new(Expr::Let {
                var: s.clone(),
                ty: ty.clone(),
                value: value.clone(),
                body: rewrite_tail(body, f, arity, op, new_callee, acc)?,
            }))
        }
        _ => match classify_tail(e, f, arity)? {
            // Base: fold the pending accumulator into the result.
            Tail::Base => Some(op_call(op, vec![var(acc), e.clone()])),
            // Direct tail call: thread the accumulator through unchanged.
            Tail::Direct(mut args) => {
                args.push(var(acc));
                Some(call(new_callee.clone(), args))
            }
            // The fold step: fold `other` into the accumulator *before*
            // recursing (associativity + commutativity; `other` is pure).
            Tail::Wrapped { op: o, mut recursive_args, other } => {
                if o != op {
                    return None;
                }
                recursive_args.push(op_call(op, vec![var(acc), other]));
                Some(call(new_callee.clone(), recursive_args))
            }
        },
    }
}

/// The entry copy of the original function: base and direct-tail arms are
/// kept verbatim (so dtype, base-case bits, and self-recursion through
/// the original name are untouched), and each op-wrapped arm hands off to
/// the accumulator function with the non-recursive operand as the seed.
/// Mirrors [`scan_tail`] like [`rewrite_tail`] does.
fn rewrite_entry(
    e: &E,
    f: &SelfRef,
    arity: usize,
    op: &str,
    new_callee: &E,
) -> Option<E> {
    match &**e {
        Expr::If { cond, then_, else_ } if occurs(e, f) => Some(Arc::new(Expr::If {
            cond: cond.clone(),
            then_: rewrite_entry(then_, f, arity, op, new_callee)?,
            else_: rewrite_entry(else_, f, arity, op, new_callee)?,
        })),
        Expr::Match { scrut, arms } if occurs(e, f) => {
            let arms = arms
                .iter()
                .map(|(p, a)| {
                    Some((p.clone(), rewrite_entry(a, f, arity, op, new_callee)?))
                })
                .collect::<Option<Vec<_>>>()?;
            Some(Arc::new(Expr::Match { scrut: scrut.clone(), arms }))
        }
        Expr::Let { var: s, ty, value, body }
            if !occurs(value, f) && classify_tail(e, f, arity).is_none() =>
        {
            Some(Arc::new(Expr::Let {
                var: s.clone(),
                ty: ty.clone(),
                value: value.clone(),
                body: rewrite_entry(body, f, arity, op, new_callee)?,
            }))
        }
        _ => match classify_tail(e, f, arity)? {
            // Base case and direct tail calls stay exactly as written:
            // the entry function recurses through the *original* name.
            Tail::Base | Tail::Direct(_) => Some(e.clone()),
            // First fold step: the non-recursive operand becomes the
            // initial accumulator — no identity constant involved.
            Tail::Wrapped { op: o, mut recursive_args, other } => {
                if o != op {
                    return None;
                }
                recursive_args.push(other);
                Some(call(new_callee.clone(), recursive_args))
            }
        },
    }
}

/// The pieces of one successful rewrite: the accumulator-passing function
/// and the entry copy that replaces the original under its name.
struct Rewritten {
    acc_fn: Function,
    wrapper: Function,
}

fn rewrite_function(fun: &Function, f: &SelfRef, new_callee: &E) -> Option<Rewritten> {
    let arity = fun.params.len();
    let (mut op, mut any_wrapped) = (None, false);
    if !scan_tail(&fun.body, f, arity, &mut op, &mut any_wrapped) || !any_wrapped {
        return None;
    }
    let op = op?;
    let acc = Var::fresh("acc");
    let new_body = rewrite_tail(&fun.body, f, arity, &op, new_callee, &acc)?;
    let mut acc_params = fun.params.clone();
    acc_params.push((acc, None));
    let acc_fn = Function {
        params: acc_params,
        ret: fun.ret.clone(),
        body: new_body,
        attrs: fun.attrs.clone(),
    };
    // Entry copy: alpha-refresh the whole function first so the two
    // copies of the body don't share binder ids, then rewrite only the
    // wrapped arms into accumulator handoffs.
    let refreshed = crate::ir::refresh(&Arc::new(Expr::Func(fun.clone())));
    let rf = match &*refreshed {
        Expr::Func(rf) => rf.clone(),
        _ => return None,
    };
    let entry_body = rewrite_entry(&rf.body, f, arity, &op, new_callee)?;
    let wrapper = Function {
        params: rf.params,
        ret: fun.ret.clone(),
        body: entry_body,
        attrs: fun.attrs.clone(),
    };
    Some(Rewritten { acc_fn, wrapper })
}

/// Rewrite every qualifying `let %f = fn ...` recursion inside `e`.
pub fn rewrite_expr(e: &E) -> E {
    crate::ir::rewrite_postorder(e, &mut |n| {
        let (fv, ty, fun, rest) = match &**n {
            Expr::Let { var: fv, ty, value, body } => match &**value {
                Expr::Func(fun) => (fv, ty, fun, body),
                _ => return None,
            },
            _ => return None,
        };
        let sr = SelfRef::Local(fv.clone());
        if !occurs(&fun.body, &sr) {
            return None;
        }
        let f_acc = Var::fresh(&format!("{}_acc", fv.name));
        let rw = rewrite_function(fun, &sr, &var(&f_acc))?;
        Some(Arc::new(Expr::Let {
            var: f_acc,
            ty: None,
            value: Arc::new(Expr::Func(rw.acc_fn)),
            body: Arc::new(Expr::Let {
                var: fv.clone(),
                ty: ty.clone(),
                value: Arc::new(Expr::Func(rw.wrapper)),
                body: rest.clone(),
            }),
        }))
    })
}

/// A definition name not already taken in `m`.
fn fresh_def_name(m: &Module, base: &str) -> String {
    let mut name = format!("{base}_acc");
    let mut i = 1;
    while m.defs.contains_key(&name) {
        name = format!("{base}_acc{i}");
        i += 1;
    }
    name
}

pub fn run(m: &Module) -> Module {
    // Let-bound recursion inside every definition body.
    let mut out = m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = rewrite_expr(&f.body);
        nf
    });
    // Self-recursive global definitions (TreeLSTM's `@sum_h` shape).
    let names: Vec<String> = out.defs.keys().cloned().collect();
    for name in names {
        let fun = out.defs[&name].clone();
        let sr = SelfRef::Global(name.clone());
        if !occurs(&fun.body, &sr) {
            continue;
        }
        let acc_name = fresh_def_name(&out, &name);
        if let Some(rw) = rewrite_function(&fun, &sr, &global(&acc_name)) {
            out.add_def(acc_name, rw.acc_fn);
            out.add_def(name, rw.wrapper);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_expr, eval_main};
    use crate::ir::{self, scalar, Pattern};

    /// `let %sum = fn (%l) { match %l { Cons(h,t) -> add(h, sum(t)),
    /// Nil -> 0f } }; %sum(list)` — the fold of the module docs.
    fn sum_fold(n: usize, anf_step: bool) -> E {
        let sum = Var::fresh("sum");
        let l = Var::fresh("l");
        let h = Var::fresh("h");
        let t = Var::fresh("t");
        let step = if anf_step {
            let s = Var::fresh("s");
            ir::let_(
                s.clone(),
                call(var(&sum), vec![var(&t)]),
                op_call("add", vec![var(&h), var(&s)]),
            )
        } else {
            op_call("add", vec![var(&h), call(var(&sum), vec![var(&t)])])
        };
        let body = ir::match_(
            var(&l),
            vec![
                (
                    Pattern::Ctor(
                        "Cons".into(),
                        vec![Pattern::Var(h.clone()), Pattern::Var(t.clone())],
                    ),
                    step,
                ),
                (Pattern::Ctor("Nil".into(), vec![]), scalar(0.0)),
            ],
        );
        let items: Vec<E> = (0..n).map(|i| scalar(i as f32 + 1.0)).collect();
        ir::let_(
            sum.clone(),
            ir::func(vec![(l, None)], body),
            call(var(&sum), vec![ir::list_expr(items)]),
        )
    }

    #[test]
    fn rewrites_list_sum_fold_and_preserves_the_value() {
        let m = Module::with_prelude();
        for anf_step in [false, true] {
            let e = sum_fold(6, anf_step);
            let before = eval_expr(&m, &e).unwrap();
            let rewritten = rewrite_expr(&e);
            let s = ir::print_expr(&rewritten);
            assert!(s.contains("sum_acc"), "not rewritten (anf={anf_step}): {s}");
            let after = eval_expr(&m, &rewritten).unwrap();
            // 1+2+..+6 in either association is exact in f32.
            assert_eq!(before.tensor().f32_value(), 21.0);
            assert!(before.bits_eq(&after), "anf={anf_step}");
        }
    }

    #[test]
    fn rewritten_fold_runs_in_constant_vm_depth() {
        let n = 300;
        let m = Module::with_prelude();
        let e = sum_fold(n, false);

        let p0 = crate::vm::compile_expr(&m, &e).unwrap();
        let vm0 = crate::vm::Vm::new(&p0);
        let v0 = vm0.run(vec![]).unwrap();
        assert!(vm0.max_depth.get() >= n, "baseline should recurse deep");

        let p1 = crate::vm::compile_expr(&m, &rewrite_expr(&e)).unwrap();
        let vm1 = crate::vm::Vm::new(&p1);
        let v1 = vm1.run(vec![]).unwrap();
        assert!(
            vm1.max_depth.get() <= 2,
            "accumulator loop still grew the frame stack: {}",
            vm1.max_depth.get()
        );
        assert_eq!(v0.tensor().f32_value(), v1.tensor().f32_value());
    }

    #[test]
    fn global_self_recursive_fold_is_rewritten() {
        // TreeLSTM's `@sum_h` shape: a global def recursing through
        // `Expr::Global`.
        let mut m = Module::with_prelude();
        let l = Var::fresh("l");
        let h = Var::fresh("h");
        let t = Var::fresh("t");
        let body = ir::match_(
            var(&l),
            vec![
                (
                    Pattern::Ctor(
                        "Cons".into(),
                        vec![Pattern::Var(h.clone()), Pattern::Var(t.clone())],
                    ),
                    op_call("add", vec![var(&h), call(global("sum"), vec![var(&t)])]),
                ),
                (Pattern::Ctor("Nil".into(), vec![]), scalar(0.0)),
            ],
        );
        m.add_def("sum", Function::new(vec![(l, None)], body));
        let items: Vec<E> = (0..5).map(|i| scalar(i as f32)).collect();
        m.add_def(
            "main",
            Function::new(
                vec![],
                call(global("sum"), vec![ir::list_expr(items)]),
            ),
        );

        let before = eval_main(&m, vec![]).unwrap();
        let out = run(&m);
        assert!(out.def("sum_acc").is_some(), "global fold not rewritten");
        // Wrapper keeps the public name and arity.
        assert_eq!(out.def("sum").unwrap().params.len(), 1);
        assert_eq!(out.def("sum_acc").unwrap().params.len(), 2);
        let after = eval_main(&out, vec![]).unwrap();
        assert_eq!(before.tensor().f32_value(), 10.0);
        assert!(before.bits_eq(&after));
    }

    #[test]
    fn non_associative_and_multi_recursive_folds_are_untouched() {
        let m = Module::with_prelude();
        // subtract is not a qualifying combine op.
        let e = ir::parse_expr(
            "let %f = fn (%i) {\n\
               if (greater(%i, 0f)) { subtract(%i, %f(subtract(%i, 1f))) }\n\
               else { 0f }\n\
             };\n\
             %f(4f)",
        )
        .unwrap();
        let r = rewrite_expr(&e);
        assert!(ir::alpha_eq(&e, &r), "subtract fold was rewritten");
        assert!(eval_expr(&m, &r).unwrap().bits_eq(&eval_expr(&m, &e).unwrap()));

        // Two recursive calls in one arm (tree shape) can't linearize.
        let e2 = ir::parse_expr(
            "let %g = fn (%i) {\n\
               if (greater(%i, 1f)) {\n\
                 add(%g(subtract(%i, 1f)), %g(subtract(%i, 2f)))\n\
               } else { %i }\n\
             };\n\
             %g(6f)",
        )
        .unwrap();
        let r2 = rewrite_expr(&e2);
        assert!(ir::alpha_eq(&e2, &r2), "two-call recursion was rewritten");
    }

    #[test]
    fn already_tail_recursive_loops_are_left_alone() {
        // No wrapped call: nothing to gain, VM TCO already flattens it.
        let e = ir::parse_expr(
            "let %loop = fn (%i, %acc) {\n\
               if (greater(%i, 0f)) {\n\
                 %loop(subtract(%i, 1f), add(%acc, %i))\n\
               } else { %acc }\n\
             };\n\
             %loop(5f, 0f)",
        )
        .unwrap();
        let r = rewrite_expr(&e);
        assert!(ir::alpha_eq(&e, &r));
    }

    #[test]
    fn escaping_function_values_disable_the_rewrite() {
        // %f is returned as a value from one arm: rewriting would change
        // the escaping closure's arity.
        let e = ir::parse_expr(
            "let %f = fn (%i) {\n\
               if (greater(%i, 0f)) { add(%i, %f(subtract(%i, 1f))) }\n\
               else { 0f }\n\
             };\n\
             (%f, %f(2f)).1",
        )
        .unwrap();
        // The fold itself qualifies; the escape is *outside* the function
        // body, where the wrapper keeps the original arity — so this MUST
        // still be rewritten and still evaluate correctly.
        let m = Module::with_prelude();
        let before = eval_expr(&m, &e).unwrap();
        let r = rewrite_expr(&e);
        let after = eval_expr(&m, &r).unwrap();
        assert!(before.bits_eq(&after));

        // But an escape in a *tail position of the body* disables it.
        let f = Var::fresh("f");
        let i = Var::fresh("i");
        let body = ir::if_(
            op_call("greater", vec![var(&i), scalar(0.0)]),
            op_call("add", vec![var(&i), call(var(&f), vec![scalar(0.0)])]),
            var(&f), // escapes
        );
        let e2 = ir::let_(
            f.clone(),
            ir::func(vec![(i, None)], body),
            call(var(&f), vec![scalar(1.0)]),
        );
        let r2 = rewrite_expr(&e2);
        assert!(ir::alpha_eq(&e2, &r2), "escaping body was rewritten");
    }

    #[test]
    fn multiply_folds_are_rewritten() {
        let m = Module::with_prelude();
        let e = ir::parse_expr(
            "let %fact = fn (%i) {\n\
               if (greater(%i, 0f)) { multiply(%i, %fact(subtract(%i, 1f))) }\n\
               else { 1f }\n\
             };\n\
             %fact(5f)",
        )
        .unwrap();
        let r = rewrite_expr(&e);
        assert!(ir::print_expr(&r).contains("fact_acc"), "{}", ir::print_expr(&r));
        let out = eval_expr(&m, &r).unwrap();
        assert_eq!(out.tensor().f32_value(), 120.0);
    }

    #[test]
    fn integer_folds_keep_their_dtype() {
        // Regression: the entry copy seeds the accumulator with the first
        // *element*, never an f32 identity constant — an i64 fold must
        // come out bit-identical and still I64 after the rewrite.
        use crate::tensor::{DType, Tensor};
        let m = Module::with_prelude();
        let f = Var::fresh("isum");
        let l = Var::fresh("l");
        let h = Var::fresh("h");
        let t = Var::fresh("t");
        let body = ir::match_(
            var(&l),
            vec![
                (
                    Pattern::Ctor(
                        "Cons".into(),
                        vec![Pattern::Var(h.clone()), Pattern::Var(t.clone())],
                    ),
                    op_call("add", vec![var(&h), call(var(&f), vec![var(&t)])]),
                ),
                (
                    Pattern::Ctor("Nil".into(), vec![]),
                    ir::constant(Tensor::zeros(&[1], DType::I64)),
                ),
            ],
        );
        let items: Vec<E> = (1..=4i64)
            .map(|i| ir::constant(Tensor::from_i64(vec![1], vec![i])))
            .collect();
        let e = ir::let_(
            f.clone(),
            ir::func(vec![(l, None)], body),
            call(var(&f), vec![ir::list_expr(items)]),
        );
        let before = eval_expr(&m, &e).unwrap();
        assert_eq!(before.tensor().dtype(), DType::I64);
        let r = rewrite_expr(&e);
        assert!(ir::print_expr(&r).contains("isum_acc"), "{}", ir::print_expr(&r));
        let after = eval_expr(&m, &r).unwrap();
        assert_eq!(after.tensor().dtype(), DType::I64, "dtype changed by rewrite");
        assert!(before.bits_eq(&after));
        assert_eq!(after.tensor().as_i64()[0], 10);
    }

    #[test]
    fn applies_inside_the_o2_pipeline() {
        let m = Module::from_expr(sum_fold(4, false));
        let opt = crate::pass::optimize(&m, crate::pass::OptLevel::O2, false).unwrap();
        let s = ir::print_expr(&opt.def("main").unwrap().body);
        assert!(s.contains("sum_acc"), "O2 pipeline skipped TailAccum: {s}");
        let v = eval_main(&opt, vec![]).unwrap();
        assert_eq!(v.tensor().f32_value(), 10.0);
    }
}
