//! Constant folding: operator calls on constant tensors are evaluated at
//! compile time with the interpreter (the -O2 tier of §5.2 — "using Relay's
//! interpreter to evaluate away operations on constants").

use crate::eval::value::Value;
use crate::ir::{constant, Expr, Module, E};
use crate::op;

pub fn fold_constants(e: &E) -> E {
    crate::ir::rewrite_postorder(e, &mut |n| match &**n {
        Expr::Call { f, args, attrs } => {
            let name = match &**f {
                Expr::Op(name) => name,
                _ => return None,
            };
            // Don't fold ops whose output should stay symbolic (constants
            // with shape attrs are fine to fold; barriers are not).
            if name == "copy" || name.starts_with("annotation.") {
                return None;
            }
            let consts: Option<Vec<Value>> = args
                .iter()
                .map(|a| match &**a {
                    Expr::Const(t) => Some(Value::Tensor(t.clone())),
                    _ => None,
                })
                .collect();
            let consts = consts?;
            let def = op::lookup(name)?;
            if let Some(ar) = def.arity {
                if consts.len() != ar {
                    return None;
                }
            }
            match (def.eval)(&consts, attrs) {
                Ok(Value::Tensor(t)) => Some(constant(t)),
                Ok(Value::Tuple(vs)) => {
                    let ts: Option<Vec<E>> = vs
                        .into_iter()
                        .map(|v| match v {
                            Value::Tensor(t) => Some(constant(t)),
                            _ => None,
                        })
                        .collect();
                    ts.map(crate::ir::tuple)
                }
                _ => None,
            }
        }
        // if on a constant guard folds to the taken branch.
        Expr::If { cond, then_, else_ } => match &**cond {
            Expr::Const(t) if t.dtype() == crate::tensor::DType::Bool => {
                Some(if t.bool_value() { then_.clone() } else { else_.clone() })
            }
            _ => None,
        },
        // Projection of a tuple literal.
        Expr::Proj(t, i) => match &**t {
            Expr::Tuple(es) => es.get(*i).cloned(),
            _ => None,
        },
        _ => None,
    })
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = fold_constants(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, print_expr};

    #[test]
    fn folds_scalar_arithmetic() {
        let e = parse_expr("add(multiply(2f, 3f), 4f)").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 10.0),
            other => panic!("not folded: {other:?}"),
        }
    }

    #[test]
    fn folds_constant_if() {
        let e = parse_expr("if (less(1f, 2f)) { 10f } else { 20f }").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 10.0),
            other => panic!("not folded: {other:?}"),
        }
    }

    #[test]
    fn leaves_variables_alone() {
        let e = parse_expr("fn (%x) { add(%x, add(1f, 2f)) }").unwrap();
        let f = fold_constants(&e);
        let s = print_expr(&f);
        assert!(s.contains("3f"), "{s}");
        assert!(s.contains("add(%x"), "{s}");
    }

    #[test]
    fn folds_tuple_projection() {
        let e = parse_expr("(1f, 2f).1").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 2.0),
            other => panic!("not folded: {other:?}"),
        }
    }
}
