//! Constant folding: operator calls on constant tensors are evaluated at
//! compile time with the interpreter (the -O2 tier of §5.2 — "using Relay's
//! interpreter to evaluate away operations on constants"), and `let`-bound
//! constants are propagated into their use sites (binding dropped), so a
//! chain `let a = 2; let b = f(a); g(b)` collapses to one constant in a
//! single application of the pass.

use crate::eval::value::Value;
use crate::ir::{constant, Expr, Module, E};
use crate::op;

/// Replace every use of var `id` by `value` (a constant — no capture or
/// effect concerns; binder ids are globally unique, so shadowing cannot
/// occur).
fn subst_const(body: &E, id: u32, value: &E) -> E {
    crate::ir::rewrite_postorder(body, &mut |n| match &**n {
        Expr::Var(v) if v.id == id => Some(value.clone()),
        _ => None,
    })
}

pub fn fold_constants(e: &E) -> E {
    crate::ir::rewrite_postorder(e, &mut |n| match &**n {
        // Propagate a let-bound constant into its use sites and drop the
        // binding (constants are pure, so elision is sound). The body is
        // re-folded after substitution: ops over the propagated constant
        // fold immediately, which cascades down let chains in one pass
        // instead of one chain link per fixpoint round.
        Expr::Let { var, value, body, .. } if matches!(&**value, Expr::Const(_)) => {
            Some(fold_constants(&subst_const(body, var.id, value)))
        }
        Expr::Call { f, args, attrs } => {
            let name = match &**f {
                Expr::Op(name) => name,
                _ => return None,
            };
            // Don't fold ops whose output should stay symbolic (constants
            // with shape attrs are fine to fold; barriers are not).
            if name == "copy" || name.starts_with("annotation.") {
                return None;
            }
            let consts: Option<Vec<Value>> = args
                .iter()
                .map(|a| match &**a {
                    Expr::Const(t) => Some(Value::Tensor(t.clone())),
                    _ => None,
                })
                .collect();
            let consts = consts?;
            let def = op::lookup(name)?;
            if let Some(ar) = def.arity {
                if consts.len() != ar {
                    return None;
                }
            }
            match (def.eval)(&consts, attrs) {
                Ok(Value::Tensor(t)) => Some(constant(t)),
                Ok(Value::Tuple(vs)) => {
                    let ts: Option<Vec<E>> = vs
                        .into_iter()
                        .map(|v| match v {
                            Value::Tensor(t) => Some(constant(t)),
                            _ => None,
                        })
                        .collect();
                    ts.map(crate::ir::tuple)
                }
                _ => None,
            }
        }
        // if on a constant guard folds to the taken branch.
        Expr::If { cond, then_, else_ } => match &**cond {
            Expr::Const(t) if t.dtype() == crate::tensor::DType::Bool => {
                Some(if t.bool_value() { then_.clone() } else { else_.clone() })
            }
            _ => None,
        },
        // Projection of a tuple literal.
        Expr::Proj(t, i) => match &**t {
            Expr::Tuple(es) => es.get(*i).cloned(),
            _ => None,
        },
        _ => None,
    })
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = fold_constants(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, print_expr};

    #[test]
    fn folds_scalar_arithmetic() {
        let e = parse_expr("add(multiply(2f, 3f), 4f)").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 10.0),
            other => panic!("not folded: {other:?}"),
        }
    }

    #[test]
    fn folds_constant_if() {
        let e = parse_expr("if (less(1f, 2f)) { 10f } else { 20f }").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 10.0),
            other => panic!("not folded: {other:?}"),
        }
    }

    #[test]
    fn leaves_variables_alone() {
        let e = parse_expr("fn (%x) { add(%x, add(1f, 2f)) }").unwrap();
        let f = fold_constants(&e);
        let s = print_expr(&f);
        assert!(s.contains("3f"), "{s}");
        assert!(s.contains("add(%x"), "{s}");
    }

    #[test]
    fn propagates_let_bound_constants_through_chains() {
        // A two-step chain collapses to ONE constant in a single pass
        // application (the ROADMAP follow-up: FoldConstant now
        // const-propagates through `let`).
        let e = parse_expr("let %a = 2f; let %b = add(%a, 3f); add(%b, %b)").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 10.0),
            other => panic!("chain not folded: {other:?}"),
        }
    }

    #[test]
    fn propagation_keeps_non_constant_bindings() {
        let e = parse_expr(
            "fn (%x) { let %a = 2f; let %b = add(%x, %a); add(%b, %b) }",
        )
        .unwrap();
        let f = fold_constants(&e);
        let s = print_expr(&f);
        // %a was propagated and dropped; %b depends on %x and stays bound.
        assert!(!s.contains("let %a"), "{s}");
        assert!(s.contains("add(%x"), "{s}");
        assert!(s.contains("2f)"), "{s}");
        assert!(s.contains("let %b"), "{s}");
    }

    #[test]
    fn let_chain_module_folds_to_a_single_constant_in_the_pipeline() {
        // The same property through the optimizing driver (FoldConstant
        // runs at -O2 and above): the chain disappears into one literal.
        let m = crate::ir::parse_module(
            "def @main(%x: Tensor[(), float32]) {\n\
               let %a = 2f;\n\
               let %b = multiply(%a, 3f);\n\
               add(%x, %b)\n\
             }",
        )
        .unwrap();
        let opt = crate::pass::optimize(&m, crate::pass::OptLevel::O2, false).unwrap();
        let s = print_expr(&opt.def("main").unwrap().body);
        assert!(!s.contains("multiply"), "chain op survived: {s}");
        assert!(s.contains("6f"), "folded constant missing: {s}");
    }

    #[test]
    fn folds_tuple_projection() {
        let e = parse_expr("(1f, 2f).1").unwrap();
        let f = fold_constants(&e);
        match &*f {
            Expr::Const(t) => assert_eq!(t.f32_value(), 2.0),
            other => panic!("not folded: {other:?}"),
        }
    }
}
