//! Forward-mode AD via dual numbers (§4.2: "we also implemented a
//! forward-mode AD algorithm using the traditional method of dual
//! numbers"). Every tensor value becomes a `(primal, tangent)` pair; no
//! references or backpropagators are needed, and the transform composes
//! with reverse mode (both produce ordinary Relay functions), enabling
//! e.g. Hessian-vector products for DARTS-style workloads.

use std::collections::BTreeMap;

use crate::ir::{self, func, op_call, proj, tuple, var, AttrValue, Expr, Var, E};

type R<T> = Result<T, String>;

/// `jvp(f)`: for `f : fn(x_1..x_n) -> y`, build
/// `fn(x_1..x_n, dx_1..dx_n) -> (y, dy)`.
pub fn jvp_expr(f: &E) -> R<E> {
    let function = match &**f {
        Expr::Func(fun) => fun.clone(),
        _ => return Err("jvp expects a function expression".into()),
    };
    let params: Vec<Var> = function.params.iter().map(|(p, _)| p.clone()).collect();
    let primals: Vec<Var> = params.iter().map(|p| Var::fresh(&p.name)).collect();
    let tangents: Vec<Var> = params.iter().map(|p| Var::fresh(format!("d{}", p.name))).collect();

    // Substitute each param with a dual tuple var.
    let duals: Vec<Var> = params.iter().map(|p| Var::fresh(format!("{}_dual", p.name))).collect();
    let mut sub = BTreeMap::new();
    for (p, d) in params.iter().zip(&duals) {
        sub.insert(p.clone(), var(d));
    }
    let body = ir::subst(&function.body, &sub);
    let tbody = dual_term(&body)?;

    let mut inner = tbody;
    for ((d, p), t) in duals.iter().zip(&primals).zip(&tangents).rev() {
        inner = ir::let_(d.clone(), tuple(vec![var(p), var(t)]), inner);
    }
    let all_params: Vec<(Var, Option<ir::Type>)> = primals
        .into_iter()
        .chain(tangents)
        .map(|p| (p, None))
        .collect();
    Ok(func(all_params, inner))
}

/// Structural dual-number transform.
fn dual_term(e: &E) -> R<E> {
    Ok(match &**e {
        Expr::Var(_) | Expr::Global(_) | Expr::Op(_) | Expr::Ctor(_) => e.clone(),
        Expr::Const(_) => tuple(vec![e.clone(), op_call("zeros_like", vec![e.clone()])]),
        Expr::Tuple(es) => {
            let ts: R<Vec<E>> = es.iter().map(dual_term).collect();
            tuple(ts?)
        }
        Expr::Proj(t, i) => proj(dual_term(t)?, *i),
        Expr::Let { var: v, value, body, .. } => {
            ir::let_(v.clone(), dual_term(value)?, dual_term(body)?)
        }
        Expr::Func(f) => {
            let params = f.params.iter().map(|(p, _)| (p.clone(), None)).collect();
            func(params, dual_term(&f.body)?)
        }
        Expr::If { cond, then_, else_ } => {
            ir::if_(proj(dual_term(cond)?, 0), dual_term(then_)?, dual_term(else_)?)
        }
        Expr::Match { scrut, arms } => {
            let s = dual_term(scrut)?;
            let arms: R<Vec<_>> = arms
                .iter()
                .map(|(p, a)| dual_term(a).map(|a| (p.clone(), a)))
                .collect();
            ir::match_(s, arms?)
        }
        Expr::RefNew(v) => ir::ref_new(dual_term(v)?),
        Expr::RefRead(r) => ir::ref_read(dual_term(r)?),
        Expr::RefWrite(r, v) => ir::ref_write(dual_term(r)?, dual_term(v)?),
        Expr::Grad(g) => {
            // Compose modes: expand reverse AD first, then dualize.
            let rev = super::ad::grad_expr(g)?;
            dual_term(&rev)?
        }
        Expr::Call { f, args, attrs } => match &**f {
            Expr::Op(name) => {
                let dargs: R<Vec<E>> = args.iter().map(dual_term).collect();
                let dargs = dargs?;
                // Bind each dual arg so primal/tangent can be used twice.
                let avars: Vec<Var> =
                    (0..dargs.len()).map(|i| Var::fresh(format!("fa{i}"))).collect();
                let prim: Vec<E> = avars.iter().map(|a| proj(var(a), 0)).collect();
                let tang: Vec<E> = avars.iter().map(|a| proj(var(a), 1)).collect();
                let primal = ir::call_attrs(ir::op(name), prim.clone(), attrs.clone());
                let pv = Var::fresh("pv");
                let tangent = fwd_rule(name, &prim, &tang, &var(&pv), attrs)?;
                let result = tuple(vec![var(&pv), tangent]);
                let mut out = ir::let_(pv, primal, result);
                for (a, d) in avars.into_iter().zip(dargs).rev() {
                    out = ir::let_(a, d, out);
                }
                out
            }
            Expr::Ctor(_) => {
                let dargs: R<Vec<E>> = args.iter().map(dual_term).collect();
                ir::call_attrs(f.clone(), dargs?, attrs.clone())
            }
            _ => {
                let df = dual_term(f)?;
                let dargs: R<Vec<E>> = args.iter().map(dual_term).collect();
                ir::call_attrs(df, dargs?, attrs.clone())
            }
        },
    })
}

/// Forward derivative rules: tangent of `op(prim...)` given tangents.
fn fwd_rule(name: &str, prim: &[E], tang: &[E], out: &E, attrs: &ir::Attrs) -> R<E> {
    let t = |i: usize| tang[i].clone();
    let p = |i: usize| prim[i].clone();
    Ok(match name {
        "add" => op_call("add", vec![t(0), t(1)]),
        "subtract" => op_call("subtract", vec![t(0), t(1)]),
        "multiply" => op_call(
            "add",
            vec![
                op_call("multiply", vec![t(0), p(1)]),
                op_call("multiply", vec![p(0), t(1)]),
            ],
        ),
        "divide" => {
            // (t0*y - x*t1) / y^2
            let num = op_call(
                "subtract",
                vec![
                    op_call("multiply", vec![t(0), p(1)]),
                    op_call("multiply", vec![p(0), t(1)]),
                ],
            );
            op_call("divide", vec![num, op_call("multiply", vec![p(1), p(1)])])
        }
        "negative" => op_call("negative", vec![t(0)]),
        "exp" => op_call("multiply", vec![t(0), out.clone()]),
        "log" => op_call("divide", vec![t(0), p(0)]),
        "sqrt" => op_call(
            "divide",
            vec![t(0), op_call("multiply", vec![ir::scalar(2.0), out.clone()])],
        ),
        "tanh" => op_call(
            "multiply",
            vec![
                t(0),
                op_call(
                    "subtract",
                    vec![ir::scalar(1.0), op_call("multiply", vec![out.clone(), out.clone()])],
                ),
            ],
        ),
        "sigmoid" => op_call(
            "multiply",
            vec![
                t(0),
                op_call(
                    "multiply",
                    vec![out.clone(), op_call("subtract", vec![ir::scalar(1.0), out.clone()])],
                ),
            ],
        ),
        "nn.relu" => op_call(
            "multiply",
            vec![
                t(0),
                ir::op_call_attrs(
                    "cast",
                    vec![op_call("greater", vec![p(0), ir::scalar(0.0)])],
                    ir::attrs(&[("dtype", AttrValue::Str("float32".into()))]),
                ),
            ],
        ),
        "matmul" => op_call(
            "add",
            vec![
                op_call("matmul", vec![t(0), p(1)]),
                op_call("matmul", vec![p(0), t(1)]),
            ],
        ),
        "nn.dense" => op_call(
            "add",
            vec![
                op_call("nn.dense", vec![t(0), p(1)]),
                op_call("nn.dense", vec![p(0), t(1)]),
            ],
        ),
        "sum" | "mean" | "reshape" | "transpose" | "nn.batch_flatten" => {
            ir::call_attrs(ir::op(name), vec![t(0)], attrs.clone())
        }
        "nn.bias_add" => ir::call_attrs(ir::op(name), vec![t(0), t(1)], attrs.clone()),
        // Linear shape ops: tangent follows the primal's second operand.
        "broadcast_to_like" | "collapse_sum_like" | "reshape_like" => {
            ir::call_attrs(ir::op(name), vec![t(0), p(1)], attrs.clone())
        }
        "mean_count_like" | "zeros_like" | "ones_like" => {
            op_call("zeros_like", vec![out.clone()])
        }
        // Non-differentiable (comparisons etc.): zero tangent.
        _ => op_call("zeros_like", vec![out.clone()]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_expr;
    use crate::ir::{parse_expr, Module};

    fn jvp_scalar(src: &str, x: f32, dx: f32) -> (f32, f32) {
        let m = Module::with_prelude();
        let f = parse_expr(src).unwrap();
        let j = jvp_expr(&f).unwrap();
        let call = ir::call(j, vec![ir::scalar(x), ir::scalar(dx)]);
        let out = eval_expr(&m, &call).unwrap();
        (
            out.tuple()[0].tensor().f32_value(),
            out.tuple()[1].tensor().f32_value(),
        )
    }

    #[test]
    fn jvp_of_square() {
        let (y, dy) = jvp_scalar("fn (%x) { multiply(%x, %x) }", 3.0, 1.0);
        assert_eq!(y, 9.0);
        assert_eq!(dy, 6.0);
    }

    #[test]
    fn jvp_direction_scales() {
        let (_, dy) = jvp_scalar("fn (%x) { multiply(%x, %x) }", 3.0, 2.0);
        assert_eq!(dy, 12.0);
    }

    #[test]
    fn jvp_of_tanh_chain() {
        let (_, dy) = jvp_scalar("fn (%x) { tanh(multiply(2f, %x)) }", 0.5, 1.0);
        let t = 1.0f32.tanh();
        assert!((dy - 2.0 * (1.0 - t * t)).abs() < 1e-5);
    }

    #[test]
    fn jvp_through_control_flow() {
        let src = "fn (%x) { if (greater(%x, 0f)) { multiply(%x, %x) } else { negative(%x) } }";
        let (_, d1) = jvp_scalar(src, 2.0, 1.0);
        assert_eq!(d1, 4.0);
        let (_, d2) = jvp_scalar(src, -3.0, 1.0);
        assert_eq!(d2, -1.0);
    }

    #[test]
    fn forward_over_reverse_second_order() {
        // h(x) = d/dx (x^3) = 3x^2 via reverse; jvp of h gives 6x.
        let m = Module::with_prelude();
        let f = parse_expr("fn (%x) { multiply(%x, multiply(%x, %x)) }").unwrap();
        let rev = crate::pass::ad::grad_expr(&f).unwrap();
        // wrap: fn(y) { rev(y).1.0 }
        let y = Var::fresh("y");
        let h = func(
            vec![(y.clone(), None)],
            proj(proj(ir::call(rev, vec![var(&y)]), 1), 0),
        );
        let j = jvp_expr(&h).unwrap();
        let out = eval_expr(&m, &ir::call(j, vec![ir::scalar(2.0), ir::scalar(1.0)])).unwrap();
        let second = out.tuple()[1].tensor().f32_value();
        assert!((second - 12.0).abs() < 1e-4, "got {second}");
    }
}
