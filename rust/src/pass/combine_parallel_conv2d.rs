//! CombineParallelConv2d (§4.6): fuse sibling convolutions that share an
//! input (Inception-style blocks) into one wider convolution plus a split,
//! reducing kernel-launch count.
//!
//! Pattern (over let chains): several `let %ci = nn.conv2d(%x, Wi)` with
//! identical attrs and kernel HW, constant weights -> one
//! `nn.conv2d(%x, concat(Wi))` followed by `split`, with each `%ci`
//! replaced by the corresponding tuple projection.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::ir::{
    constant, map_children, op_call_attrs, proj, AttrValue, Expr, Module, Var, E,
};
use crate::tensor::Tensor;

pub fn combine_parallel_conv2d(e: &E) -> E {
    match &**e {
        Expr::Let { .. } => rewrite_chain(e),
        _ => map_children(e, |c| combine_parallel_conv2d(c)),
    }
}

struct ConvBinding {
    var: Var,
    weight: Tensor,
    attrs: crate::ir::Attrs,
}

fn rewrite_chain(e: &E) -> E {
    // Collect the let chain.
    let mut bindings: Vec<(Var, Option<crate::ir::Type>, E)> = Vec::new();
    let mut cur = e.clone();
    loop {
        match &*cur.clone() {
            Expr::Let { var, ty, value, body } => {
                bindings.push((var.clone(), ty.clone(), value.clone()));
                cur = body.clone();
            }
            _ => break,
        }
    }
    let tail = cur;

    // Group conv bindings by (input var, attrs, kernel hw).
    let mut groups: BTreeMap<(u32, String, usize, usize), Vec<ConvBinding>> = BTreeMap::new();
    for (var, _, value) in &bindings {
        if let Expr::Call { f, args, attrs } = &**value {
            if matches!(&**f, Expr::Op(n) if n == "nn.conv2d") {
                if let (Expr::Var(x), Expr::Const(w)) = (&*args[0], &*args[1]) {
                    if w.shape().len() == 4 {
                        let key = (
                            x.id,
                            format!("{attrs:?}"),
                            w.shape()[2],
                            w.shape()[3],
                        );
                        groups.entry(key).or_default().push(ConvBinding {
                            var: var.clone(),
                            weight: w.clone(),
                            attrs: attrs.clone(),
                        });
                    }
                }
            }
        }
    }

    // For each group of >= 2, build the combined conv + split.
    let mut replace: BTreeMap<u32, E> = BTreeMap::new(); // var id -> replacement expr
    let mut emitted: Vec<(Var, E)> = Vec::new();
    for ((xid, _, _, _), convs) in groups {
        if convs.len() < 2 {
            continue;
        }
        // channel counts must match on the input side; output channels must
        // be equal for an even split (keep it simple: require equal O).
        let o0 = convs[0].weight.shape()[0];
        if !convs.iter().all(|c| c.weight.shape()[0] == o0)
            || !convs
                .iter()
                .all(|c| c.weight.shape()[1..] == convs[0].weight.shape()[1..])
        {
            continue;
        }
        let big_w = crate::tensor::concat(
            &convs.iter().map(|c| c.weight.clone()).collect::<Vec<_>>(),
            0,
        );
        let xvar = Var { name: "x".into(), id: xid };
        let combined_var = Var::fresh("combined_conv");
        let combined = op_call_attrs(
            "nn.conv2d",
            vec![crate::ir::var(&xvar), constant(big_w)],
            convs[0].attrs.clone(),
        );
        let split_var = Var::fresh("split");
        let split = op_call_attrs(
            "split",
            vec![crate::ir::var(&combined_var)],
            crate::ir::attrs(&[
                ("indices_or_sections", AttrValue::Int(convs.len() as i64)),
                ("axis", AttrValue::Int(1)),
            ]),
        );
        emitted.push((combined_var, combined));
        for (i, c) in convs.iter().enumerate() {
            replace.insert(c.var.id, proj(crate::ir::var(&split_var), i));
        }
        emitted.push((split_var, split));
    }

    if replace.is_empty() {
        // Nothing to do at this level; recurse into values and tail.
        let mut out = map_children(&tail, |c| combine_parallel_conv2d(c));
        if !matches!(&*tail, Expr::Let { .. }) {
            out = combine_parallel_conv2d(&tail);
        }
        return bindings.into_iter().rev().fold(out, |acc, (v, ty, val)| {
            Arc::new(Expr::Let {
                var: v,
                ty,
                value: combine_parallel_conv2d(&val),
                body: acc,
            })
        });
    }

    // Rebuild: emit combined bindings at the position of the first replaced
    // conv; replaced convs become projections.
    let mut out = combine_parallel_conv2d(&tail);
    let mut emitted_done = false;
    for (v, ty, val) in bindings.into_iter().rev() {
        if let Some(repl) = replace.get(&v.id) {
            out = Arc::new(Expr::Let { var: v, ty, value: repl.clone(), body: out });
            continue;
        }
        out = Arc::new(Expr::Let {
            var: v,
            ty,
            value: combine_parallel_conv2d(&val),
            body: out,
        });
        let _ = emitted_done;
    }
    // Prepend combined conv + split bindings at the front (their only input
    // is %x, bound further out).
    for (v, val) in emitted.into_iter().rev() {
        out = Arc::new(Expr::Let { var: v, ty: None, value: val, body: out });
    }
    out
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = combine_parallel_conv2d(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_expr, Value};
    use crate::ir::{self, print_expr};
    use crate::tensor::Rng;

    #[test]
    fn inception_like_block_combined() {
        let mut rng = Rng::new(3);
        let w1 = rng.normal_tensor(&[4, 2, 3, 3], 0.5);
        let w2 = rng.normal_tensor(&[4, 2, 3, 3], 0.5);
        let x = Var::fresh("x");
        // let %c1 = conv(x, w1); let %c2 = conv(x, w2); (c1, c2)
        let attrs = ir::attrs(&[("padding", AttrValue::Int(1))]);
        let body = ir::let_(
            Var::fresh("c1_outer"),
            ir::unit(),
            ir::unit(),
        );
        let _ = body;
        let c1 = Var::fresh("c1");
        let c2 = Var::fresh("c2");
        let e = ir::let_(
            c1.clone(),
            ir::op_call_attrs(
                "nn.conv2d",
                vec![ir::var(&x), ir::constant(w1.clone())],
                attrs.clone(),
            ),
            ir::let_(
                c2.clone(),
                ir::op_call_attrs(
                    "nn.conv2d",
                    vec![ir::var(&x), ir::constant(w2.clone())],
                    attrs.clone(),
                ),
                ir::tuple(vec![ir::var(&c1), ir::var(&c2)]),
            ),
        );
        let f = ir::func(vec![(x.clone(), None)], e);

        let combined = combine_parallel_conv2d(&f);
        let s = print_expr(&combined);
        assert_eq!(s.matches("nn.conv2d").count(), 1, "{s}");
        assert!(s.contains("split"), "{s}");

        // Numerics: run both on a random input.
        let m = ir::Module::with_prelude();
        let input = rng.normal_tensor(&[1, 2, 5, 5], 1.0);
        let run = |fe: &E| -> Vec<Value> {
            let call = ir::call(fe.clone(), vec![ir::constant(input.clone())]);
            eval_expr(&m, &call).unwrap().tuple().to_vec()
        };
        let before = run(&f);
        let after = run(&combined);
        for (b, a) in before.iter().zip(&after) {
            assert!(b.tensor().allclose(a.tensor(), 1e-4, 1e-4));
        }
    }

    #[test]
    fn different_kernels_not_combined() {
        let mut rng = Rng::new(4);
        let w1 = rng.normal_tensor(&[4, 2, 3, 3], 0.5);
        let w2 = rng.normal_tensor(&[4, 2, 1, 1], 0.5);
        let x = Var::fresh("x");
        let c1 = Var::fresh("c1");
        let c2 = Var::fresh("c2");
        let e = ir::let_(
            c1.clone(),
            ir::op_call("nn.conv2d", vec![ir::var(&x), ir::constant(w1)]),
            ir::let_(
                c2.clone(),
                ir::op_call("nn.conv2d", vec![ir::var(&x), ir::constant(w2)]),
                ir::tuple(vec![ir::var(&c1), ir::var(&c2)]),
            ),
        );
        let f = ir::func(vec![(x, None)], e);
        let out = combine_parallel_conv2d(&f);
        assert_eq!(print_expr(&out).matches("nn.conv2d").count(), 2);
    }
}
