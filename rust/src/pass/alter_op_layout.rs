//! AlterOpLayout (§5.2 -O3 item 2): change the data layout / implementation
//! of convolutions for better cache behaviour.
//!
//! The paper's TVM backend switches conv2d to blocked NCHWc layouts; on
//! this substrate the equivalent locality win is conv-as-GEMM: rewrite
//! `nn.conv2d(x, W)` into `im2col(x) @ W_matrix` so the inner loops run
//! through the cache-blocked matmul kernel instead of the direct
//! convolution's strided accesses. Weights are reshaped at compile time
//! (constant-folded away for constant weights).

use crate::ir::{op_call_attrs, rewrite_postorder, AttrValue, Expr, Module, E};
use crate::ty::TypeReport;

/// Rewrite conv2d calls whose input/weight shapes are known in `report`.
pub fn alter_op_layout(e: &E, report: &TypeReport) -> E {
    rewrite_postorder(e, &mut |n| {
        let (f, args, attrs) = match &**n {
            Expr::Call { f, args, attrs } => (f, args, attrs),
            _ => return None,
        };
        if !matches!(&**f, Expr::Op(name) if name == "nn.conv2d") {
            return None;
        }
        let groups = attrs.get("groups").map(|v| v.as_int()).unwrap_or(1);
        if groups != 1 {
            return None; // grouped convs keep the direct kernel
        }
        // Need static shapes for both operands.
        let x_shape = report.type_of(&args[0]).and_then(|t| t.concrete_shape());
        let w_shape = match &*args[1] {
            Expr::Const(t) => Some(t.shape().to_vec()),
            _ => report.type_of(&args[1]).and_then(|t| t.concrete_shape()),
        };
        let (x_shape, w_shape) = match (x_shape, w_shape) {
            (Some(a), Some(b)) => (a, b),
            _ => return None,
        };
        let (n_, o, c, kh, kw) = (x_shape[0], w_shape[0], w_shape[1], w_shape[2], w_shape[3]);
        let p = conv_params(attrs);
        let (oh, ow) = crate::tensor::conv2d_out_hw(x_shape[2], x_shape[3], kh, kw, &p);

        // patches: (N*OH*OW, C*KH*KW)
        let mut im2col_attrs = attrs.clone();
        im2col_attrs.insert(
            "kernel_size".into(),
            AttrValue::IntVec(vec![kh as i64, kw as i64]),
        );
        let patches = op_call_attrs("nn.im2col", vec![args[0].clone()], im2col_attrs);
        // weight matrix: (O, C*KH*KW) -> transpose -> (C*KH*KW, O)
        let wmat = op_call_attrs(
            "reshape",
            vec![args[1].clone()],
            crate::ir::attrs(&[(
                "newshape",
                AttrValue::IntVec(vec![o as i64, (c * kh * kw) as i64]),
            )]),
        );
        let wt = crate::ir::op_call("transpose", vec![wmat]);
        let gemm = crate::ir::op_call("matmul", vec![patches, wt]);
        // (N*OH*OW, O) -> (N, OH, OW, O) -> (N, O, OH, OW)
        let r = op_call_attrs(
            "reshape",
            vec![gemm],
            crate::ir::attrs(&[(
                "newshape",
                AttrValue::IntVec(vec![n_ as i64, oh as i64, ow as i64, o as i64]),
            )]),
        );
        Some(op_call_attrs(
            "transpose",
            vec![r],
            crate::ir::attrs(&[("axes", AttrValue::IntVec(vec![0, 3, 1, 2]))]),
        ))
    })
}

fn conv_params(attrs: &crate::ir::Attrs) -> crate::tensor::Conv2dParams {
    let stride = attrs
        .get("strides")
        .map(|v| {
            let s = v.as_int_vec();
            (s[0] as usize, s[1] as usize)
        })
        .unwrap_or((1, 1));
    let padding = attrs
        .get("padding")
        .map(|v| match v {
            AttrValue::Int(p) => (*p as usize, *p as usize),
            AttrValue::IntVec(p) => (p[0] as usize, p[1] as usize),
            _ => (0, 0),
        })
        .unwrap_or((0, 0));
    crate::tensor::Conv2dParams { stride, padding, groups: 1 }
}

/// Module-level driver: type-checks first (the pass needs shapes), then
/// rewrites every def. Rewriting a conv invalidates the address-keyed type
/// report for its consumers, so we iterate typecheck+rewrite to fixpoint —
/// each round converts at least the earliest remaining conv.
///
/// A module that does not type-check is returned *unchanged* rather than
/// failing the pipeline: this pass is a shape-directed optimization, and
/// now that every executor path routes through the -O3 driver by default
/// (control-flow/ADT programs included), "no shape info" must mean "keep
/// the direct conv kernels", not "refuse to run the program".
pub fn run(m: &Module) -> Result<Module, String> {
    run_traced(m).map(|(m, _)| m)
}

/// [`run`], also reporting whether the pass *degraded* to identity because
/// the checker could not finish on the module. The pass manager records
/// the flag on its [`crate::pass::PassRecord`] so `relay dump-passes`
/// prints the skip. The checker's error taxonomy decides the outcome:
/// [`TypeErrorKind::Unsupported`](crate::ty::TypeErrorKind) (e.g.
/// under-constrained inference over an unannotated recursive model) means
/// "no shape info — keep the direct conv kernels", while an `IllTyped`
/// verdict is a genuine bug in the program that degrading would mask, so
/// it fails the pipeline instead.
pub fn run_traced(m: &Module) -> Result<(Module, bool), String> {
    let mut cur = m.clone();
    for _ in 0..64 {
        let report = match crate::ty::check_module(&cur) {
            Ok(r) => r,
            // Checker gave up (not a verdict): roll back to the input
            // module and flag the skip.
            Err(e) if e.kind() == crate::ty::TypeErrorKind::Unsupported => {
                return Ok((m.clone(), true))
            }
            // Provably ill-typed: surface it, don't silently degrade.
            Err(e) => return Err(e.to_string()),
        };
        let next = cur.map_defs(|_, f| {
            let mut nf = f.clone();
            nf.body = alter_op_layout(&f.body, &report);
            nf
        });
        let changed = next.defs.iter().any(|(name, f)| {
            cur.def(name)
                .map(|old| !crate::ir::alpha_eq(
                    &std::sync::Arc::new(crate::ir::Expr::Func(old.clone())),
                    &std::sync::Arc::new(crate::ir::Expr::Func(f.clone())),
                ))
                .unwrap_or(true)
        });
        cur = next;
        if !changed {
            break;
        }
    }
    Ok((cur, false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_expr;
    use crate::ir::{self, print_expr};
    use crate::tensor::{Rng, Tensor};

    #[test]
    fn conv_becomes_gemm_and_matches() {
        let mut rng = Rng::new(7);
        let x = rng.normal_tensor(&[2, 3, 8, 8], 1.0);
        let w = rng.normal_tensor(&[4, 3, 3, 3], 0.5);
        let e = ir::op_call_attrs(
            "nn.conv2d",
            vec![ir::constant(x), ir::constant(w)],
            ir::attrs(&[
                ("padding", AttrValue::Int(1)),
                ("strides", AttrValue::IntVec(vec![1, 1])),
            ]),
        );
        let m = ir::Module::with_prelude();
        let before = eval_expr(&m, &e).unwrap();

        let report = crate::ty::infer_expr(&m, &e).unwrap().0;
        let altered = alter_op_layout(&e, &report);
        let s = print_expr(&altered);
        assert!(s.contains("im2col"), "{s}");
        assert!(s.contains("matmul"), "{s}");
        assert!(!s.contains("nn.conv2d"), "{s}");

        let after = eval_expr(&m, &altered).unwrap();
        assert_eq!(after.tensor().shape(), before.tensor().shape());
        assert!(
            before.tensor().allclose(after.tensor(), 1e-3, 1e-3),
            "max diff {}",
            before.tensor().max_abs_diff(after.tensor())
        );
    }

    #[test]
    fn strided_conv_matches() {
        let mut rng = Rng::new(8);
        let x = rng.normal_tensor(&[1, 2, 9, 9], 1.0);
        let w = rng.normal_tensor(&[5, 2, 3, 3], 0.5);
        let e = ir::op_call_attrs(
            "nn.conv2d",
            vec![ir::constant(x), ir::constant(w)],
            ir::attrs(&[
                ("padding", AttrValue::Int(0)),
                ("strides", AttrValue::IntVec(vec![2, 2])),
            ]),
        );
        let m = ir::Module::with_prelude();
        let before = eval_expr(&m, &e).unwrap();
        let report = crate::ty::infer_expr(&m, &e).unwrap().0;
        let after = eval_expr(&m, &alter_op_layout(&e, &report)).unwrap();
        assert!(before.tensor().allclose(after.tensor(), 1e-3, 1e-3));
    }

    #[test]
    fn underconstrained_module_degrades_to_identity() {
        // No annotations anywhere: inference is under-constrained, the
        // checker reports Unsupported, and the pass skips (degraded=true).
        let m = ir::parse_module("def @main(%x) { nn.dense(%x, %x) }").unwrap();
        let (out, degraded) = run_traced(&m).unwrap();
        assert!(degraded);
        assert!(print_expr(&out.def("main").unwrap().body).contains("nn.dense"));
    }

    #[test]
    fn ill_typed_module_fails_instead_of_degrading() {
        // A provable shape mismatch must surface as an error, not be
        // masked by the degrade path.
        let m = ir::parse_module(
            "def @main(%x: Tensor[(4, 8), float32], %w: Tensor[(16, 9), float32]) {\n\
               nn.dense(%x, %w) }",
        )
        .unwrap();
        let err = run_traced(&m).unwrap_err();
        assert!(err.contains("dense"), "{err}");
    }

    #[test]
    fn any_batch_conv_keeps_direct_kernel() {
        // Batch-polymorphic conv: the type checks fine, but conv-as-GEMM
        // needs a concrete batch to size its reshape, so the rewrite is
        // skipped (not degraded — the rest of the module still optimizes).
        let m = ir::parse_module(
            "def @main(%x: Tensor[(?, 3, 8, 8), float32], %w: Tensor[(4, 3, 3, 3), float32]) {\n\
               nn.conv2d(%x, %w, padding=1) }",
        )
        .unwrap();
        let (out, degraded) = run_traced(&m).unwrap();
        assert!(!degraded);
        assert!(print_expr(&out.def("main").unwrap().body).contains("nn.conv2d"));
    }

    #[test]
    fn grouped_conv_untouched() {
        let e = ir::op_call_attrs(
            "nn.conv2d",
            vec![
                ir::constant(Tensor::ones(&[1, 2, 4, 4], crate::tensor::DType::F32)),
                ir::constant(Tensor::ones(&[2, 1, 1, 1], crate::tensor::DType::F32)),
            ],
            ir::attrs(&[("groups", AttrValue::Int(2))]),
        );
        let m = ir::Module::with_prelude();
        let report = crate::ty::infer_expr(&m, &e).unwrap().0;
        let out = alter_op_layout(&e, &report);
        assert!(print_expr(&out).contains("nn.conv2d"));
    }
}
