//! Pass manager (§3.1.2): sequences Relay-to-Relay passes, re-running type
//! inference between passes to reject malformed output and repopulate
//! shape information. Defines the -O0..-O3 tiers measured in Fig. 10.

use crate::ir::Module;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    O0,
    O1,
    O2,
    O3,
}

impl OptLevel {
    pub fn parse(s: &str) -> Option<OptLevel> {
        Some(match s {
            "O0" | "0" => OptLevel::O0,
            "O1" | "1" => OptLevel::O1,
            "O2" | "2" => OptLevel::O2,
            "O3" | "3" => OptLevel::O3,
            _ => return None,
        })
    }

    pub fn all() -> [OptLevel; 4] {
        [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3]
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            OptLevel::O0 => "-O0",
            OptLevel::O1 => "-O1",
            OptLevel::O2 => "-O2",
            OptLevel::O3 => "-O3",
        };
        write!(f, "{s}")
    }
}

/// A named module-to-module pass.
pub struct Pass {
    pub name: &'static str,
    pub run: fn(&Module) -> Result<Module, String>,
}

/// The pass pipeline for an optimization level (§5.2):
/// * -O0: none
/// * -O1: operator fusion
/// * -O2: + constant folding
/// * -O3: + FoldScaleAxis, AlterOpLayout, CanonicalizeOps, CSE
pub fn passes(level: OptLevel) -> Vec<Pass> {
    let mut v: Vec<Pass> = Vec::new();
    // Inlining runs at every level >= O1 so fusion sees whole chains.
    if level >= OptLevel::O1 {
        v.push(Pass { name: "Inline", run: |m| Ok(super::inline::run(m)) });
    }
    if level >= OptLevel::O3 {
        v.push(Pass {
            name: "CanonicalizeOps",
            run: |m| Ok(super::canonicalize::run(m)),
        });
        v.push(Pass {
            name: "FoldScaleAxis",
            run: |m| Ok(super::fold_scale_axis::run(m)),
        });
        v.push(Pass {
            name: "CombineParallelConv2d",
            run: |m| Ok(super::combine_parallel_conv2d::run(m)),
        });
    }
    if level >= OptLevel::O2 {
        v.push(Pass { name: "FoldConstant", run: |m| Ok(super::fold_constant::run(m)) });
    }
    if level >= OptLevel::O3 {
        v.push(Pass { name: "AlterOpLayout", run: super::alter_op_layout::run });
        v.push(Pass { name: "FoldConstant2", run: |m| Ok(super::fold_constant::run(m)) });
        v.push(Pass { name: "ToANF", run: |m| Ok(super::anf::run(m)) });
        v.push(Pass { name: "CommonSubexprElim", run: |m| Ok(super::cse::run(m)) });
        v.push(Pass { name: "DeadCodeElim", run: |m| Ok(super::dce::run(m)) });
    }
    if level >= OptLevel::O1 {
        v.push(Pass { name: "FuseOps", run: |m| Ok(super::fusion::run(m)) });
    }
    v
}

/// Run the pipeline for `level`, type checking between passes
/// ("Between each pass, Relay performs type inference and checking").
pub fn optimize(m: &Module, level: OptLevel, typecheck: bool) -> Result<Module, String> {
    let mut cur = m.clone();
    for pass in passes(level) {
        cur = (pass.run)(&cur).map_err(|e| format!("pass {}: {e}", pass.name))?;
        if typecheck {
            crate::ty::check_module(&cur)
                .map_err(|e| format!("after pass {}: {e}", pass.name))?;
        }
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_main, Value};
    use crate::ir::parse_module;
    use crate::tensor::{Rng, Tensor};

    fn mlp_module() -> Module {
        parse_module(
            "def @main(%x: Tensor[(2, 4), float32]) {\n\
               let %w1 = ones(shape=[8, 4]);\n\
               let %h = nn.relu(nn.dense(%x, %w1));\n\
               let %w2 = ones(shape=[2, 8]);\n\
               nn.dense(%h, %w2)\n\
             }",
        )
        .unwrap()
    }

    #[test]
    fn tiers_are_ordered() {
        assert!(OptLevel::O0 < OptLevel::O3);
        assert_eq!(OptLevel::parse("2"), Some(OptLevel::O2));
        assert!(passes(OptLevel::O0).is_empty());
        assert!(passes(OptLevel::O3).len() > passes(OptLevel::O1).len());
    }

    #[test]
    fn optimize_preserves_semantics_all_levels() {
        let m = mlp_module();
        let mut rng = Rng::new(5);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let reference = eval_main(&m, vec![Value::Tensor(x.clone())]).unwrap();
        for level in OptLevel::all() {
            let opt = optimize(&m, level, true).unwrap();
            let out = eval_main(&opt, vec![Value::Tensor(x.clone())]).unwrap();
            assert!(
                reference.tensor().allclose(out.tensor(), 1e-3, 1e-3),
                "level {level} diverged"
            );
        }
    }

    #[test]
    fn o2_folds_weight_constants() {
        // zeros/ones with const-foldable shapes become literal tensors.
        let m = mlp_module();
        let opt = optimize(&m, OptLevel::O2, true).unwrap();
        let s = crate::ir::print_expr(&opt.def("main").unwrap().body);
        assert!(!s.contains("ones("), "{s}");
        let _ = Tensor::scalar_f32(0.0);
    }
}
