//! Pass manager (§3.1.2): sequences Relay-to-Relay passes, re-running type
//! inference between passes to reject malformed output and repopulate
//! shape information. Defines the -O0..-O3 tiers measured in Fig. 10.
//!
//! This is the *one* optimizing driver of the compilation pipeline: every
//! execution path — `eval::run_auto`, the process-wide `ProgramCache`, the
//! serving fleet, and the CLI — routes through [`optimize_traced`] (via
//! `eval::CompileOptions`) before executor lowering. The driver is
//! instrumented: each pass records wall time and the IR node-count delta
//! into a [`PassTrace`], surfaced by `relay dump-passes` and attached to
//! `eval::Execution`.

use std::time::{Duration, Instant};

use crate::ir::Module;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OptLevel {
    O0,
    O1,
    O2,
    O3,
}

impl OptLevel {
    /// Parse a level from any of the spellings users type at a CLI:
    /// `"2"`, `"O2"`, `"o2"`, `"-O2"`, `"-o2"`.
    pub fn parse(s: &str) -> Option<OptLevel> {
        let t = s.strip_prefix('-').unwrap_or(s);
        let t = t
            .strip_prefix('O')
            .or_else(|| t.strip_prefix('o'))
            .unwrap_or(t);
        Some(match t {
            "0" => OptLevel::O0,
            "1" => OptLevel::O1,
            "2" => OptLevel::O2,
            "3" => OptLevel::O3,
            _ => return None,
        })
    }

    pub fn all() -> [OptLevel; 4] {
        [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3]
    }

    /// The bare digit, as a static string — the `level` label value on
    /// `relay_degraded_executions_total` and the `compile_fallback` span
    /// annotation (label values want no `-O` punctuation).
    pub fn digit(self) -> &'static str {
        match self {
            OptLevel::O0 => "0",
            OptLevel::O1 => "1",
            OptLevel::O2 => "2",
            OptLevel::O3 => "3",
        }
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            OptLevel::O0 => "-O0",
            OptLevel::O1 => "-O1",
            OptLevel::O2 => "-O2",
            OptLevel::O3 => "-O3",
        };
        write!(f, "{s}")
    }
}

/// What one pass application produced: the rewritten module plus whether
/// the pass *degraded* — skipped its rewrite and returned the module
/// unchanged because a precondition failed (today: `AlterOpLayout` on a
/// module the type checker cannot type). Degrading keeps the pipeline
/// running on programs the checker doesn't cover (ADTs, closures), but the
/// skip is recorded on the [`PassRecord`] so `relay dump-passes` surfaces
/// it instead of silently masking a genuine type error.
pub struct PassResult {
    pub module: Module,
    pub degraded: bool,
}

impl From<Module> for PassResult {
    fn from(module: Module) -> PassResult {
        PassResult { module, degraded: false }
    }
}

/// A named module-to-module pass.
pub struct Pass {
    pub name: &'static str,
    pub run: fn(&Module) -> Result<PassResult, String>,
    /// Eligible for the driver's optional fixpoint loop
    /// ([`PipelineConfig::fixpoint`]): cleanup passes (constant folding,
    /// DCE) where one application can expose work for the next.
    pub fixpoint: bool,
}

/// The pass pipeline for an optimization level (§5.2):
/// * -O0: none
/// * -O1: operator fusion
/// * -O2: + constant folding, accumulator-passing tail-recursion rewrite
/// * -O3: + FoldScaleAxis, AlterOpLayout, CanonicalizeOps, CSE, DCE
pub fn passes(level: OptLevel) -> Vec<Pass> {
    let mut v: Vec<Pass> = Vec::new();
    let pass = |name: &'static str,
                run: fn(&Module) -> Result<PassResult, String>|
     -> Pass { Pass { name, run, fixpoint: false } };
    // Inlining runs at every level >= O1 so fusion sees whole chains.
    if level >= OptLevel::O1 {
        v.push(pass("Inline", |m| Ok(super::inline::run(m).into())));
    }
    if level >= OptLevel::O3 {
        v.push(pass("CanonicalizeOps", |m| Ok(super::canonicalize::run(m).into())));
        v.push(pass("FoldScaleAxis", |m| Ok(super::fold_scale_axis::run(m).into())));
        v.push(pass("CombineParallelConv2d", |m| {
            Ok(super::combine_parallel_conv2d::run(m).into())
        }));
    }
    if level >= OptLevel::O2 {
        v.push(Pass {
            name: "FoldConstant",
            run: |m| Ok(super::fold_constant::run(m).into()),
            fixpoint: true,
        });
        // Runs after folding so constant list spines / trip counts are
        // already literal, before ANF obscures the recursive call shape.
        v.push(pass("TailAccum", |m| Ok(super::tail_accum::run(m).into())));
    }
    if level >= OptLevel::O3 {
        v.push(pass("AlterOpLayout", |m| {
            super::alter_op_layout::run_traced(m)
                .map(|(module, degraded)| PassResult { module, degraded })
        }));
        // A second folding round cleans up the weight reshapes/transposes
        // AlterOpLayout introduced (formerly named `FoldConstant2`).
        v.push(Pass {
            name: "FoldConstantPostLayout",
            run: |m| Ok(super::fold_constant::run(m).into()),
            fixpoint: true,
        });
        v.push(pass("ToANF", |m| Ok(super::anf::run(m).into())));
        v.push(pass("CommonSubexprElim", |m| Ok(super::cse::run(m).into())));
        v.push(Pass {
            name: "DeadCodeElim",
            run: |m| Ok(super::dce::run(m).into()),
            fixpoint: true,
        });
    }
    if level >= OptLevel::O1 {
        // Tile-schedule selection runs on the final op graph, before
        // fusion wraps call sites in fused closures: one tuning decision
        // per statically-shaped (op, shape), registered for the tiled
        // kernels and snapshotted into the program-cache entry.
        v.push(pass("TuneKernels", |m| Ok(super::tune_kernels::run(m).into())));
        v.push(pass("FuseOps", |m| Ok(super::fusion::run(m).into())));
    }
    v
}

/// How the driver should run the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    pub level: OptLevel,
    /// Re-run type inference after every pass ("Between each pass, Relay
    /// performs type inference and checking").
    pub typecheck: bool,
    /// Re-apply fixpoint-eligible passes (FoldConstant, DeadCodeElim)
    /// until the module stops changing, bounded by
    /// [`MAX_FIXPOINT_ROUNDS`].
    pub fixpoint: bool,
}

impl PipelineConfig {
    pub fn new(level: OptLevel) -> PipelineConfig {
        PipelineConfig { level, typecheck: false, fixpoint: false }
    }
}

/// Bound on per-pass fixpoint iteration — folding/DCE converge in one or
/// two rounds in practice; the cap keeps a pathological rewrite cycle from
/// hanging the driver.
pub const MAX_FIXPOINT_ROUNDS: usize = 8;

/// One pass application as the instrumented driver saw it.
#[derive(Clone, Debug)]
pub struct PassRecord {
    pub name: &'static str,
    pub wall: Duration,
    pub nodes_before: usize,
    pub nodes_after: usize,
    /// Applications of the pass (1 unless [`PipelineConfig::fixpoint`]
    /// re-ran it to convergence).
    pub rounds: usize,
    /// The pass skipped its rewrite because a precondition failed (e.g.
    /// `AlterOpLayout` on an untypeable module) — surfaced by
    /// `relay dump-passes` so the skip is never silent.
    pub degraded: bool,
}

/// What the optimizing driver did to a module: one record per pass, plus
/// pipeline totals. Produced by [`optimize_traced`], cached alongside the
/// compiled program, and surfaced by `relay dump-passes` /
/// `eval::Execution::pass_trace`.
#[derive(Clone, Debug)]
pub struct PassTrace {
    pub level: OptLevel,
    pub passes: Vec<PassRecord>,
    pub total_wall: Duration,
    pub nodes_before: usize,
    pub nodes_after: usize,
    /// `Some(requested)` when the degradation ladder served this compile
    /// at a *lower* tier than the caller asked for (`level` is then the
    /// tier that actually ran). `None` on the ordinary happy path.
    pub degraded_from: Option<OptLevel>,
}

impl PassTrace {
    /// The trace of running no passes (the -O0 pipeline, or an executor
    /// tier that bypasses compilation).
    pub fn empty(level: OptLevel) -> PassTrace {
        PassTrace {
            level,
            passes: Vec::new(),
            total_wall: Duration::ZERO,
            nodes_before: 0,
            nodes_after: 0,
            degraded_from: None,
        }
    }

    /// IR nodes removed by the whole pipeline (negative if it grew).
    pub fn nodes_delta(&self) -> i64 {
        self.nodes_after as i64 - self.nodes_before as i64
    }

    /// Render the per-pass table `relay dump-passes` prints.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<24} {:>10} {:>8} {:>8} {:>7} {:>7}",
            "pass", "wall ms", "nodes", "after", "delta", "rounds"
        );
        for r in &self.passes {
            let _ = writeln!(
                out,
                "{:<24} {:>10.3} {:>8} {:>8} {:>+7} {:>7}{}",
                r.name,
                r.wall.as_secs_f64() * 1e3,
                r.nodes_before,
                r.nodes_after,
                r.nodes_after as i64 - r.nodes_before as i64,
                r.rounds,
                if r.degraded { "  DEGRADED" } else { "" },
            );
        }
        let _ = writeln!(
            out,
            "{:<24} {:>10.3} {:>8} {:>8} {:>+7} {:>7}",
            format!("total ({})", self.level),
            self.total_wall.as_secs_f64() * 1e3,
            self.nodes_before,
            self.nodes_after,
            self.nodes_delta(),
            // The rounds column doesn't total meaningfully.
            "",
        );
        if let Some(from) = self.degraded_from {
            let _ = writeln!(
                out,
                "note: degraded from {from} — the requested tier failed to \
                 compile and the ladder fell back to {}",
                self.level
            );
        }
        for r in &self.passes {
            if r.degraded {
                let _ = writeln!(
                    out,
                    "note: {} degraded to identity (module precondition failed, \
                     e.g. not typeable) — rewrite skipped, program unchanged",
                    r.name
                );
            }
        }
        out
    }
}

/// Total IR nodes across every definition body — the size metric the
/// driver reports per pass.
pub fn module_node_count(m: &Module) -> usize {
    m.defs.values().map(|f| crate::ir::count_nodes(&f.body)).sum()
}

/// Run the pipeline under an explicit [`PipelineConfig`], recording a
/// [`PassTrace`]. This is the single optimizing driver every compile path
/// goes through (`eval::cache::compile_for`, the CLI, the benches).
pub fn optimize_with(
    m: &Module,
    cfg: &PipelineConfig,
) -> Result<(Module, PassTrace), String> {
    let t0 = Instant::now();
    let nodes_before = module_node_count(m);
    let mut cur = m.clone();
    let mut records: Vec<PassRecord> = Vec::new();
    for pass in passes(cfg.level) {
        let pass_nodes_before = module_node_count(&cur);
        let started = Instant::now();
        let mut rounds = 0usize;
        let mut degraded = false;
        loop {
            rounds += 1;
            let result =
                (pass.run)(&cur).map_err(|e| format!("pass {}: {e}", pass.name))?;
            degraded |= result.degraded;
            let next = result.module;
            if !(cfg.fixpoint && pass.fixpoint) || rounds >= MAX_FIXPOINT_ROUNDS {
                cur = next;
                break;
            }
            // Fixpoint mode: re-run the pass until the (alpha-invariant)
            // module hash stops moving.
            let stable = crate::ir::module_structural_hash(&next)
                == crate::ir::module_structural_hash(&cur);
            cur = next;
            if stable {
                break;
            }
        }
        if cfg.typecheck {
            crate::ty::check_module(&cur)
                .map_err(|e| format!("after pass {}: {e}", pass.name))?;
        }
        records.push(PassRecord {
            name: pass.name,
            wall: started.elapsed(),
            nodes_before: pass_nodes_before,
            nodes_after: module_node_count(&cur),
            rounds,
            degraded,
        });
    }
    let trace = PassTrace {
        level: cfg.level,
        total_wall: t0.elapsed(),
        nodes_before,
        nodes_after: module_node_count(&cur),
        passes: records,
        degraded_from: None,
    };
    Ok((cur, trace))
}

/// [`optimize_with`] at the given level (no fixpoint), returning the
/// optimized module together with its [`PassTrace`].
pub fn optimize_traced(
    m: &Module,
    level: OptLevel,
    typecheck: bool,
) -> Result<(Module, PassTrace), String> {
    optimize_with(m, &PipelineConfig { level, typecheck, fixpoint: false })
}

/// Run the pipeline for `level`, type checking between passes when asked.
pub fn optimize(m: &Module, level: OptLevel, typecheck: bool) -> Result<Module, String> {
    optimize_traced(m, level, typecheck).map(|(m, _)| m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_main, Value};
    use crate::ir::parse_module;
    use crate::tensor::{Rng, Tensor};

    fn mlp_module() -> Module {
        parse_module(
            "def @main(%x: Tensor[(2, 4), float32]) {\n\
               let %w1 = ones(shape=[8, 4]);\n\
               let %h = nn.relu(nn.dense(%x, %w1));\n\
               let %w2 = ones(shape=[2, 8]);\n\
               nn.dense(%h, %w2)\n\
             }",
        )
        .unwrap()
    }

    #[test]
    fn tiers_are_ordered() {
        assert!(OptLevel::O0 < OptLevel::O3);
        assert_eq!(OptLevel::parse("2"), Some(OptLevel::O2));
        assert!(passes(OptLevel::O0).is_empty());
        assert!(passes(OptLevel::O3).len() > passes(OptLevel::O1).len());
    }

    #[test]
    fn parse_accepts_cli_spellings() {
        for s in ["O2", "o2", "-O2", "-o2", "2"] {
            assert_eq!(OptLevel::parse(s), Some(OptLevel::O2), "{s}");
        }
        assert_eq!(OptLevel::parse("O4"), None);
        assert_eq!(OptLevel::parse(""), None);
        assert_eq!(OptLevel::parse("fast"), None);
    }

    #[test]
    fn fold_constant_post_layout_replaced_the_old_name() {
        let names: Vec<&str> = passes(OptLevel::O3).iter().map(|p| p.name).collect();
        assert!(names.contains(&"FoldConstantPostLayout"), "{names:?}");
        assert!(!names.contains(&"FoldConstant2"), "{names:?}");
        assert!(names.contains(&"TailAccum"), "{names:?}");
    }

    #[test]
    fn optimize_preserves_semantics_all_levels() {
        let m = mlp_module();
        let mut rng = Rng::new(5);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let reference = eval_main(&m, vec![Value::Tensor(x.clone())]).unwrap();
        for level in OptLevel::all() {
            let opt = optimize(&m, level, true).unwrap();
            let out = eval_main(&opt, vec![Value::Tensor(x.clone())]).unwrap();
            assert!(
                reference.tensor().allclose(out.tensor(), 1e-3, 1e-3),
                "level {level} diverged"
            );
        }
    }

    #[test]
    fn o2_folds_weight_constants() {
        // zeros/ones with const-foldable shapes become literal tensors.
        let m = mlp_module();
        let opt = optimize(&m, OptLevel::O2, true).unwrap();
        let s = crate::ir::print_expr(&opt.def("main").unwrap().body);
        assert!(!s.contains("ones("), "{s}");
        let _ = Tensor::scalar_f32(0.0);
    }

    #[test]
    fn trace_records_every_pass_with_node_counts() {
        let m = mlp_module();
        let (opt, trace) = optimize_traced(&m, OptLevel::O3, false).unwrap();
        assert_eq!(trace.level, OptLevel::O3);
        assert_eq!(trace.passes.len(), passes(OptLevel::O3).len());
        assert_eq!(trace.nodes_after, module_node_count(&opt));
        // Records chain: each pass starts where the previous ended.
        for w in trace.passes.windows(2) {
            assert_eq!(w[0].nodes_after, w[1].nodes_before);
        }
        assert_eq!(trace.passes[0].nodes_before, module_node_count(&m));
        // The rendered table mentions every pass and the total line.
        let table = trace.render();
        for p in &trace.passes {
            assert!(table.contains(p.name), "{table}");
        }
        assert!(table.contains("total (-O3)"), "{table}");
        // O0 is the empty pipeline.
        let (_, t0) = optimize_traced(&m, OptLevel::O0, false).unwrap();
        assert!(t0.passes.is_empty());
    }

    #[test]
    fn alter_op_layout_degrade_is_recorded_and_rendered() {
        // An ADT program the type checker cannot type: AlterOpLayout
        // degrades to identity, and the skip is visible on the record and
        // in the rendered table (the PR 4 follow-up about masked type
        // errors).
        let m = parse_module(
            "def @main(%l) { match (%l) { | Cons(%h, %t) -> %h | Nil -> 0f } }",
        )
        .unwrap();
        let (_, trace) = optimize_traced(&m, OptLevel::O3, false).unwrap();
        let rec = trace
            .passes
            .iter()
            .find(|r| r.name == "AlterOpLayout")
            .expect("AlterOpLayout record");
        assert!(rec.degraded, "skip not recorded");
        let table = trace.render();
        assert!(table.contains("DEGRADED"), "{table}");
        assert!(table.contains("degraded to identity"), "{table}");
        // A typeable module is not flagged, and its table has no note.
        let (_, ok) = optimize_traced(&mlp_module(), OptLevel::O3, false).unwrap();
        assert!(!ok.passes.iter().any(|r| r.degraded));
        assert!(!ok.render().contains("DEGRADED"));
    }

    #[test]
    fn degraded_from_is_rendered_and_digit_labels_are_bare() {
        for (level, digit) in OptLevel::all().iter().zip(["0", "1", "2", "3"]) {
            assert_eq!(level.digit(), digit);
        }
        let mut t = PassTrace::empty(OptLevel::O1);
        assert!(t.degraded_from.is_none());
        assert!(!t.render().contains("degraded from"));
        t.degraded_from = Some(OptLevel::O3);
        let table = t.render();
        assert!(table.contains("degraded from -O3"), "{table}");
        assert!(table.contains("fell back to -O1"), "{table}");
    }

    #[test]
    fn fixpoint_rounds_are_recorded_and_bounded() {
        let m = mlp_module();
        let cfg = PipelineConfig {
            level: OptLevel::O2,
            typecheck: false,
            fixpoint: true,
        };
        let (with_fix, trace) = optimize_with(&m, &cfg).unwrap();
        let fold = trace
            .passes
            .iter()
            .find(|r| r.name == "FoldConstant")
            .expect("FoldConstant record");
        assert!(
            (1..=MAX_FIXPOINT_ROUNDS).contains(&fold.rounds),
            "rounds {}",
            fold.rounds
        );
        // Non-fixpoint passes always run exactly once.
        let fuse = trace.passes.iter().find(|r| r.name == "FuseOps").unwrap();
        assert_eq!(fuse.rounds, 1);
        // Fixpoint must not change what the single-round pipeline already
        // converged to on this module.
        let plain = optimize(&m, OptLevel::O2, false).unwrap();
        assert_eq!(
            crate::ir::module_structural_hash(&with_fix),
            crate::ir::module_structural_hash(&plain)
        );
    }
}
