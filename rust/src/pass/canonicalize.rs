//! CanonicalizeOps (§5.2 -O3 item 3): rewrite `nn.bias_add` into
//! `add` with explicit dimension expansion, exposing it to the broadcast
//! machinery and further analysis (fusion, FoldScaleAxis).

use crate::ir::{op_call, op_call_attrs, rewrite_postorder, AttrValue, Expr, Module, E};

pub fn canonicalize(e: &E) -> E {
    rewrite_postorder(e, &mut |n| match &**n {
        Expr::Call { f, args, attrs } => {
            match &**f {
                Expr::Op(name) if name == "nn.bias_add" => {
                    let axis = attrs.get("axis").map(|v| v.as_int()).unwrap_or(1);
                    // axis=1 over a 4-d operand needs (C,1,1); for the 2-d
                    // case plain broadcasting suffices. We expand twice when
                    // the bias feeds a conv output (axis 1 of NCHW); the
                    // expansion is harmless for 2-d because (1, n) still
                    // broadcasts. axis=-1 is already broadcast-aligned.
                    let bias = args[1].clone();
                    let expanded = if axis == 1 {
                        // (C,) -> (C,1,1): broadcasts against both
                        // (N,C,H,W) and... for (m,n) 2-d inputs axis=1 is
                        // the last axis, handled below.
                        op_call_attrs(
                            "expand_dims",
                            vec![op_call_attrs(
                                "expand_dims",
                                vec![bias],
                                crate::ir::attrs(&[("axis", AttrValue::Int(-1))]),
                            )],
                            crate::ir::attrs(&[("axis", AttrValue::Int(-1))]),
                        )
                    } else {
                        bias
                    };
                    Some(op_call("add", vec![args[0].clone(), expanded]))
                }
                _ => None,
            }
        }
        _ => None,
    })
}

/// 2-d variant: when the producer is `nn.dense`, bias is over the last
/// axis and no expansion is needed. `canonicalize_dense_bias` handles the
/// pattern `nn.bias_add(dense(...), b)` before the general rule fires.
pub fn canonicalize_dense_bias(e: &E) -> E {
    rewrite_postorder(e, &mut |n| match &**n {
        Expr::Call { f, args, attrs } => match &**f {
            Expr::Op(name)
                if name == "nn.bias_add"
                    && attrs.get("axis").map(|v| v.as_int()).unwrap_or(1) == 1
                    && is_dense_like(&args[0]) =>
            {
                Some(op_call("add", vec![args[0].clone(), args[1].clone()]))
            }
            _ => None,
        },
        _ => None,
    })
}

fn is_dense_like(e: &E) -> bool {
    match &**e {
        Expr::Call { f, .. } => {
            matches!(&**f, Expr::Op(n) if n == "nn.dense" || n == "matmul" || n == "nn.batch_flatten")
        }
        _ => false,
    }
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = canonicalize(&canonicalize_dense_bias(&f.body));
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_expr;
    use crate::ir::{parse_expr, print_expr, Module};

    #[test]
    fn bias_add_becomes_add() {
        let e = parse_expr(
            "fn (%x: Tensor[(1, 2, 2, 2), float32], %b: Tensor[(2), float32]) {\n\
               nn.bias_add(%x, %b, axis=1)\n\
             }",
        )
        .unwrap();
        let out = canonicalize(&e);
        let s = print_expr(&out);
        assert!(!s.contains("bias_add"), "{s}");
        assert!(s.contains("expand_dims"), "{s}");
    }

    #[test]
    fn semantics_preserved_4d() {
        let m = Module::with_prelude();
        let src = "nn.bias_add(reshape(multiply(1f, 1f), newshape=[1,1,1,1]), reshape(2f, newshape=[1]), axis=1)";
        let e = parse_expr(src).unwrap();
        let before = eval_expr(&m, &e).unwrap();
        let after = eval_expr(&m, &canonicalize(&e)).unwrap();
        assert_eq!(before.tensor().as_f32(), after.tensor().as_f32());
    }

    #[test]
    fn dense_bias_uses_plain_add() {
        let e = parse_expr(
            "fn (%x: Tensor[(4, 8), float32], %w: Tensor[(16, 8), float32], %b: Tensor[(16), float32]) {\n\
               nn.bias_add(nn.dense(%x, %w), %b)\n\
             }",
        )
        .unwrap();
        let out = canonicalize_dense_bias(&e);
        let s = print_expr(&out);
        assert!(!s.contains("bias_add"), "{s}");
        assert!(!s.contains("expand_dims"), "{s}");
    }
}
