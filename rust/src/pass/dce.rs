//! Dead-code elimination: remove pure let bindings whose variable is never
//! used. Run after AD + PE to crunch away the bindings the partial
//! evaluator conservatively kept (paper Fig. 5's post-DCE step).

use std::collections::BTreeSet;

use super::purity::is_pure;
use crate::ir::{free_vars, map_children, Expr, Module, Var, E};

pub fn dce(e: &E) -> E {
    // Iterate to fixpoint: removing one binding can make another dead.
    let mut cur = e.clone();
    loop {
        let next = dce_once(&cur);
        if std::sync::Arc::ptr_eq(&next, &cur) || crate::ir::alpha_eq(&next, &cur) {
            return next;
        }
        cur = next;
    }
}

fn dce_once(e: &E) -> E {
    match &**e {
        Expr::Let { var, ty, value, body } => {
            let value = dce_once(value);
            let body = dce_once(body);
            let used: BTreeSet<Var> = free_vars(&body);
            if !used.contains(var) && is_pure(&value) {
                body
            } else {
                std::sync::Arc::new(Expr::Let {
                    var: var.clone(),
                    ty: ty.clone(),
                    value,
                    body,
                })
            }
        }
        _ => map_children(e, |c| dce_once(c)),
    }
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = dce(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{parse_expr, print_expr};

    #[test]
    fn removes_unused_pure_binding() {
        let e = parse_expr("let %x = add(1f, 2f); 5f").unwrap();
        let out = dce(&e);
        assert!(!print_expr(&out).contains("let"), "{}", print_expr(&out));
    }

    #[test]
    fn keeps_used_binding() {
        let e = parse_expr("let %x = add(1f, 2f); %x").unwrap();
        let out = dce(&e);
        assert!(print_expr(&out).contains("let"));
    }

    #[test]
    fn keeps_impure_binding() {
        let e = parse_expr("let %r = ref(1f); let %_ = %r := 2f; 5f").unwrap();
        let out = dce(&e);
        let s = print_expr(&out);
        assert!(s.contains("ref("), "{s}");
        assert!(s.contains(":="), "{s}");
    }

    #[test]
    fn cascading_removal() {
        // y depends on x; both dead.
        let e = parse_expr("let %x = 1f; let %y = add(%x, 1f); 7f").unwrap();
        let out = dce(&e);
        assert!(!print_expr(&out).contains("let"), "{}", print_expr(&out));
    }

    #[test]
    fn removes_inside_functions() {
        let e = parse_expr("fn (%a) { let %dead = multiply(%a, 2f); %a }").unwrap();
        let out = dce(&e);
        assert!(!print_expr(&out).contains("dead"), "{}", print_expr(&out));
    }
}
