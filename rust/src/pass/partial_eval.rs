//! Partial evaluation (§4.3 + appendix): an interpreter whose value domain
//! is *partially static* values — each carries an optional static part and
//! a dynamic (residual) atom. The store is reified and threaded through
//! evaluation for flow-sensitive handling of references; output stays in
//! ANF so effects remain correctly ordered. Unknown code (dynamic calls,
//! dynamic branches) contaminates the store, which is then cleared.
//!
//! PE's primary client is the AD pass: it evaluates away the references
//! and backpropagator closures AD introduces (Fig. 5's AD -> PE -> DCE
//! pipeline), leaving first-order code that fusion can chew on.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::eval::value::Value;
use crate::ir::{
    let_, var, Expr, Function, Module, Pattern, Var, E,
};
use crate::op;
use crate::tensor::Tensor;

type PEnv = BTreeMap<u32, PValue>;

/// Static part of a partially-static value (the appendix's `sValue`).
#[derive(Clone)]
enum SValue {
    Tensor(Tensor),
    Tuple(Vec<PValue>),
    /// Non-recursive closure evaluated at PE time.
    Fun { params: Vec<Var>, body: E, env: PEnv },
    Ref(u64),
    Adt { ctor: String, fields: Vec<PValue> },
}

/// The appendix's `pValue`: optional static part + residual atom.
#[derive(Clone)]
struct PValue {
    stat: Option<SValue>,
    dynv: E,
}

fn dynamic(e: E) -> PValue {
    PValue { stat: None, dynv: e }
}

fn stat(s: SValue, e: E) -> PValue {
    PValue { stat: Some(s), dynv: e }
}

struct Pe<'m> {
    module: &'m Module,
    bindings: Vec<(Var, E)>,
    store: BTreeMap<u64, PValue>,
    next_store: u64,
    /// Remaining static function applications (prevents divergence on
    /// recursive programs — beyond the fuel, calls residualize).
    fuel: u32,
}

type R<T> = Result<T, String>;

impl<'m> Pe<'m> {
    fn new(module: &'m Module) -> Pe<'m> {
        Pe { module, bindings: Vec::new(), store: BTreeMap::new(), next_store: 0, fuel: 512 }
    }

    /// Emit a residual binding, returning an atom.
    fn push(&mut self, e: E) -> E {
        if e.is_atomic() {
            return e;
        }
        let v = Var::fresh("p");
        self.bindings.push((v.clone(), e));
        var(&v)
    }

    fn wrap(&mut self, from: usize, body: E) -> E {
        let tail = self.bindings.split_off(from);
        tail.into_iter().rev().fold(body, |acc, (v, val)| let_(v, val, acc))
    }

    fn clear_store(&mut self) {
        self.store.clear();
    }

    fn peval(&mut self, e: &E, env: &PEnv) -> R<PValue> {
        match &**e {
            Expr::Var(v) => env
                .get(&v.id)
                .cloned()
                .ok_or_else(|| format!("PE: unbound {v}")),
            Expr::Global(_) => Ok(dynamic(e.clone())),
            Expr::Const(t) => Ok(stat(SValue::Tensor(t.clone()), e.clone())),
            Expr::Op(_) => Ok(dynamic(e.clone())),
            Expr::Ctor(name) => {
                match self.module.ctor_info(name) {
                    Some((_, fields)) if fields.is_empty() => Ok(stat(
                        SValue::Adt { ctor: name.clone(), fields: vec![] },
                        e.clone(),
                    )),
                    _ => Ok(dynamic(e.clone())),
                }
            }
            Expr::Tuple(es) => {
                let ps: R<Vec<PValue>> = es.iter().map(|x| self.peval(x, env)).collect();
                let ps = ps?;
                let d = self.push(Arc::new(Expr::Tuple(
                    ps.iter().map(|p| p.dynv.clone()).collect(),
                )));
                Ok(stat(SValue::Tuple(ps), d))
            }
            Expr::Proj(t, i) => {
                let pt = self.peval(t, env)?;
                match &pt.stat {
                    Some(SValue::Tuple(ps)) =>

                        ps.get(*i).cloned().ok_or_else(|| format!("PE: .{i} range")),
                    _ => {
                        let d = self.push(Arc::new(Expr::Proj(pt.dynv.clone(), *i)));
                        Ok(dynamic(d))
                    }
                }
            }
            Expr::Let { var: v, value, body, .. } => {
                // Recursive function lets stay dynamic (see fuel note).
                let recursive = matches!(&**value, Expr::Func(_))
                    && crate::ir::free_vars(value).contains(v);
                let pv = if recursive {
                    // Self-reference stays dynamic inside the body.
                    let mut env_rec = env.clone();
                    env_rec.insert(v.id, dynamic(var(v)));
                    let resid = self.residualize_fn(value, &env_rec)?;
                    let d = self.push_named(v, resid);
                    dynamic(d)
                } else {
                    let p = self.peval(value, env)?;
                    // Name the binding for readability of residual code.
                    PValue { stat: p.stat, dynv: self.push_named(v, p.dynv) }
                };
                let mut env2 = env.clone();
                env2.insert(v.id, pv);
                self.peval(body, &env2)
            }
            Expr::Func(f) => {
                let resid = self.residualize_fn(e, env)?;
                let d = self.push(resid);
                Ok(stat(
                    SValue::Fun {
                        params: f.params.iter().map(|(p, _)| p.clone()).collect(),
                        body: f.body.clone(),
                        env: env.clone(),
                    },
                    d,
                ))
            }
            Expr::If { cond, then_, else_ } => {
                let pc = self.peval(cond, env)?;
                match &pc.stat {
                    Some(SValue::Tensor(t)) if t.dtype() == crate::tensor::DType::Bool => {
                        if t.bool_value() {
                            self.peval(then_, env)
                        } else {
                            self.peval(else_, env)
                        }
                    }
                    _ => {
                        // Dynamic branch: PE each side in its own scope with
                        // a copy of the store, then contaminate.
                        let saved = self.store.clone();
                        let from_t = self.bindings.len();
                        let tv = self.peval(then_, env)?;
                        let tbody = self.wrap(from_t, tv.dynv);
                        self.store = saved.clone();
                        let from_e = self.bindings.len();
                        let ev = self.peval(else_, env)?;
                        let ebody = self.wrap(from_e, ev.dynv);
                        self.store = saved;
                        self.clear_store();
                        let d = self.push(Arc::new(Expr::If {
                            cond: pc.dynv.clone(),
                            then_: tbody,
                            else_: ebody,
                        }));
                        Ok(dynamic(d))
                    }
                }
            }
            Expr::Match { scrut, arms } => {
                let ps = self.peval(scrut, env)?;
                if let Some(SValue::Adt { ctor, fields }) = &ps.stat {
                    for (p, a) in arms {
                        let mut env2 = env.clone();
                        if match_static(p, ctor, fields, &ps, &mut env2) {
                            return self.peval(a, &env2);
                        }
                    }
                    return Err("PE: non-exhaustive static match".into());
                }
                // Dynamic scrutinee.
                let mut new_arms = Vec::new();
                let saved = self.store.clone();
                for (p, a) in arms {
                    let mut env2 = env.clone();
                    for bv in p.bound_vars() {
                        env2.insert(bv.id, dynamic(var(&bv)));
                    }
                    self.store = saved.clone();
                    let from = self.bindings.len();
                    let av = self.peval(a, &env2)?;
                    let abody = self.wrap(from, av.dynv);
                    new_arms.push((p.clone(), abody));
                }
                self.store = saved;
                self.clear_store();
                let d = self.push(Arc::new(Expr::Match {
                    scrut: ps.dynv.clone(),
                    arms: new_arms,
                }));
                Ok(dynamic(d))
            }
            Expr::Grad(f) => {
                // Expand AD then partially evaluate the result: the Fig. 5
                // pipeline happens transparently.
                let g = super::ad::grad_expr(f)?;
                self.peval(&g, env)
            }
            Expr::RefNew(v) => {
                let pv = self.peval(v, env)?;
                let id = self.next_store;
                self.next_store += 1;
                self.store.insert(id, pv.clone());
                let d = self.push(Arc::new(Expr::RefNew(pv.dynv.clone())));
                Ok(stat(SValue::Ref(id), d))
            }
            Expr::RefRead(r) => {
                let pr = self.peval(r, env)?;
                if let Some(SValue::Ref(id)) = &pr.stat {
                    if let Some(v) = self.store.get(id) {
                        return Ok(v.clone());
                    }
                }
                let d = self.push(Arc::new(Expr::RefRead(pr.dynv.clone())));
                Ok(dynamic(d))
            }
            Expr::RefWrite(r, v) => {
                let pr = self.peval(r, env)?;
                let pv = self.peval(v, env)?;
                self.push(Arc::new(Expr::RefWrite(pr.dynv.clone(), pv.dynv.clone())));
                match &pr.stat {
                    Some(SValue::Ref(id)) => {
                        self.store.insert(*id, pv);
                    }
                    _ => self.clear_store(),
                }
                Ok(stat(SValue::Tuple(vec![]), crate::ir::unit()))
            }
            Expr::Call { f, args, attrs } => {
                let pargs: R<Vec<PValue>> =
                    args.iter().map(|a| self.peval(a, env)).collect();
                let pargs = pargs?;
                match &**f {
                    Expr::Op(name) => {
                        // All-static tensor args: fold at PE time.
                        let statics: Option<Vec<Value>> = pargs
                            .iter()
                            .map(|p| match &p.stat {
                                Some(SValue::Tensor(t)) => Some(Value::Tensor(t.clone())),
                                _ => None,
                            })
                            .collect();
                        if let (Some(vals), Some(def)) = (statics, op::lookup(name)) {
                            if let Ok(Value::Tensor(t)) = (def.eval)(&vals, attrs) {
                                let c = crate::ir::constant(t.clone());
                                return Ok(stat(SValue::Tensor(t), c));
                            }
                        }
                        let d = self.push(Arc::new(Expr::Call {
                            f: f.clone(),
                            args: pargs.iter().map(|p| p.dynv.clone()).collect(),
                            attrs: attrs.clone(),
                        }));
                        Ok(dynamic(d))
                    }
                    Expr::Ctor(name) => {
                        let d = self.push(Arc::new(Expr::Call {
                            f: f.clone(),
                            args: pargs.iter().map(|p| p.dynv.clone()).collect(),
                            attrs: attrs.clone(),
                        }));
                        Ok(stat(
                            SValue::Adt { ctor: name.clone(), fields: pargs },
                            d,
                        ))
                    }
                    _ => {
                        let pf = self.peval(f, env)?;
                        if let Some(SValue::Fun { params, body, env: fenv }) = &pf.stat {
                            if self.fuel > 0 && params.len() == pargs.len() {
                                self.fuel -= 1;
                                let mut env2 = fenv.clone();
                                for (p, a) in params.iter().zip(&pargs) {
                                    env2.insert(p.id, a.clone());
                                }
                                let body = body.clone();
                                return self.peval(&body, &env2);
                            }
                        }
                        // Unknown call: contaminate the store.
                        self.clear_store();
                        let d = self.push(Arc::new(Expr::Call {
                            f: pf.dynv.clone(),
                            args: pargs.iter().map(|p| p.dynv.clone()).collect(),
                            attrs: attrs.clone(),
                        }));
                        Ok(dynamic(d))
                    }
                }
            }
        }
    }

    /// Emit a named binding (reuses the source variable for readability).
    fn push_named(&mut self, v: &Var, e: E) -> E {
        if e.is_atomic() {
            return e;
        }
        self.bindings.push((v.clone(), e));
        var(v)
    }

    /// Residualize a function: PE its body under dynamic params with a
    /// fresh (empty) store — the appendix's `Abs` case.
    fn residualize_fn(&mut self, e: &E, env: &PEnv) -> R<E> {
        let f = match &**e {
            Expr::Func(f) => f,
            _ => return Err("residualize_fn on non-function".into()),
        };
        let mut env2 = env.clone();
        for (p, _) in &f.params {
            env2.insert(p.id, dynamic(var(p)));
        }
        let saved_store = std::mem::take(&mut self.store);
        let from = self.bindings.len();
        let bv = self.peval(&f.body, &env2)?;
        let body = self.wrap(from, bv.dynv);
        self.store = saved_store;
        Ok(Arc::new(Expr::Func(Function {
            params: f.params.clone(),
            ret: f.ret.clone(),
            body,
            attrs: f.attrs.clone(),
        })))
    }
}

fn match_static(
    p: &Pattern,
    ctor: &str,
    fields: &[PValue],
    whole: &PValue,
    env: &mut PEnv,
) -> bool {
    match p {
        Pattern::Wildcard => true,
        Pattern::Var(v) => {
            env.insert(v.id, whole.clone());
            true
        }
        Pattern::Ctor(name, ps) => {
            if name != ctor {
                return false;
            }
            if ps.is_empty() {
                return true;
            }
            if ps.len() != fields.len() {
                return false;
            }
            ps.iter().zip(fields).all(|(sp, f)| match &f.stat {
                Some(SValue::Adt { ctor: c2, fields: f2 }) => {
                    match_static(sp, c2, f2, f, env)
                }
                _ => match sp {
                    Pattern::Wildcard => true,
                    Pattern::Var(v) => {
                        env.insert(v.id, f.clone());
                        true
                    }
                    _ => false,
                },
            })
        }
        Pattern::Tuple(_) => false,
    }
}

/// Partially evaluate an expression (usually a function).
pub fn partial_eval(module: &Module, e: &E) -> Result<E, String> {
    let mut pe = Pe::new(module);
    match &**e {
        Expr::Func(_) => pe.residualize_fn(e, &PEnv::new()),
        _ => {
            let v = pe.peval(e, &PEnv::new())?;
            Ok(pe.wrap(0, v.dynv))
        }
    }
}

/// Dead-reference elimination: remove `ref` bindings that are only ever
/// written (never read, never escaping), together with their writes. This
/// is the cleanup that lets DCE crunch AD->PE output down to Fig. 5's
/// post-DCE form. Iterates to fixpoint (a removed write can orphan another
/// ref).
pub fn eliminate_dead_refs(e: &E) -> E {
    let mut cur = e.clone();
    loop {
        let next = eliminate_dead_refs_once(&cur);
        if crate::ir::structural_hash(&next) == crate::ir::structural_hash(&cur) {
            return next;
        }
        cur = next;
    }
}

fn eliminate_dead_refs_once(e: &E) -> E {
    use std::collections::BTreeSet;
    // Find let-bound RefNew vars.
    fn ref_vars(e: &E, out: &mut Vec<Var>) {
        if let Expr::Let { var, value, .. } = &**e {
            if matches!(&**value, Expr::RefNew(_)) {
                out.push(var.clone());
            }
        }
        crate::ir::visit_children(e, |c| ref_vars(c, out));
    }
    // A ref var is dead if every occurrence is as the target of a write.
    fn non_write_uses(e: &E, v: &Var, count: &mut usize) {
        match &**e {
            Expr::RefWrite(r, val) => {
                if !matches!(&**r, Expr::Var(rv) if rv == v) {
                    non_write_uses(r, v, count);
                }
                non_write_uses(val, v, count);
            }
            Expr::Var(x) if x == v => *count += 1,
            Expr::Let { var, value, body, .. } if var == v => {
                // The binding itself (skip); value may still use it.
                let _ = var;
                non_write_uses(value, v, count);
                non_write_uses(body, v, count);
            }
            _ => crate::ir::visit_children(e, |c| non_write_uses(c, v, count)),
        }
    }
    let mut rvars = Vec::new();
    ref_vars(e, &mut rvars);
    let mut dead: BTreeSet<u32> = BTreeSet::new();
    for v in &rvars {
        let mut uses = 0;
        // Count uses in the whole tree minus the defining binding's value.
        non_write_uses(e, v, &mut uses);
        // One "use" is the binding body reference... count only reads:
        if uses == 0 {
            dead.insert(v.id);
        }
    }
    if dead.is_empty() {
        return e.clone();
    }
    // Remove writes to dead refs and their bindings.
    fn strip(e: &E, dead: &BTreeSet<u32>) -> E {
        match &**e {
            Expr::Let { var, ty, value, body } => {
                let body = strip(body, dead);
                if dead.contains(&var.id) && matches!(&**value, Expr::RefNew(_)) {
                    return body;
                }
                let value = strip(value, dead);
                // A binding whose value was a now-removed write becomes unit.
                Arc::new(Expr::Let {
                    var: var.clone(),
                    ty: ty.clone(),
                    value,
                    body,
                })
            }
            Expr::RefWrite(r, _) => match &**r {
                Expr::Var(v) if dead.contains(&v.id) => crate::ir::unit(),
                _ => crate::ir::map_children(e, |c| strip(c, dead)),
            },
            _ => crate::ir::map_children(e, |c| strip(c, dead)),
        }
    }
    strip(e, &dead)
}

/// The Fig. 5 pipeline: AD -> PE -> (DCE <-> dead-ref elim to fixpoint).
pub fn ad_pe_dce(module: &Module, f: &E) -> Result<E, String> {
    let g = super::ad::grad_expr(f)?;
    let p = partial_eval(module, &g)?;
    Ok(cleanup(&p))
}

/// Alternate DCE and dead-ref elimination until stable (DCE removes the
/// pure consumers that keep a ref's var alive; dead-ref elim then removes
/// the ref and its writes, exposing more dead code).
pub fn cleanup(e: &E) -> E {
    let mut cur = e.clone();
    loop {
        let next = eliminate_dead_refs(&super::dce::dce(&cur));
        if crate::ir::structural_hash(&next) == crate::ir::structural_hash(&cur) {
            return next;
        }
        cur = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_expr;
    use crate::ir::{self, count_nodes, parse_expr, print_expr};

    fn pe(src: &str) -> E {
        let m = Module::with_prelude();
        let e = parse_expr(src).unwrap();
        partial_eval(&m, &e).unwrap()
    }

    #[test]
    fn folds_static_arithmetic() {
        let out = pe("add(multiply(2f, 3f), 4f)");
        let s = print_expr(&out);
        assert!(s.contains("10f"), "{s}");
    }

    #[test]
    fn static_closure_applied() {
        let out = pe("let %f = fn (%x) { add(%x, 1f) }; %f(2f)");
        let s = print_expr(&super::super::dce::dce(&out));
        assert!(s.contains("3f"), "{s}");
        assert!(!s.contains("fn ("), "{s}");
    }

    #[test]
    fn static_if_taken() {
        let out = pe("if (less(1f, 2f)) { 10f } else { 20f }");
        assert!(print_expr(&out).contains("10f"));
        assert!(!print_expr(&out).contains("20f"));
    }

    #[test]
    fn static_ref_reads_resolved() {
        // The read resolves statically even though the ref stays residual.
        let out = pe("let %r = ref(1f); %r := 41f; add(!%r, 1f)");
        let s = print_expr(&super::super::dce::dce(&eliminate_dead_refs(&out)));
        assert!(s.contains("42f"), "{s}");
        assert!(!s.contains("ref("), "{s}");
    }

    #[test]
    fn dynamic_code_residualizes() {
        let out = pe("fn (%x) { add(%x, add(1f, 2f)) }");
        let s = print_expr(&out);
        assert!(s.contains("3f"), "{s}");
        assert!(s.contains("add(%x"), "{s}");
    }

    #[test]
    fn static_match_selected() {
        let out = pe("match (Cons(5f, Nil)) { | Cons(%h, %t) -> %h | Nil -> 0f }");
        assert!(print_expr(&out).contains("5f"));
    }

    #[test]
    fn fig5_identity_pipeline() {
        // AD(identity) -> PE -> DCE must crunch to (d, (ones_like(d),))
        // with no refs or closures left.
        let m = Module::with_prelude();
        let f = parse_expr("fn (%d) { %d }").unwrap();
        let out = ad_pe_dce(&m, &f).unwrap();
        let s = print_expr(&out);
        assert!(s.contains("ones_like"), "{s}");
        assert!(!s.contains("ref("), "{s}");
        assert!(!s.contains(":="), "{s}");
        // Semantics: returns (x, (1,)).
        let r = eval_expr(&m, &ir::call(out.clone(), vec![ir::scalar(7.0)])).unwrap();
        assert_eq!(r.tuple()[0].tensor().f32_value(), 7.0);
        assert_eq!(r.tuple()[1].tuple()[0].tensor().f32_value(), 1.0);
        // And it is small (Fig 5's post-DCE is 2 ops).
        assert!(count_nodes(&out) < 25, "residual too big ({}): {s}", count_nodes(&out));
    }

    #[test]
    fn fig5_square_pipeline_is_first_order() {
        let m = Module::with_prelude();
        let f = parse_expr("fn (%x) { multiply(%x, %x) }").unwrap();
        let out = ad_pe_dce(&m, &f).unwrap();
        let s = print_expr(&out);
        assert!(!s.contains("ref("), "{s}");
        assert!(!s.contains("grad"), "{s}");
        let r = eval_expr(&m, &ir::call(out, vec![ir::scalar(3.0)])).unwrap();
        assert_eq!(r.tuple()[0].tensor().f32_value(), 9.0);
        assert_eq!(r.tuple()[1].tuple()[0].tensor().f32_value(), 6.0);
    }

    #[test]
    fn recursion_does_not_diverge() {
        let out = pe(
            "let %loop = fn (%i) { if (greater(%i, 0f)) { %loop(subtract(%i, 1f)) } else { %i } };\n\
             %loop(3f)",
        );
        // Recursive fn residualizes; result still evaluates correctly.
        let m = Module::with_prelude();
        let r = eval_expr(&m, &out).unwrap();
        assert_eq!(r.tensor().f32_value(), 0.0);
    }
}
