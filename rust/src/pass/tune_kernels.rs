//! TuneKernels: compile-time tile-schedule selection (the lightweight
//! analogue of TVM's schedule search, paper §4).
//!
//! The pass walks the optimized module, finds every statically-shaped hot
//! kernel call (`nn.dense`, `matmul`, `nn.batch_matmul`, `nn.conv2d`),
//! and makes one tuning decision per (op, shape) via
//! [`tune::ensure`] — a one-shot probe when `RELAY_TUNE_PROBE=1`, the
//! static heuristic otherwise. The module itself is returned unchanged:
//! the decision lands in the process-wide schedule registry (where the
//! tiled kernels look it up at launch), is snapshotted into the
//! `ProgramCache` entry by `eval::cache::compile_for`, and shows up as a
//! `TuneKernels` row in `relay dump-passes`.
//!
//! A symbolic batch dimension (`Dim::Any` under `--poly`) is keyed as 0;
//! concrete launches fall through to that entry in
//! [`tune::schedule_for`]. Modules the type checker cannot finish on are
//! skipped wholesale — tuning is best-effort metadata, never a reason to
//! fail a compile.

use crate::ir::{Dim, Expr, Module, Type, E};
use crate::tensor::tune::{self, TunedKernel};

/// Ops the tuner knows a schedule family for.
const TUNED_OPS: [&str; 4] = ["nn.dense", "matmul", "nn.batch_matmul", "nn.conv2d"];

/// The pass entry point: tune every hot call site, return the module
/// unchanged.
pub fn run(m: &Module) -> Module {
    let _ = tune_module(m);
    m.clone()
}

/// Walk `m` and ensure a schedule exists for every statically-shaped hot
/// kernel call. Returns the decisions (one per distinct (op, shape)) —
/// `eval::cache::compile_for` snapshots this into the cache entry, and
/// `relay dump-passes` prints it under the pass table. Idempotent: repeat
/// calls return the already-registered schedules.
pub fn tune_module(m: &Module) -> Vec<TunedKernel> {
    let Ok(report) = crate::ty::check_module(m) else {
        return Vec::new();
    };
    let mut calls: Vec<E> = Vec::new();
    for f in m.defs.values() {
        crate::ir::visit::collect(
            &f.body,
            &|e| {
                matches!(&**e,
                    Expr::Call { f, .. }
                        if matches!(&**f, Expr::Op(n) if TUNED_OPS.contains(&n.as_str())))
            },
            &mut calls,
        );
    }
    let mut out: Vec<TunedKernel> = Vec::new();
    for call in &calls {
        let Expr::Call { f, args, .. } = &**call else { continue };
        let Expr::Op(name) = &**f else { continue };
        let op: &'static str = TUNED_OPS
            .iter()
            .find(|&&o| o == name.as_str())
            .copied()
            .expect("pred matched op set");
        let shapes: Option<Vec<Vec<usize>>> = args
            .iter()
            .map(|a| report.type_of(a).and_then(dims_with_symbolic_zero))
            .collect();
        let Some(shapes) = shapes else { continue };
        let Some(dims) = kernel_dims(op, &shapes) else { continue };
        let tuned = tune::ensure(op, dims);
        if !out
            .iter()
            .any(|t| t.op == tuned.op && t.dims == tuned.dims)
        {
            out.push(tuned);
        }
    }
    out
}

/// Tensor shape with symbolic dims (`Dim::Any` / unsolved vars) as 0 —
/// the tuner's "polymorphic" marker. Non-tensor types yield `None`.
fn dims_with_symbolic_zero(t: &Type) -> Option<Vec<usize>> {
    match t {
        Type::Tensor { shape, .. } => Some(
            shape
                .iter()
                .map(|d| match d {
                    Dim::Known(k) => *k,
                    Dim::Any | Dim::Var(_) => 0,
                })
                .collect(),
        ),
        _ => None,
    }
}

/// The tuner's dims key for one call site. GEMMs key as `[m, k, n]`
/// (leading 0 = symbolic batch; a symbolic `k`/`n` is untunable), conv as
/// `[n, c, h, w, oc, kh, kw]`.
fn kernel_dims(op: &str, shapes: &[Vec<usize>]) -> Option<Vec<usize>> {
    match op {
        "nn.dense" => match (shapes.first()?.as_slice(), shapes.get(1)?.as_slice()) {
            ([m, k, ..], [n, _k2]) if *k > 0 && *n > 0 => Some(vec![*m, *k, *n]),
            _ => None,
        },
        "matmul" => match (shapes.first()?.as_slice(), shapes.get(1)?.as_slice()) {
            ([m, k], [_k2, n]) if *k > 0 && *n > 0 => Some(vec![*m, *k, *n]),
            _ => None,
        },
        "nn.batch_matmul" => {
            match (shapes.first()?.as_slice(), shapes.get(1)?.as_slice()) {
                ([_b, m, k], [_b2, _k2, n]) if *k > 0 && *n > 0 => {
                    Some(vec![*m, *k, *n])
                }
                _ => None,
            }
        }
        "nn.conv2d" => match (shapes.first()?.as_slice(), shapes.get(1)?.as_slice()) {
            ([n, c, h, w], [o, _cg, kh, kw])
                if [*c, *h, *w, *o, *kh, *kw].iter().all(|&d| d > 0) =>
            {
                Some(vec![*n, *c, *h, *w, *o, *kh, *kw])
            }
            _ => None,
        },
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parse_module;
    use crate::tensor::tune::Schedule;

    #[test]
    fn tunes_every_static_dense_in_a_module() {
        let m = parse_module(
            "def @main(%x: Tensor[(2, 4), float32]) {\n\
               let %w1 = ones(shape=[8, 4]);\n\
               let %h = nn.relu(nn.dense(%x, %w1));\n\
               let %w2 = ones(shape=[2, 8]);\n\
               nn.dense(%h, %w2)\n\
             }",
        )
        .unwrap();
        let tuned = tune_module(&m);
        assert_eq!(tuned.len(), 2, "{tuned:?}");
        assert!(tuned.iter().any(|t| t.dims == vec![2, 4, 8]));
        assert!(tuned.iter().any(|t| t.dims == vec![2, 8, 2]));
        assert!(tuned.iter().all(|t| matches!(t.schedule, Schedule::Gemm(_))));
        // Idempotent: a re-walk returns the same decisions, no new entries.
        let again = tune_module(&m);
        assert_eq!(again.len(), 2);
        assert_eq!(again[0].schedule, tuned[0].schedule);
    }

    #[test]
    fn untypeable_module_is_skipped_not_failed() {
        let m = parse_module(
            "def @main(%l) { match (%l) { | Cons(%h, %t) -> %h | Nil -> 0f } }",
        )
        .unwrap();
        assert!(tune_module(&m).is_empty());
        // The pass proper also returns the module unchanged.
        let back = run(&m);
        assert_eq!(
            crate::ir::module_structural_hash(&m),
            crate::ir::module_structural_hash(&back)
        );
    }
}
