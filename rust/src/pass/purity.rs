//! Purity analysis shared by CSE / DCE / constant folding / PE.
//!
//! Relay is pure by default; effects come only from references (and
//! potential non-termination of closure calls, which we conservatively
//! treat as impure for elimination purposes).

use crate::ir::{visit_children, Expr, E};

/// Is it safe to delete / duplicate / reorder this expression?
pub fn is_pure(e: &E) -> bool {
    match &**e {
        Expr::RefNew(_) | Expr::RefRead(_) | Expr::RefWrite(..) => false,
        // Calls to operators and constructors are pure; calls to anything
        // else (closures, globals) may diverge or touch refs.
        Expr::Call { f, args, .. } => {
            matches!(&**f, Expr::Op(_) | Expr::Ctor(_)) && args.iter().all(is_pure)
        }
        // A function VALUE is pure (its body runs later); grad likewise.
        Expr::Func(_) | Expr::Grad(_) => true,
        _ => {
            let mut ok = true;
            visit_children(e, |c| ok &= is_pure(c));
            ok
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::*;

    #[test]
    fn op_calls_are_pure() {
        assert!(is_pure(&op_call("add", vec![scalar(1.0), scalar(2.0)])));
    }

    #[test]
    fn ref_ops_are_impure() {
        assert!(!is_pure(&ref_new(scalar(1.0))));
        let r = Var::fresh("r");
        assert!(!is_pure(&ref_read(var(&r))));
        assert!(!is_pure(&ref_write(var(&r), scalar(1.0))));
    }

    #[test]
    fn closure_calls_are_impure() {
        let f = Var::fresh("f");
        assert!(!is_pure(&call(var(&f), vec![scalar(1.0)])));
    }

    #[test]
    fn function_values_are_pure_even_with_impure_bodies() {
        let r = Var::fresh("r");
        let f = func(vec![], ref_write(var(&r), scalar(1.0)));
        assert!(is_pure(&f));
    }

    #[test]
    fn let_propagates() {
        let x = Var::fresh("x");
        let pure = let_(x.clone(), scalar(1.0), var(&x));
        assert!(is_pure(&pure));
        let impure = let_(x.clone(), ref_new(scalar(1.0)), var(&x));
        assert!(!is_pure(&impure));
    }
}
