//! Operator fusion (§4.4): group chains of operator calls into *primitive*
//! functions that backends compile as single fused kernels.
//!
//! Extraction (§4.4.1): the def body is converted to ANF, giving one
//! binding per operator call; the dataflow DAG over bindings is grouped by
//! a union-find guided by the post-dominator condition — a producer joins
//! its consumer's group only when *every* consumer lands in that same
//! group (the producer's immediate post-dominator lies inside the group),
//! which also handles diamond-shaped branches. Operator patterns constrain
//! groups: at most one OutEWiseFusable anchor (conv/dense/matmul) per
//! group, Injective ops fuse freely, Reductions may close a group, Opaque
//! ops never fuse.
//!
//! Lowering happens in the backends: the interpreter executes a primitive
//! function as one "kernel launch" (its op-call counter increments once),
//! the graph runtime allocates one node, and the XLA backend compiles one
//! module per primitive function (§4.4.2's "master schedule" role).

use std::collections::BTreeMap;
use std::sync::Arc;

use super::anf::to_anf;
use crate::ir::{
    func, let_, map_children, var, Expr, FnAttrs, Function, Module, Var, E,
};
use crate::op::{self, OpPattern};

struct Binding {
    var: Var,
    value: E,
    pattern: Option<OpPattern>,
    /// Var ids of op-binding arguments.
    deps: Vec<usize>,
}

struct UnionFind {
    parent: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect() }
    }

    fn find(&mut self, i: usize) -> usize {
        if self.parent[i] != i {
            let r = self.find(self.parent[i]);
            self.parent[i] = r;
            r
        } else {
            i
        }
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra] = rb;
        }
    }
}

/// Fuse one (already-ANF) let chain.
fn fuse_chain(e: &E) -> E {
    // Bind an operator-call tail so it can participate in grouping.
    let e = match &**e {
        Expr::Call { f, .. } if matches!(&**f, Expr::Op(_)) => {
            let v = Var::fresh("tail");
            let_(v.clone(), e.clone(), var(&v))
        }
        Expr::Let { .. } => {
            // Rebind the chain's final expression if it is an op call.
            rebind_tail(e)
        }
        _ => e.clone(),
    };
    let e = &e;
    // 1. Split the chain.
    let mut bindings: Vec<Binding> = Vec::new();
    let mut var_to_idx: BTreeMap<u32, usize> = BTreeMap::new();
    let mut cur = e.clone();
    loop {
        let next = match &*cur {
            Expr::Let { var: v, value, body, .. } => {
                let value = fuse_subexprs(value);
                let pattern = op_pattern(&value);
                let deps = match &*value {
                    Expr::Call { args, .. } => args
                        .iter()
                        .filter_map(|a| match &**a {
                            Expr::Var(av) => var_to_idx.get(&av.id).copied(),
                            _ => None,
                        })
                        .collect(),
                    _ => vec![],
                };
                var_to_idx.insert(v.id, bindings.len());
                bindings.push(Binding { var: v.clone(), value, pattern, deps });
                body.clone()
            }
            _ => break,
        };
        cur = next;
    }
    let tail = fuse_subexprs(&cur);

    // 2. Consumers per binding. The tail and any non-op use counts as an
    // external consumer (usize::MAX).
    let n = bindings.len();
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, b) in bindings.iter().enumerate() {
        if b.pattern.is_some() {
            for &d in &b.deps {
                consumers[d].push(i);
            }
        } else {
            // Non-op binding: every var it references is externally used.
            for v in crate::ir::free_vars(&b.value) {
                if let Some(&d) = var_to_idx.get(&v.id) {
                    consumers[d].push(usize::MAX);
                }
            }
        }
    }
    for v in crate::ir::free_vars(&tail) {
        if let Some(&d) = var_to_idx.get(&v.id) {
            consumers[d].push(usize::MAX);
        }
    }

    // 3. Group: merge producer into consumers' group when all consumers
    // share one group and patterns allow. Iterate to fixpoint (handles
    // diamonds whose join fuses first).
    let mut uf = UnionFind::new(n);
    loop {
        let mut changed = false;
        for i in (0..n).rev() {
            let pat = match bindings[i].pattern {
                Some(p) if p != OpPattern::Opaque => p,
                _ => continue,
            };
            if consumers[i].is_empty() || consumers[i].contains(&usize::MAX) {
                continue;
            }
            let groups: Vec<usize> = consumers[i].iter().map(|&c| uf.find(c)).collect();
            let g0 = groups[0];
            if !groups.iter().all(|&g| g == g0) {
                continue;
            }
            if uf.find(i) == g0 {
                continue;
            }
            // Consumers must all be fusable ops.
            if !consumers[i].iter().all(|&c| {
                matches!(
                    bindings[c].pattern,
                    Some(OpPattern::Injective)
                        | Some(OpPattern::Reduction)
                        | Some(OpPattern::OutEWiseFusable)
                )
            }) {
                continue;
            }
            // Anchor constraint: at most one OutEWiseFusable per group;
            // reductions only close groups (nothing fuses past them).
            let group_members: Vec<usize> =
                (0..n).filter(|&j| uf.find(j) == g0).collect();
            let anchors = group_members
                .iter()
                .chain(std::iter::once(&i))
                .filter(|&&j| bindings[j].pattern == Some(OpPattern::OutEWiseFusable))
                .count();
            if anchors > 1 {
                continue;
            }
            // A reduction may not appear as a producer inside a group
            // (it closes its own group).
            if pat == OpPattern::Reduction {
                continue;
            }
            uf.union(i, g0);
            changed = true;
        }
        if !changed {
            break;
        }
    }

    // 4. Rebuild. Each group emits one binding at its last member, either
    // the bare value (singleton non-op / opaque) or a primitive function
    // call over the group's external inputs.
    let mut group_members: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for i in 0..n {
        group_members.entry(uf.find(i)).or_default().push(i);
    }

    let mut out = tail;
    // Iterate bindings in reverse order, emitting groups at their last
    // member.
    for i in (0..n).rev() {
        let root = uf.find(i);
        let members = &group_members[&root];
        let last = *members.iter().max().unwrap();
        if i != last {
            continue; // emitted with the group
        }
        if members.len() == 1 && bindings[i].pattern.is_none() {
            // Plain (non-op) binding.
            out = let_(bindings[i].var.clone(), bindings[i].value.clone(), out);
            continue;
        }
        if members.len() == 1
            && bindings[i].pattern == Some(OpPattern::Opaque)
        {
            out = let_(bindings[i].var.clone(), bindings[i].value.clone(), out);
            continue;
        }
        // Build the primitive function for this group.
        let member_vars: Vec<u32> = members.iter().map(|&j| bindings[j].var.id).collect();
        // External inputs: free vars of member values not defined by members.
        let mut inputs: Vec<Var> = Vec::new();
        for &j in members {
            for v in crate::ir::free_vars(&bindings[j].value) {
                if !member_vars.contains(&v.id) && !inputs.contains(&v) {
                    inputs.push(v);
                }
            }
        }
        // Fresh params mirroring inputs.
        let params: Vec<Var> = inputs.iter().map(|v| Var::fresh(&v.name)).collect();
        let mut sub: BTreeMap<Var, E> = BTreeMap::new();
        for (iv, pv) in inputs.iter().zip(&params) {
            sub.insert(iv.clone(), var(pv));
        }
        // Body: member bindings in order, returning the last member's var.
        let mut body: E = var(&bindings[last].var);
        for &j in members.iter().rev() {
            body = let_(
                bindings[j].var.clone(),
                crate::ir::subst(&bindings[j].value, &sub),
                body,
            );
        }
        let mut fused = Function::new(params.into_iter().map(|p| (p, None)).collect(), body);
        fused.attrs = FnAttrs { primitive: true };
        let call = crate::ir::call(
            Arc::new(Expr::Func(fused)),
            inputs.iter().map(var).collect(),
        );
        out = let_(bindings[last].var.clone(), call, out);
        // Emit any *non-member* bindings... (members are contiguous groups
        // in dependency order; non-member bindings are emitted at their own
        // index positions by this loop.)
    }
    out
}

/// Rebuild a let chain with its tail bound when the tail is an op call.
fn rebind_tail(e: &E) -> E {
    match &**e {
        Expr::Let { var: v, ty, value, body } => Arc::new(Expr::Let {
            var: v.clone(),
            ty: ty.clone(),
            value: value.clone(),
            body: rebind_tail(body),
        }),
        Expr::Call { f, .. } if matches!(&**f, Expr::Op(_)) => {
            let v = Var::fresh("tail");
            let_(v.clone(), e.clone(), var(&v))
        }
        _ => e.clone(),
    }
}

/// Pattern of a binding value if it is a direct operator call.
fn op_pattern(value: &E) -> Option<OpPattern> {
    match &**value {
        Expr::Call { f, .. } => match &**f {
            Expr::Op(name) => op::lookup(name).map(|d| d.pattern),
            _ => None,
        },
        _ => None,
    }
}

/// Recurse into nested functions / branches.
fn fuse_subexprs(e: &E) -> E {
    match &**e {
        Expr::Func(f) if !f.attrs.primitive => {
            let body = fuse_expr_anf(&f.body);
            Arc::new(Expr::Func(Function {
                params: f.params.clone(),
                ret: f.ret.clone(),
                body,
                attrs: f.attrs.clone(),
            }))
        }
        Expr::If { cond, then_, else_ } => Arc::new(Expr::If {
            cond: cond.clone(),
            then_: fuse_expr_anf(then_),
            else_: fuse_expr_anf(else_),
        }),
        Expr::Match { scrut, arms } => Arc::new(Expr::Match {
            scrut: scrut.clone(),
            arms: arms.iter().map(|(p, a)| (p.clone(), fuse_expr_anf(a))).collect(),
        }),
        _ => map_children(e, |c| fuse_subexprs(c)),
    }
}

/// ANF-convert then fuse a block.
pub fn fuse_expr_anf(e: &E) -> E {
    fuse_chain(&to_anf(e))
}

/// Fuse every definition in the module.
pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        if f.attrs.primitive {
            return f.clone();
        }
        let mut nf = f.clone();
        nf.body = fuse_expr_anf(&f.body);
        nf
    })
}

/// Count primitive-function call sites (test/bench metric: "kernel
/// launches" after fusion).
pub fn count_kernel_calls(e: &E) -> usize {
    let mut count = 0;
    fn go(e: &E, count: &mut usize) {
        match &**e {
            Expr::Call { f, args, .. } => {
                match &**f {
                    Expr::Func(func) if func.attrs.primitive => *count += 1,
                    Expr::Op(_) => *count += 1,
                    _ => {}
                }
                go(f, count);
                args.iter().for_each(|a| go(a, count));
            }
            Expr::Func(f) if f.attrs.primitive => {
                // Don't count ops inside primitive bodies.
                let _ = f;
            }
            _ => crate::ir::visit_children(e, |c| go(c, count)),
        }
    }
    go(e, &mut count);
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_expr, eval_main, Value};
    use crate::ir::{self, parse_expr, parse_module, print_expr};
    use crate::tensor::Rng;

    fn fused_fn_count(e: &E) -> usize {
        let mut v = Vec::new();
        crate::ir::collect(
            e,
            &|n| matches!(&**n, Expr::Func(f) if f.attrs.primitive),
            &mut v,
        );
        v.len()
    }

    #[test]
    fn chain_fuses_into_one_kernel() {
        // dense -> add -> relu: one group anchored by dense.
        let e = parse_expr(
            "fn (%x: Tensor[(2, 4), float32], %w: Tensor[(8, 4), float32], %b: Tensor[(8), float32]) {\n\
               nn.relu(add(nn.dense(%x, %w), %b))\n\
             }",
        )
        .unwrap();
        let fused = fuse_subexprs(&e);
        assert_eq!(fused_fn_count(&fused), 1, "{}", print_expr(&fused));
        assert_eq!(count_kernel_calls(&fused), 1);
    }

    #[test]
    fn two_anchors_stay_separate() {
        // dense -> dense: two groups (one anchor each).
        let e = parse_expr(
            "fn (%x: Tensor[(2, 4), float32], %w1: Tensor[(8, 4), float32], %w2: Tensor[(8, 8), float32]) {\n\
               nn.dense(nn.dense(%x, %w1), %w2)\n\
             }",
        )
        .unwrap();
        let fused = fuse_subexprs(&e);
        assert_eq!(fused_fn_count(&fused), 2, "{}", print_expr(&fused));
    }

    #[test]
    fn diamond_fuses_completely() {
        // x -> (exp, tanh) -> add: the join post-dominates both branches.
        let e = parse_expr("fn (%x: Tensor[(4), float32]) { add(exp(%x), tanh(%x)) }")
            .unwrap();
        let fused = fuse_subexprs(&e);
        assert_eq!(fused_fn_count(&fused), 1, "{}", print_expr(&fused));
    }

    #[test]
    fn opaque_breaks_groups() {
        // softmax is opaque: relu | softmax | relu -> 3 kernels (2 fused fns
        // + 1 bare opaque call).
        let e = parse_expr(
            "fn (%x: Tensor[(2, 4), float32]) { nn.relu(nn.softmax(nn.relu(%x))) }",
        )
        .unwrap();
        let fused = fuse_subexprs(&e);
        assert_eq!(fused_fn_count(&fused), 2, "{}", print_expr(&fused));
        assert_eq!(count_kernel_calls(&fused), 3);
    }

    #[test]
    fn multi_consumer_not_absorbed_when_groups_differ() {
        // y = relu(x) consumed by two different anchors: y cannot join both.
        let e = parse_expr(
            "fn (%x: Tensor[(4, 4), float32], %w1: Tensor[(4, 4), float32], %w2: Tensor[(4, 4), float32]) {\n\
               let %y = nn.relu(%x);\n\
               add(nn.dense(%y, %w1), nn.dense(%y, %w2))\n\
             }",
        )
        .unwrap();
        let fused = fuse_subexprs(&e);
        // groups: relu alone OR fused with one?; two dense anchors; add
        // joins one of the dense groups. Verify semantics + ≥2 groups.
        assert!(fused_fn_count(&fused) >= 2, "{}", print_expr(&fused));
        let m = ir::Module::with_prelude();
        let mut rng = Rng::new(0);
        let x = rng.normal_tensor(&[4, 4], 1.0);
        let w1 = rng.normal_tensor(&[4, 4], 1.0);
        let w2 = rng.normal_tensor(&[4, 4], 1.0);
        let args = vec![
            ir::constant(x.clone()),
            ir::constant(w1.clone()),
            ir::constant(w2.clone()),
        ];
        let before = eval_expr(&m, &ir::call(e, args.clone())).unwrap();
        let after = eval_expr(&m, &ir::call(fused, args)).unwrap();
        assert!(before.tensor().allclose(after.tensor(), 1e-4, 1e-4));
    }

    #[test]
    fn fused_module_preserves_semantics() {
        let m = parse_module(
            "def @main(%x: Tensor[(2, 3, 6, 6), float32], %w: Tensor[(4, 3, 3, 3), float32]) {\n\
               let %c = nn.conv2d(%x, %w, padding=1);\n\
               let %r = nn.relu(%c);\n\
               nn.max_pool2d(%r, pool_size=2)\n\
             }",
        )
        .unwrap();
        let fused = run(&m);
        let mut rng = Rng::new(1);
        let x = rng.normal_tensor(&[2, 3, 6, 6], 1.0);
        let w = rng.normal_tensor(&[4, 3, 3, 3], 0.5);
        let args = vec![Value::Tensor(x), Value::Tensor(w)];
        let a = eval_main(&m, args.clone()).unwrap();
        let b = eval_main(&fused, args).unwrap();
        assert!(a.tensor().allclose(b.tensor(), 1e-4, 1e-4));
    }

    #[test]
    fn reduction_closes_group() {
        // relu -> sum: sum absorbs the injective producer, nothing fuses
        // after the reduction.
        let e = parse_expr(
            "fn (%x: Tensor[(4), float32]) { add(sum(nn.relu(%x)), 1f) }",
        )
        .unwrap();
        let fused = fuse_subexprs(&e);
        // Groups: {relu, sum} and {add}: 2 primitive fns.
        assert_eq!(fused_fn_count(&fused), 2, "{}", print_expr(&fused));
    }
}
