//! Reverse-mode automatic differentiation as a source-code transformation
//! (paper §4.2, Fig. 4).
//!
//! Every tensor-typed value is lifted to a pair `(T, Ref[T])` whose second
//! component accumulates the partial derivative. A single backpropagator
//! reference `Δ` holds a closure chain; each operator call composes its
//! update closure `δ` onto `Δ` (`Δ := !Δ ∘ δ`), so executing `!Δ()` after
//! seeding the output adjoint propagates gradients output-to-input. No
//! delimited continuations required — closures + references suffice, and
//! higher-order functions / control flow / ADTs / mutation work untouched
//! because the transform is purely structural outside operator calls.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::ir::{
    self, func, let_, op_call, proj, ref_new, ref_read, ref_write, tuple, var, Expr,
    Function, Var, E,
};
use crate::op;

struct AdCtx {
    /// The backpropagator reference Δ.
    delta: Var,
}

/// Expand `grad(f)`: produce a function with the same parameters that
/// returns `(f(args), (d/darg_0, ..., d/darg_n))` (Type-Gradient rule).
pub fn grad_expr(f: &E) -> Result<E, String> {
    let function = match &**f {
        Expr::Func(func) => func.clone(),
        _ => return Err("grad expects a function expression".to_string()),
    };
    let params: Vec<Var> = function.params.iter().map(|(p, _)| p.clone()).collect();

    // Fresh outer params (original tensor types erased — AD output is
    // re-inferred afterwards).
    let outer: Vec<Var> = params.iter().map(|p| Var::fresh(&p.name)).collect();

    // Lift each param to a pair and substitute into the body.
    let mut subst_map = BTreeMap::new();
    let pairs: Vec<Var> = params
        .iter()
        .map(|p| Var::fresh(format!("{}_ad", p.name)))
        .collect();
    for (p, pv) in params.iter().zip(&pairs) {
        subst_map.insert(p.clone(), var(pv));
    }
    let body = ir::subst(&function.body, &subst_map);

    let delta = Var::fresh("bp");
    let ctx = AdCtx { delta: delta.clone() };
    let tbody = ad_term(&ctx, &body)?;

    // Assemble:
    // fn (outer...) {
    //   let pair_i = (outer_i, ref(zeros_like(outer_i)));
    //   let Δ = ref(fn () { () });
    //   let out = tbody;
    //   out.1 := ones_like(out.0);
    //   (!Δ)();
    //   (out.0, (!pair_0.1, ..., !pair_n.1))
    // }
    let out_v = Var::fresh("out");
    let grads: Vec<E> = pairs.iter().map(|p| ref_read(proj(var(p), 1))).collect();
    let result = tuple(vec![proj(var(&out_v), 0), tuple(grads)]);

    let run_bp = let_(
        Var::fresh("_"),
        ir::call(ref_read(var(&delta)), vec![]),
        result,
    );
    let seed = let_(
        Var::fresh("_"),
        ref_write(
            proj(var(&out_v), 1),
            op_call("ones_like", vec![proj(var(&out_v), 0)]),
        ),
        run_bp,
    );
    let mut inner = let_(out_v.clone(), tbody, seed);
    inner = let_(
        delta.clone(),
        ref_new(func(vec![], ir::unit())),
        inner,
    );
    for (outer_p, pair) in outer.iter().zip(&pairs).rev() {
        inner = let_(
            pair.clone(),
            tuple(vec![
                var(outer_p),
                ref_new(op_call("zeros_like", vec![var(outer_p)])),
            ]),
            inner,
        );
    }
    Ok(func(outer.into_iter().map(|p| (p, None)).collect(), inner))
}

/// The ADTerm transformation of Fig. 4.
fn ad_term(ctx: &AdCtx, e: &E) -> Result<E, String> {
    Ok(match &**e {
        // Variables already hold transformed values.
        Expr::Var(_) | Expr::Global(_) | Expr::Op(_) | Expr::Ctor(_) => e.clone(),
        // Lit l -> (l, ref(zeros_like l))
        Expr::Const(_) => tuple(vec![
            e.clone(),
            ref_new(op_call("zeros_like", vec![e.clone()])),
        ]),
        Expr::Tuple(es) => {
            let ts: Result<Vec<_>, _> = es.iter().map(|x| ad_term(ctx, x)).collect();
            tuple(ts?)
        }
        Expr::Proj(t, i) => proj(ad_term(ctx, t)?, *i),
        Expr::Let { var: v, value, body, .. } => let_(
            v.clone(),
            ad_term(ctx, value)?,
            ad_term(ctx, body)?,
        ),
        Expr::Func(f) => {
            // Closure params receive transformed (pair) values at runtime;
            // drop stale type annotations.
            let params = f.params.iter().map(|(p, _)| (p.clone(), None)).collect();
            let body = ad_term(ctx, &f.body)?;
            func(params, body)
        }
        Expr::If { cond, then_, else_ } => ir::if_(
            proj(ad_term(ctx, cond)?, 0),
            ad_term(ctx, then_)?,
            ad_term(ctx, else_)?,
        ),
        Expr::Match { scrut, arms } => {
            let s = ad_term(ctx, scrut)?;
            let as_: Result<Vec<_>, _> = arms
                .iter()
                .map(|(p, a)| ad_term(ctx, a).map(|a| (p.clone(), a)))
                .collect();
            ir::match_(s, as_?)
        }
        // Mutation is supported "for free" (paper §4.2).
        Expr::RefNew(v) => ref_new(ad_term(ctx, v)?),
        Expr::RefRead(r) => ref_read(ad_term(ctx, r)?),
        Expr::RefWrite(r, v) => ref_write(ad_term(ctx, r)?, ad_term(ctx, v)?),
        // Nested grad: expand first (enables higher-order gradients).
        Expr::Grad(f) => {
            let g = grad_expr(f)?;
            ad_term(ctx, &g)?
        }
        Expr::Call { f, args, attrs } => match &**f {
            Expr::Op(name) => ad_op_call(ctx, name, args, attrs)?,
            Expr::Ctor(_) => {
                let ts: Result<Vec<_>, _> = args.iter().map(|a| ad_term(ctx, a)).collect();
                ir::call_attrs(f.clone(), ts?, attrs.clone())
            }
            _ => {
                // Closure call: callee and args are transformed values.
                let cf = ad_term(ctx, f)?;
                let ts: Result<Vec<_>, _> = args.iter().map(|a| ad_term(ctx, a)).collect();
                ir::call_attrs(cf, ts?, attrs.clone())
            }
        },
    })
}

/// Fig. 4's operator-call case: the heart of the transform.
fn ad_op_call(
    ctx: &AdCtx,
    name: &str,
    args: &[E],
    attrs: &ir::Attrs,
) -> Result<E, String> {
    let def = op::lookup(name).ok_or_else(|| format!("unknown operator {name}"))?;

    // let a_i = ADTerm(arg_i);
    let arg_vars: Vec<Var> = (0..args.len()).map(|i| Var::fresh(format!("a{i}"))).collect();
    // let v = op(a_0.0, ..., a_n.0);
    let raw_args: Vec<E> = arg_vars.iter().map(|a| proj(var(a), 0)).collect();
    let v = Var::fresh("v");
    let vbar = Var::fresh("vb");

    // Build δ: fn () { g = !vbar; a_i.1 := !a_i.1 + grad_i; () }
    let delta_body = if let Some(grad_rule) = def.grad {
        let g = Var::fresh("g");
        let grads = grad_rule(&raw_args, &var(&v), &var(&g), attrs);
        if grads.len() != args.len() {
            return Err(format!("grad rule for {name} returned {} grads for {} args",
                grads.len(), args.len()));
        }
        let mut body: E = ir::unit();
        for (a, gexpr) in arg_vars.iter().zip(grads).rev() {
            let acc = ref_write(
                proj(var(a), 1),
                op_call("add", vec![ref_read(proj(var(a), 1)), gexpr]),
            );
            body = let_(Var::fresh("_"), acc, body);
        }
        let_(g.clone(), ref_read(var(&vbar)), body)
    } else {
        // Non-differentiable op (comparison, cast, argmax...): no updates.
        ir::unit()
    };
    let delta_fn = func(vec![], delta_body);

    // Δ := !Δ ∘ δ  — i.e. new Δ runs δ first, then the old chain.
    let old = Var::fresh("old_bp");
    let dvar = Var::fresh("d");
    let compose = func(
        vec![],
        let_(
            Var::fresh("_"),
            ir::call(var(&dvar), vec![]),
            ir::call(var(&old), vec![]),
        ),
    );

    // Assemble the whole let chain, innermost first.
    let result = tuple(vec![var(&v), var(&vbar)]);
    let update = let_(
        Var::fresh("_"),
        ref_write(var(&ctx.delta), compose),
        result,
    );
    let bind_old = let_(old.clone(), ref_read(var(&ctx.delta)), update);
    let bind_delta = let_(dvar.clone(), delta_fn, bind_old);
    let bind_vbar = let_(
        vbar.clone(),
        ref_new(op_call("zeros_like", vec![var(&v)])),
        bind_delta,
    );
    let bind_v = let_(
        v.clone(),
        Arc::new(Expr::Call {
            f: ir::op(name),
            args: raw_args.clone(),
            attrs: attrs.clone(),
        }),
        bind_vbar,
    );
    // Outermost: evaluate transformed args.
    let mut out = bind_v;
    for (avar, arg) in arg_vars.iter().zip(args).rev() {
        out = let_(avar.clone(), ad_term(ctx, arg)?, out);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_expr, Value};
    use crate::ir::{parse_expr, Module};
    use crate::tensor::Tensor;

    fn grad_of(src: &str, inputs: &[f32]) -> (f32, Vec<f32>) {
        let m = Module::with_prelude();
        let f = parse_expr(src).unwrap();
        let g = grad_expr(&f).unwrap();
        let args: Vec<E> = inputs.iter().map(|&x| ir::scalar(x)).collect();
        let call = ir::call(g, args);
        let out = eval_expr(&m, &call).unwrap();
        let loss = out.tuple()[0].tensor().f32_value();
        let grads: Vec<f32> = out.tuple()[1]
            .tuple()
            .iter()
            .map(|v| v.tensor().f32_value())
            .collect();
        (loss, grads)
    }

    #[test]
    fn grad_of_square() {
        // d/dx x^2 = 2x at x=3 -> 6
        let (loss, grads) = grad_of("fn (%x) { multiply(%x, %x) }", &[3.0]);
        assert_eq!(loss, 9.0);
        assert_eq!(grads, vec![6.0]);
    }

    #[test]
    fn grad_of_identity_fig5() {
        // Fig. 5's running example: grad of identity is 1.
        let (loss, grads) = grad_of("fn (%x) { %x }", &[5.0]);
        assert_eq!(loss, 5.0);
        assert_eq!(grads, vec![1.0]);
    }

    #[test]
    fn grad_two_args() {
        // f(x, y) = x*y + x  => df/dx = y + 1, df/dy = x
        let (loss, grads) =
            grad_of("fn (%x, %y) { add(multiply(%x, %y), %x) }", &[2.0, 3.0]);
        assert_eq!(loss, 8.0);
        assert_eq!(grads, vec![4.0, 2.0]);
    }

    #[test]
    fn grad_through_let_sharing() {
        // z = x + x; loss = z * z  => d/dx = 2z * 2 = 8x at x=1 -> 8
        let (loss, grads) =
            grad_of("fn (%x) { let %z = add(%x, %x); multiply(%z, %z) }", &[1.0]);
        assert_eq!(loss, 4.0);
        assert_eq!(grads, vec![8.0]);
    }

    #[test]
    fn grad_through_control_flow() {
        // f(x) = if x > 0 then x*x else -x : at 2 -> grad 4; at -3 -> grad -1
        let src = "fn (%x) { if (greater(%x, 0f)) { multiply(%x, %x) } else { negative(%x) } }";
        let (_, g1) = grad_of(src, &[2.0]);
        assert_eq!(g1, vec![4.0]);
        let (_, g2) = grad_of(src, &[-3.0]);
        assert_eq!(g2, vec![-1.0]);
    }

    #[test]
    fn grad_of_tanh_chain() {
        // d/dx tanh(2x) = 2 * (1 - tanh(2x)^2)
        let (_, grads) = grad_of("fn (%x) { tanh(multiply(2f, %x)) }", &[0.5]);
        let t: f32 = 1.0f32.tanh();
        assert!((grads[0] - 2.0 * (1.0 - t * t)).abs() < 1e-5);
    }

    #[test]
    fn grad_through_closure() {
        // Higher-order: apply a locally-defined square function.
        let (_, grads) = grad_of(
            "fn (%x) { let %sq = fn (%y) { multiply(%y, %y) }; %sq(%sq(%x)) }",
            &[2.0],
        );
        // d/dx x^4 = 4x^3 = 32
        assert_eq!(grads, vec![32.0]);
    }

    #[test]
    fn second_order_gradient() {
        // g = grad(x^3) = (x^3, (3x^2,)); h = grad(fn x -> proj(g(x),1).0)
        // d/dx 3x^2 = 6x at x=2 -> 12.
        let m = Module::with_prelude();
        let f = parse_expr("fn (%x) { multiply(%x, multiply(%x, %x)) }").unwrap();
        let inner = grad_expr(&f).unwrap();
        // fn (%y) { inner(%y).1.0 }
        let y = Var::fresh("y");
        let outer_f = func(
            vec![(y.clone(), None)],
            proj(proj(ir::call(inner, vec![var(&y)]), 1), 0),
        );
        let outer = grad_expr(&outer_f).unwrap();
        let out = eval_expr(&m, &ir::call(outer, vec![ir::scalar(2.0)])).unwrap();
        let second = out.tuple()[1].tuple()[0].tensor().f32_value();
        assert!((second - 12.0).abs() < 1e-4, "got {second}");
    }

    #[test]
    fn grad_vector_dense_like() {
        // Vector case: f(x) = sum(x * x) over a 3-vector; grad = 2x.
        let m = Module::with_prelude();
        let f = parse_expr("fn (%x) { sum(multiply(%x, %x)) }").unwrap();
        let g = grad_expr(&f).unwrap();
        let x = Tensor::from_f32(vec![3], vec![1.0, -2.0, 0.5]);
        let out = eval_expr(&m, &ir::call(g, vec![ir::constant(x)])).unwrap();
        let grads = out.tuple()[1].tuple()[0].tensor().as_f32().to_vec();
        assert_eq!(grads, vec![2.0, -4.0, 1.0]);
        let loss = out.tuple()[0].tensor().f32_value();
        assert!((loss - 5.25).abs() < 1e-6);
        let _ = Value::unit();
    }
}
