//! FoldScaleAxis (§4.6): fold constant channel-wise scales surrounding a
//! convolution / dense layer into the weights. Required by accelerators
//! like VTA that have no scalar multiplier — after this pass (plus
//! constant folding) no standalone scale multiply remains.

use crate::ir::{call_attrs, constant, op_call, rewrite_postorder, Expr, Module, E};
use crate::tensor::Tensor;

pub fn fold_scale_axis(e: &E) -> E {
    rewrite_postorder(e, &mut |n| {
        let (f, args) = match &**n {
            Expr::Call { f, args, .. } => (f, args),
            _ => return None,
        };
        if !matches!(&**f, Expr::Op(name) if name == "multiply") {
            return None;
        }
        // multiply(conv_like(x, W_const), scale_const)  — either order.
        let (producer, scale) = if is_const(&args[1]) {
            (&args[0], &args[1])
        } else if is_const(&args[0]) {
            (&args[1], &args[0])
        } else {
            return None;
        };
        let scale_t = as_const(scale)?;
        let (pf, pargs, pattrs) = match &**producer {
            Expr::Call { f, args, attrs } => (f, args, attrs),
            _ => return None,
        };
        let op_name = match &**pf {
            Expr::Op(name) => name.as_str(),
            _ => return None,
        };
        let w = as_const(pargs.get(1)?)?;
        let new_w = match op_name {
            "nn.conv2d" => {
                // Scale must be per-output-channel: shapes (O,1,1), (1,O,1,1)
                // or scalar.
                let o = w.shape()[0];
                let per_chan = scale_per_channel(&scale_t, o)?;
                let wv = w.as_f32();
                let block: usize = w.shape()[1..].iter().product();
                let mut out = Vec::with_capacity(wv.len());
                for oc in 0..o {
                    let s = per_chan[oc];
                    out.extend(wv[oc * block..(oc + 1) * block].iter().map(|v| v * s));
                }
                Tensor::from_f32(w.shape().to_vec(), out)
            }
            "nn.dense" => {
                // w is (n, k); scale per output feature (n,) or scalar.
                let nfeat = w.shape()[0];
                let per = scale_per_channel(&scale_t, nfeat)?;
                let wv = w.as_f32();
                let k = w.shape()[1];
                let mut out = Vec::with_capacity(wv.len());
                for i in 0..nfeat {
                    out.extend(wv[i * k..(i + 1) * k].iter().map(|v| v * per[i]));
                }
                Tensor::from_f32(w.shape().to_vec(), out)
            }
            _ => return None,
        };
        Some(call_attrs(
            op_call(op_name, vec![]).as_call_f(),
            vec![pargs[0].clone(), constant(new_w)],
            pattrs.clone(),
        ))
    })
}

/// Extract per-channel scale factors; `None` if the scale is not a
/// per-channel (or scalar) constant.
fn scale_per_channel(scale: &Tensor, channels: usize) -> Option<Vec<f32>> {
    let n = scale.numel();
    if n == 1 {
        return Some(vec![scale.get_f64(0) as f32; channels]);
    }
    if n == channels {
        // Accept shapes (O,), (O,1,1), (1,O,1,1).
        let nontrivial: Vec<usize> =
            scale.shape().iter().cloned().filter(|&d| d != 1).collect();
        if nontrivial == vec![channels] || nontrivial.is_empty() {
            return Some(scale.to_f32_vec());
        }
    }
    None
}

fn is_const(e: &E) -> bool {
    matches!(&**e, Expr::Const(_))
}

fn as_const(e: &E) -> Option<Tensor> {
    match &**e {
        Expr::Const(t) => Some(t.clone()),
        _ => None,
    }
}

// Small helper so we can rebuild `op(...)` heads cleanly.
trait AsCallF {
    fn as_call_f(&self) -> E;
}

impl AsCallF for E {
    fn as_call_f(&self) -> E {
        match &**self {
            Expr::Call { f, .. } => f.clone(),
            _ => self.clone(),
        }
    }
}

pub fn run(m: &Module) -> Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = fold_scale_axis(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::eval_expr;
    use crate::ir::{self, print_expr, Module, Var};
    use crate::tensor::Rng;

    #[test]
    fn folds_post_conv_scale() {
        let mut rng = Rng::new(0);
        let x = rng.normal_tensor(&[1, 2, 4, 4], 1.0);
        let w = rng.normal_tensor(&[3, 2, 3, 3], 1.0);
        let scale = Tensor::from_f32(vec![3, 1, 1], vec![0.5, 2.0, 1.5]);
        let conv = ir::op_call_attrs(
            "nn.conv2d",
            vec![ir::constant(x), ir::constant(w)],
            ir::attrs(&[("padding", ir::AttrValue::Int(1))]),
        );
        let e = ir::op_call("multiply", vec![conv, ir::constant(scale)]);
        let m = Module::with_prelude();
        let before = eval_expr(&m, &e).unwrap();
        let folded = fold_scale_axis(&e);
        assert!(!print_expr(&folded).contains("multiply"), "{}", print_expr(&folded));
        let after = eval_expr(&m, &folded).unwrap();
        assert!(before.tensor().allclose(after.tensor(), 1e-4, 1e-4));
    }

    #[test]
    fn folds_dense_scale() {
        let mut rng = Rng::new(1);
        let x = rng.normal_tensor(&[2, 4], 1.0);
        let w = rng.normal_tensor(&[3, 4], 1.0);
        let scale = Tensor::from_f32(vec![3], vec![2.0, 0.5, 1.0]);
        let dense = ir::op_call("nn.dense", vec![ir::constant(x), ir::constant(w)]);
        let e = ir::op_call("multiply", vec![dense, ir::constant(scale)]);
        let m = Module::with_prelude();
        let before = eval_expr(&m, &e).unwrap();
        let folded = fold_scale_axis(&e);
        assert!(!print_expr(&folded).contains("multiply"));
        let after = eval_expr(&m, &folded).unwrap();
        assert!(before.tensor().allclose(after.tensor(), 1e-4, 1e-4));
    }

    #[test]
    fn non_constant_scale_untouched() {
        let sv = Var::fresh("s");
        let conv = ir::op_call(
            "nn.conv2d",
            vec![
                ir::constant(Tensor::zeros(&[1, 1, 2, 2], crate::tensor::DType::F32)),
                ir::constant(Tensor::zeros(&[1, 1, 1, 1], crate::tensor::DType::F32)),
            ],
        );
        let e = ir::op_call("multiply", vec![conv, ir::var(&sv)]);
        let folded = fold_scale_axis(&e);
        assert!(print_expr(&folded).contains("multiply"));
    }

    #[test]
    fn non_channel_scale_untouched() {
        // A full-tensor scale (wrong shape) must not fold.
        let conv = ir::op_call(
            "nn.conv2d",
            vec![
                ir::constant(Tensor::ones(&[1, 1, 2, 2], crate::tensor::DType::F32)),
                ir::constant(Tensor::ones(&[2, 1, 1, 1], crate::tensor::DType::F32)),
            ],
        );
        let scale = Tensor::ones(&[2, 2, 2], crate::tensor::DType::F32);
        let e = ir::op_call("multiply", vec![conv, ir::constant(scale)]);
        let folded = fold_scale_axis(&e);
        assert!(print_expr(&folded).contains("multiply"));
    }
}
