//! Compiler passes (paper §3.1.2, §4): traditional optimizations, AD, the
//! partial evaluator, fusion, quantization hooks, and the pass manager with
//! the -O0..-O3 tiers of §5.2.

pub mod ad;
pub mod ad_fwd;
pub mod alter_op_layout;
pub mod anf;
pub mod canonicalize;
pub mod combine_parallel_conv2d;
pub mod cse;
pub mod dce;
pub mod fold_constant;
pub mod fold_scale_axis;
pub mod fusion;
pub mod inline;
pub mod manager;
pub mod partial_eval;
pub mod purity;
pub mod tail_accum;
pub mod tune_kernels;

pub use ad::grad_expr;
pub use manager::{
    optimize, optimize_traced, optimize_with, OptLevel, PassRecord, PassResult,
    PassTrace, PipelineConfig,
};
