//! A-normal form conversion (used by the partial evaluator to keep effects
//! ordered — §4.3 — and by the backends, which require operator arguments
//! to be atoms).

use std::sync::Arc;

use crate::ir::{let_, var, Expr, Function, Var, E};

/// Convert an expression to ANF: every non-atomic subexpression of a call,
/// tuple, projection, etc. is let-bound first.
///
/// Arc-shared subtrees (the paper's §3.2.2 *implicit sharing* — zoo models
/// build residual blocks by reusing the same node) are bound once per
/// block via a pointer-keyed memo table, turning graph sharing into
/// explicit `let` sharing instead of exponential duplication.
pub fn to_anf(e: &E) -> E {
    let mut ctx = Ctx { bindings: Vec::new(), memo: std::collections::HashMap::new() };
    let body = anf_expr(e, &mut ctx, /*tail=*/ true);
    wrap(ctx.bindings, body)
}

struct Ctx {
    bindings: Vec<(Var, E)>,
    /// Arc address -> atom already bound in this block (pure exprs only).
    memo: std::collections::HashMap<usize, E>,
}

fn wrap(bindings: Vec<(Var, E)>, body: E) -> E {
    bindings
        .into_iter()
        .rev()
        .fold(body, |acc, (v, val)| let_(v, val, acc))
}

/// Return an atom for `e`, emitting bindings.
fn atomize(e: &E, ctx: &mut Ctx) -> E {
    let key = std::sync::Arc::as_ptr(e) as usize;
    let sharable = crate::pass::purity::is_pure(e);
    if sharable {
        if let Some(atom) = ctx.memo.get(&key) {
            return atom.clone();
        }
    }
    let v = anf_expr(e, ctx, false);
    let atom = if v.is_atomic() {
        v
    } else {
        let fresh = Var::fresh("t");
        ctx.bindings.push((fresh.clone(), v));
        var(&fresh)
    };
    if sharable {
        ctx.memo.insert(key, atom.clone());
    }
    atom
}

/// `tail` = this expression's value is returned directly (may stay compound).
fn anf_expr(e: &E, ctx: &mut Ctx, tail: bool) -> E {
    match &**e {
        Expr::Var(_) | Expr::Global(_) | Expr::Const(_) | Expr::Op(_) | Expr::Ctor(_) => {
            e.clone()
        }
        Expr::Let { var: v, value, body, .. } => {
            let value = anf_expr(value, ctx, false);
            ctx.bindings.push((v.clone(), value));
            anf_expr(body, ctx, tail)
        }
        Expr::Call { f, args, attrs } => {
            let f = match &**f {
                Expr::Op(_) | Expr::Ctor(_) => f.clone(),
                // Keep primitive (fused) callees in place: backends compile
                // `(fn primitive ...)(args)` as one kernel node.
                Expr::Func(func) if func.attrs.primitive => {
                    anf_expr(f, ctx, false)
                }
                _ => atomize(f, ctx),
            };
            let args = args.iter().map(|a| atomize(a, ctx)).collect();
            Arc::new(Expr::Call { f, args, attrs: attrs.clone() })
        }
        Expr::Tuple(es) => {
            Arc::new(Expr::Tuple(es.iter().map(|x| atomize(x, ctx)).collect()))
        }
        Expr::Proj(t, i) => Arc::new(Expr::Proj(atomize(t, ctx), *i)),
        Expr::If { cond, then_, else_ } => {
            let cond = atomize(cond, ctx);
            // Branches get their own binding scopes (they execute
            // conditionally — hoisting would change effects).
            Arc::new(Expr::If { cond, then_: to_anf(then_), else_: to_anf(else_) })
        }
        Expr::Match { scrut, arms } => {
            let scrut = atomize(scrut, ctx);
            let arms = arms.iter().map(|(p, a)| (p.clone(), to_anf(a))).collect();
            Arc::new(Expr::Match { scrut, arms })
        }
        Expr::Func(f) => Arc::new(Expr::Func(Function {
            params: f.params.clone(),
            ret: f.ret.clone(),
            body: to_anf(&f.body),
            attrs: f.attrs.clone(),
        })),
        Expr::Grad(g) => Arc::new(Expr::Grad(atomize(g, ctx))),
        Expr::RefNew(v) => Arc::new(Expr::RefNew(atomize(v, ctx))),
        Expr::RefRead(r) => Arc::new(Expr::RefRead(atomize(r, ctx))),
        Expr::RefWrite(r, v) => {
            let r = atomize(r, ctx);
            let v = atomize(v, ctx);
            Arc::new(Expr::RefWrite(r, v))
        }
    }
}

/// Is the expression already in ANF? (test helper / pass invariant check)
pub fn is_anf(e: &E) -> bool {
    fn atoms_only(args: &[E]) -> bool {
        args.iter().all(|a| a.is_atomic())
    }
    fn check(e: &E, tail: bool) -> bool {
        match &**e {
            Expr::Var(_) | Expr::Global(_) | Expr::Const(_) | Expr::Op(_) | Expr::Ctor(_) => true,
            Expr::Let { value, body, .. } => check(value, false) && check(body, tail),
            Expr::Call { f, args, .. } => {
                (f.is_atomic()) && atoms_only(args)
            }
            Expr::Tuple(es) => atoms_only(es),
            Expr::Proj(t, _) => t.is_atomic(),
            Expr::If { cond, then_, else_ } => {
                cond.is_atomic() && check(then_, true) && check(else_, true)
            }
            Expr::Match { scrut, arms } => {
                scrut.is_atomic() && arms.iter().all(|(_, a)| check(a, true))
            }
            Expr::Func(f) => check(&f.body, true),
            Expr::Grad(g) => g.is_atomic(),
            Expr::RefNew(v) => v.is_atomic(),
            Expr::RefRead(r) => r.is_atomic(),
            Expr::RefWrite(r, v) => r.is_atomic() && v.is_atomic(),
        }
    }
    check(e, true)
}

pub fn run(m: &crate::ir::Module) -> crate::ir::Module {
    m.map_defs(|_, f| {
        let mut nf = f.clone();
        nf.body = to_anf(&f.body);
        nf
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{eval_expr, Value};
    use crate::ir::{parse_expr, Module};

    fn same_value(src: &str) {
        let m = Module::with_prelude();
        let e = parse_expr(src).unwrap();
        let a = eval_expr(&m, &e).unwrap();
        let n = to_anf(&e);
        assert!(is_anf(&n), "not ANF: {}", crate::ir::print_expr(&n));
        let b = eval_expr(&m, &n).unwrap();
        match (&a, &b) {
            (Value::Tensor(x), Value::Tensor(y)) => assert_eq!(x, y),
            _ => {}
        }
    }

    #[test]
    fn nested_calls_flattened() {
        same_value("add(multiply(2f, 3f), add(1f, 1f))");
    }

    #[test]
    fn tuples_and_projections() {
        same_value("(add(1f, 2f), 4f).0");
    }

    #[test]
    fn if_branches_scoped() {
        same_value("if (less(1f, 2f)) { add(1f, 1f) } else { multiply(2f, 2f) }");
    }

    #[test]
    fn effects_stay_ordered() {
        // The write must still happen before the read.
        let m = Module::with_prelude();
        let e = parse_expr("let %r = ref(1f); %r := add(!%r, 1f); !%r").unwrap();
        let n = to_anf(&e);
        let out = eval_expr(&m, &n).unwrap();
        assert_eq!(out.tensor().f32_value(), 2.0);
    }

    #[test]
    fn recursion_preserved() {
        same_value(
            "let %f = fn (%i) { if (greater(%i, 0f)) { %f(subtract(%i, 1f)) } else { %i } };\n\
             %f(3f)",
        );
    }
}
