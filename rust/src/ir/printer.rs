//! Pretty-printer for the Relay text format (inverse of [`super::parser`]).

use std::fmt::Write;

use super::expr::{AttrValue, Expr, Function, Pattern, E};
use super::module::Module;

pub fn print_expr(e: &E) -> String {
    let mut p = Printer::new();
    p.expr(e);
    p.out
}

pub fn print_module(m: &Module) -> String {
    let mut p = Printer::new();
    for (name, td) in &m.types {
        // Skip prelude types when printing (they are implicit).
        if matches!(name.as_str(), "List" | "Option" | "Tree") {
            continue;
        }
        p.typedef(td);
    }
    for (name, f) in &m.defs {
        p.def(name, f);
    }
    p.out
}

struct Printer {
    out: String,
    indent: usize,
}

impl Printer {
    fn new() -> Printer {
        Printer { out: String::new(), indent: 0 }
    }

    fn nl(&mut self) {
        self.out.push('\n');
        for _ in 0..self.indent {
            self.out.push_str("  ");
        }
    }

    fn typedef(&mut self, td: &super::module::TypeDef) {
        let params = if td.params.is_empty() {
            String::new()
        } else {
            format!("<{}>", td.params.join(", "))
        };
        write!(self.out, "type {}{} {{", td.name, params).unwrap();
        self.indent += 1;
        for (c, fields) in &td.constructors {
            self.nl();
            if fields.is_empty() {
                write!(self.out, "{c}").unwrap();
            } else {
                let fs: Vec<String> = fields.iter().map(|t| t.to_string()).collect();
                write!(self.out, "{c}({})", fs.join(", ")).unwrap();
            }
            self.out.push(',');
        }
        self.indent -= 1;
        self.nl();
        self.out.push_str("}\n");
    }

    fn def(&mut self, name: &str, f: &Function) {
        write!(self.out, "def @{name}").unwrap();
        self.fn_sig_body(f);
        self.out.push('\n');
    }

    fn fn_sig_body(&mut self, f: &Function) {
        self.out.push('(');
        for (i, (p, t)) in f.params.iter().enumerate() {
            if i > 0 {
                self.out.push_str(", ");
            }
            write!(self.out, "{p}").unwrap();
            if let Some(t) = t {
                write!(self.out, ": {t}").unwrap();
            }
        }
        self.out.push(')');
        if let Some(r) = &f.ret {
            write!(self.out, " -> {r}").unwrap();
        }
        if f.attrs.primitive {
            self.out.push_str(" /* primitive */");
        }
        self.out.push_str(" {");
        self.indent += 1;
        self.nl();
        self.expr(&f.body);
        self.indent -= 1;
        self.nl();
        self.out.push('}');
    }

    fn attrs(&mut self, attrs: &super::expr::Attrs) {
        if attrs.is_empty() {
            return;
        }
        self.out.push_str(", ");
        let parts: Vec<String> = attrs
            .iter()
            .map(|(k, v)| {
                let vs = match v {
                    AttrValue::Int(i) => i.to_string(),
                    AttrValue::Float(f) => format!("{f}f"),
                    AttrValue::Bool(b) => b.to_string(),
                    AttrValue::Str(s) => format!("\"{s}\""),
                    AttrValue::IntVec(v) => format!(
                        "[{}]",
                        v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(", ")
                    ),
                };
                format!("{k}={vs}")
            })
            .collect();
        write!(self.out, "{}", parts.join(", ")).unwrap();
    }

    fn pattern(&mut self, p: &Pattern) {
        match p {
            Pattern::Wildcard => self.out.push('_'),
            Pattern::Var(v) => write!(self.out, "{v}").unwrap(),
            Pattern::Ctor(name, ps) => {
                write!(self.out, "{name}").unwrap();
                if !ps.is_empty() {
                    self.out.push('(');
                    for (i, p) in ps.iter().enumerate() {
                        if i > 0 {
                            self.out.push_str(", ");
                        }
                        self.pattern(p);
                    }
                    self.out.push(')');
                }
            }
            Pattern::Tuple(ps) => {
                self.out.push('(');
                for (i, p) in ps.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(", ");
                    }
                    self.pattern(p);
                }
                self.out.push(')');
            }
        }
    }

    /// Print a subexpression in argument position: binding/control forms
    /// are parenthesized so the text round-trips through the parser.
    fn arg_expr(&mut self, e: &E) {
        match &**e {
            Expr::Let { .. } | Expr::If { .. } | Expr::Match { .. } | Expr::RefWrite(..) => {
                self.out.push('(');
                self.expr(e);
                self.out.push(')');
            }
            _ => self.expr(e),
        }
    }

    fn expr(&mut self, e: &E) {
        match &**e {
            Expr::Var(v) => write!(self.out, "{v}").unwrap(),
            Expr::Global(g) => write!(self.out, "@{g}").unwrap(),
            Expr::Const(t) => {
                if t.numel() == 1 && t.rank() == 0 {
                    match t.dtype() {
                        crate::tensor::DType::Bool => {
                            write!(self.out, "{}", t.bool_value()).unwrap()
                        }
                        d if d.is_float() => {
                            write!(self.out, "{}f", t.get_f64(0)).unwrap()
                        }
                        _ => write!(self.out, "{}", t.get_f64(0) as i64).unwrap(),
                    }
                } else {
                    // Non-scalar constants print as a meta reference with
                    // shape info (cf. the paper's constant pool, Fig. 2).
                    write!(
                        self.out,
                        "meta[Constant][{:?}, {}]",
                        t.shape(),
                        t.dtype()
                    )
                    .unwrap()
                }
            }
            Expr::Op(name) => write!(self.out, "{name}").unwrap(),
            Expr::Ctor(name) => write!(self.out, "{name}").unwrap(),
            Expr::Call { f, args, attrs } => {
                self.expr(f);
                self.out.push('(');
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(", ");
                    }
                    self.arg_expr(a);
                }
                self.attrs(attrs);
                self.out.push(')');
            }
            Expr::Let { var, ty, value, body } => {
                write!(self.out, "let {var}").unwrap();
                if let Some(t) = ty {
                    write!(self.out, ": {t}").unwrap();
                }
                self.out.push_str(" = ");
                self.arg_expr(value);
                self.out.push(';');
                self.nl();
                self.expr(body);
            }
            Expr::Func(f) => {
                self.out.push_str("fn ");
                self.fn_sig_body(f);
            }
            Expr::Tuple(es) => {
                self.out.push('(');
                for (i, x) in es.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(", ");
                    }
                    self.arg_expr(x);
                }
                if es.len() == 1 {
                    self.out.push(',');
                }
                self.out.push(')');
            }
            Expr::Proj(t, i) => {
                self.arg_expr(t);
                write!(self.out, ".{i}").unwrap();
            }
            Expr::If { cond, then_, else_ } => {
                self.out.push_str("if (");
                self.expr(cond);
                self.out.push_str(") {");
                self.indent += 1;
                self.nl();
                self.expr(then_);
                self.indent -= 1;
                self.nl();
                self.out.push_str("} else {");
                self.indent += 1;
                self.nl();
                self.expr(else_);
                self.indent -= 1;
                self.nl();
                self.out.push('}');
            }
            Expr::Match { scrut, arms } => {
                self.out.push_str("match (");
                self.expr(scrut);
                self.out.push_str(") {");
                self.indent += 1;
                for (p, a) in arms {
                    self.nl();
                    self.out.push_str("| ");
                    self.pattern(p);
                    self.out.push_str(" -> ");
                    self.expr(a);
                }
                self.indent -= 1;
                self.nl();
                self.out.push('}');
            }
            Expr::Grad(g) => {
                self.out.push_str("grad(");
                self.expr(g);
                self.out.push(')');
            }
            Expr::RefNew(v) => {
                self.out.push_str("ref(");
                self.expr(v);
                self.out.push(')');
            }
            Expr::RefRead(r) => {
                self.out.push('!');
                self.expr(r);
            }
            Expr::RefWrite(r, v) => {
                self.expr(r);
                self.out.push_str(" := ");
                self.expr(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::expr::*;
    use super::*;

    #[test]
    fn prints_let_chain() {
        let x = Var::fresh("x");
        let e = let_(x.clone(), scalar(1.0), op_call("add", vec![var(&x), var(&x)]));
        let s = print_expr(&e);
        assert!(s.contains("let %x_"));
        assert!(s.contains("add("));
    }

    #[test]
    fn prints_if_and_match() {
        let e = if_(
            constant(crate::tensor::Tensor::scalar_bool(true)),
            scalar(1.0),
            scalar(2.0),
        );
        let s = print_expr(&e);
        assert!(s.contains("if (true)"));
        let m = match_(
            unit(),
            vec![(Pattern::Wildcard, scalar(0.0))],
        );
        assert!(print_expr(&m).contains("| _ ->"));
    }

    #[test]
    fn prints_refs() {
        let e = ref_write(ref_new(scalar(0.0)), scalar(1.0));
        let s = print_expr(&e);
        assert!(s.contains("ref(0f)"));
        assert!(s.contains(":="));
    }
}
