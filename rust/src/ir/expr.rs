//! The Relay expression language (paper Fig. 1 / appendix Fig. 14).
//!
//! Expressions are immutable `Arc` trees; passes rewrite by rebuilding.
//! Variables carry globally unique ids so passes never capture.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use super::types::Type;
use crate::tensor::Tensor;

pub type E = Arc<Expr>;

static NEXT_VAR_ID: AtomicU32 = AtomicU32::new(1);

/// A local variable (`%x`). Identity is the numeric id; the name is a hint.
#[derive(Clone, Debug, Eq)]
pub struct Var {
    pub name: String,
    pub id: u32,
}

impl Var {
    /// Fresh variable with a unique id.
    pub fn fresh(name: impl Into<String>) -> Var {
        Var { name: name.into(), id: NEXT_VAR_ID.fetch_add(1, Ordering::Relaxed) }
    }
}

impl PartialEq for Var {
    fn eq(&self, other: &Self) -> bool {
        self.id == other.id
    }
}

impl std::hash::Hash for Var {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.id.hash(state);
    }
}

impl PartialOrd for Var {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Var {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.id.cmp(&other.id)
    }
}

impl fmt::Display for Var {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{}_{}", self.name, self.id)
    }
}

/// Attribute values on operator calls (strides, axes, dtypes, ...).
#[derive(Clone, Debug, PartialEq)]
pub enum AttrValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    IntVec(Vec<i64>),
}

impl AttrValue {
    pub fn as_int(&self) -> i64 {
        match self {
            AttrValue::Int(i) => *i,
            _ => panic!("attr is not an int: {self:?}"),
        }
    }

    pub fn as_str(&self) -> &str {
        match self {
            AttrValue::Str(s) => s,
            _ => panic!("attr is not a str: {self:?}"),
        }
    }

    pub fn as_int_vec(&self) -> &[i64] {
        match self {
            AttrValue::IntVec(v) => v,
            _ => panic!("attr is not an int vec: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> bool {
        match self {
            AttrValue::Bool(b) => *b,
            _ => panic!("attr is not a bool: {self:?}"),
        }
    }

    pub fn as_float(&self) -> f64 {
        match self {
            AttrValue::Float(f) => *f,
            AttrValue::Int(i) => *i as f64,
            _ => panic!("attr is not a float: {self:?}"),
        }
    }
}

pub type Attrs = BTreeMap<String, AttrValue>;

/// Pattern language for `match` (paper appendix "Pattern p").
#[derive(Clone, Debug, PartialEq)]
pub enum Pattern {
    Wildcard,
    Var(Var),
    /// Constructor pattern `Cons(p1, p2)`.
    Ctor(String, Vec<Pattern>),
    Tuple(Vec<Pattern>),
}

impl Pattern {
    /// Variables bound by this pattern, in order.
    pub fn bound_vars(&self) -> Vec<Var> {
        match self {
            Pattern::Wildcard => vec![],
            Pattern::Var(v) => vec![v.clone()],
            Pattern::Ctor(_, ps) | Pattern::Tuple(ps) => {
                ps.iter().flat_map(|p| p.bound_vars()).collect()
            }
        }
    }
}

/// Function attribute: the fusion pass marks extracted functions primitive
/// so backends compile them as single fused kernels (paper §4.4.1).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FnAttrs {
    pub primitive: bool,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Function {
    pub params: Vec<(Var, Option<Type>)>,
    pub ret: Option<Type>,
    pub body: E,
    pub attrs: FnAttrs,
}

impl Function {
    pub fn new(params: Vec<(Var, Option<Type>)>, body: E) -> Function {
        Function { params, ret: None, body, attrs: FnAttrs::default() }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// `%x` — local variable.
    Var(Var),
    /// `@f` — global definition reference.
    Global(String),
    /// Constant tensor.
    Const(Tensor),
    /// Operator reference by registry name (`add`, `nn.conv2d`, ...).
    Op(String),
    /// ADT constructor reference (`Cons`, `Nil`, ...).
    Ctor(String),
    /// `f(a1, ..., an)` — attrs carry operator options.
    Call { f: E, args: Vec<E>, attrs: Attrs },
    /// `let %x (: T)? = v; body`.
    Let { var: Var, ty: Option<Type>, value: E, body: E },
    /// `fn (params) (-> T)? { body }`.
    Func(Function),
    /// `(e1, ..., en)`; unit is the empty tuple.
    Tuple(Vec<E>),
    /// `e.n` — tuple projection.
    Proj(E, usize),
    /// `if (c) { t } else { e }` — guard is a rank-0 bool tensor.
    If { cond: E, then_: E, else_: E },
    /// `match (e) { p -> e, ... }`.
    Match { scrut: E, arms: Vec<(Pattern, E)> },
    /// `grad(f)` — reverse-mode AD macro (paper §4.2).
    Grad(E),
    /// `ref(e)`, `!e`, `lhs := rhs` — ML-style references.
    RefNew(E),
    RefRead(E),
    RefWrite(E, E),
}

impl Expr {
    pub fn is_atomic(&self) -> bool {
        matches!(
            self,
            Expr::Var(_) | Expr::Global(_) | Expr::Const(_) | Expr::Op(_) | Expr::Ctor(_)
        )
    }
}

// ---------------------------------------------------------------------------
// Constructors
// ---------------------------------------------------------------------------

pub fn var(v: &Var) -> E {
    Arc::new(Expr::Var(v.clone()))
}

pub fn global(name: impl Into<String>) -> E {
    Arc::new(Expr::Global(name.into()))
}

pub fn constant(t: Tensor) -> E {
    Arc::new(Expr::Const(t))
}

pub fn scalar(v: f32) -> E {
    constant(Tensor::scalar_f32(v))
}

pub fn op(name: impl Into<String>) -> E {
    Arc::new(Expr::Op(name.into()))
}

pub fn ctor(name: impl Into<String>) -> E {
    Arc::new(Expr::Ctor(name.into()))
}

pub fn call(f: E, args: Vec<E>) -> E {
    Arc::new(Expr::Call { f, args, attrs: Attrs::new() })
}

pub fn call_attrs(f: E, args: Vec<E>, attrs: Attrs) -> E {
    Arc::new(Expr::Call { f, args, attrs })
}

/// Call an operator by name.
pub fn op_call(name: &str, args: Vec<E>) -> E {
    call(op(name), args)
}

pub fn op_call_attrs(name: &str, args: Vec<E>, attrs: Attrs) -> E {
    call_attrs(op(name), args, attrs)
}

pub fn let_(v: Var, value: E, body: E) -> E {
    Arc::new(Expr::Let { var: v, ty: None, value, body })
}

pub fn func(params: Vec<(Var, Option<Type>)>, body: E) -> E {
    Arc::new(Expr::Func(Function::new(params, body)))
}

pub fn tuple(es: Vec<E>) -> E {
    Arc::new(Expr::Tuple(es))
}

pub fn unit() -> E {
    tuple(vec![])
}

pub fn proj(e: E, i: usize) -> E {
    Arc::new(Expr::Proj(e, i))
}

pub fn if_(cond: E, then_: E, else_: E) -> E {
    Arc::new(Expr::If { cond, then_, else_ })
}

pub fn match_(scrut: E, arms: Vec<(Pattern, E)>) -> E {
    Arc::new(Expr::Match { scrut, arms })
}

pub fn grad(e: E) -> E {
    Arc::new(Expr::Grad(e))
}

pub fn ref_new(e: E) -> E {
    Arc::new(Expr::RefNew(e))
}

pub fn ref_read(e: E) -> E {
    Arc::new(Expr::RefRead(e))
}

pub fn ref_write(r: E, v: E) -> E {
    Arc::new(Expr::RefWrite(r, v))
}

/// Helper to build attrs inline.
pub fn attrs(pairs: &[(&str, AttrValue)]) -> Attrs {
    pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_vars_are_unique() {
        let a = Var::fresh("x");
        let b = Var::fresh("x");
        assert_ne!(a, b);
        assert_eq!(a, a.clone());
    }

    #[test]
    fn pattern_bound_vars() {
        let v1 = Var::fresh("a");
        let v2 = Var::fresh("b");
        let p = Pattern::Ctor(
            "Cons".into(),
            vec![Pattern::Var(v1.clone()), Pattern::Tuple(vec![Pattern::Var(v2.clone()), Pattern::Wildcard])],
        );
        assert_eq!(p.bound_vars(), vec![v1, v2]);
    }

    #[test]
    fn builders_compose() {
        let x = Var::fresh("x");
        let e = let_(x.clone(), scalar(1.0), op_call("add", vec![var(&x), var(&x)]));
        match &*e {
            Expr::Let { var: v, .. } => assert_eq!(*v, x),
            _ => panic!(),
        }
    }

    #[test]
    fn attr_accessors() {
        let a = attrs(&[("axis", AttrValue::Int(1)), ("name", AttrValue::Str("s".into()))]);
        assert_eq!(a["axis"].as_int(), 1);
        assert_eq!(a["name"].as_str(), "s");
    }
}
