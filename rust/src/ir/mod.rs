//! The Relay IR (paper §3.2): a functional, statically-typed, differentiable
//! expression language with tensors, tuples, `let`, first-class functions,
//! `if`/`match` control flow, ADTs, and ML-style references.

pub mod expr;
pub mod hash;
pub mod module;
pub mod parser;
pub mod printer;
pub mod types;
pub mod visit;

pub use expr::{
    attrs, call, call_attrs, constant, ctor, func, global, grad, if_, let_, match_, op,
    op_call, op_call_attrs, proj, ref_new, ref_read, ref_write, scalar, tuple, unit, var,
    AttrValue, Attrs, Expr, FnAttrs, Function, Pattern, Var, E,
};
pub use hash::{alpha_eq, module_structural_hash, modules_structurally_eq, structural_hash};
pub use module::{list_expr, Module, TypeDef};
pub use parser::{parse_expr, parse_module, ParseError};
pub use printer::{print_expr, print_module};
pub use types::{Dim, Type};
pub use visit::{
    collect, count_nodes, free_vars, map_children, refresh, rewrite_postorder, subst,
    subst1, visit_children,
};
