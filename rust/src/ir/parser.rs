//! Parser for the Relay text format (paper Fig. 1 grammar).
//!
//! Covers the constructs the evaluation uses: defs, typedefs, let, fn, if,
//! match, tuples/projection, operator calls with attributes, refs, grad,
//! scalar constants. Shapes in types must be concrete or `?` (Any).

use std::collections::BTreeMap;

use super::expr::{self, AttrValue, Attrs, Expr, Function, Pattern, Var, E};
use super::module::{Module, TypeDef};
use super::types::{Dim, Type};
use crate::tensor::{DType, Tensor};

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

type Result<T> = std::result::Result<T, ParseError>;

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),   // add, Cons, Tensor, fn, let ...
    LocalVar(String),  // %x
    GlobalVar(String), // @f
    Int(i64),
    Float(f64),
    Str(String),
    Sym(String), // punctuation, multi-char ops
}

fn tokenize(src: &str) -> Result<Vec<(Tok, usize)>> {
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // comments
        if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
            i += 2;
            while i + 1 < b.len() && !(b[i] == '*' && b[i + 1] == '/') {
                i += 1;
            }
            i += 2;
            continue;
        }
        let start = i;
        if c == '%' || c == '@' {
            i += 1;
            let s = read_ident(&b, &mut i);
            if s.is_empty() {
                return Err(ParseError { msg: format!("dangling {c}"), pos: start });
            }
            out.push((
                if c == '%' { Tok::LocalVar(s) } else { Tok::GlobalVar(s) },
                start,
            ));
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let s = read_ident(&b, &mut i);
            out.push((Tok::Ident(s), start));
            continue;
        }
        if c.is_ascii_digit() || (c == '-' && i + 1 < b.len() && b[i + 1].is_ascii_digit()) {
            let mut j = i + 1;
            let mut is_float = false;
            while j < b.len() && (b[j].is_ascii_digit() || b[j] == '.' || b[j] == 'e'
                || (b[j] == '-' && b[j - 1] == 'e'))
            {
                if b[j] == '.' || b[j] == 'e' {
                    is_float = true;
                }
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            // trailing 'f' marks a float literal
            if j < b.len() && b[j] == 'f' {
                is_float = true;
                j += 1;
            }
            i = j;
            if is_float {
                let v: f64 = text.parse().map_err(|_| ParseError {
                    msg: format!("bad float {text}"),
                    pos: start,
                })?;
                out.push((Tok::Float(v), start));
            } else {
                let v: i64 = text.parse().map_err(|_| ParseError {
                    msg: format!("bad int {text}"),
                    pos: start,
                })?;
                out.push((Tok::Int(v), start));
            }
            continue;
        }
        if c == '"' {
            let mut j = i + 1;
            while j < b.len() && b[j] != '"' {
                j += 1;
            }
            let s: String = b[i + 1..j].iter().collect();
            i = j + 1;
            out.push((Tok::Str(s), start));
            continue;
        }
        // multi-char symbols
        let two: String = b[i..(i + 2).min(b.len())].iter().collect();
        if two == "->" || two == ":=" {
            out.push((Tok::Sym(two), start));
            i += 2;
            continue;
        }
        out.push((Tok::Sym(c.to_string()), start));
        i += 1;
    }
    Ok(out)
}

fn read_ident(b: &[char], i: &mut usize) -> String {
    let start = *i;
    while *i < b.len() {
        let c = b[*i];
        if c.is_alphanumeric() || c == '_' {
            *i += 1;
        } else if c == '.' && *i + 1 < b.len() && (b[*i + 1].is_alphabetic() || b[*i + 1] == '_')
        {
            // dotted operator names like `nn.conv2d`; `.1` stays a
            // projection, not part of the identifier.
            *i += 1;
        } else {
            break;
        }
    }
    b[start..*i].iter().collect()
}

pub struct Parser {
    toks: Vec<(Tok, usize)>,
    pos: usize,
    /// Scoped name -> Var environment for locals.
    scopes: Vec<BTreeMap<String, Var>>,
}

impl Parser {
    fn new(src: &str) -> Result<Parser> {
        Ok(Parser { toks: tokenize(src)?, pos: 0, scopes: vec![BTreeMap::new()] })
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _)| t)
    }

    fn peek2(&self) -> Option<&Tok> {
        self.toks.get(self.pos + 1).map(|(t, _)| t)
    }

    fn here(&self) -> usize {
        self.toks.get(self.pos).map(|(_, p)| *p).unwrap_or(usize::MAX)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|(t, _)| t.clone());
        self.pos += 1;
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T> {
        Err(ParseError { msg: msg.into(), pos: self.here() })
    }

    fn expect_sym(&mut self, s: &str) -> Result<()> {
        match self.bump() {
            Some(Tok::Sym(x)) if x == s => Ok(()),
            other => self.err(format!("expected '{s}', got {other:?}")),
        }
    }

    fn eat_sym(&mut self, s: &str) -> bool {
        if matches!(self.peek(), Some(Tok::Sym(x)) if x == s) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn eat_ident(&mut self, kw: &str) -> bool {
        if matches!(self.peek(), Some(Tok::Ident(x)) if x == kw) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn lookup_var(&self, name: &str) -> Option<Var> {
        for scope in self.scopes.iter().rev() {
            if let Some(v) = scope.get(name) {
                return Some(v.clone());
            }
        }
        None
    }

    fn bind_var(&mut self, name: &str) -> Var {
        let v = Var::fresh(name);
        self.scopes.last_mut().unwrap().insert(name.to_string(), v.clone());
        v
    }

    fn push_scope(&mut self) {
        self.scopes.push(BTreeMap::new());
    }

    fn pop_scope(&mut self) {
        self.scopes.pop();
    }

    // ------------------------------------------------------------- types

    fn parse_type(&mut self) -> Result<Type> {
        match self.peek().cloned() {
            Some(Tok::Ident(id)) if id == "Tensor" => {
                self.bump();
                self.expect_sym("[")?;
                self.expect_sym("(")?;
                let mut dims = Vec::new();
                while !self.eat_sym(")") {
                    match self.bump() {
                        Some(Tok::Int(d)) => dims.push(Dim::Known(d as usize)),
                        Some(Tok::Sym(s)) if s == "?" => dims.push(Dim::Any),
                        other => return self.err(format!("bad dim {other:?}")),
                    }
                    self.eat_sym(",");
                }
                self.expect_sym(",")?;
                let dt = match self.bump() {
                    Some(Tok::Ident(d)) => DType::parse(&d)
                        .ok_or_else(|| ParseError { msg: format!("bad dtype {d}"), pos: self.here() })?,
                    other => return self.err(format!("bad dtype token {other:?}")),
                };
                self.expect_sym("]")?;
                Ok(Type::Tensor { shape: dims, dtype: dt })
            }
            Some(Tok::Ident(id)) if id == "Ref" => {
                self.bump();
                self.expect_sym("[")?;
                let inner = self.parse_type()?;
                self.expect_sym("]")?;
                Ok(Type::Ref(Box::new(inner)))
            }
            Some(Tok::Ident(id)) if id == "fn" => {
                self.bump();
                self.expect_sym("(")?;
                let mut params = Vec::new();
                while !self.eat_sym(")") {
                    params.push(self.parse_type()?);
                    self.eat_sym(",");
                }
                self.expect_sym("->")?;
                let ret = self.parse_type()?;
                Ok(Type::Func { params, ret: Box::new(ret) })
            }
            Some(Tok::Ident(name)) => {
                self.bump();
                let mut args = Vec::new();
                if self.eat_sym("[") {
                    while !self.eat_sym("]") {
                        args.push(self.parse_type()?);
                        self.eat_sym(",");
                    }
                }
                Ok(Type::Adt { name, args })
            }
            Some(Tok::Sym(s)) if s == "(" => {
                self.bump();
                let mut ts = Vec::new();
                while !self.eat_sym(")") {
                    ts.push(self.parse_type()?);
                    self.eat_sym(",");
                }
                Ok(Type::Tuple(ts))
            }
            other => self.err(format!("expected type, got {other:?}")),
        }
    }

    // ---------------------------------------------------------- patterns

    fn parse_pattern(&mut self) -> Result<Pattern> {
        match self.peek().cloned() {
            Some(Tok::Ident(id)) if id == "_" => {
                self.bump();
                Ok(Pattern::Wildcard)
            }
            Some(Tok::LocalVar(name)) => {
                self.bump();
                Ok(Pattern::Var(self.bind_var(&name)))
            }
            Some(Tok::Ident(ctor)) => {
                self.bump();
                let mut fields = Vec::new();
                if self.eat_sym("(") {
                    while !self.eat_sym(")") {
                        fields.push(self.parse_pattern()?);
                        self.eat_sym(",");
                    }
                }
                Ok(Pattern::Ctor(ctor, fields))
            }
            Some(Tok::Sym(s)) if s == "(" => {
                self.bump();
                let mut ps = Vec::new();
                while !self.eat_sym(")") {
                    ps.push(self.parse_pattern()?);
                    self.eat_sym(",");
                }
                Ok(Pattern::Tuple(ps))
            }
            other => self.err(format!("expected pattern, got {other:?}")),
        }
    }

    // --------------------------------------------------------- expressions

    fn parse_expr(&mut self) -> Result<E> {
        // let binding chain
        if self.eat_ident("let") {
            let name = match self.bump() {
                Some(Tok::LocalVar(n)) => n,
                other => return self.err(format!("expected %var after let, got {other:?}")),
            };
            let ty = if self.eat_sym(":") { Some(self.parse_type()?) } else { None };
            self.expect_sym("=")?;
            // `let %f = fn ...` is recursive (Fig. 2's loop encoding): bind
            // the name before parsing the function body.
            let recursive = matches!(self.peek(), Some(Tok::Ident(id)) if id == "fn");
            let v = Var::fresh(&name);
            if recursive {
                self.scopes.last_mut().unwrap().insert(name.clone(), v.clone());
            }
            let value = self.parse_postfix()?;
            self.expect_sym(";")?;
            if !recursive {
                self.scopes.last_mut().unwrap().insert(name.clone(), v.clone());
            }
            let body = self.parse_expr()?;
            return Ok(std::sync::Arc::new(Expr::Let { var: v, ty, value, body }));
        }
        let e = self.parse_postfix()?;
        // `e; rest` sequencing sugar (paper grammar: `let %_ = e; e`).
        if self.eat_sym(";") {
            let rest = self.parse_expr()?;
            return Ok(expr::let_(Var::fresh("_"), e, rest));
        }
        Ok(e)
    }

    /// A non-let expression with postfix call/projection/:= chains.
    fn parse_postfix(&mut self) -> Result<E> {
        let mut e = self.parse_atom()?;
        loop {
            if self.eat_sym("(") {
                let (args, attrs) = self.parse_call_args()?;
                e = expr::call_attrs(e, args, attrs);
            } else if matches!(self.peek(), Some(Tok::Sym(s)) if s == ".") {
                // projection only when followed by an int
                if let Some(Tok::Int(_)) = self.peek2() {
                    self.bump();
                    let i = match self.bump() {
                        Some(Tok::Int(i)) => i as usize,
                        _ => unreachable!(),
                    };
                    e = expr::proj(e, i);
                } else {
                    break;
                }
            } else if matches!(self.peek(), Some(Tok::Sym(s)) if s == ":=") {
                self.bump();
                let v = self.parse_postfix()?;
                e = expr::ref_write(e, v);
            } else {
                break;
            }
        }
        Ok(e)
    }

    fn parse_call_args(&mut self) -> Result<(Vec<E>, Attrs)> {
        let mut args = Vec::new();
        let mut attrs = Attrs::new();
        while !self.eat_sym(")") {
            // attr form: ident '=' value
            if let (Some(Tok::Ident(k)), Some(Tok::Sym(eq))) = (self.peek(), self.peek2()) {
                if eq == "=" {
                    let k = k.clone();
                    self.bump();
                    self.bump();
                    let v = self.parse_attr_value()?;
                    attrs.insert(k, v);
                    self.eat_sym(",");
                    continue;
                }
            }
            args.push(self.parse_postfix()?);
            self.eat_sym(",");
        }
        Ok((args, attrs))
    }

    fn parse_attr_value(&mut self) -> Result<AttrValue> {
        match self.bump() {
            Some(Tok::Int(i)) => Ok(AttrValue::Int(i)),
            Some(Tok::Float(f)) => Ok(AttrValue::Float(f)),
            Some(Tok::Str(s)) => Ok(AttrValue::Str(s)),
            Some(Tok::Ident(id)) if id == "true" => Ok(AttrValue::Bool(true)),
            Some(Tok::Ident(id)) if id == "false" => Ok(AttrValue::Bool(false)),
            Some(Tok::Ident(id)) => Ok(AttrValue::Str(id)),
            Some(Tok::Sym(s)) if s == "[" => {
                let mut v = Vec::new();
                while !self.eat_sym("]") {
                    match self.bump() {
                        Some(Tok::Int(i)) => v.push(i),
                        other => return self.err(format!("bad int-vec item {other:?}")),
                    }
                    self.eat_sym(",");
                }
                Ok(AttrValue::IntVec(v))
            }
            other => self.err(format!("bad attr value {other:?}")),
        }
    }

    fn parse_atom(&mut self) -> Result<E> {
        match self.peek().cloned() {
            Some(Tok::LocalVar(name)) => {
                self.bump();
                match self.lookup_var(&name) {
                    Some(v) => Ok(expr::var(&v)),
                    None => self.err(format!("unbound variable %{name}")),
                }
            }
            Some(Tok::GlobalVar(name)) => {
                self.bump();
                Ok(expr::global(name))
            }
            Some(Tok::Int(i)) => {
                self.bump();
                Ok(expr::constant(Tensor::scalar_i64(i)))
            }
            Some(Tok::Float(f)) => {
                self.bump();
                Ok(expr::scalar(f as f32))
            }
            Some(Tok::Ident(id)) => match id.as_str() {
                "true" | "false" => {
                    self.bump();
                    Ok(expr::constant(Tensor::scalar_bool(id == "true")))
                }
                "fn" => {
                    self.bump();
                    let f = self.parse_fn_rest()?;
                    Ok(std::sync::Arc::new(Expr::Func(f)))
                }
                "if" => {
                    self.bump();
                    self.expect_sym("(")?;
                    let cond = self.parse_postfix()?;
                    self.expect_sym(")")?;
                    self.expect_sym("{")?;
                    self.push_scope();
                    let t = self.parse_expr()?;
                    self.pop_scope();
                    self.expect_sym("}")?;
                    if !self.eat_ident("else") {
                        return self.err("if requires else");
                    }
                    self.expect_sym("{")?;
                    self.push_scope();
                    let e = self.parse_expr()?;
                    self.pop_scope();
                    self.expect_sym("}")?;
                    Ok(expr::if_(cond, t, e))
                }
                "match" => {
                    self.bump();
                    self.expect_sym("(")?;
                    let scrut = self.parse_postfix()?;
                    self.expect_sym(")")?;
                    self.expect_sym("{")?;
                    let mut arms = Vec::new();
                    while !self.eat_sym("}") {
                        self.eat_sym("|");
                        self.push_scope();
                        let p = self.parse_pattern()?;
                        self.expect_sym("->")?;
                        let a = self.parse_expr()?;
                        self.pop_scope();
                        arms.push((p, a));
                        self.eat_sym(",");
                    }
                    Ok(expr::match_(scrut, arms))
                }
                "grad" => {
                    self.bump();
                    self.expect_sym("(")?;
                    let g = self.parse_postfix()?;
                    self.expect_sym(")")?;
                    Ok(expr::grad(g))
                }
                "ref" => {
                    self.bump();
                    self.expect_sym("(")?;
                    let v = self.parse_postfix()?;
                    self.expect_sym(")")?;
                    Ok(expr::ref_new(v))
                }
                _ => {
                    self.bump();
                    // Capitalized identifiers are ADT constructors, the
                    // rest are operator names.
                    if id.chars().next().unwrap().is_uppercase() {
                        Ok(expr::ctor(id))
                    } else {
                        Ok(expr::op(id))
                    }
                }
            },
            Some(Tok::Sym(s)) if s == "(" => {
                self.bump();
                let mut es = Vec::new();
                let mut trailing_comma = false;
                while !self.eat_sym(")") {
                    // Full expressions (incl. let-chains) are allowed inside
                    // parens; the printer parenthesizes them in argument
                    // position.
                    es.push(self.parse_expr()?);
                    trailing_comma = self.eat_sym(",");
                }
                if es.len() == 1 && !trailing_comma {
                    Ok(es.pop().unwrap())
                } else {
                    Ok(expr::tuple(es))
                }
            }
            Some(Tok::Sym(s)) if s == "!" => {
                self.bump();
                let r = self.parse_postfix()?;
                Ok(expr::ref_read(r))
            }
            other => self.err(format!("expected expression, got {other:?}")),
        }
    }

    fn parse_fn_rest(&mut self) -> Result<Function> {
        self.expect_sym("(")?;
        self.push_scope();
        let mut params = Vec::new();
        while !self.eat_sym(")") {
            let name = match self.bump() {
                Some(Tok::LocalVar(n)) => n,
                other => return self.err(format!("expected param, got {other:?}")),
            };
            let ty = if self.eat_sym(":") { Some(self.parse_type()?) } else { None };
            params.push((self.bind_var(&name), ty));
            self.eat_sym(",");
        }
        let ret = if self.eat_sym("->") { Some(self.parse_type()?) } else { None };
        self.expect_sym("{")?;
        let body = self.parse_expr()?;
        self.expect_sym("}")?;
        self.pop_scope();
        Ok(Function { params, ret, body, attrs: Default::default() })
    }

    // ----------------------------------------------------------- program

    fn parse_module(&mut self) -> Result<Module> {
        let mut m = Module::with_prelude();
        loop {
            if self.eat_ident("def") {
                let name = match self.bump() {
                    Some(Tok::GlobalVar(n)) => n,
                    other => return self.err(format!("expected @name, got {other:?}")),
                };
                let f = self.parse_fn_rest()?;
                m.add_def(name, f);
            } else if self.eat_ident("type") {
                let name = match self.bump() {
                    Some(Tok::Ident(n)) => n,
                    other => return self.err(format!("expected type name, got {other:?}")),
                };
                let mut params = Vec::new();
                if self.eat_sym("<") {
                    while !self.eat_sym(">") {
                        match self.bump() {
                            Some(Tok::Ident(p)) => params.push(p),
                            other => return self.err(format!("bad type param {other:?}")),
                        }
                        self.eat_sym(",");
                    }
                }
                self.expect_sym("{")?;
                let mut ctors = Vec::new();
                while !self.eat_sym("}") {
                    let cname = match self.bump() {
                        Some(Tok::Ident(c)) => c,
                        other => return self.err(format!("bad ctor {other:?}")),
                    };
                    let mut fields = Vec::new();
                    if self.eat_sym("(") {
                        while !self.eat_sym(")") {
                            fields.push(self.parse_type()?);
                            self.eat_sym(",");
                        }
                    }
                    ctors.push((cname, fields));
                    self.eat_sym(",");
                }
                m.add_type(TypeDef { name, params, constructors: ctors });
            } else if self.peek().is_none() {
                break;
            } else {
                return self.err(format!("expected def/type, got {:?}", self.peek()));
            }
        }
        Ok(m)
    }
}

/// Parse a full module (defs + typedefs).
pub fn parse_module(src: &str) -> Result<Module> {
    Parser::new(src)?.parse_module()
}

/// Parse a single expression.
pub fn parse_expr(src: &str) -> Result<E> {
    let mut p = Parser::new(src)?;
    let e = p.parse_expr()?;
    if p.peek().is_some() {
        return p.err("trailing input");
    }
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::print_expr;

    #[test]
    fn parses_let_and_call() {
        let e = parse_expr("let %x = 1f; add(%x, %x)").unwrap();
        let s = print_expr(&e);
        assert!(s.contains("let %x_"));
        assert!(s.contains("add("));
    }

    #[test]
    fn parses_fn_with_types() {
        let e = parse_expr("fn (%x: Tensor[(2, 2), float32]) { relu(%x) }").unwrap();
        match &*e {
            Expr::Func(f) => {
                assert_eq!(f.params.len(), 1);
                assert!(f.params[0].1.is_some());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_if_else() {
        let e = parse_expr("if (true) { 1f } else { 2f }").unwrap();
        assert!(matches!(&*e, Expr::If { .. }));
    }

    #[test]
    fn parses_match_with_ctors() {
        let e = parse_expr(
            "match (Nil()) { | Cons(%h, %t) -> %h | Nil -> 0f }",
        )
        .unwrap();
        match &*e {
            Expr::Match { arms, .. } => assert_eq!(arms.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn parses_attrs() {
        let e = parse_expr("nn.conv2d(%0, %1, strides=[2, 2], padding=1)");
        // %0/%1 unbound -> error; bind them via a fn wrapper:
        assert!(e.is_err());
        let e = parse_expr("fn (%x, %w) { nn.conv2d(%x, %w, strides=[2, 2], padding=1) }")
            .unwrap();
        match &*e {
            Expr::Func(f) => match &*f.body {
                Expr::Call { attrs, .. } => {
                    assert_eq!(attrs["strides"].as_int_vec(), &[2, 2]);
                    assert_eq!(attrs["padding"].as_int(), 1);
                }
                _ => panic!(),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn parses_refs_and_grad() {
        let e = parse_expr("let %r = ref(0f); %r := 1f; !%r").unwrap();
        assert!(print_expr(&e).contains(":="));
        let g = parse_expr("grad(fn (%x) { multiply(%x, %x) })").unwrap();
        assert!(matches!(&*g, Expr::Grad(_)));
    }

    #[test]
    fn parses_module_with_defs_and_types() {
        let m = parse_module(
            "type Pair<a, b> { MkPair(a, b), }\n\
             def @id(%x) { %x }\n\
             def @main() { @id(1f) }",
        )
        .unwrap();
        assert!(m.def("id").is_some());
        assert!(m.def("main").is_some());
        assert!(m.ctor_info("MkPair").is_some());
    }

    #[test]
    fn unbound_var_is_error() {
        assert!(parse_expr("%nope").is_err());
    }

    #[test]
    fn roundtrip_print_parse() {
        let src = "let %x = 1f; let %y = add(%x, 2f); multiply(%y, %y)";
        let e1 = parse_expr(src).unwrap();
        let printed = print_expr(&e1);
        let e2 = parse_expr(&printed).unwrap();
        assert!(crate::ir::hash::alpha_eq(&e1, &e2));
    }

    #[test]
    fn graph_style_sequencing() {
        // `e; e` sugar is expressed via let with wildcard-ish var in the
        // printer; the parser accepts explicit lets only — verify nested.
        let e = parse_expr("let %_ = print(1f); 2f");
        assert!(e.is_ok());
    }

    #[test]
    fn tuple_and_projection() {
        let e = parse_expr("let %t = (1f, 2f); %t.1").unwrap();
        let s = print_expr(&e);
        assert!(s.contains(".1"));
        // 1-tuple needs trailing comma
        let one = parse_expr("(1f,)").unwrap();
        assert!(matches!(&*one, Expr::Tuple(es) if es.len() == 1));
        // parenthesized expression is not a tuple
        let paren = parse_expr("(1f)").unwrap();
        assert!(matches!(&*paren, Expr::Const(_)));
    }
}
