//! Module: global function definitions + ADT declarations, plus the prelude
//! (List, Option, Tree — the data types the paper's NLP workloads need).

use std::collections::BTreeMap;
use std::sync::Arc;

use super::expr::{Expr, Function, E};
use super::types::Type;

/// An algebraic data type declaration (paper §3.2.5).
#[derive(Clone, Debug, PartialEq)]
pub struct TypeDef {
    pub name: String,
    /// Type parameter names, e.g. `["a"]` for `List[a]`.
    pub params: Vec<String>,
    /// Constructor name -> field types (may mention params as `Adt` with
    /// empty args or via `TypeParam` spelled as Adt{name: param}).
    pub constructors: Vec<(String, Vec<Type>)>,
}

#[derive(Clone, Debug, Default)]
pub struct Module {
    pub defs: BTreeMap<String, Function>,
    pub types: BTreeMap<String, TypeDef>,
    /// Constructor name -> (ADT name, field types).
    pub ctors: BTreeMap<String, (String, Vec<Type>)>,
}

impl Module {
    pub fn new() -> Module {
        Module::default()
    }

    /// A module preloaded with the prelude ADTs.
    pub fn with_prelude() -> Module {
        let mut m = Module::new();
        m.add_prelude();
        m
    }

    pub fn add_def(&mut self, name: impl Into<String>, f: Function) {
        self.defs.insert(name.into(), f);
    }

    pub fn def(&self, name: &str) -> Option<&Function> {
        self.defs.get(name)
    }

    pub fn add_type(&mut self, td: TypeDef) {
        for (cname, fields) in &td.constructors {
            self.ctors
                .insert(cname.clone(), (td.name.clone(), fields.clone()));
        }
        self.types.insert(td.name.clone(), td);
    }

    /// ADT + field types for a constructor.
    pub fn ctor_info(&self, ctor: &str) -> Option<&(String, Vec<Type>)> {
        self.ctors.get(ctor)
    }

    /// The paper's prelude: List, Option, and (for TreeLSTM) Rose trees.
    pub fn add_prelude(&mut self) {
        let a = || Type::Adt { name: "a".into(), args: vec![] };
        self.add_type(TypeDef {
            name: "List".into(),
            params: vec!["a".into()],
            constructors: vec![
                ("Nil".into(), vec![]),
                (
                    "Cons".into(),
                    vec![a(), Type::Adt { name: "List".into(), args: vec![a()] }],
                ),
            ],
        });
        self.add_type(TypeDef {
            name: "Option".into(),
            params: vec!["a".into()],
            constructors: vec![("None".into(), vec![]), ("Some".into(), vec![a()])],
        });
        // Rose tree: a node payload and a list of children.
        self.add_type(TypeDef {
            name: "Tree".into(),
            params: vec!["a".into()],
            constructors: vec![(
                "Rose".into(),
                vec![
                    a(),
                    Type::Adt {
                        name: "List".into(),
                        args: vec![Type::Adt { name: "Tree".into(), args: vec![a()] }],
                    },
                ],
            )],
        });
    }

    /// Main entry function, conventionally `main`.
    pub fn entry(&self) -> Option<&Function> {
        self.def("main")
    }

    /// Wrap a bare expression as `@main` with no params.
    pub fn from_expr(e: E) -> Module {
        let mut m = Module::with_prelude();
        let f = match &*e {
            Expr::Func(f) => f.clone(),
            _ => Function::new(vec![], e),
        };
        m.add_def("main", f);
        m
    }

    /// Apply `f` to every definition body, rebuilding the module.
    pub fn map_defs(&self, mut f: impl FnMut(&str, &Function) -> Function) -> Module {
        let mut m = self.clone();
        m.defs = self
            .defs
            .iter()
            .map(|(name, func)| (name.clone(), f(name, func)))
            .collect();
        m
    }
}

/// Convenience: build a `List` expression from a vector of elements.
pub fn list_expr(items: Vec<E>) -> E {
    let mut acc: E = super::expr::call(super::expr::ctor("Nil"), vec![]);
    for item in items.into_iter().rev() {
        acc = super::expr::call(super::expr::ctor("Cons"), vec![item, acc]);
    }
    acc
}

/// Unit expression helper for module-level code.
pub fn unit_expr() -> E {
    Arc::new(Expr::Tuple(vec![]))
}

#[cfg(test)]
mod tests {
    use super::super::expr::*;
    use super::*;

    #[test]
    fn prelude_has_list_option_tree() {
        let m = Module::with_prelude();
        assert!(m.types.contains_key("List"));
        assert!(m.types.contains_key("Option"));
        assert!(m.types.contains_key("Tree"));
        assert_eq!(m.ctor_info("Cons").unwrap().0, "List");
        assert_eq!(m.ctor_info("None").unwrap().0, "Option");
        assert_eq!(m.ctor_info("Rose").unwrap().0, "Tree");
    }

    #[test]
    fn from_expr_wraps_main() {
        let m = Module::from_expr(scalar(1.0));
        assert!(m.entry().is_some());
        assert!(m.entry().unwrap().params.is_empty());
    }

    #[test]
    fn list_expr_builds_cons_chain() {
        let e = list_expr(vec![scalar(1.0), scalar(2.0)]);
        // Cons(1, Cons(2, Nil))
        match &*e {
            Expr::Call { f, args, .. } => {
                assert!(matches!(&**f, Expr::Ctor(c) if c == "Cons"));
                assert_eq!(args.len(), 2);
            }
            _ => panic!(),
        }
    }
}
