//! Structural (alpha-invariant) hashing and equality.
//!
//! Bound variables hash by binder-occurrence index, free variables by id,
//! so alpha-equivalent functions collide — the key for CSE and for the XLA
//! backend's compiled-kernel cache (same fused function => same executable).

use std::collections::BTreeMap;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use super::expr::{Expr, Pattern, Var, E};
use super::module::Module;

struct Ctx {
    binders: BTreeMap<u32, u64>,
    next: u64,
}

impl Ctx {
    fn new() -> Ctx {
        Ctx { binders: BTreeMap::new(), next: 0 }
    }

    fn bind(&mut self, v: &Var) -> u64 {
        let n = self.next;
        self.next += 1;
        self.binders.insert(v.id, n);
        n
    }

    fn unbind(&mut self, v: &Var) {
        self.binders.remove(&v.id);
    }

    fn lookup(&self, v: &Var) -> Option<u64> {
        self.binders.get(&v.id).copied()
    }
}

fn hash_pattern<H: Hasher>(p: &Pattern, ctx: &mut Ctx, h: &mut H) {
    match p {
        Pattern::Wildcard => 0u8.hash(h),
        Pattern::Var(v) => {
            1u8.hash(h);
            ctx.bind(v).hash(h);
        }
        Pattern::Ctor(name, ps) => {
            2u8.hash(h);
            name.hash(h);
            ps.iter().for_each(|p| hash_pattern(p, ctx, h));
        }
        Pattern::Tuple(ps) => {
            3u8.hash(h);
            ps.iter().for_each(|p| hash_pattern(p, ctx, h));
        }
    }
}

fn hash_expr<H: Hasher>(e: &E, ctx: &mut Ctx, h: &mut H) {
    match &**e {
        Expr::Var(v) => {
            0u8.hash(h);
            match ctx.lookup(v) {
                Some(ix) => {
                    0u8.hash(h);
                    ix.hash(h);
                }
                None => {
                    1u8.hash(h);
                    v.id.hash(h);
                }
            }
        }
        Expr::Global(g) => {
            1u8.hash(h);
            g.hash(h);
        }
        Expr::Const(t) => {
            2u8.hash(h);
            t.shape().hash(h);
            format!("{:?}", t.dtype()).hash(h);
            // Hash contents bitwise via the f64 view (stable and cheap for
            // the small constants that appear in programs).
            for i in 0..t.numel().min(64) {
                t.get_f64(i).to_bits().hash(h);
            }
            t.numel().hash(h);
        }
        Expr::Op(name) => {
            3u8.hash(h);
            name.hash(h);
        }
        Expr::Ctor(name) => {
            4u8.hash(h);
            name.hash(h);
        }
        Expr::Call { f, args, attrs } => {
            5u8.hash(h);
            hash_expr(f, ctx, h);
            args.len().hash(h);
            args.iter().for_each(|a| hash_expr(a, ctx, h));
            for (k, v) in attrs {
                k.hash(h);
                format!("{v:?}").hash(h);
            }
        }
        Expr::Let { var, value, body, .. } => {
            6u8.hash(h);
            hash_expr(value, ctx, h);
            ctx.bind(var).hash(h);
            hash_expr(body, ctx, h);
            ctx.unbind(var);
        }
        Expr::Func(f) => {
            7u8.hash(h);
            f.params.len().hash(h);
            for (p, _) in &f.params {
                ctx.bind(p).hash(h);
            }
            f.attrs.primitive.hash(h);
            hash_expr(&f.body, ctx, h);
            for (p, _) in &f.params {
                ctx.unbind(p);
            }
        }
        Expr::Tuple(es) => {
            8u8.hash(h);
            es.len().hash(h);
            es.iter().for_each(|x| hash_expr(x, ctx, h));
        }
        Expr::Proj(t, i) => {
            9u8.hash(h);
            hash_expr(t, ctx, h);
            i.hash(h);
        }
        Expr::If { cond, then_, else_ } => {
            10u8.hash(h);
            hash_expr(cond, ctx, h);
            hash_expr(then_, ctx, h);
            hash_expr(else_, ctx, h);
        }
        Expr::Match { scrut, arms } => {
            11u8.hash(h);
            hash_expr(scrut, ctx, h);
            arms.len().hash(h);
            for (p, a) in arms {
                hash_pattern(p, ctx, h);
                hash_expr(a, ctx, h);
                for v in p.bound_vars() {
                    ctx.unbind(&v);
                }
            }
        }
        Expr::Grad(g) => {
            12u8.hash(h);
            hash_expr(g, ctx, h);
        }
        Expr::RefNew(v) => {
            13u8.hash(h);
            hash_expr(v, ctx, h);
        }
        Expr::RefRead(r) => {
            14u8.hash(h);
            hash_expr(r, ctx, h);
        }
        Expr::RefWrite(r, v) => {
            15u8.hash(h);
            hash_expr(r, ctx, h);
            hash_expr(v, ctx, h);
        }
    }
}

/// Alpha-invariant structural hash.
pub fn structural_hash(e: &E) -> u64 {
    let mut h = DefaultHasher::new();
    hash_expr(e, &mut Ctx::new(), &mut h);
    h.finish()
}

/// Alpha-invariant structural hash of a whole module: definition names,
/// parameter/return type annotations, definition bodies, and ADT
/// declarations. Two modules that hash equal are (with overwhelming
/// probability) interchangeable compilation inputs — the key of the
/// compiled-program cache ([`crate::eval::ProgramCache`]).
///
/// Unlike [`structural_hash`] on a bare function expression, type
/// annotations DO contribute here: the executors specialize on shapes
/// (e.g. the serving batcher's per-bucket batch dimension), so modules
/// differing only in a parameter type must not collide.
pub fn module_structural_hash(m: &Module) -> u64 {
    let mut h = DefaultHasher::new();
    m.defs.len().hash(&mut h);
    for (name, f) in &m.defs {
        name.hash(&mut h);
        f.params.len().hash(&mut h);
        for (_, ty) in &f.params {
            format!("{ty:?}").hash(&mut h);
        }
        format!("{:?}", f.ret).hash(&mut h);
        structural_hash(&Arc::new(Expr::Func(f.clone()))).hash(&mut h);
    }
    m.types.len().hash(&mut h);
    for (name, td) in &m.types {
        name.hash(&mut h);
        td.params.hash(&mut h);
        td.constructors.len().hash(&mut h);
        for (cname, fields) in &td.constructors {
            cname.hash(&mut h);
            fields.len().hash(&mut h);
            // Field types participate: the verifier compares them, so a
            // hash that ignored them would let two such modules collide
            // permanently and thrash the cache entry.
            for fty in fields {
                format!("{fty:?}").hash(&mut h);
            }
        }
    }
    h.finish()
}

/// Full structural module equality: same definitions (alpha-equivalent
/// bodies, equal type annotations) and same ADT declarations. Used by the
/// program cache to verify a [`module_structural_hash`] hit, so a 64-bit
/// collision (or the constant-hash truncation in [`structural_hash`])
/// can never alias two different programs to one compiled artifact.
///
/// This runs on the cache's per-call hit path, so it goes straight to the
/// recursive equality check — [`alpha_eq`]'s hash fast-path would just
/// re-traverse both modules to recompute hashes the caller already matched.
pub fn modules_structurally_eq(a: &Module, b: &Module) -> bool {
    a.defs.len() == b.defs.len()
        && a.types == b.types
        && a.defs.iter().zip(&b.defs).all(|((n1, f1), (n2, f2))| {
            n1 == n2
                && f1.params.len() == f2.params.len()
                && f1
                    .params
                    .iter()
                    .zip(&f2.params)
                    .all(|((_, t1), (_, t2))| t1 == t2)
                && f1.ret == f2.ret
                && alpha_eq_unhashed(
                    &Arc::new(Expr::Func(f1.clone())),
                    &Arc::new(Expr::Func(f2.clone())),
                )
        })
}

/// Alpha-equivalence (hash-based fast path + full recursive check).
pub fn alpha_eq(a: &E, b: &E) -> bool {
    structural_hash(a) == structural_hash(b) && eq(a, b, &mut BTreeMap::new())
}

/// Alpha-equivalence without the hash fast-path: the recursive check only.
/// For callers that already matched the operands' structural hashes (the
/// program cache, the fused-kernel interner) — [`alpha_eq`] would re-walk
/// both trees just to recompute hashes known to be equal.
pub fn alpha_eq_unhashed(a: &E, b: &E) -> bool {
    eq(a, b, &mut BTreeMap::new())
}

fn eq(a: &E, b: &E, map: &mut BTreeMap<u32, u32>) -> bool {
    use Expr::*;
    match (&**a, &**b) {
        (Var(x), Var(y)) => map.get(&x.id).map(|m| *m == y.id).unwrap_or(x.id == y.id),
        (Global(x), Global(y)) => x == y,
        (Const(x), Const(y)) => x == y,
        (Op(x), Op(y)) => x == y,
        (Ctor(x), Ctor(y)) => x == y,
        (
            Call { f: f1, args: a1, attrs: at1 },
            Call { f: f2, args: a2, attrs: at2 },
        ) => {
            at1 == at2
                && eq(f1, f2, map)
                && a1.len() == a2.len()
                && a1.iter().zip(a2).all(|(x, y)| eq(x, y, map))
        }
        (
            Let { var: v1, value: val1, body: b1, .. },
            Let { var: v2, value: val2, body: b2, .. },
        ) => {
            if !eq(val1, val2, map) {
                return false;
            }
            map.insert(v1.id, v2.id);
            let r = eq(b1, b2, map);
            map.remove(&v1.id);
            r
        }
        (Func(f1), Func(f2)) => {
            if f1.params.len() != f2.params.len() || f1.attrs != f2.attrs {
                return false;
            }
            for ((p1, _), (p2, _)) in f1.params.iter().zip(&f2.params) {
                map.insert(p1.id, p2.id);
            }
            let r = eq(&f1.body, &f2.body, map);
            for (p1, _) in &f1.params {
                map.remove(&p1.id);
            }
            r
        }
        (Tuple(x), Tuple(y)) => {
            x.len() == y.len() && x.iter().zip(y).all(|(a, b)| eq(a, b, map))
        }
        (Proj(x, i), Proj(y, j)) => i == j && eq(x, y, map),
        (
            If { cond: c1, then_: t1, else_: e1 },
            If { cond: c2, then_: t2, else_: e2 },
        ) => eq(c1, c2, map) && eq(t1, t2, map) && eq(e1, e2, map),
        (Match { scrut: s1, arms: ar1 }, Match { scrut: s2, arms: ar2 }) => {
            if !eq(s1, s2, map) || ar1.len() != ar2.len() {
                return false;
            }
            ar1.iter().zip(ar2).all(|((p1, a1), (p2, a2))| {
                if !pat_eq(p1, p2, map) {
                    return false;
                }
                let r = eq(a1, a2, map);
                for v in p1.bound_vars() {
                    map.remove(&v.id);
                }
                r
            })
        }
        (Grad(x), Grad(y)) => eq(x, y, map),
        (RefNew(x), RefNew(y)) => eq(x, y, map),
        (RefRead(x), RefRead(y)) => eq(x, y, map),
        (RefWrite(r1, v1), RefWrite(r2, v2)) => eq(r1, r2, map) && eq(v1, v2, map),
        _ => false,
    }
}

fn pat_eq(a: &Pattern, b: &Pattern, map: &mut BTreeMap<u32, u32>) -> bool {
    match (a, b) {
        (Pattern::Wildcard, Pattern::Wildcard) => true,
        (Pattern::Var(x), Pattern::Var(y)) => {
            map.insert(x.id, y.id);
            true
        }
        (Pattern::Ctor(n1, p1), Pattern::Ctor(n2, p2)) => {
            n1 == n2 && p1.len() == p2.len() && p1.iter().zip(p2).all(|(x, y)| pat_eq(x, y, map))
        }
        (Pattern::Tuple(p1), Pattern::Tuple(p2)) => {
            p1.len() == p2.len() && p1.iter().zip(p2).all(|(x, y)| pat_eq(x, y, map))
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::super::expr::*;
    use super::*;

    #[test]
    fn alpha_equivalent_functions_collide() {
        let x = Var::fresh("x");
        let y = Var::fresh("y");
        let f = func(vec![(x.clone(), None)], op_call("add", vec![var(&x), var(&x)]));
        let g = func(vec![(y.clone(), None)], op_call("add", vec![var(&y), var(&y)]));
        assert_eq!(structural_hash(&f), structural_hash(&g));
        assert!(alpha_eq(&f, &g));
    }

    #[test]
    fn different_ops_differ() {
        let x = Var::fresh("x");
        let f = func(vec![(x.clone(), None)], op_call("add", vec![var(&x), var(&x)]));
        let g = func(vec![(x.clone(), None)], op_call("multiply", vec![var(&x), var(&x)]));
        assert!(!alpha_eq(&f, &g));
    }

    #[test]
    fn free_vars_matter() {
        let x = Var::fresh("x");
        let y = Var::fresh("y");
        // Free vars hash by identity: x and y are distinct free vars.
        assert_ne!(structural_hash(&var(&x)), structural_hash(&var(&y)));
        assert!(!alpha_eq(&var(&x), &var(&y)));
    }

    #[test]
    fn const_values_matter() {
        assert!(!alpha_eq(&scalar(1.0), &scalar(2.0)));
        assert!(alpha_eq(&scalar(1.0), &scalar(1.0)));
    }

    #[test]
    fn attrs_matter() {
        let a = op_call_attrs("sum", vec![scalar(1.0)], attrs(&[("axis", AttrValue::Int(0))]));
        let b = op_call_attrs("sum", vec![scalar(1.0)], attrs(&[("axis", AttrValue::Int(1))]));
        assert!(!alpha_eq(&a, &b));
    }

    #[test]
    fn module_hash_is_alpha_invariant_and_type_sensitive() {
        use super::super::parse_module;
        let a = parse_module("def @main(%x: Tensor[(2, 2), float32]) { add(%x, %x) }")
            .unwrap();
        // Re-parse: same program, fresh var ids.
        let b = parse_module("def @main(%y: Tensor[(2, 2), float32]) { add(%y, %y) }")
            .unwrap();
        assert_eq!(module_structural_hash(&a), module_structural_hash(&b));
        assert!(modules_structurally_eq(&a, &b));
        // A different param type (e.g. a different batch bucket) must not
        // collide: the cache would otherwise serve a wrongly-shaped program.
        let c = parse_module("def @main(%x: Tensor[(4, 2), float32]) { add(%x, %x) }")
            .unwrap();
        assert_ne!(module_structural_hash(&a), module_structural_hash(&c));
        assert!(!modules_structurally_eq(&a, &c));
        // A different body must not collide either.
        let d = parse_module("def @main(%x: Tensor[(2, 2), float32]) { multiply(%x, %x) }")
            .unwrap();
        assert_ne!(module_structural_hash(&a), module_structural_hash(&d));
        assert!(!modules_structurally_eq(&a, &d));
    }

    #[test]
    fn let_alpha_equivalence() {
        let x = Var::fresh("x");
        let y = Var::fresh("y");
        let e1 = let_(x.clone(), scalar(1.0), var(&x));
        let e2 = let_(y.clone(), scalar(1.0), var(&y));
        assert!(alpha_eq(&e1, &e2));
    }
}
