//! Generic traversal utilities: child mapping, free variables, capture-free
//! substitution. Every pass is built from these.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use super::expr::{Expr, Function, Pattern, Var, E};

/// Rebuild `e` with each direct child mapped through `f`. Returns the
/// original Arc when nothing changed (pointer-equality check) — this keeps
/// implicit sharing (§3.2.2) intact across passes, so shared subgraphs
/// (residual skips) don't silently duplicate.
pub fn map_children(e: &E, f: impl FnMut(&E) -> E) -> E {
    let mut f = f;
    let mut changed = false;
    let mut f = |c: &E| -> E {
        let n = f(c);
        if !Arc::ptr_eq(&n, c) {
            changed = true;
        }
        n
    };
    let rebuilt = match &**e {
        Expr::Var(_) | Expr::Global(_) | Expr::Const(_) | Expr::Op(_) | Expr::Ctor(_) => {
            return e.clone()
        }
        Expr::Call { f: callee, args, attrs } => Expr::Call {
            f: f(callee),
            args: args.iter().map(&mut f).collect(),
            attrs: attrs.clone(),
        },
        Expr::Let { var, ty, value, body } => Expr::Let {
            var: var.clone(),
            ty: ty.clone(),
            value: f(value),
            body: f(body),
        },
        Expr::Func(func) => Expr::Func(Function {
            params: func.params.clone(),
            ret: func.ret.clone(),
            body: f(&func.body),
            attrs: func.attrs.clone(),
        }),
        Expr::Tuple(es) => Expr::Tuple(es.iter().map(&mut f).collect()),
        Expr::Proj(t, i) => Expr::Proj(f(t), *i),
        Expr::If { cond, then_, else_ } => Expr::If {
            cond: f(cond),
            then_: f(then_),
            else_: f(else_),
        },
        Expr::Match { scrut, arms } => Expr::Match {
            scrut: f(scrut),
            arms: arms.iter().map(|(p, a)| (p.clone(), f(a))).collect(),
        },
        Expr::Grad(g) => Expr::Grad(f(g)),
        Expr::RefNew(v) => Expr::RefNew(f(v)),
        Expr::RefRead(r) => Expr::RefRead(f(r)),
        Expr::RefWrite(r, v) => Expr::RefWrite(f(r), f(v)),
    };
    if changed {
        Arc::new(rebuilt)
    } else {
        e.clone()
    }
}

/// Visit each direct child (no rebuild).
pub fn visit_children(e: &E, mut f: impl FnMut(&E)) {
    match &**e {
        Expr::Var(_) | Expr::Global(_) | Expr::Const(_) | Expr::Op(_) | Expr::Ctor(_) => {}
        Expr::Call { f: callee, args, .. } => {
            f(callee);
            args.iter().for_each(&mut f);
        }
        Expr::Let { value, body, .. } => {
            f(value);
            f(body);
        }
        Expr::Func(func) => f(&func.body),
        Expr::Tuple(es) => es.iter().for_each(&mut f),
        Expr::Proj(t, _) => f(t),
        Expr::If { cond, then_, else_ } => {
            f(cond);
            f(then_);
            f(else_);
        }
        Expr::Match { scrut, arms } => {
            f(scrut);
            arms.iter().for_each(|(_, a)| f(a));
        }
        Expr::Grad(g) => f(g),
        Expr::RefNew(v) => f(v),
        Expr::RefRead(r) => f(r),
        Expr::RefWrite(r, v) => {
            f(r);
            f(v);
        }
    }
}

/// Post-order full-tree rewrite: children first, then `f` on the rebuilt
/// node. `f` returning `None` keeps the node. Memoized by Arc address so
/// implicitly-shared subgraphs (§3.2.2) are rewritten once and stay shared.
pub fn rewrite_postorder(e: &E, f: &mut dyn FnMut(&E) -> Option<E>) -> E {
    let mut memo: BTreeMap<usize, E> = BTreeMap::new();
    fn go(
        e: &E,
        f: &mut dyn FnMut(&E) -> Option<E>,
        memo: &mut BTreeMap<usize, E>,
    ) -> E {
        let key = Arc::as_ptr(e) as usize;
        if let Some(done) = memo.get(&key) {
            return done.clone();
        }
        let rebuilt = map_children(e, |c| go(c, f, memo));
        let out = f(&rebuilt).unwrap_or(rebuilt);
        memo.insert(key, out.clone());
        out
    }
    go(e, f, &mut memo)
}

/// Free variables of `e` (ordered by var id).
pub fn free_vars(e: &E) -> BTreeSet<Var> {
    fn go(e: &E, bound: &mut Vec<Var>, out: &mut BTreeSet<Var>) {
        match &**e {
            Expr::Var(v) => {
                if !bound.contains(v) {
                    out.insert(v.clone());
                }
            }
            Expr::Let { var, value, body, .. } => {
                go(value, bound, out);
                bound.push(var.clone());
                go(body, bound, out);
                bound.pop();
            }
            Expr::Func(func) => {
                let n = func.params.len();
                for (p, _) in &func.params {
                    bound.push(p.clone());
                }
                go(&func.body, bound, out);
                for _ in 0..n {
                    bound.pop();
                }
            }
            Expr::Match { scrut, arms } => {
                go(scrut, bound, out);
                for (p, a) in arms {
                    let vs = p.bound_vars();
                    let n = vs.len();
                    bound.extend(vs);
                    go(a, bound, out);
                    for _ in 0..n {
                        bound.pop();
                    }
                }
            }
            _ => visit_children(e, |c| go(c, bound, out)),
        }
    }
    let mut out = BTreeSet::new();
    go(e, &mut Vec::new(), &mut out);
    out
}

/// Capture-free substitution of variables. Because every binder carries a
/// globally unique id, substitution never captures and binders need no
/// renaming.
pub fn subst(e: &E, map: &BTreeMap<Var, E>) -> E {
    if map.is_empty() {
        return e.clone();
    }
    match &**e {
        Expr::Var(v) => map.get(v).cloned().unwrap_or_else(|| e.clone()),
        _ => map_children(e, |c| subst(c, map)),
    }
}

/// Replace one variable.
pub fn subst1(e: &E, v: &Var, with: &E) -> E {
    let mut m = BTreeMap::new();
    m.insert(v.clone(), with.clone());
    subst(e, &m)
}

/// Count nodes (used by tests and pass statistics).
pub fn count_nodes(e: &E) -> usize {
    let mut n = 1;
    visit_children(e, |c| n += count_nodes(c));
    n
}

/// Collect every subexpression satisfying `pred` (pre-order).
pub fn collect(e: &E, pred: &dyn Fn(&E) -> bool, out: &mut Vec<E>) {
    if pred(e) {
        out.push(e.clone());
    }
    visit_children(e, |c| collect(c, pred, out));
}

/// Alpha-rename all binders in `e` with fresh ids (used when duplicating a
/// function body, e.g. by inlining or the partial evaluator).
pub fn refresh(e: &E) -> E {
    fn go(e: &E, env: &mut BTreeMap<Var, Var>) -> E {
        match &**e {
            Expr::Var(v) => match env.get(v) {
                Some(nv) => super::expr::var(nv),
                None => e.clone(),
            },
            Expr::Let { var, ty, value, body } => {
                let value = go(value, env);
                let nv = Var::fresh(&var.name);
                env.insert(var.clone(), nv.clone());
                let body = go(body, env);
                env.remove(var);
                Arc::new(Expr::Let { var: nv, ty: ty.clone(), value, body })
            }
            Expr::Func(f) => {
                let mut params = Vec::new();
                for (p, t) in &f.params {
                    let np = Var::fresh(&p.name);
                    env.insert(p.clone(), np.clone());
                    params.push((np, t.clone()));
                }
                let body = go(&f.body, env);
                for (p, _) in &f.params {
                    env.remove(p);
                }
                Arc::new(Expr::Func(Function {
                    params,
                    ret: f.ret.clone(),
                    body,
                    attrs: f.attrs.clone(),
                }))
            }
            Expr::Match { scrut, arms } => {
                let scrut = go(scrut, env);
                let arms = arms
                    .iter()
                    .map(|(p, a)| {
                        let mut np = p.clone();
                        refresh_pattern(&mut np, env);
                        let a = go(a, env);
                        for v in p.bound_vars() {
                            env.remove(&v);
                        }
                        (np, a)
                    })
                    .collect();
                Arc::new(Expr::Match { scrut, arms })
            }
            _ => map_children(e, |c| go(c, env)),
        }
    }
    fn refresh_pattern(p: &mut Pattern, env: &mut BTreeMap<Var, Var>) {
        match p {
            Pattern::Wildcard => {}
            Pattern::Var(v) => {
                let nv = Var::fresh(&v.name);
                env.insert(v.clone(), nv.clone());
                *v = nv;
            }
            Pattern::Ctor(_, ps) | Pattern::Tuple(ps) => {
                ps.iter_mut().for_each(|p| refresh_pattern(p, env))
            }
        }
    }
    go(e, &mut BTreeMap::new())
}

#[cfg(test)]
mod tests {
    use super::super::expr::*;
    use super::*;

    #[test]
    fn free_vars_respects_binders() {
        let x = Var::fresh("x");
        let y = Var::fresh("y");
        // let x = y; x + x  — free: {y}
        let e = let_(x.clone(), var(&y), op_call("add", vec![var(&x), var(&x)]));
        let fv = free_vars(&e);
        assert_eq!(fv.len(), 1);
        assert!(fv.contains(&y));
    }

    #[test]
    fn free_vars_in_function_params() {
        let x = Var::fresh("x");
        let y = Var::fresh("y");
        let f = func(vec![(x.clone(), None)], op_call("add", vec![var(&x), var(&y)]));
        let fv = free_vars(&f);
        assert!(fv.contains(&y) && !fv.contains(&x));
    }

    #[test]
    fn subst_replaces_free_only() {
        let x = Var::fresh("x");
        // (fn (x) { x })  with outer x substituted must not touch the bound x.
        let inner = func(vec![(x.clone(), None)], var(&x));
        let e = tuple(vec![var(&x), inner.clone()]);
        let s = subst1(&e, &x, &scalar(3.0));
        match &*s {
            Expr::Tuple(es) => {
                assert!(matches!(&*es[0], Expr::Const(_)));
                // The lambda's body still refers to its own param... note our
                // vars are globally unique, so the bound x IS the same id and
                // would be replaced — the invariant is binders are never
                // duplicated, so subst1 is only called with genuinely free
                // vars. Here we document the unique-id semantics instead:
                match &*es[1] {
                    Expr::Func(f) => match &*f.body {
                        Expr::Const(_) => {} // replaced: same id
                        Expr::Var(_) => {}
                        other => panic!("unexpected {other:?}"),
                    },
                    other => panic!("unexpected {other:?}"),
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn refresh_gives_new_ids() {
        let x = Var::fresh("x");
        let f = func(vec![(x.clone(), None)], var(&x));
        let g = refresh(&f);
        match (&*f, &*g) {
            (Expr::Func(a), Expr::Func(b)) => {
                assert_ne!(a.params[0].0, b.params[0].0);
                match &*b.body {
                    Expr::Var(v) => assert_eq!(*v, b.params[0].0),
                    other => panic!("unexpected {other:?}"),
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn count_nodes_counts() {
        let e = op_call("add", vec![scalar(1.0), scalar(2.0)]);
        // call + op + 2 consts
        assert_eq!(count_nodes(&e), 4);
    }

    #[test]
    fn rewrite_postorder_folds() {
        // Replace every const with 9.
        let e = op_call("add", vec![scalar(1.0), scalar(2.0)]);
        let out = rewrite_postorder(&e, &mut |n| match &**n {
            Expr::Const(_) => Some(scalar(9.0)),
            _ => None,
        });
        let mut consts = Vec::new();
        collect(&out, &|n| matches!(&**n, Expr::Const(_)), &mut consts);
        assert_eq!(consts.len(), 2);
        for c in consts {
            match &*c {
                Expr::Const(t) => assert_eq!(t.f32_value(), 9.0),
                _ => unreachable!(),
            }
        }
    }
}
