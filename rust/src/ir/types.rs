//! Relay's type language (paper Fig. 1 / appendix Fig. 14 "Type τ").
//!
//! Tensor types carry a shape whose dimensions may be concrete, `Any`
//! (paper §3.3.1), or inference variables; function types may carry type
//! relations (§3.3.2) attached during operator typing.

use std::fmt;

pub use crate::tensor::DType;

/// A single tensor dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dim {
    /// Statically known extent.
    Known(usize),
    /// `Any`: statically unknown, checked at runtime (paper §3.3.1).
    Any,
    /// Shape-inference variable (solved by the relation solver).
    Var(u32),
}

impl Dim {
    pub fn known(self) -> Option<usize> {
        match self {
            Dim::Known(d) => Some(d),
            _ => None,
        }
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dim::Known(d) => write!(f, "{d}"),
            Dim::Any => write!(f, "?"),
            Dim::Var(v) => write!(f, "'d{v}"),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Type {
    /// `Tensor[(d1, ..., dn), bt]`.
    Tensor { shape: Vec<Dim>, dtype: DType },
    /// Unification variable introduced by inference.
    Var(u32),
    /// `fn (T1, ..., Tn) -> O`.
    Func { params: Vec<Type>, ret: Box<Type> },
    /// `(T1, ..., Tn)`; unit is the empty tuple.
    Tuple(Vec<Type>),
    /// `Ref[T]`.
    Ref(Box<Type>),
    /// Named ADT instantiated with type arguments, e.g. `List[T]`.
    Adt { name: String, args: Vec<Type> },
}

impl Type {
    pub fn unit() -> Type {
        Type::Tuple(vec![])
    }

    pub fn tensor(shape: Vec<usize>, dtype: DType) -> Type {
        Type::Tensor { shape: shape.into_iter().map(Dim::Known).collect(), dtype }
    }

    pub fn scalar(dtype: DType) -> Type {
        Type::Tensor { shape: vec![], dtype }
    }

    pub fn scalar_bool() -> Type {
        Type::scalar(DType::Bool)
    }

    /// Concrete shape if every dim is `Known`.
    pub fn concrete_shape(&self) -> Option<Vec<usize>> {
        match self {
            Type::Tensor { shape, .. } => {
                shape.iter().map(|d| d.known()).collect::<Option<Vec<_>>>()
            }
            _ => None,
        }
    }

    pub fn dtype(&self) -> Option<DType> {
        match self {
            Type::Tensor { dtype, .. } => Some(*dtype),
            _ => None,
        }
    }

    /// Does this type contain any inference variable (type or dim)?
    pub fn has_vars(&self) -> bool {
        match self {
            Type::Var(_) => true,
            Type::Tensor { shape, .. } => shape.iter().any(|d| matches!(d, Dim::Var(_))),
            Type::Func { params, ret } => {
                params.iter().any(Type::has_vars) || ret.has_vars()
            }
            Type::Tuple(ts) => ts.iter().any(Type::has_vars),
            Type::Ref(t) => t.has_vars(),
            Type::Adt { args, .. } => args.iter().any(Type::has_vars),
        }
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Type::Tensor { shape, dtype } => {
                if shape.is_empty() {
                    write!(f, "Tensor[(), {dtype}]")
                } else {
                    let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
                    write!(f, "Tensor[({}), {dtype}]", dims.join(", "))
                }
            }
            Type::Var(v) => write!(f, "'t{v}"),
            Type::Func { params, ret } => {
                let ps: Vec<String> = params.iter().map(|p| p.to_string()).collect();
                write!(f, "fn ({}) -> {ret}", ps.join(", "))
            }
            Type::Tuple(ts) => {
                let ps: Vec<String> = ts.iter().map(|p| p.to_string()).collect();
                write!(f, "({})", ps.join(", "))
            }
            Type::Ref(t) => write!(f, "Ref[{t}]"),
            Type::Adt { name, args } => {
                if args.is_empty() {
                    write!(f, "{name}")
                } else {
                    let ps: Vec<String> = args.iter().map(|p| p.to_string()).collect();
                    write!(f, "{name}[{}]", ps.join(", "))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        let t = Type::tensor(vec![2, 3], DType::F32);
        assert_eq!(t.to_string(), "Tensor[(2, 3), float32]");
        assert_eq!(Type::unit().to_string(), "()");
        assert_eq!(Type::scalar_bool().to_string(), "Tensor[(), bool]");
        let f = Type::Func { params: vec![t.clone()], ret: Box::new(t) };
        assert!(f.to_string().starts_with("fn ("));
    }

    #[test]
    fn concrete_shape_extraction() {
        let t = Type::tensor(vec![4, 5], DType::F32);
        assert_eq!(t.concrete_shape(), Some(vec![4, 5]));
        let t2 = Type::Tensor { shape: vec![Dim::Known(4), Dim::Any], dtype: DType::F32 };
        assert_eq!(t2.concrete_shape(), None);
    }

    #[test]
    fn has_vars_detection() {
        assert!(Type::Var(0).has_vars());
        let t = Type::Tensor { shape: vec![Dim::Var(1)], dtype: DType::F32 };
        assert!(t.has_vars());
        assert!(!Type::tensor(vec![1], DType::F32).has_vars());
    }
}
