//! PJRT runtime: loads AOT artifacts (`artifacts/*.hlo.txt`, produced once
//! by `make artifacts` from the JAX/Pallas build path) and XLA computations
//! built by [`crate::backend::xla`], compiles them on the CPU PJRT client,
//! and executes them from the Rust hot path. Python is never involved at
//! run time.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and aot.py).
//!
//! The real implementation needs the `xla` bindings crate and is gated
//! behind the off-by-default `xla` cargo feature. Without the feature a
//! stub [`Runtime`] with the same method surface is compiled instead; it
//! fails at construction time ([`Runtime::cpu`]) with a clear error, so
//! callers (the coordinator's artifact path, the serving batcher's PJRT
//! branch) degrade gracefully while the rest of the compiler — including
//! the interpreter, graph runtime, and bytecode VM — stays fully usable.

pub mod manifest;

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    use crate::sync::lock_unpoisoned;
    use crate::tensor::{DType, Tensor};

    pub struct Runtime {
        client: xla::PjRtClient,
        /// Compiled-executable cache keyed by artifact path or structural hash.
        cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact (cached by path).
        pub fn load_artifact(
            &self,
            path: &Path,
        ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            let key = path.display().to_string();
            if let Some(exe) = lock_unpoisoned(&self.cache).get(&key) {
                return Ok(exe.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("parsing {key}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = std::sync::Arc::new(
                self.client.compile(&comp).map_err(|e| anyhow!("compiling {key}: {e:?}"))?,
            );
            lock_unpoisoned(&self.cache).insert(key, exe.clone());
            Ok(exe)
        }

        /// Compile an in-memory computation (cached by caller-provided key).
        pub fn compile_cached(
            &self,
            key: &str,
            comp: &xla::XlaComputation,
        ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = lock_unpoisoned(&self.cache).get(key) {
                return Ok(exe.clone());
            }
            let exe = std::sync::Arc::new(
                self.client.compile(comp).map_err(|e| anyhow!("compiling {key}: {e:?}"))?,
            );
            lock_unpoisoned(&self.cache).insert(key.to_string(), exe.clone());
            Ok(exe)
        }

        pub fn cache_len(&self) -> usize {
            lock_unpoisoned(&self.cache).len()
        }

        /// Execute with tensor inputs; returns the flattened outputs.
        /// jax artifacts are lowered with `return_tuple=True`, so a 1-tuple
        /// result is unwrapped into its elements.
        pub fn execute(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            inputs: &[Tensor],
        ) -> Result<Vec<Tensor>> {
            let literals: Result<Vec<xla::Literal>> =
                inputs.iter().map(tensor_to_literal).collect();
            let result = exe
                .execute::<xla::Literal>(&literals?)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let parts = lit
                .to_tuple()
                .map_err(|e| anyhow!("detuple: {e:?}"))?;
            if parts.is_empty() {
                return Ok(vec![]);
            }
            parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
        }
    }

    /// Convert our Tensor into an xla Literal.
    pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        let lit = match t.dtype() {
            DType::F32 => xla::Literal::vec1(t.as_f32()),
            DType::F64 => xla::Literal::vec1(t.as_f64()),
            DType::I64 => xla::Literal::vec1(t.as_i64()),
            DType::I32 => xla::Literal::vec1(t.as_i32()),
            DType::Bool => {
                // No direct bool vec; go through i32 + convert to PRED.
                let v: Vec<i32> = t.as_bool().iter().map(|&b| b as i32).collect();
                xla::Literal::vec1(&v)
                    .convert(xla::PrimitiveType::Pred)
                    .map_err(|e| anyhow!("bool convert: {e:?}"))?
            }
            other => return Err(anyhow!("unsupported literal dtype {other}")),
        };
        lit.reshape(&dims).map_err(|e| anyhow!("literal reshape: {e:?}"))
    }

    /// Convert an xla Literal back into our Tensor.
    pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
        let shape = l.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let t = match shape.ty() {
            xla::ElementType::F32 => {
                Tensor::from_f32(dims, l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?)
            }
            xla::ElementType::S64 => {
                Tensor::from_i64(dims, l.to_vec::<i64>().map_err(|e| anyhow!("{e:?}"))?)
            }
            xla::ElementType::S32 => {
                Tensor::from_i32(dims, l.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?)
            }
            xla::ElementType::Pred => {
                let l2 = l.convert(xla::PrimitiveType::S32).map_err(|e| anyhow!("{e:?}"))?;
                let v: Vec<i32> = l2.to_vec().map_err(|e| anyhow!("{e:?}"))?;
                Tensor::from_bool(dims, v.into_iter().map(|b| b != 0).collect())
            }
            other => return Err(anyhow!("unsupported output element type {other:?}")),
        };
        Ok(t)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn literal_roundtrip_f32() {
            let t = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]);
            let l = tensor_to_literal(&t).unwrap();
            let back = literal_to_tensor(&l).unwrap();
            assert_eq!(back.shape(), t.shape());
            assert_eq!(back.as_f32(), t.as_f32());
        }

        #[test]
        fn literal_roundtrip_i64() {
            let t = Tensor::from_i64(vec![3], vec![1, -2, 3]);
            let l = tensor_to_literal(&t).unwrap();
            let back = literal_to_tensor(&l).unwrap();
            assert_eq!(back.as_i64(), t.as_i64());
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::*;

#[cfg(not(feature = "xla"))]
mod pjrt_stub {
    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{anyhow, Result};

    use crate::tensor::Tensor;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: relay was built without the `xla` feature \
         (enable it with the xla bindings crate patched into the workspace)";

    /// Opaque stand-in for `xla::PjRtLoadedExecutable`; never constructed.
    pub struct LoadedExecutable {
        _private: (),
    }

    /// Stub runtime with the same method surface as the PJRT-backed one.
    /// [`Runtime::cpu`] always fails, so the other methods are never
    /// reachable — they exist so feature-independent callers typecheck.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_artifact(&self, _path: &Path) -> Result<Arc<LoadedExecutable>> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn cache_len(&self) -> usize {
            0
        }

        pub fn execute(
            &self,
            _exe: &LoadedExecutable,
            _inputs: &[Tensor],
        ) -> Result<Vec<Tensor>> {
            Err(anyhow!(UNAVAILABLE))
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use pjrt_stub::*;
