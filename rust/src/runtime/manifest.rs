//! Minimal parser for `artifacts/manifest.json` (written by aot.py).
//!
//! The build environment vendors no JSON crate, and the schema is tiny and
//! fixed, so this is a purpose-built recursive-descent parser for exactly
//! the subset aot.py emits: objects, arrays, strings, integers.

use std::collections::BTreeMap;
use std::path::Path;

use crate::tensor::DType;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug, Default)]
pub struct Entry {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

pub type Manifest = BTreeMap<String, Entry>;

#[derive(Debug)]
pub enum Json {
    Object(BTreeMap<String, Json>),
    Array(Vec<Json>),
    Str(String),
    Num(f64),
}

pub fn parse_json(src: &str) -> Result<Json, String> {
    let chars: Vec<char> = src.chars().collect();
    let mut pos = 0usize;
    let v = parse_value(&chars, &mut pos)?;
    Ok(v)
}

fn skip_ws(c: &[char], p: &mut usize) {
    while *p < c.len() && c[*p].is_whitespace() {
        *p += 1;
    }
}

fn parse_value(c: &[char], p: &mut usize) -> Result<Json, String> {
    skip_ws(c, p);
    match c.get(*p) {
        Some('{') => {
            *p += 1;
            let mut map = BTreeMap::new();
            loop {
                skip_ws(c, p);
                if c.get(*p) == Some(&'}') {
                    *p += 1;
                    break;
                }
                let key = match parse_value(c, p)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key {other:?}")),
                };
                skip_ws(c, p);
                if c.get(*p) != Some(&':') {
                    return Err("expected ':'".into());
                }
                *p += 1;
                let v = parse_value(c, p)?;
                map.insert(key, v);
                skip_ws(c, p);
                if c.get(*p) == Some(&',') {
                    *p += 1;
                }
            }
            Ok(Json::Object(map))
        }
        Some('[') => {
            *p += 1;
            let mut arr = Vec::new();
            loop {
                skip_ws(c, p);
                if c.get(*p) == Some(&']') {
                    *p += 1;
                    break;
                }
                arr.push(parse_value(c, p)?);
                skip_ws(c, p);
                if c.get(*p) == Some(&',') {
                    *p += 1;
                }
            }
            Ok(Json::Array(arr))
        }
        Some('"') => {
            *p += 1;
            let mut s = String::new();
            while *p < c.len() && c[*p] != '"' {
                s.push(c[*p]);
                *p += 1;
            }
            *p += 1;
            Ok(Json::Str(s))
        }
        Some(ch) if ch.is_ascii_digit() || *ch == '-' => {
            let start = *p;
            while *p < c.len()
                && (c[*p].is_ascii_digit() || c[*p] == '.' || c[*p] == '-' || c[*p] == 'e')
            {
                *p += 1;
            }
            let text: String = c[start..*p].iter().collect();
            text.parse::<f64>().map(Json::Num).map_err(|e| e.to_string())
        }
        other => Err(format!("unexpected {other:?} at {p}")),
    }
}

fn spec_of(j: &Json) -> Result<TensorSpec, String> {
    let obj = match j {
        Json::Object(o) => o,
        _ => return Err("spec not object".into()),
    };
    let shape = match obj.get("shape") {
        Some(Json::Array(a)) => a
            .iter()
            .map(|v| match v {
                Json::Num(n) => Ok(*n as usize),
                _ => Err("bad dim".to_string()),
            })
            .collect::<Result<Vec<_>, _>>()?,
        _ => return Err("missing shape".into()),
    };
    let dtype = match obj.get("dtype") {
        Some(Json::Str(s)) => DType::parse(s).ok_or(format!("bad dtype {s}"))?,
        _ => return Err("missing dtype".into()),
    };
    Ok(TensorSpec { shape, dtype })
}

pub fn load(path: &Path) -> Result<Manifest, String> {
    let src = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let root = parse_json(&src)?;
    let obj = match root {
        Json::Object(o) => o,
        _ => return Err("manifest root not an object".into()),
    };
    let mut m = Manifest::new();
    for (name, entry) in obj {
        let eo = match entry {
            Json::Object(o) => o,
            _ => continue,
        };
        let get_specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
            match eo.get(key) {
                Some(Json::Array(a)) => a.iter().map(spec_of).collect(),
                _ => Ok(vec![]),
            }
        };
        m.insert(name, Entry { inputs: get_specs("inputs")?, outputs: get_specs("outputs")? });
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_schema() {
        let src = r#"{
          "mlp_forward": {
            "inputs": [{"shape": [64, 128], "dtype": "float32"},
                       {"shape": [], "dtype": "int32"}],
            "outputs": [{"shape": [32, 10], "dtype": "float32"}]
          }
        }"#;
        let j = parse_json(src).unwrap();
        let obj = match j {
            Json::Object(o) => o,
            _ => panic!(),
        };
        assert!(obj.contains_key("mlp_forward"));
        let tmp = std::env::temp_dir().join("relay_manifest_test.json");
        std::fs::write(&tmp, src).unwrap();
        let m = load(&tmp).unwrap();
        let e = &m["mlp_forward"];
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![64, 128]);
        assert_eq!(e.inputs[1].dtype, DType::I32);
        assert_eq!(e.outputs[0].shape, vec![32, 10]);
    }
}
