//! Process-wide metrics registry: named counters, gauges, and fixed-bucket
//! latency histograms, rendered as Prometheus-style text.
//!
//! Handles are `Arc`s over lock-free atomics — the registry lock is taken
//! only at registration and render time, never on the update path. A series
//! is identified by `(name, labels)`; registering the same series twice
//! returns the same handle, so independent subsystems can share a counter
//! by name alone ("one counter source of truth").
//!
//! Naming conventions (see `telemetry/README.md`): metric names are
//! `relay_<subsystem>_<what>`, counters end in `_total`, duration
//! histograms end in `_seconds` and observe `f64` seconds.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Canonical metric names used across the crate. Keeping them in one place
/// means the serving fleet, the CLI, and the tests can never drift apart on
/// spelling.
pub mod names {
    pub const REQUESTS_TOTAL: &str = "relay_requests_total";
    pub const BATCHES_TOTAL: &str = "relay_batches_total";
    pub const COMPILES_TOTAL: &str = "relay_compiles_total";
    /// Zero-filled rows dispatched to round a batch up to a compiled
    /// fixed shape. The shape-polymorphic serving path (`--poly`) never
    /// pads, so this stays 0 there; the bucketed baseline and the
    /// fixed-shape PJRT artifact path count their padding waste here.
    pub const PADDED_ROWS_TOTAL: &str = "relay_padded_rows_total";
    pub const INPLACE_HITS_TOTAL: &str = "relay_inplace_hits_total";
    pub const INPLACE_MISSES_TOTAL: &str = "relay_inplace_misses_total";
    pub const QUEUE_DEPTH: &str = "relay_queue_depth";
    /// Requests refused without execution, labeled by `reason`:
    /// `queue_full` (admission over budget), `deadline` (dropped at drain
    /// time, already past its deadline), `shutdown` (arrived during drain).
    pub const SHED_TOTAL: &str = "relay_shed_total";
    /// How every request ended, labeled by `outcome`
    /// (ok / error / shed / deadline) — see `telemetry::Outcome`.
    pub const REQUEST_OUTCOMES_TOTAL: &str = "relay_request_outcomes_total";
    /// Backend executions that panicked (caught at the worker, answered
    /// with a typed error; the worker survives).
    pub const WORKER_PANICS_TOTAL: &str = "relay_worker_panics_total";
    /// Worker threads the supervisor respawned after an abnormal death.
    pub const WORKER_RESPAWNS_TOTAL: &str = "relay_worker_respawns_total";
    /// Live worker threads in the fleet (0 after a graceful drain).
    pub const WORKERS_ALIVE: &str = "relay_workers_alive";
    /// Resolved kernel worker-pool width (participants per parallel
    /// region, caller included); 1 = the pool is bypassed entirely.
    pub const KERNEL_POOL_THREADS: &str = "relay_kernel_pool_threads";
    /// Distinct (op, shape) tile-schedule decisions made by the tuner
    /// (`tensor::tune::ensure` — the `TuneKernels` pass and lazy launches).
    pub const TUNED_SCHEDULES_TOTAL: &str = "relay_tuned_schedules_total";
    /// Compile attempts that failed, labeled by `kind`: `panic` (the
    /// compiler unwound — caught by the cache's panic guard), `error` (a
    /// typed pipeline/lowering error), `negative_cache` (fast-failed
    /// against a remembered bad key without recompiling).
    pub const COMPILE_FAILURES_TOTAL: &str = "relay_compile_failures_total";
    /// Executions served below the requested optimization tier, labeled by
    /// `level` — the tier that actually ran (`"1"` = the -O1 retry rung,
    /// `"0"` = the interpreter floor).
    pub const DEGRADED_EXECUTIONS_TOTAL: &str = "relay_degraded_executions_total";
    /// Per-bucket compile circuit-breaker state, labeled by `bucket` and
    /// `scope`: 0 = closed (compiles allowed), 1 = open (serving last-good
    /// / interpreter only), 2 = half-open (one probe in flight).
    pub const BREAKER_STATE: &str = "relay_breaker_state";
    pub const REQUEST_SECONDS: &str = "relay_request_seconds";
    pub const QUEUE_WAIT_SECONDS: &str = "relay_queue_wait_seconds";
    pub const BATCH_FORM_SECONDS: &str = "relay_batch_form_seconds";
    pub const COMPILE_SECONDS: &str = "relay_compile_seconds";
    pub const EXECUTE_SECONDS: &str = "relay_execute_seconds";
}

/// Default bucket upper bounds (seconds) for latency histograms: 250 µs to
/// 5 s, roughly ×2–×2.5 per step — the range the serving fleet and the
/// executors actually land in.
pub const LATENCY_BUCKETS: [f64; 14] = [
    0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
];

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (e.g. queue depth).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket histogram. `bounds` are the finite upper bounds (strictly
/// increasing); one extra overflow bucket catches everything above the last
/// bound. Observations and renders are lock-free; quantiles are estimated
/// by linear interpolation inside the bucket where the cumulative count
/// crosses the requested rank, so the estimate is always within one bucket
/// width of the exact sample quantile (asserted by the property test below).
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>, // bounds.len() + 1 (last = overflow)
    sum_bits: AtomicU64,    // f64 bits, CAS-accumulated
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Record a duration in seconds.
    pub fn observe_duration(&self, d: std::time::Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Estimated `q`-quantile (`0.0 ..= 1.0`) via in-bucket linear
    /// interpolation. Returns 0.0 for an empty histogram; observations in
    /// the overflow bucket clamp to the last finite bound.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if (cum + n) as f64 >= rank {
                let last = *self.bounds.last().expect("non-empty bounds");
                if i == self.bounds.len() {
                    return last; // overflow bucket: clamp
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = ((rank - cum as f64) / n as f64).clamp(0.0, 1.0);
                return lo + frac * (hi - lo);
            }
            cum += n;
        }
        *self.bounds.last().expect("non-empty bounds")
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// A named collection of metric series. One process-wide instance lives
/// behind [`registry()`]; fresh instances are only for tests.
#[derive(Debug, Default)]
pub struct Registry {
    // Key = (name, rendered-labels); BTreeMap keeps render output stable.
    series: Mutex<BTreeMap<(String, String), Metric>>,
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    let mut sorted: Vec<_> = labels.to_vec();
    sorted.sort_unstable_by_key(|&(k, _)| k);
    let mut out = String::new();
    for (i, (k, v)) in sorted.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let escaped: String = v
            .chars()
            .flat_map(|c| match c {
                '\\' => vec!['\\', '\\'],
                '"' => vec!['\\', '"'],
                '\n' => vec!['\\', 'n'],
                c => vec![c],
            })
            .collect();
        let _ = write!(out, "{k}=\"{escaped}\"");
    }
    out
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    fn get_or_insert(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let key = (name.to_string(), render_labels(labels));
        let mut series = self.series.lock().unwrap_or_else(|e| e.into_inner());
        series.entry(key).or_insert_with(make).clone()
    }

    /// Get or register a counter with no labels.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_with(name, &[])
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.get_or_insert(name, labels, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            m => panic!("metric `{name}` already registered as a {}", m.kind()),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauge_with(name, &[])
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.get_or_insert(name, labels, || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            m => panic!("metric `{name}` already registered as a {}", m.kind()),
        }
    }

    /// Get or register a histogram with the default latency buckets.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_with(name, &[])
    }

    pub fn histogram_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        self.histogram_buckets(name, labels, &LATENCY_BUCKETS)
    }

    pub fn histogram_buckets(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Arc<Histogram> {
        let make = || Metric::Histogram(Arc::new(Histogram::new(bounds)));
        match self.get_or_insert(name, labels, make) {
            Metric::Histogram(h) => h,
            m => panic!("metric `{name}` already registered as a {}", m.kind()),
        }
    }

    /// Render every series as Prometheus-style text: `# TYPE` comments plus
    /// `name{labels} value` sample lines. Histograms expand into cumulative
    /// `_bucket{le=...}` series plus `_sum` and `_count`.
    pub fn render(&self) -> String {
        let series = self.series.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::new();
        let mut last_name = "";
        for ((name, labels), metric) in series.iter() {
            if name != last_name {
                let _ = writeln!(out, "# TYPE {name} {}", metric.kind());
            }
            last_name = name;
            let sep = if labels.is_empty() { "" } else { "," };
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{name}{} {}", braced(labels), c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{name}{} {}", braced(labels), g.get());
                }
                Metric::Histogram(h) => {
                    let mut cum = 0u64;
                    for (bound, count) in h.bounds.iter().zip(&h.counts) {
                        cum += count.load(Ordering::Relaxed);
                        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{bound}\"}} {cum}");
                    }
                    let total = cum + h.counts[h.bounds.len()].load(Ordering::Relaxed);
                    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {total}");
                    let _ = writeln!(out, "{name}_sum{} {}", braced(labels), h.sum());
                    let _ = writeln!(out, "{name}_count{} {}", braced(labels), total);
                }
            }
        }
        out
    }
}

fn braced(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry every subsystem reports into.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::new)
}

/// True if `line` is a well-formed render line: a `#` comment, blank, or
/// `name{labels} value` where `value` parses as a float. Shared by the unit
/// tests, the serving integration test, and (in awk form) the CI smoke step.
pub fn line_is_well_formed(line: &str) -> bool {
    if line.is_empty() || line.starts_with('#') {
        return true;
    }
    let name_end = line
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_' || c == ':'))
        .unwrap_or(line.len());
    if name_end == 0 {
        return false;
    }
    let rest = &line[name_end..];
    let rest = if let Some(stripped) = rest.strip_prefix('{') {
        match stripped.find('}') {
            Some(close) => &stripped[close + 1..],
            None => return false,
        }
    } else {
        rest
    };
    match rest.strip_prefix(' ') {
        Some(value) => value.parse::<f64>().is_ok(),
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_and_share_handles() {
        let r = Registry::new();
        let c = r.counter("relay_test_total");
        c.inc();
        c.add(2);
        assert_eq!(c.get(), 3);
        // Same (name, labels) → same underlying atomic.
        r.counter("relay_test_total").inc();
        assert_eq!(c.get(), 4);
        // Different labels → distinct series.
        let c2 = r.counter_with("relay_test_total", &[("port", "7000")]);
        c2.inc();
        assert_eq!(c.get(), 4);
        let g = r.gauge("relay_test_depth");
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);

        let text = r.render();
        assert!(text.contains("# TYPE relay_test_total counter"));
        assert!(text.contains("relay_test_total 4"));
        assert!(text.contains("relay_test_total{port=\"7000\"} 1"));
        assert!(text.contains("# TYPE relay_test_depth gauge"));
        assert!(text.contains("relay_test_depth 3"));
        for line in text.lines() {
            assert!(line_is_well_formed(line), "bad line: {line:?}");
        }
    }

    #[test]
    fn histogram_bucket_boundaries_are_inclusive_upper() {
        let r = Registry::new();
        let h = r.histogram_buckets("relay_test_seconds", &[], &[1.0, 2.0, 4.0]);
        // Exactly on a bound lands in that bucket (le = ≤).
        h.observe(1.0);
        h.observe(2.0);
        h.observe(4.0);
        // Just above the last bound lands in the overflow bucket.
        h.observe(4.5);
        // Below the first bound lands in the first bucket.
        h.observe(0.1);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 11.6).abs() < 1e-9);
        let text = r.render();
        assert!(text.contains("relay_test_seconds_bucket{le=\"1\"} 2"));
        assert!(text.contains("relay_test_seconds_bucket{le=\"2\"} 3"));
        assert!(text.contains("relay_test_seconds_bucket{le=\"4\"} 4"));
        assert!(text.contains("relay_test_seconds_bucket{le=\"+Inf\"} 5"));
        assert!(text.contains("relay_test_seconds_count 5"));
        for line in text.lines() {
            assert!(line_is_well_formed(line), "bad line: {line:?}");
        }
    }

    #[test]
    fn quantiles_interpolate_within_the_crossing_bucket() {
        let r = Registry::new();
        let h = r.histogram_buckets("relay_q_seconds", &[], &[1.0, 2.0, 3.0]);
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        // 10 observations in (1, 2]: the median interpolates inside that
        // bucket; rank 5 of 10 → halfway through → 1.5.
        for i in 0..10 {
            h.observe(1.05 + 0.09 * i as f64);
        }
        assert!((h.p50() - 1.5).abs() < 1e-9, "p50 = {}", h.p50());
        // All mass in one bucket → every quantile stays inside it.
        assert!(h.p99() > 1.0 && h.p99() <= 2.0);
        // Overflow observations clamp to the last finite bound.
        let r2 = Registry::new();
        let h2 = r2.histogram_buckets("relay_q2_seconds", &[], &[1.0]);
        h2.observe(100.0);
        assert_eq!(h2.p50(), 1.0);
    }

    /// Hand-rolled property test (proptest is not vendored; randomness is
    /// the deterministic xoshiro [`crate::tensor::Rng`]): for random samples
    /// and random quantiles, the histogram estimate is within one bucket
    /// width of the exact sample quantile.
    #[test]
    fn quantile_estimates_within_one_bucket_width_of_exact() {
        let mut rng = crate::tensor::Rng::new(0x7e1e_9e37);
        let bounds: Vec<f64> = LATENCY_BUCKETS.to_vec();
        for case in 0..50 {
            let r = Registry::new();
            let h = r.histogram_buckets("relay_prop_seconds", &[], &bounds);
            let n = 1 + (rng.next_u64() % 400) as usize;
            let mut samples: Vec<f64> = (0..n)
                // Uniform in [0, last bound] so nothing lands in the
                // unbounded overflow bucket (where no error bound holds).
                .map(|_| rng.uniform() as f64 * bounds[bounds.len() - 1])
                .collect();
            for &s in &samples {
                h.observe(s);
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &q in &[0.5, 0.9, 0.95, 0.99] {
                let exact = samples[((q * n as f64).ceil() as usize).clamp(1, n) - 1];
                let est = h.quantile(q);
                // Width of the bucket containing the exact quantile.
                let idx = bounds.iter().position(|&b| exact <= b).unwrap();
                let lo = if idx == 0 { 0.0 } else { bounds[idx - 1] };
                let width = bounds[idx] - lo;
                assert!(
                    (est - exact).abs() <= width + 1e-12,
                    "case {case}: q={q} exact={exact} est={est} width={width} n={n}"
                );
            }
        }
    }

    #[test]
    fn well_formedness_checker_rejects_garbage() {
        assert!(line_is_well_formed("# TYPE x counter"));
        assert!(line_is_well_formed("relay_x_total 3"));
        assert!(line_is_well_formed("relay_x_bucket{le=\"+Inf\"} 5"));
        assert!(line_is_well_formed("relay_x_sum 0.0000125"));
        assert!(!line_is_well_formed("no value here"));
        assert!(!line_is_well_formed("relay_x_total"));
        assert!(!line_is_well_formed("relay_x{unclosed 3"));
        assert!(!line_is_well_formed(" leading_space 1"));
    }
}
