//! Opt-in per-op profiler: aggregates per-(op, shape) call count, wall
//! time, and in-place hit/miss on the executing thread.
//!
//! Profiling is off by default and costs one thread-local check per kernel
//! when inactive. A [`ProfileScope`] installs a collector on the current
//! thread; while it is live, the executors report through two hooks:
//!
//! - [`note_launch`] — called next to every `LaunchCounter::bump()` site
//!   (graph-runtime node dispatch, VM `InvokePacked`/`IfCmp`/op-ref calls,
//!   interpreter op application), so `Profile::launches` equals the run's
//!   [`crate::eval::LaunchCounter`] value exactly.
//! - [`op_timer`] / [`record_op`] — bracket each individual operator kernel
//!   (`op::inplace::eval_step` and the interpreter's direct op path). Fused
//!   kernels report one launch but one row update per inner step, so the
//!   table stays per-op even when ops execute fused.
//!
//! The collector is thread-local: a scope profiles the kernels the *calling
//! thread* runs, unpolluted by parallel test threads or fleet workers.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::marker::PhantomData;
use std::time::{Duration, Instant};

#[derive(Debug, Default)]
struct RowAgg {
    calls: u64,
    wall: Duration,
    hits: u64,
    misses: u64,
}

#[derive(Debug)]
struct Collector {
    rows: BTreeMap<(&'static str, String), RowAgg>,
    launches: u64,
    started: Instant,
}

thread_local! {
    static ACTIVE: RefCell<Option<Collector>> = const { RefCell::new(None) };
}

/// Guard that enables profiling on the current thread for its lifetime.
/// Consume it with [`ProfileScope::finish`] to get the aggregated
/// [`Profile`]; dropping it without finishing discards the data.
#[derive(Debug)]
pub struct ProfileScope {
    // Keep the scope on the thread whose collector it installed.
    _not_send: PhantomData<*const ()>,
}

impl ProfileScope {
    /// Install a fresh collector on this thread. Panics if one is already
    /// active — scopes do not nest.
    pub fn begin() -> ProfileScope {
        ACTIVE.with(|a| {
            let mut slot = a.borrow_mut();
            assert!(slot.is_none(), "ProfileScope does not nest");
            *slot = Some(Collector {
                rows: BTreeMap::new(),
                launches: 0,
                started: Instant::now(),
            });
        });
        ProfileScope { _not_send: PhantomData }
    }

    /// Uninstall the collector and return what it gathered.
    pub fn finish(self) -> Profile {
        let collector = ACTIVE.with(|a| a.borrow_mut().take());
        let collector = collector.expect("ProfileScope::finish with no active collector");
        let wall = collector.started.elapsed();
        let mut rows: Vec<ProfileRow> = collector
            .rows
            .into_iter()
            .map(|((op, shape), agg)| ProfileRow {
                op,
                shape,
                calls: agg.calls,
                wall: agg.wall,
                inplace_hits: agg.hits,
                inplace_misses: agg.misses,
            })
            .collect();
        rows.sort_by(|a, b| b.wall.cmp(&a.wall).then(a.op.cmp(b.op)));
        Profile { rows, launches: collector.launches, wall }
    }
}

impl Drop for ProfileScope {
    fn drop(&mut self) {
        ACTIVE.with(|a| a.borrow_mut().take());
    }
}

/// True while a [`ProfileScope`] is live on this thread.
pub fn active() -> bool {
    ACTIVE.with(|a| a.borrow().is_some())
}

/// Count one kernel launch (placed beside every `LaunchCounter::bump()`).
#[inline]
pub fn note_launch() {
    ACTIVE.with(|a| {
        if let Some(c) = a.borrow_mut().as_mut() {
            c.launches += 1;
        }
    });
}

/// Start timing one operator kernel. Returns `None` (and costs only the
/// thread-local check) when profiling is inactive.
#[inline]
pub fn op_timer() -> Option<OpTimer> {
    if active() {
        Some(OpTimer { start: Instant::now() })
    } else {
        None
    }
}

#[derive(Debug)]
pub struct OpTimer {
    start: Instant,
}

/// Record one finished kernel under `(op, shape)`. `hits`/`misses` are the
/// in-place planner outcome for this call (0/0 for ineligible ops).
pub fn record_op(timer: OpTimer, op: &'static str, shape: String, hits: u64, misses: u64) {
    let wall = timer.start.elapsed();
    ACTIVE.with(|a| {
        if let Some(c) = a.borrow_mut().as_mut() {
            let row = c.rows.entry((op, shape)).or_default();
            row.calls += 1;
            row.wall += wall;
            row.hits += hits;
            row.misses += misses;
        }
    });
}

/// One aggregated table row: every call of `op` on argument shapes `shape`.
#[derive(Clone, Debug)]
pub struct ProfileRow {
    pub op: &'static str,
    pub shape: String,
    pub calls: u64,
    pub wall: Duration,
    pub inplace_hits: u64,
    pub inplace_misses: u64,
}

/// Result of a profiled execution, attached to
/// [`crate::eval::Execution::profile`] and printed by `relay run --profile`.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    /// Rows sorted by wall time, heaviest first.
    pub rows: Vec<ProfileRow>,
    /// Kernel launches observed — equals the run's `LaunchCounter` value.
    pub launches: u64,
    /// Wall-clock span of the whole scope (launches plus glue).
    pub wall: Duration,
}

impl Profile {
    /// Total op calls across all rows (≥ `launches` when kernels fuse).
    pub fn total_calls(&self) -> u64 {
        self.rows.iter().map(|r| r.calls).sum()
    }

    fn total_kernel_wall(&self) -> Duration {
        self.rows.iter().map(|r| r.wall).sum()
    }

    /// Render the per-op table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<28} {:<34} {:>7} {:>12} {:>5} {:>7} {:>7}",
            "op", "shape", "calls", "wall(us)", "%", "ip-hit", "ip-miss"
        );
        let kernel_wall = self.total_kernel_wall();
        for row in &self.rows {
            let us = row.wall.as_secs_f64() * 1e6;
            let pct = if kernel_wall.is_zero() {
                0.0
            } else {
                100.0 * row.wall.as_secs_f64() / kernel_wall.as_secs_f64()
            };
            let _ = writeln!(
                out,
                "{:<28} {:<34} {:>7} {:>12.1} {:>5.1} {:>7} {:>7}",
                row.op, row.shape, row.calls, us, pct, row.inplace_hits, row.inplace_misses
            );
        }
        let _ = writeln!(
            out,
            "total: {} op calls over {} launches; kernel wall {:.1} us of {:.1} us scope",
            self.total_calls(),
            self.launches,
            kernel_wall.as_secs_f64() * 1e6,
            self.wall.as_secs_f64() * 1e6,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_hooks_are_no_ops() {
        assert!(!active());
        assert!(op_timer().is_none());
        note_launch(); // must not panic or record anywhere
    }

    #[test]
    fn scope_aggregates_rows_and_launches() {
        let scope = ProfileScope::begin();
        assert!(active());
        note_launch();
        note_launch();
        let t = op_timer().expect("active scope");
        record_op(t, "add", "(f32[4],f32[4])".into(), 1, 0);
        let t = op_timer().unwrap();
        record_op(t, "add", "(f32[4],f32[4])".into(), 0, 1);
        let t = op_timer().unwrap();
        record_op(t, "nn.dense", "(f32[2,4],f32[8,4])".into(), 0, 0);
        let profile = scope.finish();
        assert!(!active());
        assert_eq!(profile.launches, 2);
        assert_eq!(profile.total_calls(), 3);
        let add = profile.rows.iter().find(|r| r.op == "add").unwrap();
        assert_eq!((add.calls, add.inplace_hits, add.inplace_misses), (2, 1, 1));
        let table = profile.render();
        assert!(table.contains("nn.dense"));
        assert!(table.contains("3 op calls over 2 launches"));
    }

    #[test]
    fn dropping_a_scope_uninstalls_the_collector() {
        {
            let _scope = ProfileScope::begin();
            assert!(active());
        }
        assert!(!active());
        // A fresh scope starts from zero.
        let scope = ProfileScope::begin();
        let profile = scope.finish();
        assert_eq!(profile.launches, 0);
        assert!(profile.rows.is_empty());
    }
}
