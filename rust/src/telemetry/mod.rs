//! Unified telemetry: one observability layer for the whole stack.
//!
//! Three pieces, each usable alone, all feeding one another:
//!
//! - [`registry`]: process-wide named counters, gauges, and fixed-bucket
//!   latency histograms (p50/p95/p99 by bucket interpolation), lock-free on
//!   the update path, rendered as Prometheus-style text. Served by
//!   `relay serve` at `GET /metrics` and dumped by `relay metrics`.
//! - [`profiler`]: opt-in per-op profiling. A [`ProfileScope`] on the
//!   executing thread aggregates per-(op, shape) call counts, wall time,
//!   and in-place hit/miss from the executors' kernel dispatch; surfaced
//!   by `relay run --profile` and on [`crate::eval::Execution::profile`].
//! - [`span`]: per-request latency breakdown in the serving fleet
//!   (queue-wait → batch-form → compile → execute), rolled up into the
//!   registry histograms and optionally streamed as chrome://tracing JSON
//!   by `relay serve --trace-json PATH`.
//!
//! This module depends on nothing else in the crate (std only), so every
//! layer — `tensor` up through `coordinator` — can report into it. It
//! replaces what used to be four disconnected instrument islands
//! (`LaunchCounter` totals, `tensor::AllocStats`, `pass::PassTrace`
//! timings, and the serving `Stats` println reporting): the first three
//! still exist as APIs but their process-wide aggregates now live here.
//! See `README.md` in this directory for the model and naming conventions.

pub mod profiler;
pub mod registry;
pub mod span;

pub use profiler::{Profile, ProfileRow, ProfileScope};
pub use registry::{registry, Counter, Gauge, Histogram, Registry};
pub use span::{ChromeTraceWriter, MemorySpans, Outcome, RequestSpan, SpanSink};
