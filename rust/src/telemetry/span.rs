//! Request spans for the serving fleet: one [`RequestSpan`] per served
//! request, recording where its latency went (queue-wait, batch formation,
//! compile, execute). Completed spans go to a [`SpanSink`]; the fleet also
//! rolls them up into the registry histograms.
//!
//! [`ChromeTraceWriter`] streams spans as chrome://tracing "X" (complete)
//! events — open the file with `chrome://tracing` or Perfetto. Timestamps
//! are microseconds since the process [`epoch`], one track (`tid`) per
//! fleet worker.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Process-wide time origin for span timestamps. First call pins it.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds from the process epoch to `t` (0 if `t` predates it).
pub fn micros_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

/// How a served request ended. Rendered as the `outcome` label on
/// `relay_request_outcomes_total` and carried on every span, so a failed
/// batch can no longer masquerade as a cache-hit success (the pre-PR 7
/// span shape had no outcome and error batches recorded `compile_hit:
/// true` / `compile: ZERO` — indistinguishable from a healthy hit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Executed and answered with a prediction.
    Ok,
    /// Answered with a typed error (backend error or worker panic).
    Error,
    /// Rejected at admission (queue over budget, or shutting down).
    Shed,
    /// Admitted, but its deadline passed before a worker could run it;
    /// dropped at drain time with a `deadline exceeded` reply.
    Deadline,
}

impl Outcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Error => "error",
            Outcome::Shed => "shed",
            Outcome::Deadline => "deadline",
        }
    }
}

/// Where one served request's latency went, phase by phase.
#[derive(Clone, Debug)]
pub struct RequestSpan {
    /// Process-unique request id.
    pub id: u64,
    /// Fleet worker that executed the batch holding this request.
    pub worker: usize,
    /// Size of that batch.
    pub batch_size: usize,
    /// Enqueue time, microseconds since the process [`epoch`].
    pub enqueued_us: u64,
    /// Enqueue → drained off the shared queue by a worker.
    pub queue_wait: Duration,
    /// Drained → batch closed (waiting for stragglers / the batch timer).
    pub batch_form: Duration,
    /// Compile time charged to this batch (zero on a program-cache hit).
    pub compile: Duration,
    /// Whether the batch's program came out of the cache.
    pub compile_hit: bool,
    /// Running the compiled batch (pad, execute, unpack).
    pub execute: Duration,
    /// Enqueue → response handed back.
    pub total: Duration,
    /// How the request ended (see [`Outcome`]). Shed spans never reached
    /// a worker, so their phase durations are zero and `worker` /
    /// `batch_size` are 0; deadline spans have a real `queue_wait` but no
    /// batch or execute phases.
    pub outcome: Outcome,
    /// `Some(level-digit)` when the batch was served by a degraded
    /// artifact instead of the requested tier (`"1"` = the -O1 retry,
    /// `"0"` = the interpreter floor); `None` on the healthy path. Carried
    /// into the chrome-trace `args` so fallback batches are visually
    /// attributable.
    pub compile_fallback: Option<&'static str>,
}

/// Destination for completed spans. Implementations must tolerate calls
/// from multiple fleet workers at once.
pub trait SpanSink: Send + Sync {
    fn record(&self, span: &RequestSpan);

    /// Flush buffered spans to durable storage. Called by the fleet's
    /// graceful drain after the last worker exits; the default is a no-op
    /// for sinks that do not buffer.
    fn flush(&self) {}
}

/// In-memory sink for tests and embedders.
#[derive(Debug, Default)]
pub struct MemorySpans {
    spans: Mutex<Vec<RequestSpan>>,
    flushes: std::sync::atomic::AtomicUsize,
}

impl MemorySpans {
    pub fn new() -> Self {
        MemorySpans::default()
    }

    /// Copy of everything recorded so far.
    pub fn spans(&self) -> Vec<RequestSpan> {
        self.spans.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// How many times [`SpanSink::flush`] was called (the graceful-drain
    /// tests assert the fleet flushed its sink on shutdown).
    pub fn flushes(&self) -> usize {
        self.flushes.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl SpanSink for MemorySpans {
    fn record(&self, span: &RequestSpan) {
        self.spans.lock().unwrap_or_else(|e| e.into_inner()).push(span.clone());
    }

    fn flush(&self) {
        self.flushes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Streams spans to `path` as a chrome://tracing JSON event array. Events
/// are flushed per span so the file is useful even if the serve process is
/// killed; the closing `]` is written on drop (trace viewers accept a
/// missing terminator too).
pub struct ChromeTraceWriter {
    out: Mutex<TraceFile>,
}

struct TraceFile {
    w: BufWriter<File>,
    first: bool,
}

impl ChromeTraceWriter {
    pub fn create(path: &Path) -> io::Result<Self> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(b"[\n")?;
        w.flush()?;
        Ok(ChromeTraceWriter { out: Mutex::new(TraceFile { w, first: true }) })
    }
}

fn push_event(
    buf: &mut String,
    first: &mut bool,
    name: &str,
    ts: u64,
    dur: Duration,
    span: &RequestSpan,
) {
    if !*first {
        buf.push_str(",\n");
    }
    *first = false;
    let fallback = match span.compile_fallback {
        Some(level) => format!(",\"compile_fallback\":\"{level}\""),
        None => String::new(),
    };
    let _ = write!(
        buf,
        "{{\"name\":\"{name}\",\"cat\":\"request\",\"ph\":\"X\",\"ts\":{ts},\
         \"dur\":{},\"pid\":1,\"tid\":{},\"args\":{{\"req\":{},\"batch\":{},\
         \"compile_hit\":{},\"outcome\":\"{}\"{fallback}}}}}",
        dur.as_micros(),
        span.worker,
        span.id,
        span.batch_size,
        span.compile_hit,
        span.outcome.as_str(),
    );
}

impl SpanSink for ChromeTraceWriter {
    fn record(&self, span: &RequestSpan) {
        let mut out = self.out.lock().unwrap_or_else(|e| e.into_inner());
        let mut buf = String::new();
        let mut first = out.first;
        let mut ts = span.enqueued_us;
        push_event(&mut buf, &mut first, "request", ts, span.total, span);
        push_event(&mut buf, &mut first, "queue", ts, span.queue_wait, span);
        ts += span.queue_wait.as_micros() as u64;
        push_event(&mut buf, &mut first, "batch", ts, span.batch_form, span);
        ts += span.batch_form.as_micros() as u64;
        if !span.compile.is_zero() {
            push_event(&mut buf, &mut first, "compile", ts, span.compile, span);
            ts += span.compile.as_micros() as u64;
        }
        push_event(&mut buf, &mut first, "execute", ts, span.execute, span);
        out.first = first;
        // Serving must not die on a full disk; drop the event instead.
        let _ = out.w.write_all(buf.as_bytes());
        let _ = out.w.flush();
    }

    fn flush(&self) {
        let mut out = self.out.lock().unwrap_or_else(|e| e.into_inner());
        let _ = out.w.flush();
    }
}

impl Drop for ChromeTraceWriter {
    fn drop(&mut self) {
        let mut out = self.out.lock().unwrap_or_else(|e| e.into_inner());
        let _ = out.w.write_all(b"\n]\n");
        let _ = out.w.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64) -> RequestSpan {
        RequestSpan {
            id,
            worker: 2,
            batch_size: 3,
            enqueued_us: 1000,
            queue_wait: Duration::from_micros(50),
            batch_form: Duration::from_micros(10),
            compile: Duration::from_micros(400),
            compile_hit: false,
            execute: Duration::from_micros(90),
            total: Duration::from_micros(560),
            outcome: Outcome::Ok,
            compile_fallback: None,
        }
    }

    #[test]
    fn memory_sink_collects_spans_and_counts_flushes() {
        let sink = MemorySpans::new();
        sink.record(&span(1));
        sink.record(&span(2));
        let got = sink.spans();
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].id, 2);
        assert_eq!(got[0].queue_wait, Duration::from_micros(50));
        assert_eq!(sink.flushes(), 0);
        sink.flush();
        assert_eq!(sink.flushes(), 1);
    }

    #[test]
    fn outcomes_render_as_stable_label_values() {
        assert_eq!(Outcome::Ok.as_str(), "ok");
        assert_eq!(Outcome::Error.as_str(), "error");
        assert_eq!(Outcome::Shed.as_str(), "shed");
        assert_eq!(Outcome::Deadline.as_str(), "deadline");
    }

    #[test]
    fn chrome_trace_writer_emits_a_json_event_array() {
        let name = format!("relay_trace_test_{}.json", std::process::id());
        let path = std::env::temp_dir().join(name);
        {
            let w = ChromeTraceWriter::create(&path).expect("create trace file");
            w.record(&span(7));
            let mut hit = span(8);
            hit.compile = Duration::ZERO;
            hit.compile_hit = true;
            hit.compile_fallback = Some("0");
            w.record(&hit);
        }
        let text = std::fs::read_to_string(&path).expect("read trace file");
        std::fs::remove_file(&path).ok();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"name\":\"queue\""));
        assert!(text.contains("\"name\":\"execute\""));
        assert!(text.contains("\"req\":7"));
        assert!(text.contains("\"outcome\":\"ok\""));
        // The degraded span carries the fallback annotation; the healthy
        // one omits the key entirely.
        assert!(text.contains("\"compile_fallback\":\"0\""));
        assert_eq!(text.matches("compile_fallback").count(), 4);
        // Cache-hit span: no compile event for request 8.
        assert_eq!(text.matches("\"name\":\"compile\"").count(), 1);
        // Events are comma-separated: n events → n-1 separators (9 events:
        // 5 for the miss span, 4 for the hit span).
        assert_eq!(text.matches("},\n{").count(), 8);
    }
}
