//! Compile-once execution: a compiled-program cache keyed by the
//! alpha-invariant module structural hash ([`crate::ir::module_structural_hash`])
//! **plus the requested compile options** (optimization level, executor).
//!
//! The serving story of the paper (and of TVM / nGraph's cached-executable
//! layer) is that compilation cost is paid once and the lean artifact runs
//! millions of times. [`ProgramCache`] makes the executor-selection layer
//! behave that way: `run_auto` / `run_with` on an unchanged module performs
//! exactly one optimize + ANF + compile, and every later call is pure
//! dispatch on the cached [`crate::graphrt::GraphRt`] / [`crate::vm::Program`].
//!
//! # One optimizing pipeline
//!
//! [`compile_for`] is the single compile driver: it runs the §5.2 pass
//! pipeline ([`crate::pass::optimize_traced`]) at the requested
//! [`CompileOptions::opt_level`] first, then lowers the *optimized* module
//! for the requested executor — normalizing to ANF **once** and sharing
//! that normal form between the graph-runtime attempt and the VM compile.
//! The per-pass [`crate::pass::PassTrace`] is cached alongside the program
//! and handed back on every hit.
//!
//! # Keying
//!
//! Keys are `(module_structural_hash, OptLevel, Executor)`, so `-O0` and
//! `-O3` artifacts of the same module coexist. Hit verification compares
//! the **pre-optimization** module snapshot with full structural equality
//! ([`crate::ir::modules_structurally_eq`]) — alpha-equivalent inputs
//! share entries no matter what the pipeline rewrote — and a 64-bit hash
//! collision can never route a module to the wrong artifact; it just
//! recompiles.
//!
//! # Thread safety
//!
//! Compiled programs are `Arc`-backed `Send + Sync` values, so one cache
//! serves the whole process: [`default_cache`] is a process-wide instance
//! shared by [`super::run_with`] / [`super::run_auto`] on every thread, and
//! serving fleets (`coordinator::server`) share one explicit instance
//! across all workers. Lookup takes a short lock (O(1) clones only);
//! **hit verification and compilation both run outside the critical
//! section**, with an in-flight key set so two threads racing on the same
//! miss compile at most once (the loser waits on a condvar and is served
//! the winner's artifact).
//!
//! # Eviction
//!
//! Entries are evicted least-recently-used, bounded both by entry count
//! and by resident constant-pool bytes ([`ProgramCache::with_limits`]), so
//! a mixed fleet with a few giant-weight models and many small ones keeps
//! its hot set resident instead of cycling FIFO-style.
//!
//! # Fault containment
//!
//! The compile step runs inside `catch_unwind`, *behind* the same RAII
//! in-flight guard that coordinates coalescing — so whether a compile
//! returns an error or panics outright, the guard's `Drop` always clears
//! the in-flight key and notifies the condvar, and no coalesced waiter
//! can ever hang on a failed compile. Panics surface as typed
//! [`CompileError`]s (`kind: Panic`) instead of unwinding into the
//! caller; plain pipeline/lowering failures keep their message under
//! `kind: Error`.
//!
//! Failed keys go into a bounded **negative cache**
//! ([`NEGATIVE_CACHE_CAP`] keys, FIFO): a known-bad (module, options)
//! pair fails fast on the remembered error — verified against the module
//! snapshot outside the lock, exactly like positive hits — instead of
//! re-running a doomed compile per request. A later successful insert
//! for the key (or an explicit [`ProgramCache::forget_negative`], the
//! circuit breaker's half-open probe) clears it.
//!
//! [`ProgramCache::get_or_compile_resilient`] layers the **degradation
//! ladder** on top: when the requested tier fails, retry at `-O1`, then
//! fall back to the `-O0` interpreter artifact (which cannot fail at
//! compile time and is the crate's semantic ground truth, so degraded
//! results stay bit-identical). The degraded level is recorded on the
//! cache entry, the [`PassTrace`] (`degraded_from`), and the returned
//! [`Resolved`], and failures/degradations are counted on
//! `relay_compile_failures_total{kind}`.
//!
//! Deterministic chaos for tests and the fig. 18 bench is injected with
//! [`ProgramCache::set_compile_hook`]: the hook runs *inside* the
//! `catch_unwind` region, in front of [`compile_for`], so an injected
//! panic exercises the genuine containment path.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use super::{env_empty, CompileOptions, Execution, Executor, Interp, LaunchCounter, Value};
use crate::ir::{self, Expr, Module};
use crate::pass::{OptLevel, PassTrace};
use crate::telemetry::registry::names as metric_names;
use crate::tensor::tune;

/// What executor-selection resolved a module to, compiled and ready to run.
#[derive(Clone)]
pub enum Compiled {
    /// First-order, control-flow-free: the graph runtime.
    Graph(Arc<crate::graphrt::GraphRt>),
    /// Everything else the VM compiles (closures, ADTs, recursion).
    Vm(Arc<crate::vm::Program>),
    /// The interpreter tier: no bytecode, but the *optimized* module is
    /// the artifact (the pass pipeline ran on it like any other tier).
    Interp(Arc<Module>),
}

impl Compiled {
    /// The tier this entry executes on (never "auto").
    pub fn executor_name(&self) -> &'static str {
        match self {
            Compiled::Graph(_) => "graphrt",
            Compiled::Vm(_) => "vm",
            Compiled::Interp(_) => "interp",
        }
    }

    /// Tensor bytes this artifact keeps resident in its constant pool —
    /// the metric behind the cache's byte-budgeted eviction. For the
    /// interpreter tier this is the optimized module's constant tensors.
    pub fn const_bytes(&self) -> usize {
        match self {
            Compiled::Graph(g) => g.const_bytes(),
            Compiled::Vm(p) => p.const_bytes(),
            Compiled::Interp(m) => module_const_bytes(m),
        }
    }
}

/// How a compile attempt failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompileErrorKind {
    /// The compiler unwound — caught by the cache's panic guard and
    /// converted instead of propagating into the caller.
    Panic,
    /// A typed pipeline or lowering error (the pre-existing `String`
    /// failures of `compile_for`).
    Error,
}

impl CompileErrorKind {
    pub fn label(self) -> &'static str {
        match self {
            CompileErrorKind::Panic => "panic",
            CompileErrorKind::Error => "error",
        }
    }
}

/// A typed compile failure. Every failure mode of the compile path —
/// pipeline errors, lowering errors, panics — arrives here; `Display`
/// renders the human message (so callers that stringify keep working).
#[derive(Clone, Debug)]
pub struct CompileError {
    pub kind: CompileErrorKind,
    pub message: String,
    /// This failure was served from the negative cache (fail-fast) rather
    /// than by running the compiler again.
    pub from_negative_cache: bool,
}

impl CompileError {
    fn new(kind: CompileErrorKind, message: String) -> CompileError {
        CompileError { kind, message, from_negative_cache: false }
    }

    /// The `kind` label value on `relay_compile_failures_total`:
    /// `panic` / `error` for fresh failures, `negative_cache` for
    /// fail-fast replays.
    pub fn kind_label(&self) -> &'static str {
        if self.from_negative_cache {
            "negative_cache"
        } else {
            self.kind.label()
        }
    }
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            CompileErrorKind::Panic => write!(f, "compile panicked: {}", self.message),
            CompileErrorKind::Error => write!(f, "{}", self.message),
        }
    }
}

impl std::error::Error for CompileError {}

impl From<CompileError> for String {
    fn from(e: CompileError) -> String {
        e.to_string()
    }
}

/// Best-effort human message from a caught panic payload.
fn panic_payload_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// What a cache lookup resolved to: the artifact, its pass trace, whether
/// *this* call compiled it, and whether it serves below the requested
/// optimization tier (`degraded_to` is the tier that actually ran —
/// `None` on the healthy path).
#[derive(Clone)]
pub struct Resolved {
    pub compiled: Compiled,
    pub trace: Arc<PassTrace>,
    pub compiled_now: bool,
    pub degraded_to: Option<OptLevel>,
}

/// Chaos/validation hook run inside the panic guard, in front of the real
/// compile (see [`ProgramCache::set_compile_hook`]).
pub type CompileHook = dyn Fn(&Module, &CompileOptions) -> Result<(), String> + Send + Sync;

/// Total bytes of `Expr::Const` tensors across a module's definitions.
fn module_const_bytes(m: &Module) -> usize {
    let mut total = 0usize;
    for f in m.defs.values() {
        let mut consts: Vec<ir::E> = Vec::new();
        ir::collect(&f.body, &|e| matches!(&**e, Expr::Const(_)), &mut consts);
        for c in consts {
            if let Expr::Const(t) = &*c {
                total += t.numel() * t.dtype().size_bytes();
            }
        }
    }
    total
}

/// Cache key: pre-optimization structural hash + the options that shape
/// the artifact, `fixpoint` included (it changes what the pipeline
/// produces, so fixpoint and single-round artifacts coexist).
/// (`typecheck` is validation-only — it never changes the compiled
/// output — so it is deliberately *not* part of the key.)
type Key = (u64, OptLevel, &'static str, bool);

fn key_for(module: &Module, opts: &CompileOptions) -> Key {
    (
        ir::module_structural_hash(module),
        opts.opt_level,
        opts.executor.name(),
        opts.fixpoint,
    )
}

struct Entry {
    /// Snapshot of the **pre-optimization** source module, for exact hit
    /// verification (so alpha-equivalent inputs share entries regardless
    /// of what the pipeline rewrote). `Arc` so the hit path can take an
    /// O(1) clone under the lock and run the deep structural comparison
    /// *after* releasing it.
    module: Arc<Module>,
    compiled: Compiled,
    /// What the optimizing driver did when this entry was built.
    trace: Arc<PassTrace>,
    /// Tile schedules the `TuneKernels` pass selected for this artifact's
    /// hot kernels (one per (op, shape)) — the compiled program and its
    /// kernel schedules live and evict together.
    schedules: tune::ScheduleSet,
    /// Cached [`Compiled::const_bytes`] of this entry.
    bytes: usize,
    /// Recency stamp (monotonic per cache) for LRU eviction.
    last_used: u64,
    /// The tier that actually compiled when the degradation ladder
    /// served this key below its requested level (`None` = healthy).
    degraded_to: Option<OptLevel>,
}

/// A remembered compile failure: the pre-optimization module snapshot
/// (for the same outside-the-lock structural verification positive hits
/// get) plus the typed error to replay.
struct NegativeEntry {
    module: Arc<Module>,
    error: CompileError,
}

/// Mutable cache state, all behind one lock: the resident entries, the
/// keys currently being compiled by some thread, and the LRU clock.
struct CacheState {
    entries: HashMap<Key, Entry>,
    in_flight: HashSet<Key>,
    total_bytes: usize,
    tick: u64,
    /// Known-bad keys, bounded by [`NEGATIVE_CACHE_CAP`].
    negative: HashMap<Key, NegativeEntry>,
    /// Insertion order of `negative` keys (FIFO eviction).
    negative_order: VecDeque<Key>,
}

/// Default bound on resident entries.
pub const DEFAULT_MAX_ENTRIES: usize = 128;
/// Default bound on resident constant-pool bytes (256 MiB).
pub const DEFAULT_MAX_BYTES: usize = 256 << 20;
/// Bound on remembered compile failures (FIFO): enough to cover a fleet's
/// worth of bad models, small enough that a scan of hostile one-off
/// modules cannot grow the map without limit.
pub const NEGATIVE_CACHE_CAP: usize = 64;

/// A bounded map from (module structural hash, opt level, executor) to a
/// compiled program, with hit/miss counters. One miss == one compile,
/// process-wide: concurrent misses on the same key are coalesced.
pub struct ProgramCache {
    state: Mutex<CacheState>,
    /// Signalled whenever an in-flight compile finishes (success or not).
    compiled: Condvar,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Fail-fast replays served from the negative cache.
    neg_hits: AtomicUsize,
    max_entries: usize,
    max_bytes: usize,
    /// Optional chaos/validation hook run inside the panic guard before
    /// every real compile (never on hits or fail-fast replays).
    hook: Mutex<Option<Arc<CompileHook>>>,
}

impl Default for ProgramCache {
    fn default() -> ProgramCache {
        ProgramCache::new()
    }
}

/// Removes `key` from the in-flight set (and wakes waiters) when dropped,
/// so a compile that errors — or panics — can never strand other threads
/// waiting on the condvar.
struct InFlightGuard<'a> {
    cache: &'a ProgramCache,
    key: Key,
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.cache.lock_state();
        st.in_flight.remove(&self.key);
        drop(st);
        self.cache.compiled.notify_all();
    }
}

impl ProgramCache {
    pub fn new() -> ProgramCache {
        ProgramCache::with_limits(DEFAULT_MAX_ENTRIES, DEFAULT_MAX_BYTES)
    }

    /// A cache bounded by `max_entries` resident programs and `max_bytes`
    /// of resident constant-pool tensor data (whichever trips first).
    pub fn with_limits(max_entries: usize, max_bytes: usize) -> ProgramCache {
        ProgramCache {
            state: Mutex::new(CacheState {
                entries: HashMap::new(),
                in_flight: HashSet::new(),
                total_bytes: 0,
                tick: 0,
                negative: HashMap::new(),
                negative_order: VecDeque::new(),
            }),
            compiled: Condvar::new(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            neg_hits: AtomicUsize::new(0),
            max_entries: max_entries.max(1),
            max_bytes,
            hook: Mutex::new(None),
        }
    }

    fn lock_state(&self) -> MutexGuard<'_, CacheState> {
        super::value::lock_unpoisoned(&self.state)
    }

    /// Cache hits so far (calls served without compiling).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far — equivalently, the number of compile
    /// *attempts* (failed attempts count: they did the work).
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fail-fast replays served from the negative cache (no compiler run).
    pub fn negative_hits(&self) -> usize {
        self.neg_hits.load(Ordering::Relaxed)
    }

    /// Known-bad keys currently remembered.
    pub fn negative_len(&self) -> usize {
        self.lock_state().negative.len()
    }

    /// Install the chaos/validation hook run (inside the panic guard)
    /// before every real compile. Replaces any previous hook.
    pub fn set_compile_hook(&self, hook: Arc<CompileHook>) {
        *crate::sync::lock_unpoisoned(&self.hook) = Some(hook);
    }

    /// Remove the compile hook.
    pub fn clear_compile_hook(&self) {
        *crate::sync::lock_unpoisoned(&self.hook) = None;
    }

    /// Drop the remembered failure for (module, opts), if any — the
    /// circuit breaker calls this before its half-open probe so the probe
    /// runs a *real* compile instead of replaying the cached error.
    /// Returns whether a negative entry was present.
    pub fn forget_negative(&self, module: &Module, opts: &CompileOptions) -> bool {
        let key = key_for(module, opts);
        let mut st = self.lock_state();
        if st.negative.remove(&key).is_some() {
            st.negative_order.retain(|k| k != &key);
            true
        } else {
            false
        }
    }

    /// Resident compiled programs.
    pub fn len(&self) -> usize {
        self.lock_state().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident constant-pool bytes across all entries.
    pub fn resident_bytes(&self) -> usize {
        self.lock_state().total_bytes
    }

    /// Drop all entries (negative cache included) and reset the counters.
    pub fn clear(&self) {
        let mut st = self.lock_state();
        st.entries.clear();
        st.total_bytes = 0;
        st.negative.clear();
        st.negative_order.clear();
        drop(st);
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.neg_hits.store(0, Ordering::Relaxed);
    }

    /// Look up (or optimize + compile and insert) the program for `module`
    /// under the given options. Accepts a bare [`Executor`] for the
    /// default optimization level.
    pub fn get_or_compile(
        &self,
        module: &Module,
        opts: impl Into<CompileOptions>,
    ) -> Result<Compiled, String> {
        self.get_or_compile_full(module, opts.into())
            .map(|r| r.compiled)
            .map_err(Into::into)
    }

    /// [`Self::get_or_compile`], also reporting whether *this* call
    /// performed the compile (`true`) or was served a resident/raced
    /// artifact (`false`). Callers that track their own compiles-per-
    /// lifetime invariant (the serving fleet's `Stats::compiles`) use this
    /// instead of diffing the global hit/miss counters, which other cache
    /// users may be bumping concurrently.
    pub fn get_or_compile_traced(
        &self,
        module: &Module,
        opts: impl Into<CompileOptions>,
    ) -> Result<(Compiled, bool), String> {
        self.get_or_compile_full(module, opts.into())
            .map(|r| (r.compiled, r.compiled_now))
            .map_err(Into::into)
    }

    /// The full lookup: the compiled program, the [`PassTrace`] recorded
    /// when it was built, whether this call performed the compile, and
    /// whether the resident artifact is a degraded one (see [`Resolved`]).
    pub fn get_or_compile_full(
        &self,
        module: &Module,
        opts: CompileOptions,
    ) -> Result<Resolved, CompileError> {
        if opts.is_uncached_interp() {
            // Nothing to optimize, nothing to compile: bypass the map.
            // (This materializes a snapshot per call for API users that
            // need an owned artifact; the execution path —
            // `super::run_with_cache` — short-circuits earlier and runs
            // on the borrowed module instead.)
            return Ok(Resolved {
                compiled: Compiled::Interp(Arc::new(module.clone())),
                trace: Arc::new(PassTrace::empty(OptLevel::O0)),
                compiled_now: false,
                degraded_to: None,
            });
        }
        let key = key_for(module, &opts);

        // Phase 1, under the lock: find a candidate entry — positive or
        // negative — (O(1) clones only) or claim the key for compilation.
        // The deep structural verification and the compile itself both run
        // outside the critical section, so hits on large modules don't
        // serialize the whole process.
        enum Candidate {
            Hit(Arc<Module>, Compiled, Arc<PassTrace>, Option<OptLevel>),
            Bad(Arc<Module>, CompileError),
            Claimed,
        }
        let candidate = {
            let mut guard = self.lock_state();
            loop {
                let st: &mut CacheState = &mut guard;
                let tick = st.tick;
                if let Some(entry) = st.entries.get_mut(&key) {
                    entry.last_used = tick;
                    st.tick = tick + 1;
                    break Candidate::Hit(
                        entry.module.clone(),
                        entry.compiled.clone(),
                        entry.trace.clone(),
                        entry.degraded_to,
                    );
                }
                if let Some(bad) = st.negative.get(&key) {
                    // Known-bad key: fail fast on the remembered error
                    // (verified outside the lock, below) instead of
                    // recompiling per request.
                    break Candidate::Bad(bad.module.clone(), bad.error.clone());
                }
                if st.in_flight.contains(&key) {
                    // Another thread is compiling this module right now:
                    // wait for it and re-check instead of compiling twice.
                    guard = self
                        .compiled
                        .wait(guard)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                    continue;
                }
                st.in_flight.insert(key);
                break Candidate::Claimed;
            }
        };
        let coordinated = match candidate {
            Candidate::Hit(snapshot, compiled, trace, degraded_to) => {
                // Verification is against the *pre-optimization* snapshot:
                // two alpha-equivalent inputs compare equal here even
                // though neither matches the optimized artifact.
                if ir::modules_structurally_eq(&snapshot, module) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(Resolved {
                        compiled,
                        trace,
                        compiled_now: false,
                        degraded_to,
                    });
                }
                // Verified hash collision: compile without claiming the
                // key (the resident entry stays until we replace it, and
                // coordinating would hand waiters the wrong module's
                // artifact anyway).
                false
            }
            Candidate::Bad(snapshot, mut error) => {
                if ir::modules_structurally_eq(&snapshot, module) {
                    self.neg_hits.fetch_add(1, Ordering::Relaxed);
                    crate::telemetry::registry()
                        .counter_with(
                            metric_names::COMPILE_FAILURES_TOTAL,
                            &[("kind", "negative_cache")],
                        )
                        .inc();
                    error.from_negative_cache = true;
                    return Err(error);
                }
                // Hash collision against a remembered failure: this is a
                // different module — compile it, uncoordinated (same rule
                // as a positive-entry collision).
                false
            }
            Candidate::Claimed => true,
        };

        self.misses.fetch_add(1, Ordering::Relaxed);
        let _inflight = coordinated.then(|| InFlightGuard { cache: self, key });
        // The optimize + compile runs outside the lock — other keys hit
        // and miss freely while this one builds — and inside the panic
        // guard, *behind* `_inflight`: error or panic, the key always
        // leaves the in-flight set and waiters always wake.
        let (compiled, trace, schedules) = match self.guarded_compile(module, &opts) {
            Ok(built) => built,
            Err(err) => {
                if coordinated {
                    // Remember the failure so waiters (woken by the guard
                    // drop just below) and later requests fail fast.
                    self.remember_negative(key, module, &err);
                }
                return Err(err);
            }
        };
        let trace = Arc::new(trace);
        self.insert_entry(key, module, compiled.clone(), trace.clone(), schedules, None);
        // _inflight drops here: key leaves the in-flight set, waiters wake
        // and find the entry resident.
        Ok(Resolved { compiled, trace, compiled_now: true, degraded_to: None })
    }

    /// [`Self::get_or_compile_full`] with the degradation ladder: when the
    /// requested tier fails, spend up to `max_opt_retries` fallback rungs
    /// — `-O1` (if the request was above it), then the `-O0` interpreter
    /// artifact, which cannot fail at compile time. A degraded success is
    /// cached under the *requested* key (so later calls hit in one
    /// lookup), with the ladder recorded on the entry and its trace.
    /// `max_opt_retries == 0` is exactly the strict behavior.
    pub fn get_or_compile_resilient(
        &self,
        module: &Module,
        opts: CompileOptions,
        max_opt_retries: usize,
    ) -> Result<Resolved, CompileError> {
        let first = match self.get_or_compile_full(module, opts) {
            Ok(resolved) => return Ok(resolved),
            Err(e) => e,
        };
        let mut budget = max_opt_retries;
        if budget > 0 && opts.opt_level > OptLevel::O1 {
            budget -= 1;
            // Rung 1: the same executor at -O1 — fusion only, none of the
            // aggressive -O2/-O3 rewrites. Goes through the full cached
            // path (coalescing and negative caching apply at the -O1 key).
            let lowered = CompileOptions { opt_level: OptLevel::O1, ..opts };
            if let Ok(r) = self.get_or_compile_full(module, lowered) {
                let trace = self.alias_degraded(module, &opts, &r, OptLevel::O1);
                return Ok(Resolved {
                    compiled: r.compiled,
                    trace,
                    compiled_now: r.compiled_now,
                    degraded_to: Some(OptLevel::O1),
                });
            }
        }
        if budget > 0 {
            // Rung 2: the interpreter floor. No pipeline, no lowering —
            // it cannot fail here, and the interpreter is the crate's
            // semantic ground truth, so the degraded result is
            // bit-identical to it by construction.
            let compiled = Compiled::Interp(Arc::new(module.clone()));
            let mut trace = PassTrace::empty(OptLevel::O0);
            trace.degraded_from = Some(opts.opt_level);
            let trace = Arc::new(trace);
            self.insert_entry(
                key_for(module, &opts),
                module,
                compiled.clone(),
                trace.clone(),
                Arc::new(Vec::new()),
                Some(OptLevel::O0),
            );
            return Ok(Resolved {
                compiled,
                trace,
                compiled_now: true,
                degraded_to: Some(OptLevel::O0),
            });
        }
        Err(first)
    }

    /// Cache a degraded artifact under the *requested* key so later
    /// requests for the original options hit in one lookup, with the
    /// ladder recorded on the entry and a degraded-marked trace.
    fn alias_degraded(
        &self,
        module: &Module,
        opts: &CompileOptions,
        resolved: &Resolved,
        to: OptLevel,
    ) -> Arc<PassTrace> {
        let mut trace = (*resolved.trace).clone();
        trace.degraded_from = Some(opts.opt_level);
        let trace = Arc::new(trace);
        let lowered = CompileOptions { opt_level: to, ..*opts };
        let schedules = self
            .cached_schedules(module, &lowered)
            .unwrap_or_else(|| Arc::new(Vec::new()));
        self.insert_entry(
            key_for(module, opts),
            module,
            resolved.compiled.clone(),
            trace.clone(),
            schedules,
            Some(to),
        );
        trace
    }

    /// Run the hook + compile inside `catch_unwind`, converting panics
    /// and errors into typed [`CompileError`]s and counting them on
    /// `relay_compile_failures_total{kind}`.
    fn guarded_compile(
        &self,
        module: &Module,
        opts: &CompileOptions,
    ) -> Result<(Compiled, PassTrace, tune::ScheduleSet), CompileError> {
        let hook = crate::sync::lock_unpoisoned(&self.hook).clone();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if let Some(h) = &hook {
                h(module, opts)?;
            }
            compile_for(module, opts)
        }));
        let err = match outcome {
            Ok(Ok(built)) => return Ok(built),
            Ok(Err(message)) => CompileError::new(CompileErrorKind::Error, message),
            Err(payload) => CompileError::new(
                CompileErrorKind::Panic,
                panic_payload_message(payload.as_ref()),
            ),
        };
        crate::telemetry::registry()
            .counter_with(metric_names::COMPILE_FAILURES_TOTAL, &[("kind", err.kind.label())])
            .inc();
        Err(err)
    }

    /// Insert (or replace) a resident entry, clear any remembered failure
    /// for the key, and enforce the LRU budgets.
    fn insert_entry(
        &self,
        key: Key,
        module: &Module,
        compiled: Compiled,
        trace: Arc<PassTrace>,
        schedules: tune::ScheduleSet,
        degraded_to: Option<OptLevel>,
    ) {
        let bytes = compiled.const_bytes();
        let mut guard = self.lock_state();
        let st: &mut CacheState = &mut guard;
        let tick = st.tick;
        st.tick = tick + 1;
        if let Some(old) = st.entries.remove(&key) {
            st.total_bytes -= old.bytes;
        }
        st.total_bytes += bytes;
        st.entries.insert(
            key,
            Entry {
                module: Arc::new(module.clone()),
                compiled,
                trace,
                schedules,
                bytes,
                last_used: tick,
                degraded_to,
            },
        );
        // A success supersedes any remembered failure for this key.
        if st.negative.remove(&key).is_some() {
            st.negative_order.retain(|k| k != &key);
        }
        self.evict_over_budget(st);
    }

    /// Remember a failed key (bounded, FIFO) so later requests fail fast.
    fn remember_negative(&self, key: Key, module: &Module, error: &CompileError) {
        let mut st = self.lock_state();
        let entry = NegativeEntry { module: Arc::new(module.clone()), error: error.clone() };
        if st.negative.insert(key, entry).is_none() {
            st.negative_order.push_back(key);
        }
        while st.negative.len() > NEGATIVE_CACHE_CAP {
            match st.negative_order.pop_front() {
                Some(old) => {
                    st.negative.remove(&old);
                }
                None => break,
            }
        }
    }

    /// The tile schedules stored next to a resident artifact (empty set if
    /// the entry was compiled below -O1). `None` when the module has no
    /// resident entry for these options. Does not touch LRU recency.
    pub fn cached_schedules(
        &self,
        module: &Module,
        opts: &CompileOptions,
    ) -> Option<tune::ScheduleSet> {
        if opts.is_uncached_interp() {
            return None;
        }
        let key = key_for(module, opts);
        let guard = self.lock_state();
        guard.entries.get(&key).map(|e| e.schedules.clone())
    }

    /// The degradation recorded on a resident entry: `None` when the
    /// module has no entry for these options, `Some(None)` for a healthy
    /// artifact, `Some(Some(level))` when the ladder cached a lower tier
    /// under this key. Does not touch LRU recency.
    pub fn cached_degraded_to(
        &self,
        module: &Module,
        opts: &CompileOptions,
    ) -> Option<Option<OptLevel>> {
        let key = key_for(module, opts);
        let guard = self.lock_state();
        guard.entries.get(&key).map(|e| e.degraded_to)
    }

    /// Evict least-recently-used entries until both the entry-count and
    /// byte budgets hold. Never evicts the last entry: a single program
    /// larger than the byte budget still serves (nothing else is resident
    /// to make room for).
    fn evict_over_budget(&self, st: &mut CacheState) {
        while st.entries.len() > 1
            && (st.entries.len() > self.max_entries || st.total_bytes > self.max_bytes)
        {
            let oldest = st
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    if let Some(e) = st.entries.remove(&k) {
                        st.total_bytes -= e.bytes;
                    }
                }
                None => break,
            }
        }
    }
}

/// The unified compile driver: run the optimization pipeline at the
/// requested level, then lower the optimized module for the requested
/// tier — the one place the selection chain (graph runtime -> VM ->
/// interpreter) lives. The ANF pass runs **once** on the optimized module
/// and is shared between the graph-runtime attempt and the VM compile.
/// Also returns the tile schedules the `TuneKernels` pass selected for the
/// optimized module (idempotent registry reads), so the cache can store
/// them next to the artifact.
pub fn compile_for(
    module: &Module,
    opts: &CompileOptions,
) -> Result<(Compiled, PassTrace, tune::ScheduleSet), String> {
    let cfg = crate::pass::PipelineConfig {
        level: opts.opt_level,
        typecheck: opts.typecheck,
        fixpoint: opts.fixpoint,
    };
    let (optimized, trace) = crate::pass::optimize_with(module, &cfg)?;
    let schedules: tune::ScheduleSet = if opts.opt_level >= OptLevel::O1 {
        Arc::new(crate::pass::tune_kernels::tune_module(&optimized))
    } else {
        Arc::new(Vec::new())
    };
    let compiled = match opts.executor {
        Executor::Interp => Compiled::Interp(Arc::new(optimized)),
        Executor::GraphRt => {
            let anfed = crate::pass::anf::run(&optimized);
            let main = anfed.def("main").ok_or("no @main in module")?;
            let g = crate::graphrt::GraphRt::compile(main).map_err(|e| e.to_string())?;
            Compiled::Graph(Arc::new(g))
        }
        Executor::Vm => {
            // Shares the normalization with the Auto arm: `compile_normalized`
            // on the already-ANF module, not `vm::compile` (which would
            // re-run ANF on the raw module).
            let anfed = crate::pass::anf::run(&optimized);
            let program =
                crate::vm::compile_normalized(&anfed).map_err(|e| e.to_string())?;
            Compiled::Vm(Arc::new(program))
        }
        Executor::Auto => {
            let anfed = crate::pass::anf::run(&optimized);
            if let Some(main) = anfed.def("main") {
                if let Ok(g) = crate::graphrt::GraphRt::compile(main) {
                    return Ok((Compiled::Graph(Arc::new(g)), trace, schedules));
                }
            }
            match crate::vm::compile_normalized(&anfed) {
                Ok(program) => Compiled::Vm(Arc::new(program)),
                // The VM compiles everything the interpreter runs; the
                // fallback is belt-and-braces for exotic inputs.
                Err(_) => Compiled::Interp(Arc::new(optimized)),
            }
        }
    };
    Ok((compiled, trace, schedules))
}

/// Run `@main(args...)` on an already-compiled program.
///
/// Launch counts are per-call: a cached artifact may be executing on
/// several threads at once, so each call counts on its own
/// [`LaunchCounter`] instead of diffing a counter shared across threads.
pub fn run_compiled(compiled: &Compiled, args: Vec<Value>) -> Result<Execution, String> {
    match compiled {
        Compiled::Graph(g) => {
            let launches = LaunchCounter::new();
            // Arguments are handed over by value: a tensor the caller
            // owns exclusively can be reused in place at its last use
            // (the VM path below gets the same property via `Vm::run`).
            let value = g.run_owned(args, &launches)?;
            Ok(Execution {
                value,
                executor: "graphrt",
                launches: launches.get(),
                pass_trace: None,
                profile: None,
                degraded_to: None,
            })
        }
        Compiled::Vm(p) => {
            let vm = crate::vm::Vm::new(p);
            let value = vm.run(args)?;
            Ok(Execution {
                value,
                executor: "vm",
                launches: vm.launches.get(),
                pass_trace: None,
                profile: None,
                degraded_to: None,
            })
        }
        Compiled::Interp(module) => interp_main(module, args),
    }
}

/// Interpreter tier over a borrowed module — shared by the
/// `Compiled::Interp` artifact path and the `-O0` interp fast path in
/// [`super::run_with_cache`] (which runs on the caller's module directly,
/// no snapshot clone, no cache traffic).
pub(crate) fn interp_main(module: &Module, args: Vec<Value>) -> Result<Execution, String> {
    let interp = Interp::new(module);
    let f = module.entry().ok_or("no @main in module")?.clone();
    let value = interp.apply(
        Value::Closure { func: f, env: env_empty(), rec: None },
        args,
        &crate::ir::Attrs::new(),
    )?;
    Ok(Execution {
        value,
        executor: "interp",
        launches: interp.op_calls(),
        pass_trace: None,
        profile: None,
        degraded_to: None,
    })
}

static DEFAULT_CACHE: OnceLock<ProgramCache> = OnceLock::new();

/// The process-wide default program cache (what [`super::run_with`] and
/// [`super::run_auto`] compile into, from every thread).
pub fn default_cache() -> &'static ProgramCache {
    DEFAULT_CACHE.get_or_init(ProgramCache::new)
}

/// Access the process-wide default program cache. Retained for callers
/// written against the old per-thread API; new code can use
/// [`default_cache`] directly.
pub fn with_default_cache<R>(f: impl FnOnce(&ProgramCache) -> R) -> R {
    f(default_cache())
}

#[cfg(test)]
mod tests {
    use super::super::{run_with_cache, Executor};
    use super::*;
    use crate::ir::parse_module;
    use crate::tensor::Tensor;

    fn tensor_arg(v: f32) -> Vec<Value> {
        vec![Value::Tensor(Tensor::scalar_f32(v))]
    }

    const CF_SRC: &str = "def @main(%x: Tensor[(), float32]) {\n\
                            if (greater(%x, 0f)) { %x } else { negative(%x) }\n\
                          }";

    #[test]
    fn repeated_auto_calls_compile_exactly_once() {
        let cache = ProgramCache::new();
        let m = parse_module(CF_SRC).unwrap();
        for i in 0..5 {
            let out = run_with_cache(&m, Executor::Auto, tensor_arg(-2.0 - i as f32), &cache)
                .unwrap();
            assert_eq!(out.executor, "vm");
            assert_eq!(out.value.tensor().f32_value(), 2.0 + i as f32);
        }
        assert_eq!(cache.misses(), 1, "exactly one compile across 5 calls");
        assert_eq!(cache.hits(), 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn compiled_entry_carries_its_tuned_schedules() {
        let cache = ProgramCache::new();
        let m = parse_module(
            "def @main(%x: Tensor[(8, 32), float32], %w: Tensor[(32, 32), float32]) {\n\
               nn.dense(%x, %w)\n\
             }",
        )
        .unwrap();
        let dense_args = || {
            vec![
                Value::Tensor(Tensor::from_f32(vec![8, 32], vec![0.5; 8 * 32])),
                Value::Tensor(Tensor::from_f32(vec![32, 32], vec![0.25; 32 * 32])),
            ]
        };
        let o3 = CompileOptions::at(Executor::Auto, OptLevel::O3);
        run_with_cache(&m, o3, dense_args(), &cache).unwrap();
        let schedules = cache.cached_schedules(&m, &o3).expect("entry resident");
        assert!(
            schedules.iter().any(|t| t.op == "nn.dense" && t.dims == [8, 32, 32]),
            "dense schedule missing from the entry: {schedules:?}"
        );
        // Below -O1 TuneKernels never runs: the entry stores an empty set.
        let o0 = CompileOptions::at(Executor::Auto, OptLevel::O0);
        run_with_cache(&m, o0, dense_args(), &cache).unwrap();
        let none = cache.cached_schedules(&m, &o0).expect("O0 entry resident");
        assert!(none.is_empty(), "O0 entry must hold no schedules: {none:?}");
    }

    #[test]
    fn alpha_renamed_module_shares_the_entry() {
        let cache = ProgramCache::new();
        let a = parse_module(CF_SRC).unwrap();
        // Re-parsing mints fresh variable ids: alpha-equivalent, not
        // identical — still one cache entry, even though hit verification
        // happens against the pre-optimization snapshot.
        let b = parse_module(&CF_SRC.replace("%x", "%renamed")).unwrap();
        run_with_cache(&a, Executor::Auto, tensor_arg(1.0), &cache).unwrap();
        run_with_cache(&b, Executor::Auto, tensor_arg(1.0), &cache).unwrap();
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn opt_levels_get_distinct_entries_and_distinct_compiles() {
        // The cache-keying regression of the pipeline refactor: the same
        // module requested at -O0 and then -O3 must compile twice into
        // two coexisting entries — while an alpha-renamed module at an
        // already-resident level hits.
        let cache = ProgramCache::new();
        let src = "def @main(%x: Tensor[(2, 2), float32]) { nn.relu(add(%x, 1f)) }";
        let m = parse_module(src).unwrap();
        let x = Tensor::from_f32(vec![2, 2], vec![-3.0, -1.0, 0.5, 2.0]);
        let args = vec![Value::Tensor(x)];

        let o0 = run_with_cache(
            &m,
            CompileOptions::at(Executor::Vm, OptLevel::O0),
            args.clone(),
            &cache,
        )
        .unwrap();
        let o3 = run_with_cache(
            &m,
            CompileOptions::at(Executor::Vm, OptLevel::O3),
            args.clone(),
            &cache,
        )
        .unwrap();
        assert_eq!(cache.misses(), 2, "each level compiles once");
        assert_eq!(cache.len(), 2, "O0 and O3 artifacts coexist");
        assert!(o0.value.bits_eq(&o3.value));
        assert!(o3.launches < o0.launches, "O3 entry is the fused one");
        // Traces record their level.
        assert_eq!(o0.pass_trace.as_ref().unwrap().level, OptLevel::O0);
        assert_eq!(o3.pass_trace.as_ref().unwrap().level, OptLevel::O3);

        // Alpha-renamed module at an existing level: pure hit.
        let renamed = parse_module(&src.replace("%x", "%y")).unwrap();
        let hit = run_with_cache(
            &renamed,
            CompileOptions::at(Executor::Vm, OptLevel::O3),
            args,
            &cache,
        )
        .unwrap();
        assert_eq!(cache.misses(), 2, "alpha-renamed module recompiled");
        assert_eq!(cache.hits(), 1);
        assert!(hit.value.bits_eq(&o3.value));
    }

    #[test]
    fn fixpoint_and_single_round_artifacts_coexist_in_the_cache() {
        // `fixpoint` shapes the compiled artifact, so it is part of the
        // key: the same module requested with and without it compiles
        // twice into two coexisting entries — and both compute the same
        // thing.
        let cache = ProgramCache::new();
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) {\n\
               let %a = 2f;\n\
               let %b = multiply(%a, 3f);\n\
               add(%x, %b)\n\
             }",
        )
        .unwrap();
        let plain = CompileOptions::at(Executor::Vm, OptLevel::O2);
        let fix = plain.with_fixpoint(true);
        let a = run_with_cache(&m, plain, tensor_arg(1.0), &cache).unwrap();
        let b = run_with_cache(&m, fix, tensor_arg(1.0), &cache).unwrap();
        assert_eq!(cache.misses(), 2, "fixpoint artifact shared the plain entry");
        assert_eq!(cache.len(), 2);
        assert!(a.value.bits_eq(&b.value));
        // Re-requesting either option is a pure hit.
        run_with_cache(&m, plain, tensor_arg(2.0), &cache).unwrap();
        run_with_cache(&m, fix, tensor_arg(2.0), &cache).unwrap();
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 2);
        // The fixpoint compile's trace records multi-round (or at least
        // recorded) cleanup passes.
        let fold = b
            .pass_trace
            .as_ref()
            .unwrap()
            .passes
            .iter()
            .find(|r| r.name == "FoldConstant")
            .expect("FoldConstant record");
        assert!(fold.rounds >= 1);
    }

    #[test]
    fn cached_path_is_differentially_equal_to_cold_path() {
        let m = parse_module(
            "def @main(%x: Tensor[(2, 2), float32]) { nn.relu(add(%x, 1f)) }",
        )
        .unwrap();
        let x = Tensor::from_f32(vec![2, 2], vec![-3.0, -1.0, 0.5, 2.0]);
        let args = vec![Value::Tensor(x)];
        let cache = ProgramCache::new();
        let cold = run_with_cache(&m, Executor::Auto, args.clone(), &cache).unwrap();
        let warm = run_with_cache(&m, Executor::Auto, args, &cache).unwrap();
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert!(cold.value.bits_eq(&warm.value), "cache hit changed the result");
        assert_eq!(cold.executor, warm.executor);
        // Per-call launch counters, not a shared counter's running total.
        assert_eq!(cold.launches, warm.launches);
        // The hit is served the same cached trace the cold compile built.
        let (ct, wt) = (cold.pass_trace.unwrap(), warm.pass_trace.unwrap());
        assert!(Arc::ptr_eq(&ct, &wt), "hit rebuilt the pass trace");
    }

    #[test]
    fn executors_get_distinct_entries_and_o0_interp_bypasses() {
        let cache = ProgramCache::new();
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) { add(%x, 1f) }",
        )
        .unwrap();
        let a = run_with_cache(&m, Executor::GraphRt, tensor_arg(1.0), &cache).unwrap();
        let b = run_with_cache(&m, Executor::Vm, tensor_arg(1.0), &cache).unwrap();
        // -O0 interp has nothing to optimize and nothing to compile: it
        // bypasses the map entirely.
        let c = run_with_cache(
            &m,
            CompileOptions::at(Executor::Interp, OptLevel::O0),
            tensor_arg(1.0),
            &cache,
        )
        .unwrap();
        assert_eq!(a.executor, "graphrt");
        assert_eq!(b.executor, "vm");
        assert_eq!(c.executor, "interp");
        assert_eq!(a.value.tensor().f32_value(), 2.0);
        assert!(a.value.bits_eq(&b.value) && a.value.bits_eq(&c.value));
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
        // An *optimizing* interp compile is real work and takes a slot:
        // the optimized module is its artifact.
        let d = run_with_cache(&m, Executor::Interp, tensor_arg(1.0), &cache).unwrap();
        assert_eq!(d.executor, "interp");
        assert!(a.value.bits_eq(&d.value));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn different_modules_do_not_collide() {
        let cache = ProgramCache::new();
        let a = parse_module("def @main(%x: Tensor[(), float32]) { add(%x, 1f) }").unwrap();
        let b =
            parse_module("def @main(%x: Tensor[(), float32]) { multiply(%x, 3f) }").unwrap();
        let ra = run_with_cache(&a, Executor::Auto, tensor_arg(2.0), &cache).unwrap();
        let rb = run_with_cache(&b, Executor::Auto, tensor_arg(2.0), &cache).unwrap();
        assert_eq!(ra.value.tensor().f32_value(), 3.0);
        assert_eq!(rb.value.tensor().f32_value(), 6.0);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn clear_resets_entries_and_counters() {
        let cache = ProgramCache::new();
        let m = parse_module("def @main(%x: Tensor[(), float32]) { add(%x, 1f) }").unwrap();
        run_with_cache(&m, Executor::Auto, tensor_arg(0.0), &cache).unwrap();
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.misses(), 0);
        run_with_cache(&m, Executor::Auto, tensor_arg(0.0), &cache).unwrap();
        assert_eq!(cache.misses(), 1);
    }

    fn distinct_module(i: usize) -> Module {
        // Constants participate in the structural hash, so each of these
        // is a distinct cache key.
        parse_module(&format!(
            "def @main(%x: Tensor[(), float32]) {{ add(%x, {i}f) }}"
        ))
        .unwrap()
    }

    #[test]
    fn lru_keeps_a_hot_entry_across_200_distinct_module_insertions() {
        // Regression for the FIFO eviction of PR 2: a hot entry touched
        // between insertions must survive arbitrary distinct-module
        // pressure (FIFO evicted it as soon as 128 newer compiles landed).
        let cache = ProgramCache::new();
        let hot =
            parse_module("def @main(%x: Tensor[(), float32]) { add(%x, 424242f) }").unwrap();
        run_with_cache(&hot, Executor::Auto, tensor_arg(1.0), &cache).unwrap();
        for i in 0..200 {
            run_with_cache(&distinct_module(i), Executor::Auto, tensor_arg(0.0), &cache)
                .unwrap();
            // Touch the hot entry so LRU keeps it resident.
            let (_, compiled_now) =
                cache.get_or_compile_traced(&hot, Executor::Auto).unwrap();
            assert!(!compiled_now, "hot entry evicted after {i} distinct insertions");
        }
        assert!(
            cache.len() <= DEFAULT_MAX_ENTRIES,
            "entry budget not enforced: {} resident",
            cache.len()
        );
    }

    #[test]
    fn cold_entries_are_evicted_lru_first() {
        let cache = ProgramCache::with_limits(4, usize::MAX);
        let a = distinct_module(9000);
        let b = distinct_module(9001);
        cache.get_or_compile(&a, Executor::Auto).unwrap();
        cache.get_or_compile(&b, Executor::Auto).unwrap();
        // Refresh `a`, then insert three more: `b` is now the LRU victim.
        cache.get_or_compile(&a, Executor::Auto).unwrap();
        for i in 9002..9005 {
            cache.get_or_compile(&distinct_module(i), Executor::Auto).unwrap();
        }
        assert_eq!(cache.len(), 4);
        let (_, a_compiled) = cache.get_or_compile_traced(&a, Executor::Auto).unwrap();
        assert!(!a_compiled, "recently-used entry was evicted");
        let (_, b_compiled) = cache.get_or_compile_traced(&b, Executor::Auto).unwrap();
        assert!(b_compiled, "least-recently-used entry survived eviction");
    }

    #[test]
    fn byte_budget_evicts_by_resident_constant_bytes() {
        // Modules whose constant pools are ~4KiB each (a 32x32 f32 weight).
        let weighted = |seed: u64| -> Module {
            let mut w = crate::zoo::Weights::new(seed);
            let x = crate::ir::Var::fresh("x");
            let body = crate::ir::op_call(
                "nn.dense",
                vec![crate::ir::var(&x), w.he(&[32, 32])],
            );
            let mut m = Module::with_prelude();
            let ty = crate::ir::Type::tensor(vec![1, 32], crate::tensor::DType::F32);
            m.add_def("main", crate::ir::Function::new(vec![(x, Some(ty))], body));
            m
        };
        // Budget fits two 4KiB pools, not three.
        let cache = ProgramCache::with_limits(64, 9 << 10);
        for seed in 0..3 {
            let c = cache.get_or_compile(&weighted(seed), Executor::Auto).unwrap();
            assert!(c.const_bytes() >= 4 << 10, "weight not in the constant pool");
        }
        assert!(
            cache.len() < 3,
            "byte budget did not evict: {} entries / {} bytes resident",
            cache.len(),
            cache.resident_bytes()
        );
        assert!(cache.resident_bytes() <= 9 << 10);
    }

    /// Hook that panics (or errors) only above a level threshold, so the
    /// -O1 ladder rung can succeed while -O3 fails.
    fn failing_above(threshold: OptLevel, panic_mode: bool) -> Arc<CompileHook> {
        Arc::new(move |_m: &Module, opts: &CompileOptions| {
            if opts.opt_level > threshold {
                if panic_mode {
                    panic!("injected compile panic at {}", opts.opt_level);
                }
                return Err(format!("injected compile error at {}", opts.opt_level));
            }
            Ok(())
        })
    }

    #[test]
    fn panicking_compile_returns_a_typed_error_not_an_unwind() {
        let cache = ProgramCache::new();
        cache.set_compile_hook(failing_above(OptLevel::O0, true));
        let m = parse_module(CF_SRC).unwrap();
        let err = cache
            .get_or_compile_full(&m, CompileOptions::at(Executor::Auto, OptLevel::O3))
            .expect_err("injected panic must fail the compile");
        assert_eq!(err.kind, CompileErrorKind::Panic);
        assert!(!err.from_negative_cache);
        assert!(err.to_string().contains("compile panicked"), "{err}");
        assert!(err.to_string().contains("injected compile panic"), "{err}");
        // The in-flight set is clean: a healthy recompile (hook cleared,
        // negative entry forgotten) proceeds without any waiting.
        cache.clear_compile_hook();
        assert!(cache.forget_negative(&m, &CompileOptions::at(Executor::Auto, OptLevel::O3)));
        let out = run_with_cache(&m, Executor::Auto, tensor_arg(-3.0), &cache).unwrap();
        assert_eq!(out.value.tensor().f32_value(), 3.0);
    }

    #[test]
    fn negative_cache_fails_fast_and_is_bounded() {
        let cache = ProgramCache::new();
        cache.set_compile_hook(failing_above(OptLevel::O0, false));
        let m = parse_module(CF_SRC).unwrap();
        let opts = CompileOptions::at(Executor::Auto, OptLevel::O3);
        let first = cache.get_or_compile_full(&m, opts).expect_err("injected error");
        assert_eq!(first.kind, CompileErrorKind::Error);
        assert_eq!(cache.misses(), 1);
        // Replays come from the negative cache: typed, flagged, and
        // without another compile attempt (misses stay put).
        let again = cache.get_or_compile_full(&m, opts).expect_err("still bad");
        assert!(again.from_negative_cache);
        assert_eq!(again.kind_label(), "negative_cache");
        assert_eq!(again.to_string(), first.to_string());
        assert_eq!(cache.misses(), 1, "negative hit recompiled");
        assert_eq!(cache.negative_hits(), 1);
        // The map is bounded: far more bad keys than the cap leaves at
        // most the cap remembered.
        for i in 0..(NEGATIVE_CACHE_CAP + 20) {
            let _ = cache.get_or_compile_full(&distinct_module(i), opts);
        }
        assert!(cache.negative_len() <= NEGATIVE_CACHE_CAP);
        // A compile that later succeeds clears its remembered failure.
        cache.clear_compile_hook();
        cache.forget_negative(&m, &opts);
        run_with_cache(&m, opts, tensor_arg(1.0), &cache).unwrap();
        let replay = cache.get_or_compile_full(&m, opts).expect("healthy after forget");
        assert!(!replay.compiled_now, "healthy entry not resident");
    }

    #[test]
    fn ladder_degrades_to_o1_and_stays_bit_identical_to_interp() {
        let cache = ProgramCache::new();
        cache.set_compile_hook(failing_above(OptLevel::O1, false));
        let m = parse_module(CF_SRC).unwrap();
        let opts = CompileOptions::at(Executor::Auto, OptLevel::O3);
        let r = cache
            .get_or_compile_resilient(&m, opts, 2)
            .expect("ladder must rescue the -O3 failure");
        assert_eq!(r.degraded_to, Some(OptLevel::O1));
        assert!(r.compiled_now);
        assert_eq!(r.trace.level, OptLevel::O1, "trace is the rung that ran");
        assert_eq!(r.trace.degraded_from, Some(OptLevel::O3));
        // The degraded artifact is cached under the requested key: the
        // next resilient call is a pure hit that still reports the ladder.
        let hit = cache.get_or_compile_resilient(&m, opts, 2).unwrap();
        assert!(!hit.compiled_now);
        assert_eq!(hit.degraded_to, Some(OptLevel::O1));
        assert_eq!(cache.cached_degraded_to(&m, &opts), Some(Some(OptLevel::O1)));
        // Bit-identical to the interpreter ground truth.
        for v in [-2.5f32, 0.0, 4.0] {
            let deg = run_compiled(&r.compiled, tensor_arg(v)).unwrap();
            let interp = run_with_cache(
                &m,
                CompileOptions::at(Executor::Interp, OptLevel::O0),
                tensor_arg(v),
                &cache,
            )
            .unwrap();
            assert!(deg.value.bits_eq(&interp.value), "diverged at {v}");
        }
    }

    #[test]
    fn ladder_falls_to_the_interpreter_floor_when_everything_fails() {
        let cache = ProgramCache::new();
        // Every optimizing level fails (the floor bypasses the compiler).
        cache.set_compile_hook(Arc::new(|_m, _o| Err("all levels broken".into())));
        let m = parse_module(CF_SRC).unwrap();
        let opts = CompileOptions::at(Executor::Auto, OptLevel::O3);
        // With no retry budget the failure is strict.
        let strict = cache.get_or_compile_resilient(&m, opts, 0);
        assert!(strict.is_err());
        let r = cache.get_or_compile_resilient(&m, opts, 2).expect("interp floor");
        assert_eq!(r.degraded_to, Some(OptLevel::O0));
        assert_eq!(r.compiled.executor_name(), "interp");
        assert_eq!(r.trace.degraded_from, Some(OptLevel::O3));
        let out = run_compiled(&r.compiled, tensor_arg(-8.0)).unwrap();
        assert_eq!(out.value.tensor().f32_value(), 8.0);
    }

    #[test]
    fn racing_panicking_compiles_strand_no_waiter() {
        // The regression the tentpole exists for: before the panic guard,
        // a panicking compile left its key in the in-flight set forever
        // and every coalesced waiter hung on the condvar. Eight threads
        // race the same bad key; all must return (with a typed error)
        // promptly.
        let cache = Arc::new(ProgramCache::new());
        cache.set_compile_hook(failing_above(OptLevel::O0, true));
        let m = Arc::new(parse_module(CF_SRC).unwrap());
        let opts = CompileOptions::at(Executor::Auto, OptLevel::O3);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                cache.get_or_compile_full(&m, opts).expect_err("injected panic")
            }));
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        for h in handles {
            assert!(
                std::time::Instant::now() < deadline,
                "waiters still blocked: in-flight key leaked across a panic"
            );
            let err = h.join().expect("worker thread itself must not die");
            assert!(
                matches!(err.kind, CompileErrorKind::Panic),
                "unexpected kind: {err:?}"
            );
        }
    }

    #[test]
    fn racing_threads_on_one_module_compile_exactly_once() {
        let cache = ProgramCache::new();
        let m = parse_module(CF_SRC).unwrap();
        std::thread::scope(|s| {
            for t in 0..8 {
                let cache = &cache;
                let m = &m;
                s.spawn(move || {
                    let out = run_with_cache(
                        m,
                        Executor::Auto,
                        tensor_arg(-(t as f32) - 1.0),
                        cache,
                    )
                    .unwrap();
                    assert_eq!(out.value.tensor().f32_value(), t as f32 + 1.0);
                });
            }
        });
        assert_eq!(
            cache.misses(),
            1,
            "racing threads compiled the same module more than once"
        );
        assert_eq!(cache.hits(), 7);
    }
}
