//! Compile-once execution: a compiled-program cache keyed by the
//! alpha-invariant module structural hash ([`crate::ir::module_structural_hash`]).
//!
//! The serving story of the paper (and of TVM / nGraph's cached-executable
//! layer) is that compilation cost is paid once and the lean artifact runs
//! millions of times. [`ProgramCache`] makes the executor-selection layer
//! behave that way: `run_auto` / `run_with` on an unchanged module performs
//! exactly one ANF normalization + compile, and every later call is pure
//! dispatch on the cached [`crate::graphrt::GraphRt`] / [`crate::vm::Program`].
//!
//! Keys are verified on hit with full structural equality
//! ([`crate::ir::modules_structurally_eq`]), so a 64-bit hash collision can
//! never route a module to the wrong artifact — it just recompiles.
//!
//! Compiled programs hold `Rc`-backed values (not `Send`), so a cache is a
//! single-thread object: each thread gets its own default cache
//! ([`with_default_cache`]), and long-lived loops like the serving batcher
//! own an explicit instance.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::collections::VecDeque;
use std::rc::Rc;

use super::{env_empty, Execution, Executor, Interp, Value};
use crate::ir::{self, Module};

/// What executor-selection resolved a module to, compiled and ready to run.
#[derive(Clone)]
pub enum Compiled {
    /// First-order, control-flow-free: the graph runtime.
    Graph(Rc<crate::graphrt::GraphRt>),
    /// Everything else the VM compiles (closures, ADTs, recursion).
    Vm(Rc<crate::vm::Program>),
    /// Neither compiled (exotic input under `Auto`): tree-walk per call.
    Interp,
}

impl Compiled {
    /// The tier this entry executes on (never "auto").
    pub fn executor_name(&self) -> &'static str {
        match self {
            Compiled::Graph(_) => "graphrt",
            Compiled::Vm(_) => "vm",
            Compiled::Interp => "interp",
        }
    }
}

struct Entry {
    /// Snapshot of the source module, for exact hit verification.
    module: Module,
    compiled: Compiled,
}

/// Bound on resident entries; eviction is FIFO (oldest compile first).
const CACHE_CAP: usize = 128;

/// A bounded map from (module structural hash, requested executor) to a
/// compiled program, with hit/miss counters. One miss == one compile.
#[derive(Default)]
pub struct ProgramCache {
    entries: RefCell<HashMap<(u64, &'static str), Entry>>,
    order: RefCell<VecDeque<(u64, &'static str)>>,
    hits: Cell<usize>,
    misses: Cell<usize>,
}

impl ProgramCache {
    pub fn new() -> ProgramCache {
        ProgramCache::default()
    }

    /// Cache hits so far (calls served without compiling).
    pub fn hits(&self) -> usize {
        self.hits.get()
    }

    /// Cache misses so far — equivalently, the number of compiles.
    pub fn misses(&self) -> usize {
        self.misses.get()
    }

    /// Resident compiled programs.
    pub fn len(&self) -> usize {
        self.entries.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.borrow().is_empty()
    }

    /// Drop all entries and reset the counters.
    pub fn clear(&self) {
        self.entries.borrow_mut().clear();
        self.order.borrow_mut().clear();
        self.hits.set(0);
        self.misses.set(0);
    }

    /// Look up (or compile and insert) the program for `module` under the
    /// given executor request. `Executor::Interp` needs no compilation and
    /// bypasses the map entirely.
    pub fn get_or_compile(
        &self,
        module: &Module,
        executor: Executor,
    ) -> Result<Compiled, String> {
        if executor == Executor::Interp {
            return Ok(Compiled::Interp);
        }
        let key = (ir::module_structural_hash(module), executor.name());
        if let Some(entry) = self.entries.borrow().get(&key) {
            if ir::modules_structurally_eq(&entry.module, module) {
                self.hits.set(self.hits.get() + 1);
                return Ok(entry.compiled.clone());
            }
        }
        self.misses.set(self.misses.get() + 1);
        let compiled = compile_for(module, executor)?;
        let mut entries = self.entries.borrow_mut();
        let mut order = self.order.borrow_mut();
        while entries.len() >= CACHE_CAP {
            match order.pop_front() {
                Some(old) => {
                    entries.remove(&old);
                }
                None => break,
            }
        }
        // A replaced entry (hash collision verified unequal) keeps its
        // original queue position — pushing again would grow `order`
        // without bound under alternating colliding modules.
        if entries
            .insert(key, Entry { module: module.clone(), compiled: compiled.clone() })
            .is_none()
        {
            order.push_back(key);
        }
        Ok(compiled)
    }
}

/// Compile `module` for the requested tier — the one place the selection
/// chain (graph runtime -> VM -> interpreter) lives. The ANF pass runs
/// once and is shared between the graphrt attempt and the VM compile.
fn compile_for(module: &Module, executor: Executor) -> Result<Compiled, String> {
    match executor {
        Executor::Interp => Ok(Compiled::Interp),
        Executor::GraphRt => {
            let anfed = crate::pass::anf::run(module);
            let main = anfed.def("main").ok_or("no @main in module")?;
            let g = crate::graphrt::GraphRt::compile(main).map_err(|e| e.to_string())?;
            Ok(Compiled::Graph(Rc::new(g)))
        }
        Executor::Vm => {
            let program = crate::vm::compile(module).map_err(|e| e.to_string())?;
            Ok(Compiled::Vm(Rc::new(program)))
        }
        Executor::Auto => {
            let anfed = crate::pass::anf::run(module);
            if let Some(main) = anfed.def("main") {
                if let Ok(g) = crate::graphrt::GraphRt::compile(main) {
                    return Ok(Compiled::Graph(Rc::new(g)));
                }
            }
            match crate::vm::compile_normalized(&anfed) {
                Ok(program) => Ok(Compiled::Vm(Rc::new(program))),
                // The VM compiles everything the interpreter runs; the
                // fallback is belt-and-braces for exotic inputs.
                Err(_) => Ok(Compiled::Interp),
            }
        }
    }
}

/// Run `@main(args...)` on an already-compiled program. `module` is only
/// consulted on the interpreter tier (which has no compiled artifact).
pub fn run_compiled(
    compiled: &Compiled,
    module: &Module,
    args: Vec<Value>,
) -> Result<Execution, String> {
    match compiled {
        Compiled::Graph(g) => {
            // The cached runtime's launch counter accumulates across
            // calls; report the per-call delta.
            let before = g.launches.get();
            let value = g.run(&args)?;
            Ok(Execution {
                value,
                executor: "graphrt",
                launches: g.launches.get() - before,
            })
        }
        Compiled::Vm(p) => {
            let vm = crate::vm::Vm::new(p);
            let value = vm.run(args)?;
            Ok(Execution { value, executor: "vm", launches: vm.launches.get() })
        }
        Compiled::Interp => {
            let interp = Interp::new(module);
            let f = module.entry().ok_or("no @main in module")?.clone();
            let value = interp.apply(
                Value::Closure { func: f, env: env_empty(), rec: None },
                args,
                &crate::ir::Attrs::new(),
            )?;
            Ok(Execution { value, executor: "interp", launches: interp.op_calls() })
        }
    }
}

thread_local! {
    static DEFAULT_CACHE: ProgramCache = ProgramCache::new();
}

/// Access this thread's default program cache (what [`super::run_with`] and
/// [`super::run_auto`] compile into).
pub fn with_default_cache<R>(f: impl FnOnce(&ProgramCache) -> R) -> R {
    DEFAULT_CACHE.with(f)
}

#[cfg(test)]
mod tests {
    use super::super::{run_with_cache, Executor};
    use super::*;
    use crate::ir::parse_module;
    use crate::tensor::Tensor;

    fn tensor_arg(v: f32) -> Vec<Value> {
        vec![Value::Tensor(Tensor::scalar_f32(v))]
    }

    const CF_SRC: &str = "def @main(%x: Tensor[(), float32]) {\n\
                            if (greater(%x, 0f)) { %x } else { negative(%x) }\n\
                          }";

    #[test]
    fn repeated_auto_calls_compile_exactly_once() {
        let cache = ProgramCache::new();
        let m = parse_module(CF_SRC).unwrap();
        for i in 0..5 {
            let out = run_with_cache(&m, Executor::Auto, tensor_arg(-2.0 - i as f32), &cache)
                .unwrap();
            assert_eq!(out.executor, "vm");
            assert_eq!(out.value.tensor().f32_value(), 2.0 + i as f32);
        }
        assert_eq!(cache.misses(), 1, "exactly one compile across 5 calls");
        assert_eq!(cache.hits(), 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn alpha_renamed_module_shares_the_entry() {
        let cache = ProgramCache::new();
        let a = parse_module(CF_SRC).unwrap();
        // Re-parsing mints fresh variable ids: alpha-equivalent, not
        // identical — still one cache entry.
        let b = parse_module(&CF_SRC.replace("%x", "%renamed")).unwrap();
        run_with_cache(&a, Executor::Auto, tensor_arg(1.0), &cache).unwrap();
        run_with_cache(&b, Executor::Auto, tensor_arg(1.0), &cache).unwrap();
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn cached_path_is_differentially_equal_to_cold_path() {
        let m = parse_module(
            "def @main(%x: Tensor[(2, 2), float32]) { nn.relu(add(%x, 1f)) }",
        )
        .unwrap();
        let x = Tensor::from_f32(vec![2, 2], vec![-3.0, -1.0, 0.5, 2.0]);
        let args = vec![Value::Tensor(x)];
        let cache = ProgramCache::new();
        let cold = run_with_cache(&m, Executor::Auto, args.clone(), &cache).unwrap();
        let warm = run_with_cache(&m, Executor::Auto, args, &cache).unwrap();
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert!(cold.value.bits_eq(&warm.value), "cache hit changed the result");
        assert_eq!(cold.executor, warm.executor);
        // Per-call launch deltas, not the shared counter's running total.
        assert_eq!(cold.launches, warm.launches);
    }

    #[test]
    fn executors_get_distinct_entries_and_interp_bypasses() {
        let cache = ProgramCache::new();
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) { add(%x, 1f) }",
        )
        .unwrap();
        let a = run_with_cache(&m, Executor::GraphRt, tensor_arg(1.0), &cache).unwrap();
        let b = run_with_cache(&m, Executor::Vm, tensor_arg(1.0), &cache).unwrap();
        let c = run_with_cache(&m, Executor::Interp, tensor_arg(1.0), &cache).unwrap();
        assert_eq!(a.executor, "graphrt");
        assert_eq!(b.executor, "vm");
        assert_eq!(c.executor, "interp");
        assert_eq!(a.value.tensor().f32_value(), 2.0);
        assert!(a.value.bits_eq(&b.value) && a.value.bits_eq(&c.value));
        // Interp compiles nothing and takes no slot.
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn different_modules_do_not_collide() {
        let cache = ProgramCache::new();
        let a = parse_module("def @main(%x: Tensor[(), float32]) { add(%x, 1f) }").unwrap();
        let b =
            parse_module("def @main(%x: Tensor[(), float32]) { multiply(%x, 3f) }").unwrap();
        let ra = run_with_cache(&a, Executor::Auto, tensor_arg(2.0), &cache).unwrap();
        let rb = run_with_cache(&b, Executor::Auto, tensor_arg(2.0), &cache).unwrap();
        assert_eq!(ra.value.tensor().f32_value(), 3.0);
        assert_eq!(rb.value.tensor().f32_value(), 6.0);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn clear_resets_entries_and_counters() {
        let cache = ProgramCache::new();
        let m = parse_module("def @main(%x: Tensor[(), float32]) { add(%x, 1f) }").unwrap();
        run_with_cache(&m, Executor::Auto, tensor_arg(0.0), &cache).unwrap();
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.misses(), 0);
        run_with_cache(&m, Executor::Auto, tensor_arg(0.0), &cache).unwrap();
        assert_eq!(cache.misses(), 1);
    }
}
