//! Runtime values (paper appendix operational semantics): tensors, tuples,
//! closures, references, ADT instances, and operator/constructor references.
//!
//! # Thread safety
//!
//! Every value is `Send + Sync` (compile-time asserted in the tests): the
//! whole domain is built from `Arc`-backed immutable structure — tensors
//! share storage through `Arc`, environments are persistent `Arc` chains,
//! IR fragments captured by closures are `Arc<Expr>` trees. The single
//! mutable runtime object, the ML-style reference cell, is an
//! `Arc<Mutex<Value>>` ([`Value::new_ref`] / [`lock_ref`]). This is what
//! lets one process-wide [`super::ProgramCache`] hand the same compiled
//! artifact (constant pool included) to any number of serving workers.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::ir::{Function, Var, E};
use crate::tensor::{DType, Tensor};

/// Environment mapping vars to values (persistent via Arc chain).
pub type Env = Arc<EnvNode>;

#[derive(Debug)]
pub enum EnvNode {
    Empty,
    Bind { var: Var, value: Value, rest: Env },
}

pub fn env_empty() -> Env {
    Arc::new(EnvNode::Empty)
}

pub fn env_bind(env: &Env, var: Var, value: Value) -> Env {
    Arc::new(EnvNode::Bind { var, value, rest: env.clone() })
}

pub fn env_lookup(env: &Env, var: &Var) -> Option<Value> {
    let mut cur = env;
    loop {
        match &**cur {
            EnvNode::Empty => return None,
            EnvNode::Bind { var: v, value, rest } => {
                if v == var {
                    return Some(value.clone());
                }
                cur = rest;
            }
        }
    }
}

/// Lock a mutex, riding through poison. The runtime's shared state (ref
/// cells, the program cache, the serving queue) is only ever mutated in
/// whole-value or all-or-nothing steps, so a panic in another thread
/// cannot leave it in a state later readers would misinterpret.
/// (Re-exported from the crate-wide [`crate::sync`] helper so every
/// layer — tensor pool, tuning registry, PJRT cache — shares one policy.)
pub use crate::sync::lock_unpoisoned;

/// Lock a reference cell ([`lock_unpoisoned`] specialized to `Value::Ref`
/// payloads).
pub fn lock_ref(cell: &Mutex<Value>) -> MutexGuard<'_, Value> {
    lock_unpoisoned(cell)
}

#[derive(Clone)]
pub enum Value {
    Tensor(Tensor),
    Tuple(Vec<Value>),
    Closure {
        func: Function,
        env: Env,
        /// `let %f = fn ... ;` binds recursively (the paper's Fig. 2 loop
        /// encoding): applying the closure re-binds `rec` to itself.
        rec: Option<Var>,
    },
    Ref(Arc<Mutex<Value>>),
    Adt { ctor: String, fields: Vec<Value> },
    /// Partially-applied constructor / operator references are represented
    /// by the interpreter as direct call targets; these values appear when
    /// ops/ctors are used first-class.
    OpRef(String),
    CtorRef(String),
    /// A closure created by the bytecode VM ([`crate::vm`]): an index into
    /// the program's function table plus the captured environment, flat —
    /// no linked env chain. Self-reference for recursion is re-supplied at
    /// call time (no `Arc` cycles).
    VmClosure(Arc<VmClosure>),
}

/// Payload of [`Value::VmClosure`].
#[derive(Debug)]
pub struct VmClosure {
    /// Index into [`crate::vm::Program::funcs`].
    pub func: u32,
    /// Captured free-variable values, in the function's capture order.
    pub captures: Vec<Value>,
}

fn short_dtype(d: DType) -> &'static str {
    match d {
        DType::F32 => "f32",
        DType::F64 => "f64",
        DType::I64 => "i64",
        DType::I32 => "i32",
        DType::I16 => "i16",
        DType::I8 => "i8",
        DType::U8 => "u8",
        DType::Bool => "bool",
    }
}

/// Shape label for an argument list, e.g. `(f32[2,4],f32[4])` — the
/// per-(op, shape) aggregation key of [`crate::telemetry::profiler`].
pub fn args_shape_label(args: &[Value]) -> String {
    let inner: Vec<String> = args.iter().map(|v| v.shape_label()).collect();
    format!("({})", inner.join(","))
}

/// Shape label for one tensor, e.g. `f32[2,4]` (scalars render `f32[]`).
pub fn tensor_shape_label(t: &Tensor) -> String {
    let dims: Vec<String> = t.shape().iter().map(|d| d.to_string()).collect();
    format!("{}[{}]", short_dtype(t.dtype()), dims.join(","))
}

impl Value {
    pub fn unit() -> Value {
        Value::Tuple(vec![])
    }

    /// Compact shape label: `f32[2,4]` for tensors, parenthesized element
    /// labels for tuples, `-` for closures/refs/ADTs.
    pub fn shape_label(&self) -> String {
        match self {
            Value::Tensor(t) => tensor_shape_label(t),
            Value::Tuple(items) => args_shape_label(items),
            _ => "-".to_string(),
        }
    }

    /// A fresh mutable reference cell holding `v`.
    pub fn new_ref(v: Value) -> Value {
        Value::Ref(Arc::new(Mutex::new(v)))
    }

    /// Structural equality over data values (tensors, tuples, ADTs),
    /// comparing tensors element-for-element with no tolerance — the
    /// differential-executor guarantee (interpreter vs graph runtime vs
    /// VM run identical kernels in identical order). Closures, refs, and
    /// op/ctor references compare `false`.
    pub fn bits_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Tensor(a), Value::Tensor(b)) => a == b,
            (Value::Tuple(a), Value::Tuple(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.bits_eq(y))
            }
            (
                Value::Adt { ctor: c1, fields: f1 },
                Value::Adt { ctor: c2, fields: f2 },
            ) => {
                c1 == c2
                    && f1.len() == f2.len()
                    && f1.iter().zip(f2).all(|(x, y)| x.bits_eq(y))
            }
            _ => false,
        }
    }

    /// Bytes of tensor payload reachable from this value (storage actually
    /// held alive, ignoring `Arc` sharing). The size metric behind the
    /// program cache's byte-budgeted eviction.
    pub fn tensor_bytes(&self) -> usize {
        match self {
            Value::Tensor(t) => t.numel() * t.dtype().size_bytes(),
            Value::Tuple(vs) | Value::Adt { fields: vs, .. } => {
                vs.iter().map(|v| v.tensor_bytes()).sum()
            }
            Value::VmClosure(c) => c.captures.iter().map(|v| v.tensor_bytes()).sum(),
            // Refs are skipped (like `bits_eq`, which treats them as
            // non-data): a ref can participate in a cycle (a closure
            // capturing the cell that holds it), and locking through the
            // chain would deadlock on the second visit.
            Value::Ref(_) => 0,
            Value::Closure { .. } | Value::OpRef(_) | Value::CtorRef(_) => 0,
        }
    }

    pub fn tensor(&self) -> &Tensor {
        match self {
            Value::Tensor(t) => t,
            other => panic!("expected tensor value, got {other:?}"),
        }
    }

    pub fn into_tensor(self) -> Tensor {
        match self {
            Value::Tensor(t) => t,
            other => panic!("expected tensor value, got {other:?}"),
        }
    }

    pub fn tuple(&self) -> &[Value] {
        match self {
            Value::Tuple(vs) => vs,
            other => panic!("expected tuple value, got {other:?}"),
        }
    }

    /// Build a Relay `List` value from items.
    pub fn list(items: Vec<Value>) -> Value {
        let mut acc = Value::Adt { ctor: "Nil".into(), fields: vec![] };
        for item in items.into_iter().rev() {
            acc = Value::Adt { ctor: "Cons".into(), fields: vec![item, acc] };
        }
        acc
    }

    /// Flatten a `List` value back to a vector.
    pub fn list_items(&self) -> Vec<Value> {
        let mut out = Vec::new();
        let mut cur = self.clone();
        loop {
            match cur {
                Value::Adt { ref ctor, ref fields } if ctor == "Cons" => {
                    out.push(fields[0].clone());
                    cur = fields[1].clone();
                }
                Value::Adt { ref ctor, .. } if ctor == "Nil" => break,
                other => panic!("not a list: {other:?}"),
            }
        }
        out
    }
}

impl fmt::Debug for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Tensor(t) => write!(f, "{t:?}"),
            Value::Tuple(vs) => f.debug_list().entries(vs).finish(),
            Value::Closure { func, .. } => {
                write!(f, "<closure/{}>", func.params.len())
            }
            Value::Ref(_) => write!(f, "<ref>"),
            Value::Adt { ctor, fields } => {
                write!(f, "{ctor}")?;
                if !fields.is_empty() {
                    f.debug_list().entries(fields).finish()?;
                }
                Ok(())
            }
            Value::OpRef(n) => write!(f, "<op {n}>"),
            Value::CtorRef(n) => write!(f, "<ctor {n}>"),
            Value::VmClosure(c) => {
                write!(f, "<vmclosure #{}/{}>", c.func, c.captures.len())
            }
        }
    }
}

/// A snapshot of values keyed by name, used at module boundaries.
pub type Bindings = BTreeMap<String, Value>;

/// Thunk used by `grad`: expression plus captured env (for debugging).
#[derive(Clone)]
pub struct Suspended {
    pub expr: E,
    pub env: Env,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_shadowing() {
        let x = Var::fresh("x");
        let e0 = env_empty();
        let e1 = env_bind(&e0, x.clone(), Value::Tensor(Tensor::scalar_f32(1.0)));
        let e2 = env_bind(&e1, x.clone(), Value::Tensor(Tensor::scalar_f32(2.0)));
        assert_eq!(env_lookup(&e2, &x).unwrap().tensor().f32_value(), 2.0);
        assert_eq!(env_lookup(&e1, &x).unwrap().tensor().f32_value(), 1.0);
        assert!(env_lookup(&e0, &x).is_none());
    }

    #[test]
    fn list_roundtrip() {
        let v = Value::list(vec![
            Value::Tensor(Tensor::scalar_f32(1.0)),
            Value::Tensor(Tensor::scalar_f32(2.0)),
        ]);
        let items = v.list_items();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].tensor().f32_value(), 2.0);
    }

    #[test]
    fn refs_are_shared() {
        let r = Value::new_ref(Value::unit());
        if let Value::Ref(cell) = &r {
            *lock_ref(cell) = Value::Tensor(Tensor::scalar_f32(7.0));
        }
        let r2 = r.clone();
        if let Value::Ref(cell) = &r2 {
            assert_eq!(lock_ref(cell).tensor().f32_value(), 7.0);
        }
    }

    #[test]
    fn tensor_bytes_counts_nested_payloads() {
        let t = Value::Tensor(Tensor::zeros(&[2, 3], crate::tensor::DType::F32));
        assert_eq!(t.tensor_bytes(), 24);
        let nested = Value::Tuple(vec![
            t.clone(),
            Value::Adt { ctor: "Cons".into(), fields: vec![t.clone()] },
            Value::OpRef("add".into()),
        ]);
        assert_eq!(nested.tensor_bytes(), 48);
    }

    /// The tentpole guarantee: the whole value domain crosses threads.
    #[test]
    fn values_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Value>();
        assert_send_sync::<Env>();
        assert_send_sync::<EnvNode>();
        assert_send_sync::<VmClosure>();
        assert_send_sync::<Suspended>();
    }
}
