//! Execution backends over the IR and the layer that selects among them.
//!
//! Three executors share one value domain ([`value::Value`]) and one
//! kernel-launch metric ([`LaunchCounter`]):
//!
//! * [`Interp`] — the reference tree-walk interpreter (paper §3.1.3's
//!   "Relay interpreter"); ground truth, runs everything.
//! * [`crate::graphrt::GraphRt`] — flat node-list runtime for first-order,
//!   control-flow-free programs.
//! * [`crate::vm::Vm`] — the bytecode VM for control-flow-heavy programs
//!   (closures, ADTs, recursion) at much lower dispatch cost than the
//!   interpreter.
//!
//! [`run_with`] / [`run_auto`] are the single entry point call sites use
//! (CLI, server, benches, zoo) instead of hand-rolled fallback chains.
//! Both compile through one process-wide [`ProgramCache`]
//! ([`default_cache`]) keyed by the module's alpha-invariant structural
//! hash **plus the requested [`CompileOptions`]**, so repeated calls on an
//! unchanged module — from *any* thread — compile exactly once per
//! (level, executor) pair ([`cache`] module docs).
//!
//! # One optimizing pipeline for every executor
//!
//! Compilation always flows through the pass manager first
//! ([`crate::pass::optimize_traced`]): [`CompileOptions::opt_level`]
//! selects the §5.2 tier (default [`DEFAULT_OPT_LEVEL`] = -O3, the same
//! default the CLI uses), and the resulting [`crate::pass::PassTrace`] is
//! cached with the program and attached to every [`Execution`]. Passing a
//! bare [`Executor`] where options are expected selects the default
//! level; use [`CompileOptions::at`] to pin one (e.g. `-O0` for
//! differential tests against unoptimized references).
//!
//! # Thread safety
//!
//! The value domain ([`value::Value`], [`value::Env`]), the shared launch
//! counter ([`LaunchCounter`]), and compiled programs ([`Compiled`]) are
//! all `Send + Sync`: values are `Arc`-backed immutable structure (the one
//! mutable cell, the ML-style reference, is an `Arc<Mutex<..>>`), counters
//! are atomics, and the cache is a lock around shared state. Executor
//! *instances* (`Interp`, `vm::Vm`) stay cheap per-call objects — what is
//! shared across threads is the compiled artifact, not the frame state.
//!
//! # Fault containment
//!
//! Compilation is panic-safe: the cache runs the compiler under
//! `catch_unwind` *inside* its in-flight coalescing guard, so a panicking
//! pass can never strand the threads parked on the same key — they wake,
//! observe the remembered failure, and get the same typed
//! [`cache::CompileError`] the panicking thread got ([`cache`] module
//! docs, "Fault containment"). On top of that, [`run_with_cache_resilient`]
//! (which [`run_auto`] routes through) degrades rather than fails: a
//! broken `-O3` compile retries at `-O1` and finally falls back to the
//! `-O0` interpreter floor, recording the served tier in
//! [`Execution::degraded_to`] and bumping
//! `relay_degraded_executions_total{level}`. Degraded results are
//! bit-identical to the interpreter's — only latency degrades, never
//! answers.

pub mod cache;
pub mod interp;
pub mod value;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

pub use cache::{
    default_cache, run_compiled, with_default_cache, Compiled, CompileError,
    CompileErrorKind, ProgramCache, Resolved,
};
pub use interp::{eval_expr, eval_main, Interp};
pub use value::{env_bind, env_empty, Env, Value};

use crate::ir::Module;
use crate::pass::{OptLevel, PassTrace};

// ---------------------------------------------------------------------------
// Shared kernel-launch counting.
// ---------------------------------------------------------------------------

/// A shared, resettable kernel-launch counter.
///
/// One operator call — or one *fused primitive function* call — counts as
/// one launch; this is the fusion-benefit metric of Fig 10–12. All three
/// executors bump a `LaunchCounter`, and clones share state, so a single
/// counter can be threaded through an entire pipeline regardless of which
/// tier executes. `Arc<AtomicUsize>` inside, so clones may live on
/// different threads (a fleet of serving workers can aggregate into one
/// counter, or keep per-call counters — see [`cache::run_compiled`]).
#[derive(Clone, Debug, Default)]
pub struct LaunchCounter(Arc<AtomicUsize>);

impl LaunchCounter {
    pub fn new() -> LaunchCounter {
        LaunchCounter::default()
    }

    /// Record one kernel launch.
    pub fn bump(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Executor selection (paper §3.1.3: interpreter vs graph runtime, extended
// with the bytecode VM tier).
// ---------------------------------------------------------------------------

/// Which execution tier to run a module on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Executor {
    /// Reference tree-walk interpreter.
    Interp,
    /// Graph runtime (first-order, control-flow-free programs only).
    GraphRt,
    /// Bytecode VM (any program).
    Vm,
    /// Pick automatically: graph runtime if the program compiles to it,
    /// else the VM, else the interpreter.
    Auto,
}

impl Executor {
    pub fn parse(s: &str) -> Option<Executor> {
        Some(match s {
            "interp" | "interpreter" => Executor::Interp,
            "graph" | "graphrt" => Executor::GraphRt,
            "vm" => Executor::Vm,
            "auto" => Executor::Auto,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Executor::Interp => "interp",
            Executor::GraphRt => "graphrt",
            Executor::Vm => "vm",
            Executor::Auto => "auto",
        }
    }
}

impl std::fmt::Display for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Compile options: the one knob set every compile path shares.
// ---------------------------------------------------------------------------

/// Optimization level used when a caller passes a bare [`Executor`]
/// (matches the CLI's `-O` default).
pub const DEFAULT_OPT_LEVEL: OptLevel = OptLevel::O3;

/// Everything the unified compile driver needs to turn a module into a
/// runnable program: which §5.2 pass tier to run, which executor to lower
/// for, and whether to type-check between passes.
///
/// This — together with the module's structural hash — is the
/// [`ProgramCache`] key, so `-O0` and `-O3` artifacts of the same module
/// coexist in one cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CompileOptions {
    pub opt_level: OptLevel,
    pub executor: Executor,
    /// Re-run type inference between passes (slower; the CLI's `compile`
    /// command uses it, execution paths default to off).
    pub typecheck: bool,
    /// Re-run the fixpoint-eligible cleanup passes (FoldConstant,
    /// DeadCodeElim) to convergence
    /// ([`crate::pass::PipelineConfig::fixpoint`]). Costs compile time,
    /// usually converges in a round or two; serving opts in with
    /// `relay serve --fixpoint`. Part of the cache key — fixpoint and
    /// single-round artifacts of one module coexist.
    pub fixpoint: bool,
}

impl Default for CompileOptions {
    fn default() -> CompileOptions {
        CompileOptions {
            opt_level: DEFAULT_OPT_LEVEL,
            executor: Executor::Auto,
            typecheck: false,
            fixpoint: false,
        }
    }
}

impl CompileOptions {
    /// Default options for a tier: optimize at [`DEFAULT_OPT_LEVEL`].
    pub fn new(executor: Executor) -> CompileOptions {
        CompileOptions { executor, ..CompileOptions::default() }
    }

    /// Explicit (executor, level) pair, no inter-pass typechecking.
    pub fn at(executor: Executor, opt_level: OptLevel) -> CompileOptions {
        CompileOptions { executor, opt_level, ..CompileOptions::default() }
    }

    pub fn with_typecheck(mut self, typecheck: bool) -> CompileOptions {
        self.typecheck = typecheck;
        self
    }

    /// Enable the fixpoint FoldConstant/DCE loop for this compile.
    pub fn with_fixpoint(mut self, fixpoint: bool) -> CompileOptions {
        self.fixpoint = fixpoint;
        self
    }

    /// `-O0` interpreter: no optimization, no compilation artifact —
    /// nothing for the cache to hold. [`run_with_cache`] runs this case
    /// on the borrowed module directly; the cache API materializes an
    /// uncached snapshot for it.
    pub fn is_uncached_interp(&self) -> bool {
        self.executor == Executor::Interp && self.opt_level == OptLevel::O0
    }
}

impl From<Executor> for CompileOptions {
    fn from(executor: Executor) -> CompileOptions {
        CompileOptions::new(executor)
    }
}

impl From<(Executor, OptLevel)> for CompileOptions {
    fn from((executor, opt_level): (Executor, OptLevel)) -> CompileOptions {
        CompileOptions::at(executor, opt_level)
    }
}

/// The result of [`run_with`]: the value plus which tier actually ran,
/// how many kernel launches it performed, and what the optimizing driver
/// did when the program was compiled.
#[derive(Debug)]
pub struct Execution {
    pub value: Value,
    /// Tier that executed (never "auto").
    pub executor: &'static str,
    pub launches: usize,
    /// Per-pass wall time / node deltas from compilation. Shared with the
    /// cache entry (compilation happens once; the trace is a snapshot of
    /// that one run, not of this call). `None` when the caller ran a
    /// pre-compiled program directly ([`run_compiled`]).
    pub pass_trace: Option<Arc<PassTrace>>,
    /// Per-op profile of *this* execution — populated only by
    /// [`run_with_profile`], `None` everywhere else (profiling is opt-in).
    pub profile: Option<crate::telemetry::Profile>,
    /// `Some(level)` when the degradation ladder served this execution at
    /// a lower tier than requested (`O1` for the retry rung, `O0` for the
    /// interpreter floor) — either because [`run_with_cache_resilient`]
    /// degraded on this call, or because a strict lookup hit a cache entry
    /// a previous degraded compile left behind. `None` on the healthy
    /// path.
    pub degraded_to: Option<OptLevel>,
}

/// Bump `relay_degraded_executions_total{level}` when an execution was
/// served below its requested tier.
fn record_degraded(degraded_to: Option<OptLevel>) {
    if let Some(level) = degraded_to {
        crate::telemetry::registry()
            .counter_with(
                crate::telemetry::registry::names::DEGRADED_EXECUTIONS_TOTAL,
                &[("level", level.digit())],
            )
            .inc();
    }
}

/// Run `@main(args...)` on the chosen executor / optimization level,
/// compiling through an explicit [`ProgramCache`]: the first call on a
/// module optimizes (pass pipeline) and compiles (ANF + tier selection +
/// codegen), every later call on a structurally-equal module at the same
/// options is pure dispatch.
pub fn run_with_cache(
    module: &Module,
    opts: impl Into<CompileOptions>,
    args: Vec<Value>,
    cache: &ProgramCache,
) -> Result<Execution, String> {
    let opts: CompileOptions = opts.into();
    if opts.is_uncached_interp() {
        // Run the interpreter on the borrowed module (no snapshot clone).
        let mut out = cache::interp_main(module, args)?;
        out.pass_trace = Some(Arc::new(PassTrace::empty(OptLevel::O0)));
        return Ok(out);
    }
    let resolved = cache.get_or_compile_full(module, opts)?;
    let mut out = run_compiled(&resolved.compiled, args)?;
    out.pass_trace = Some(resolved.trace);
    // A strict lookup can still land on an entry the ladder degraded
    // earlier; surface (and count) that honestly.
    out.degraded_to = resolved.degraded_to;
    record_degraded(out.degraded_to);
    Ok(out)
}

/// [`run_with_cache`] with the graceful degradation ladder: if compiling
/// at the requested tier fails (error *or* panic — both are contained and
/// typed), retry at `-O1`, and finally fall back to running the
/// unoptimized module on the `-O0` interpreter, which cannot fail to
/// "compile". `max_opt_retries` bounds how many fallback rungs may be
/// taken (0 = strict, 1 = allow the `-O1` retry, 2 = allow the
/// interpreter floor too). The served tier lands in
/// [`Execution::degraded_to`] and on the cached entry, the attached
/// [`PassTrace`] carries `degraded_from`, and every degraded execution
/// bumps `relay_degraded_executions_total{level}`.
pub fn run_with_cache_resilient(
    module: &Module,
    opts: impl Into<CompileOptions>,
    args: Vec<Value>,
    cache: &ProgramCache,
    max_opt_retries: usize,
) -> Result<Execution, String> {
    let opts: CompileOptions = opts.into();
    if opts.is_uncached_interp() {
        let mut out = cache::interp_main(module, args)?;
        out.pass_trace = Some(Arc::new(PassTrace::empty(OptLevel::O0)));
        return Ok(out);
    }
    let resolved = cache
        .get_or_compile_resilient(module, opts, max_opt_retries)
        .map_err(String::from)?;
    let mut out = run_compiled(&resolved.compiled, args)?;
    out.pass_trace = Some(resolved.trace);
    out.degraded_to = resolved.degraded_to;
    record_degraded(out.degraded_to);
    Ok(out)
}

/// Run `@main(args...)` on the chosen executor (or explicit
/// [`CompileOptions`]). Optimization + ANF + codegen happen internally,
/// and the compiled program is cached in the process-wide default
/// [`ProgramCache`] — repeated calls on an unchanged module, from any
/// thread, compile once per options.
pub fn run_with(
    module: &Module,
    opts: impl Into<CompileOptions>,
    args: Vec<Value>,
) -> Result<Execution, String> {
    let opts: CompileOptions = opts.into();
    with_default_cache(|cache| run_with_cache(module, opts, args, cache))
}

/// Fallback rungs [`run_auto`] allows: the `-O1` retry and the `-O0`
/// interpreter floor.
pub const DEFAULT_MAX_OPT_RETRIES: usize = 2;

/// [`run_with`] with automatic tier selection at the default optimization
/// level: graph runtime if the program compiles to it, else the VM, else
/// the interpreter.
///
/// `run_auto` is the resilient entry point: a compile failure (including
/// a contained panic) degrades down the ladder
/// ([`run_with_cache_resilient`], [`DEFAULT_MAX_OPT_RETRIES`] rungs)
/// instead of erroring, so callers always get an answer — possibly slower,
/// never wrong ([`Execution::degraded_to`] says which tier served it).
pub fn run_auto(module: &Module, args: Vec<Value>) -> Result<Execution, String> {
    with_default_cache(|cache| {
        run_with_cache_resilient(
            module,
            Executor::Auto,
            args,
            cache,
            DEFAULT_MAX_OPT_RETRIES,
        )
    })
}

/// [`run_with`] under a [`crate::telemetry::ProfileScope`]: the returned
/// [`Execution::profile`] holds the per-(op, shape) table and a launch
/// count equal to [`Execution::launches`].
///
/// Compilation happens *before* the scope is installed, so constant
/// folding's operator evaluations (which run op kernels at compile time)
/// do not pollute the table — the profile covers exactly this call's
/// execution on the calling thread.
pub fn run_with_profile(
    module: &Module,
    opts: impl Into<CompileOptions>,
    args: Vec<Value>,
) -> Result<Execution, String> {
    let opts: CompileOptions = opts.into();
    if opts.is_uncached_interp() {
        let scope = crate::telemetry::ProfileScope::begin();
        let mut out = cache::interp_main(module, args)?;
        out.profile = Some(scope.finish());
        out.pass_trace = Some(Arc::new(PassTrace::empty(OptLevel::O0)));
        return Ok(out);
    }
    with_default_cache(|cache| {
        let resolved = cache.get_or_compile_full(module, opts)?;
        let scope = crate::telemetry::ProfileScope::begin();
        let mut out = run_compiled(&resolved.compiled, args)?;
        out.profile = Some(scope.finish());
        out.pass_trace = Some(resolved.trace);
        out.degraded_to = resolved.degraded_to;
        record_degraded(out.degraded_to);
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parse_module;
    use crate::tensor::Tensor;

    fn tensor_arg(v: f32) -> Vec<Value> {
        vec![Value::Tensor(Tensor::scalar_f32(v))]
    }

    #[test]
    fn launch_counter_is_shared_and_resettable() {
        let a = LaunchCounter::new();
        let b = a.clone();
        a.bump();
        b.bump();
        assert_eq!(a.get(), 2);
        a.reset();
        assert_eq!(b.get(), 0);
    }

    #[test]
    fn auto_picks_graphrt_for_first_order_programs() {
        let m = parse_module("def @main(%x: Tensor[(), float32]) { add(%x, 1f) }").unwrap();
        let out = run_auto(&m, tensor_arg(1.0)).unwrap();
        assert_eq!(out.executor, "graphrt");
        assert_eq!(out.value.tensor().f32_value(), 2.0);
        assert_eq!(out.launches, 1);
        // run_auto compiles at the default level; the trace says so.
        let trace = out.pass_trace.expect("execution carries its pass trace");
        assert_eq!(trace.level, DEFAULT_OPT_LEVEL);
        assert!(!trace.passes.is_empty());
    }

    #[test]
    fn auto_picks_vm_for_control_flow() {
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) {\n\
               if (greater(%x, 0f)) { %x } else { negative(%x) }\n\
             }",
        )
        .unwrap();
        let out = run_auto(&m, tensor_arg(-3.0)).unwrap();
        assert_eq!(out.executor, "vm");
        assert_eq!(out.value.tensor().f32_value(), 3.0);
    }

    #[test]
    fn all_three_tiers_agree_where_they_apply() {
        // At every optimization level, the three tiers run the *same*
        // optimized module, so results are bit-identical and launch
        // counts match across tiers (fused primitives count once on each).
        let m = parse_module(
            "def @main(%x: Tensor[(2, 2), float32]) { nn.relu(add(%x, 1f)) }",
        )
        .unwrap();
        let x = Tensor::from_f32(vec![2, 2], vec![-3.0, -1.0, 0.5, 2.0]);
        let args = vec![Value::Tensor(x)];
        for level in OptLevel::all() {
            let a = run_with(
                &m,
                CompileOptions::at(Executor::Interp, level),
                args.clone(),
            )
            .unwrap();
            let b = run_with(
                &m,
                CompileOptions::at(Executor::GraphRt, level),
                args.clone(),
            )
            .unwrap();
            let c =
                run_with(&m, CompileOptions::at(Executor::Vm, level), args.clone())
                    .unwrap();
            assert_eq!(a.value.tensor().as_f32(), b.value.tensor().as_f32());
            assert_eq!(a.value.tensor().as_f32(), c.value.tensor().as_f32());
            // Same launch count on every tier.
            assert_eq!(a.launches, b.launches, "{level}");
            assert_eq!(a.launches, c.launches, "{level}");
        }
        // And fusion actually reduced launches at O1+ vs O0.
        let o0 =
            run_with(&m, CompileOptions::at(Executor::Vm, OptLevel::O0), args.clone())
                .unwrap();
        let o1 =
            run_with(&m, CompileOptions::at(Executor::Vm, OptLevel::O1), args).unwrap();
        assert!(o1.launches < o0.launches, "{} !< {}", o1.launches, o0.launches);
    }

    #[test]
    fn run_auto_compiles_once_via_the_process_default_cache() {
        // The default cache is process-wide and other tests exercise it
        // concurrently, so global hit/miss deltas are not meaningful here;
        // per-key behavior is. Use a module source unique to this test.
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) {\n\
               if (greater(%x, 31337f)) { %x } else { negative(%x) }\n\
             }",
        )
        .unwrap();
        let out = run_auto(&m, tensor_arg(-4.0)).unwrap();
        assert_eq!(out.executor, "vm");
        assert_eq!(out.value.tensor().f32_value(), 4.0);
        // The module is now resident in the shared cache: a traced lookup
        // under the same (default) options must report it did not compile
        // again.
        let (_, compiled_now) = with_default_cache(|c| {
            c.get_or_compile_traced(&m, CompileOptions::default())
        })
        .unwrap();
        assert!(!compiled_now, "run_auto did not populate the process-wide cache");
        for _ in 0..3 {
            let again = run_auto(&m, tensor_arg(-4.0)).unwrap();
            assert_eq!(again.value.tensor().f32_value(), 4.0);
        }
    }

    #[test]
    fn resilient_run_degrades_instead_of_failing() {
        // Private cache with a hook that fails everything above -O0: the
        // strict path errors, the resilient path answers from the
        // interpreter floor with the degradation recorded on the
        // Execution.
        let m = parse_module(
            "def @main(%x: Tensor[(), float32]) { multiply(add(%x, 1f), 2f) }",
        )
        .unwrap();
        let cache = ProgramCache::new();
        cache.set_compile_hook(std::sync::Arc::new(|_m, _o| {
            Err("chaos: compile disabled".to_string())
        }));
        let opts = CompileOptions::at(Executor::Auto, OptLevel::O3);
        let strict = run_with_cache(&m, opts, tensor_arg(3.0), &cache);
        assert!(strict.is_err(), "strict path must surface the failure");
        let out =
            run_with_cache_resilient(&m, opts, tensor_arg(3.0), &cache, 2).unwrap();
        assert_eq!(out.degraded_to, Some(OptLevel::O0));
        assert_eq!(out.executor, "interp");
        assert_eq!(out.value.tensor().f32_value(), 8.0);
        let trace = out.pass_trace.expect("degraded execution carries a trace");
        assert_eq!(trace.degraded_from, Some(OptLevel::O3));
        // The degraded answer is bit-identical to the plain interpreter's.
        let reference = run_with_cache(
            &m,
            CompileOptions::at(Executor::Interp, OptLevel::O0),
            tensor_arg(3.0),
            &cache,
        )
        .unwrap();
        assert_eq!(
            out.value.tensor().f32_value().to_bits(),
            reference.value.tensor().f32_value().to_bits()
        );
        // With the hook cleared (and the failure forgotten) the resilient
        // path is exactly the strict path: no degradation.
        cache.clear_compile_hook();
        // The interp-floor entry is cached under the requested key; a
        // fresh module forces a real compile.
        let m2 = parse_module(
            "def @main(%x: Tensor[(), float32]) { multiply(add(%x, 2f), 2f) }",
        )
        .unwrap();
        let healthy =
            run_with_cache_resilient(&m2, opts, tensor_arg(3.0), &cache, 2).unwrap();
        assert_eq!(healthy.degraded_to, None);
        assert_eq!(healthy.value.tensor().f32_value(), 10.0);
    }

    #[test]
    fn shared_runtime_surface_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<LaunchCounter>();
        assert_send_sync::<Compiled>();
        assert_send_sync::<ProgramCache>();
        assert_send_sync::<crate::graphrt::GraphRt>();
        assert_send_sync::<crate::vm::Program>();
    }

    #[test]
    fn executor_parse_roundtrip() {
        for e in [Executor::Interp, Executor::GraphRt, Executor::Vm, Executor::Auto] {
            assert_eq!(Executor::parse(e.name()), Some(e));
        }
        assert_eq!(Executor::parse("tpu"), None);
    }

    #[test]
    fn compile_options_conversions() {
        let d = CompileOptions::default();
        assert_eq!(d.opt_level, DEFAULT_OPT_LEVEL);
        assert_eq!(d.executor, Executor::Auto);
        assert!(!d.typecheck);
        let from_exec: CompileOptions = Executor::Vm.into();
        assert_eq!(from_exec.executor, Executor::Vm);
        assert_eq!(from_exec.opt_level, DEFAULT_OPT_LEVEL);
        let pair: CompileOptions = (Executor::GraphRt, OptLevel::O1).into();
        assert_eq!(pair, CompileOptions::at(Executor::GraphRt, OptLevel::O1));
        assert!(CompileOptions::new(Executor::Auto).with_typecheck(true).typecheck);
        // Fixpoint defaults off and distinguishes options (it is part of
        // the cache key).
        assert!(!d.fixpoint);
        let fix = CompileOptions::new(Executor::Auto).with_fixpoint(true);
        assert!(fix.fixpoint);
        assert_ne!(fix, CompileOptions::new(Executor::Auto));
    }
}
