//! Execution backends over the IR: runtime values and the reference
//! interpreter (paper §3.1.3's "Relay interpreter").

pub mod interp;
pub mod value;

pub use interp::{eval_expr, eval_main, Interp};
pub use value::{env_bind, env_empty, Env, Value};
